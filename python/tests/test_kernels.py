"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels.ref).

This is the core correctness signal of the build: if these pass, the HLO
artifacts the Rust runtime executes contain numerically-correct kernels.
Hypothesis sweeps shapes and value ranges; fixed cases pin the exact shapes
the three paper architectures use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional dependency: skip (not error) collection where hypothesis is
# not installed — the fixed-shape cases below still need it via @given.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_mm, pool, ref

RNG = np.random.default_rng(1234)


def randf(*shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# Fused matmul + bias + activation
# ---------------------------------------------------------------------------

# (M, K, N) shapes the paper's three architectures actually produce
# (B=64 folded into M), plus awkward non-multiple-of-tile shapes.
ARCH_MATMUL_SHAPES = [
    (64 * 676, 16, 5),      # small C1: 26*26 patches, k=4*4, 5 maps
    (64 * 676, 16, 20),     # medium/large C1
    (64 * 81, 500, 40),     # medium C2: 9*9 patches, 20*5*5, 40 maps
    (64 * 121, 180, 60),    # large C2: 11*11, 20*3*3, 60 maps
    (64 * 36, 2160, 100),   # large C3: 6*6, 60*6*6, 100 maps
    (64, 845, 10),          # small output dense
    (64, 360, 150),         # medium F
    (64, 150, 10),          # output dense
]


@pytest.mark.parametrize("m,k,n", ARCH_MATMUL_SHAPES)
@pytest.mark.parametrize("act", ["none", "tanh", "sigmoid"])
def test_matmul_arch_shapes(m, k, n, act):
    a, b, bias = randf(m, k, scale=0.1), randf(k, n, scale=0.1), randf(n)
    got = conv_mm.matmul_bias_act(a, b, bias, act)
    want = ref.matmul_bias_act(a, b, bias, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 300), k=st.integers(1, 64), n=st.integers(1, 200),
       act=st.sampled_from(["none", "tanh", "sigmoid"]))
def test_matmul_hypothesis_shapes(m, k, n, act):
    a, b, bias = randf(m, k, scale=0.2), randf(k, n, scale=0.2), randf(n)
    got = conv_mm.matmul_bias_act(a, b, bias, act)
    assert got.shape == (m, n)
    want = ref.matmul_bias_act(a, b, bias, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(scale=st.floats(1e-3, 1e3), m=st.integers(1, 64), k=st.integers(1, 32),
       n=st.integers(1, 32))
def test_matmul_value_ranges(scale, m, k, n):
    """Numerics hold across magnitudes (saturating acts included)."""
    a, b, bias = randf(m, k, scale=scale), randf(k, n, scale=scale), randf(n)
    for act in ("none", "tanh"):
        got = conv_mm.matmul_bias_act(a, b, bias, act)
        want = ref.matmul_bias_act(a, b, bias, act)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_exact_tile_multiple():
    """M, N exactly at tile boundaries (no padding path)."""
    a, b, bias = randf(256, 32, scale=0.1), randf(32, 128, scale=0.1), randf(128)
    got = conv_mm.matmul_bias_act(a, b, bias, "none")
    np.testing.assert_allclose(got, ref.matmul_bias_act(a, b, bias, "none"),
                               rtol=1e-5, atol=1e-5)


def test_matmul_single_element():
    a, b, bias = randf(1, 1), randf(1, 1), randf(1)
    got = conv_mm.matmul_bias_act(a, b, bias, "none")
    np.testing.assert_allclose(got, a * b + bias, rtol=1e-6)


def test_matmul_unknown_act_raises():
    with pytest.raises(ValueError):
        ref.matmul_bias_act(randf(2, 2), randf(2, 2), randf(2), "relu6")


def test_matmul_zero_inputs():
    a = jnp.zeros((8, 8), jnp.float32)
    b = jnp.zeros((8, 8), jnp.float32)
    bias = jnp.zeros((8,), jnp.float32)
    assert float(jnp.abs(conv_mm.matmul_bias_act(a, b, bias, "none")).max()) == 0.0
    # sigmoid(0) = 0.5 exactly
    got = conv_mm.matmul_bias_act(a, b, bias, "sigmoid")
    np.testing.assert_allclose(got, jnp.full((8, 8), 0.5), rtol=1e-6)


# ---------------------------------------------------------------------------
# Backward path (custom VJP through the Pallas kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ["none", "tanh", "sigmoid"])
def test_matmul_vjp_matches_ref_grad(act):
    a, b, bias = randf(20, 12, scale=0.3), randf(12, 7, scale=0.3), randf(7)

    def f_pallas(a, b, bias):
        return conv_mm.matmul_bias_act(a, b, bias, act).sum()

    def f_ref(a, b, bias):
        return ref.matmul_bias_act(a, b, bias, act).sum()

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(a, b, bias)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(a, b, bias)
    for got, want in zip(gp, gr):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_vjp_nontrivial_cotangent():
    """VJP with a structured (non-ones) upstream gradient."""
    a, b, bias = randf(9, 5), randf(5, 6), randf(6)
    ct = randf(9, 6)

    def f(a, b, bias):
        return (conv_mm.matmul_bias_act(a, b, bias, "tanh") * ct).sum()

    def fr(a, b, bias):
        return (ref.matmul_bias_act(a, b, bias, "tanh") * ct).sum()

    gp = jax.grad(f, argnums=(0, 1, 2))(a, b, bias)
    gr = jax.grad(fr, argnums=(0, 1, 2))(a, b, bias)
    for got, want in zip(gp, gr):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 40), k=st.integers(1, 24), n=st.integers(1, 24))
def test_matmul_vjp_hypothesis(m, k, n):
    a, b, bias = randf(m, k, scale=0.2), randf(k, n, scale=0.2), randf(n)
    gp = jax.grad(lambda a: conv_mm.matmul_bias_act(a, b, bias, "tanh").sum())(a)
    gr = jax.grad(lambda a: ref.matmul_bias_act(a, b, bias, "tanh").sum())(a)
    np.testing.assert_allclose(gp, gr, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Max pooling
# ---------------------------------------------------------------------------

ARCH_POOL_SHAPES = [
    (5, 26, 2),     # small M
    (20, 26, 2),    # medium/large M1
    (40, 9, 3),     # medium M2
    (100, 6, 2),    # large M2
]


@pytest.mark.parametrize("c,h,k", ARCH_POOL_SHAPES)
def test_pool_arch_shapes(c, h, k):
    x = randf(c, h, h)
    got = pool.maxpool(x, k)
    want = ref.maxpool(x, k)
    assert got.shape == (c, h // k, h // k)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(c=st.integers(1, 32), hk=st.integers(1, 10), k=st.integers(1, 4))
def test_pool_hypothesis(c, hk, k):
    h = hk * k
    x = randf(c, h, h)
    np.testing.assert_allclose(pool.maxpool(x, k), ref.maxpool(x, k),
                               rtol=1e-6)


def test_pool_identity_window():
    x = randf(3, 5, 5)
    np.testing.assert_allclose(pool.maxpool(x, 1), x)


def test_pool_grad_matches_ref():
    x = randf(4, 6, 6)
    gp = jax.grad(lambda x: (pool.maxpool(x, 2) ** 2).sum())(x)
    gr = jax.grad(lambda x: (ref.maxpool(x, 2) ** 2).sum())(x)
    np.testing.assert_allclose(gp, gr, rtol=1e-5, atol=1e-6)


def test_pool_grad_ties_split_equally():
    """All-equal window: gradient splits equally among the k*k inputs."""
    x = jnp.ones((1, 2, 2), jnp.float32)
    g = jax.grad(lambda x: pool.maxpool(x, 2).sum())(x)
    np.testing.assert_allclose(g, jnp.full((1, 2, 2), 0.25), rtol=1e-6)


def test_pool_selects_max_not_first():
    x = jnp.array([[[1.0, 9.0], [3.0, -2.0]]], jnp.float32)
    got = pool.maxpool(x, 2)
    np.testing.assert_allclose(got, jnp.array([[[9.0]]]))


# ---------------------------------------------------------------------------
# im2col + full conv against the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cin,h,k,cout", [
    (1, 29, 4, 5),    # small / medium / large C1
    (20, 13, 5, 40),  # medium C2
    (20, 13, 3, 60),  # large C2
    (60, 11, 6, 100)  # large C3
])
def test_conv_matches_oracle(cin, h, k, cout):
    from compile import model
    x = randf(cin, h, h, scale=0.5)
    w = randf(cout, cin, k, k, scale=0.2)
    b = randf(cout)
    # Batch path (model.im2col_batch folds batch into M) vs per-image oracle.
    patches = model.im2col_batch(x[None], k)[0]
    wmat = w.reshape(cout, cin * k * k).T
    got = conv_mm.matmul_bias_act(patches, wmat, b, "tanh")
    got = got.T.reshape(cout, h - k + 1, h - k + 1)
    want = ref.conv2d(x, w, b, "tanh")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_im2col_batch_matches_ref():
    from compile import model
    x = randf(7, 9, 9)
    got = model.im2col_batch(x[None], 3)[0]
    want = ref.im2col(x, 3)
    np.testing.assert_allclose(got, want)


# ---------------------------------------------------------------------------
# VMEM footprint estimator (perf-analysis helper)
# ---------------------------------------------------------------------------

def test_vmem_footprint_within_budget():
    """Every arch matmul fits one grid step comfortably in 16 MiB VMEM."""
    for m, k, n in ARCH_MATMUL_SHAPES:
        fp = conv_mm.vmem_footprint_bytes(m, k, n)
        assert fp["total"] < 4 * 1024 * 1024, (m, k, n, fp)


def test_vmem_footprint_fields_consistent():
    fp = conv_mm.vmem_footprint_bytes(256, 64, 128)
    assert fp["total"] == (fp["a_tile"] + fp["b_tile"] + fp["o_tile"]
                           + fp["bias_tile"])
    assert fp["mxu_n_occupancy"] == 1.0
