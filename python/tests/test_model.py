"""L2 correctness: architecture fidelity to Fig. 2, shapes, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# Fig. 2 caption fidelity — every quantity the paper states, verified.
# ---------------------------------------------------------------------------

def _layer(arch, idx):
    return model.layer_shapes(arch)[idx]


def test_input_layer_has_841_neurons_29x29():
    for arch in model.ARCHS:
        rec = _layer(arch, 0)
        assert rec["neurons"] == 841 and rec["hw"] == 29


def test_small_first_conv_matches_fig2a():
    rec = _layer("small", 1)
    assert rec["maps"] == 5
    assert rec["neurons"] == 3380
    assert rec["kernel"] == 4
    assert rec["hw"] == 26
    assert rec["weights"] == 85


def test_medium_first_conv_matches_fig2b():
    rec = _layer("medium", 1)
    assert rec["maps"] == 20
    assert rec["neurons"] == 13520
    assert rec["kernel"] == 4
    assert rec["hw"] == 26
    assert rec["weights"] == 340


def test_large_last_conv_matches_fig2c():
    recs = [r for r in model.layer_shapes("large") if r["type"] == "conv"]
    last = recs[-1]
    assert last["maps"] == 100
    assert last["neurons"] == 3600
    assert last["kernel"] == 6
    assert last["hw"] == 6
    assert last["weights"] == 216100


def test_output_layer_has_10_neurons():
    for arch in model.ARCHS:
        assert model.layer_shapes(arch)[-1]["neurons"] == 10


def test_arch_sizes_are_ordered():
    """small < medium < large in total weights (the paper's premise)."""
    totals = {a: sum(r["weights"] for r in model.layer_shapes(a))
              for a in model.ARCHS}
    assert totals["small"] < totals["medium"] < totals["large"]


# ---------------------------------------------------------------------------
# Shape inference and parameter layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(model.ARCHS))
def test_param_shapes_consistent_with_layer_walk(arch):
    shapes = model.param_shapes(arch)
    convs = [r for r in model.layer_shapes(arch) if r["type"] == "conv"]
    denses = [r for r in model.layer_shapes(arch) if r["type"] == "dense"]
    assert len(shapes) == len(convs) + len(denses)
    for (w_shape, b_shape), rec in zip(shapes[:len(convs)], convs):
        assert w_shape[0] == rec["maps"]
        assert b_shape == (rec["maps"],)
        n_weights = int(np.prod(w_shape)) + b_shape[0]
        assert n_weights == rec["weights"]


@pytest.mark.parametrize("arch", list(model.ARCHS))
def test_init_params_shapes_and_scale(arch):
    params = model.init_params(arch, KEY)
    shapes = model.param_shapes(arch)
    assert len(params) == 2 * len(shapes)
    for i, (w_shape, b_shape) in enumerate(shapes):
        assert params[2 * i].shape == w_shape
        assert params[2 * i + 1].shape == b_shape
        assert float(jnp.abs(params[2 * i]).max()) <= 1.0
        assert float(jnp.abs(params[2 * i + 1]).max()) == 0.0


@pytest.mark.parametrize("arch", list(model.ARCHS))
def test_forward_output_shape(arch):
    params = model.init_params(arch, KEY)
    x = jax.random.normal(KEY, (4, 1, 29, 29), jnp.float32)
    logits = model.forward(params, x, arch)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


# ---------------------------------------------------------------------------
# Training signal
# ---------------------------------------------------------------------------

def test_initial_loss_near_log10():
    """Untrained softmax CE over 10 classes ~= ln(10)."""
    params = model.init_params("small", KEY)
    x = jax.random.normal(KEY, (16, 1, 29, 29), jnp.float32) * 0.1
    y = jnp.arange(16, dtype=jnp.int32) % 10
    loss = model.loss_fn(params, x, y, "small")
    assert abs(float(loss) - np.log(10)) < 0.5


@pytest.mark.parametrize("arch", ["small", "medium"])
def test_train_step_reduces_loss_on_fixed_batch(arch):
    params = model.init_params(arch, KEY)
    x = jax.random.normal(KEY, (16, 1, 29, 29), jnp.float32) * 0.5
    y = jnp.arange(16, dtype=jnp.int32) % 10
    losses = []
    for _ in range(5):
        out = model.train_step(params, x, y, arch, lr=0.1)
        params, loss = list(out[:-1]), float(out[-1])
        losses.append(loss)
    assert losses[-1] < losses[0], losses


def test_train_step_grads_touch_every_param():
    """No dead parameters: every w/b changes after one step."""
    params = model.init_params("medium", KEY)
    x = jax.random.normal(KEY, (8, 1, 29, 29), jnp.float32)
    y = jnp.arange(8, dtype=jnp.int32) % 10
    out = model.train_step(params, x, y, "medium", lr=0.5)
    for before, after in zip(params, out[:-1]):
        assert float(jnp.abs(before - after).max()) > 0.0


def test_loss_finite_for_large_inputs():
    params = model.init_params("small", KEY)
    x = jnp.full((4, 1, 29, 29), 50.0, jnp.float32)
    y = jnp.zeros((4,), jnp.int32)
    loss = model.loss_fn(params, x, y, "small")
    assert bool(jnp.isfinite(loss))


def test_predict_equals_forward():
    params = model.init_params("small", KEY)
    x = jax.random.normal(KEY, (3, 1, 29, 29), jnp.float32)
    np.testing.assert_allclose(model.predict(params, x, "small"),
                               model.forward(params, x, "small"))


def test_train_step_batch_one():
    """Per-image SGD (the paper's scheme) is the B=1 special case."""
    params = model.init_params("small", KEY)
    x = jax.random.normal(KEY, (1, 1, 29, 29), jnp.float32)
    y = jnp.zeros((1,), jnp.int32)
    out = model.train_step(params, x, y, "small", lr=0.05)
    assert bool(jnp.isfinite(out[-1]))
