"""AOT path: HLO text emission is well-formed and numerically faithful.

Executes the emitted HLO back through the local XLA client and compares
against direct jax execution — the same round-trip the Rust runtime does.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

KEY = jax.random.PRNGKey(3)


def test_hlo_text_emitted_for_small():
    txt = aot.lower_train("small", batch=4, lr=0.05)
    assert "HloModule" in txt
    assert "ENTRY" in txt
    # The conv contraction must appear as a dot op (the MXU path).
    assert " dot(" in txt or " dot." in txt or "dot(" in txt


def test_infer_hlo_smaller_than_train():
    """No backward pass in the inference artifact."""
    train = aot.lower_train("small", batch=4, lr=0.05)
    infer = aot.lower_infer("small", batch=4)
    assert len(infer) < len(train)


def test_meta_layout_counts():
    meta = aot.build_meta(["small", "medium", "large"], batch=8, lr=0.01)
    for arch, rec in meta["archs"].items():
        n = len(rec["params"])
        assert rec["train_inputs"] == 2 * n + 2
        assert rec["train_outputs"] == 2 * n + 1
        shapes = model.param_shapes(arch)
        assert n == len(shapes)
        for p, (w, b) in zip(rec["params"], shapes):
            assert tuple(p["w"]) == w and tuple(p["b"]) == b


def test_main_writes_artifacts(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--archs", "small",
                "--batch", "2"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    assert (tmp_path / "train_small_b2.hlo.txt").exists()
    assert (tmp_path / "infer_small_b2.hlo.txt").exists()
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta["batch"] == 2
    assert "small" in meta["archs"]


@pytest.mark.parametrize("arch", ["small", "medium"])
def test_train_hlo_roundtrip_matches_jax(arch):
    """HLO-text -> parse -> compile -> execute == direct jax call.

    This mirrors the Rust runtime's path (the HLO text parse reassigns the
    64-bit instruction ids that xla_extension 0.5.1 rejects in protos).
    ``aot.compile_hlo_text`` picks the conversion API for the installed
    jaxlib (0.4.x and >= 0.5 moved it).
    """
    batch, lr = 2, 0.05
    txt = aot.lower_train(arch, batch=batch, lr=lr)

    params = model.init_params(arch, KEY)
    x = jax.random.normal(KEY, (batch, 1, 29, 29), jnp.float32)
    y = jnp.arange(batch, dtype=jnp.int32) % 10
    # Inputs are donated in the artifact; evaluate the reference first and
    # hand the executable its own copies.
    want = [np.asarray(o) for o in model.train_step(params, x, y, arch, lr=lr)]

    exe = aot.compile_hlo_text(txt)
    inputs = [jax.device_put(np.asarray(p).copy()) for p in params]
    inputs += [jax.device_put(x), jax.device_put(y)]
    res = exe.execute_sharded(inputs)
    got = [np.asarray(a[0])
           for a in res.disassemble_into_single_device_arrays()]

    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("arch", ["small"])
def test_infer_hlo_roundtrip_matches_jax(arch):
    """The inference artifact round-trips numerically too."""
    batch = 2
    txt = aot.lower_infer(arch, batch=batch)
    params = model.init_params(arch, KEY)
    x = jax.random.normal(KEY, (batch, 1, 29, 29), jnp.float32)
    want = np.asarray(model.predict(params, x, arch))

    exe = aot.compile_hlo_text(txt)
    inputs = [jax.device_put(np.asarray(p).copy()) for p in params]
    inputs += [jax.device_put(x)]
    res = exe.execute_sharded(inputs)
    got = [np.asarray(a[0])
           for a in res.disassemble_into_single_device_arrays()]
    assert len(got) == 1
    np.testing.assert_allclose(got[0], want, rtol=1e-4, atol=1e-5)
