"""AOT: lower the L2 train/predict functions to HLO text artifacts.

Emits, for each architecture:

    artifacts/train_<arch>_b<B>.hlo.txt    train_step: (params..., x, y) ->
                                           (params'..., loss)
    artifacts/infer_<arch>_b<B>.hlo.txt    predict:    (params..., x) -> logits
    artifacts/meta.json                    shapes / input-output layout for
                                           the Rust runtime

HLO *text* (not ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Python runs ONCE here (``make artifacts``); the Rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

DEFAULT_BATCH = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def compile_hlo_text(txt: str, client=None):
    """HLO text -> loaded executable on the local CPU client.

    The round-trip the Rust runtime does: parse the HLO text (which
    reassigns instruction ids), convert to StableHLO/MHLO, compile. The
    conversion API moved across jaxlib versions, so both paths are
    supported:

    * jaxlib >= 0.5: ``mlir.hlo_to_stablehlo`` + ``compile_and_load``
    * jaxlib 0.4.x:  ``XlaComputation`` -> ``xla_computation_to_mlir_module``
      + ``client.compile``

    Returns a LoadedExecutable whose ``execute_sharded`` takes the
    flattened input buffers in artifact order.
    """
    if client is None:
        client = jax.devices("cpu")[0].client
    hlo_mod = xc._xla.hlo_module_from_text(txt)
    proto = hlo_mod.as_serialized_hlo_module_proto()
    mlir_api = xc._xla.mlir
    if hasattr(mlir_api, "hlo_to_stablehlo"):  # jaxlib >= 0.5
        import jaxlib._jax as _jax
        mlir = mlir_api.hlo_to_stablehlo(proto)
        return client.compile_and_load(
            mlir, _jax.DeviceList(tuple(client.devices()[:1])))
    # jaxlib 0.4.x: through XlaComputation -> MHLO module text.
    comp = xc.XlaComputation(proto)
    mlir = mlir_api.xla_computation_to_mlir_module(comp)
    return client.compile(mlir)


def lower_train(arch: str, batch: int, lr: float) -> str:
    n_params = 2 * len(model.param_shapes(arch))

    def step(*args):
        params = args[:n_params]
        x, y = args[n_params], args[n_params + 1]
        return model.train_step(params, x, y, arch, lr=lr)

    specs = _param_specs(arch) + [
        jax.ShapeDtypeStruct((batch, 1, model.INPUT_HW, model.INPUT_HW),
                             jax.numpy.float32),
        jax.ShapeDtypeStruct((batch,), jax.numpy.int32),
    ]
    # Donate the parameter buffers: the step is params -> params', so XLA
    # may update weights in place on the Rust side (perf pass, L2).
    donate = tuple(range(n_params))
    lowered = jax.jit(step, donate_argnums=donate).lower(*specs)
    return to_hlo_text(lowered)


def lower_infer(arch: str, batch: int) -> str:
    n_params = 2 * len(model.param_shapes(arch))

    def infer(*args):
        params = args[:n_params]
        x = args[n_params]
        return (model.predict(params, x, arch),)

    specs = _param_specs(arch) + [
        jax.ShapeDtypeStruct((batch, 1, model.INPUT_HW, model.INPUT_HW),
                             jax.numpy.float32),
    ]
    lowered = jax.jit(infer).lower(*specs)
    return to_hlo_text(lowered)


def _param_specs(arch: str):
    import jax.numpy as jnp
    specs = []
    for w_shape, b_shape in model.param_shapes(arch):
        specs.append(jax.ShapeDtypeStruct(w_shape, jnp.float32))
        specs.append(jax.ShapeDtypeStruct(b_shape, jnp.float32))
    return specs


def build_meta(archs, batch: int, lr: float) -> dict:
    meta = {"batch": batch, "lr": lr, "input_hw": model.INPUT_HW,
            "num_classes": model.NUM_CLASSES, "archs": {}}
    for arch in archs:
        params = []
        for w_shape, b_shape in model.param_shapes(arch):
            params.append({"w": list(w_shape), "b": list(b_shape)})
        meta["archs"][arch] = {
            "params": params,
            "layers": model.layer_shapes(arch),
            "train_hlo": f"train_{arch}_b{batch}.hlo.txt",
            "infer_hlo": f"infer_{arch}_b{batch}.hlo.txt",
            # Input order: w0,b0,...,wn,bn,x[,y]; output: w0',b0',...,loss.
            "train_inputs": 2 * len(params) + 2,
            "train_outputs": 2 * len(params) + 1,
        }
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--archs", default="small,medium,large")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    archs = [a for a in args.archs.split(",") if a]
    os.makedirs(args.out_dir, exist_ok=True)
    meta = build_meta(archs, args.batch, args.lr)

    for arch in archs:
        train_txt = lower_train(arch, args.batch, args.lr)
        train_path = os.path.join(args.out_dir, meta["archs"][arch]["train_hlo"])
        with open(train_path, "w") as f:
            f.write(train_txt)
        print(f"wrote {train_path} ({len(train_txt)} chars)")

        infer_txt = lower_infer(arch, args.batch)
        infer_path = os.path.join(args.out_dir, meta["archs"][arch]["infer_hlo"])
        with open(infer_path, "w") as f:
            f.write(infer_txt)
        print(f"wrote {infer_path} ({len(infer_txt)} chars)")

    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
