"""Pure-jnp reference oracle for the Pallas kernels.

Every Pallas kernel in this package has an exact jnp counterpart here.
pytest (and hypothesis sweeps) assert allclose between kernel and oracle —
this is the core L1 correctness signal of the build.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_bias_act(a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray,
                    act: str = "none") -> jnp.ndarray:
    """Reference for kernels.conv_mm.matmul_bias_act.

    a: (M, K), b: (K, N), bias: (N,). Returns (M, N).
    act: "none" | "tanh" | "sigmoid".
    """
    out = jnp.dot(a, b, preferred_element_type=jnp.float32) + bias[None, :]
    if act == "tanh":
        out = jnp.tanh(out)
    elif act == "sigmoid":
        out = 1.0 / (1.0 + jnp.exp(-out))
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return out


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Reference plain matmul (used by the custom-vjp backward path)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def maxpool(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Reference for kernels.pool.maxpool.

    x: (C, H, W) with H % k == 0 and W % k == 0. Non-overlapping max pooling
    with stride k (the paper's pooling scheme — LeNet-style sub-sampling).
    """
    c, h, w = x.shape
    x = x.reshape(c, h // k, k, w // k, k)
    return x.max(axis=(2, 4))


def im2col(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Reference patch extraction for valid convolution.

    x: (Cin, H, W) -> (Ho*Wo, Cin*k*k) with Ho = H-k+1, Wo = W-k+1.
    Column order matches compile.model.im2col: cin-major, (dy, dx)-minor.
    """
    cin, h, w = x.shape
    ho, wo = h - k + 1, w - k + 1
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(x[:, dy:dy + ho, dx:dx + wo])
    # list of (Cin, Ho, Wo) -> (Cin, k*k, Ho, Wo) -> (Ho*Wo, Cin*k*k)
    patches = jnp.stack(cols, axis=1)
    patches = patches.transpose(2, 3, 0, 1)
    return patches.reshape(ho * wo, cin * k * k)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
           act: str = "tanh") -> jnp.ndarray:
    """Reference valid conv: x (Cin,H,W), w (Cout,Cin,k,k), b (Cout,)."""
    cout, cin, k, _ = w.shape
    _, h, wdim = x.shape
    ho, wo = h - k + 1, wdim - k + 1
    patches = im2col(x, k)                       # (Ho*Wo, Cin*k*k)
    wmat = w.reshape(cout, cin * k * k).T        # (Cin*k*k, Cout)
    out = matmul_bias_act(patches, wmat, b, act)  # (Ho*Wo, Cout)
    return out.T.reshape(cout, ho, wo)
