"""L1 Pallas kernel: tiled matmul + bias + activation (the conv hot-spot).

The paper's compute hot-spot is convolution (Table VII: ~80-96% of forward
operations). On KNC the authors vectorize the per-neuron dot products with
512-bit SIMD; the TPU re-think (DESIGN.md §Hardware-Adaptation) expresses the
same contraction as an im2col patch matrix multiplied by the reshaped kernel
bank, so the MXU systolic array does the work:

    patches (M=B*Ho*Wo, K=Cin*k*k) @ wmat (K, N=Cout) + bias -> act

The kernel tiles M and N onto a 2-D grid; each grid step stages an
(bm, K) patch tile and a (K, bn) weight tile through VMEM (BlockSpec) and
writes one (bm, bn) output tile. K for the paper's architectures is at most
2,160 (large CNN, C3: 60 maps * 6*6), so a full-K block fits comfortably in
VMEM (see EXPERIMENTS.md §Perf for the footprint table).

The backward pass is a custom VJP whose two gradient contractions
(dA = dZ @ B^T, dB = A^T @ dZ) run through the *same* Pallas kernel, so the
lowered training-step HLO exercises Pallas on both the forward and backward
paths.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated against kernels.ref by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles. 128x128 matches the systolic array; K is kept
# whole per block (small for these architectures, see module docstring).
BLOCK_M = 128
BLOCK_N = 128


def _matmul_kernel(a_ref, b_ref, bias_ref, o_ref, *, act: str):
    """One (bm, bn) output tile: full-K contraction + bias + activation."""
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    acc = acc + bias_ref[...][None, :]
    if act == "tanh":
        acc = jnp.tanh(acc)
    elif act == "sigmoid":
        acc = 1.0 / (1.0 + jnp.exp(-acc))
    o_ref[...] = acc


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def matmul_bias_act_fwd(a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray,
                        act: str, block_m: int = BLOCK_M,
                        block_n: int = BLOCK_N) -> jnp.ndarray:
    """Raw (non-differentiable) Pallas call: (M,K) @ (K,N) + bias, act."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert bias.shape == (n,)

    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    a_p = _pad_to(a, 0, bm)
    b_p = _pad_to(b, 1, bn)
    bias_p = _pad_to(bias, 0, bn)
    mp, np_ = a_p.shape[0], b_p.shape[1]

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, act=act),
        grid=grid,
        in_specs=[
            # (bm, K) patch tile: new M-tile per i, K resident.
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            # (K, bn) weight tile: resident across i (weight reuse).
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p, bias_p)
    return out[:m, :n]


def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain Pallas matmul (zero bias, no activation)."""
    return matmul_bias_act_fwd(a, b, jnp.zeros((b.shape[1],), jnp.float32),
                               act="none")


@functools.lru_cache(maxsize=None)
def make_matmul_bias_act(act: str):
    """Build the differentiable fused matmul for a given activation.

    Cached per activation string so repeated tracing reuses one custom_vjp
    instance (keeps the lowered HLO small).
    """

    @jax.custom_vjp
    def fused(a, b, bias):
        return matmul_bias_act_fwd(a, b, bias, act)

    def fwd(a, b, bias):
        y = matmul_bias_act_fwd(a, b, bias, act)
        return y, (a, b, y)

    def bwd(res, g):
        a, b, y = res
        if act == "tanh":
            dz = g * (1.0 - y * y)
        elif act == "sigmoid":
            dz = g * y * (1.0 - y)
        else:
            dz = g
        # Both gradient contractions go through the Pallas kernel as well.
        da = matmul_pallas(dz, b.T)
        db = matmul_pallas(a.T, dz)
        dbias = dz.sum(axis=0)
        return da, db, dbias

    fused.defvjp(fwd, bwd)
    return fused


def matmul_bias_act(a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray,
                    act: str = "none") -> jnp.ndarray:
    """Differentiable fused matmul+bias+activation on the Pallas kernel."""
    return make_matmul_bias_act(act)(a, b, bias)


def vmem_footprint_bytes(m: int, k: int, n: int,
                         block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                         dtype_bytes: int = 4) -> dict:
    """Static VMEM footprint estimate for one grid step (perf analysis).

    Used by EXPERIMENTS.md §Perf: interpret-mode wallclock is not a TPU
    proxy, so kernel quality is assessed from the BlockSpec-implied VMEM
    residency and MXU tile occupancy instead.
    """
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    a_tile = bm * k * dtype_bytes
    b_tile = k * bn * dtype_bytes
    o_tile = bm * bn * dtype_bytes
    bias_tile = bn * dtype_bytes
    total = a_tile + b_tile + o_tile + bias_tile
    return {
        "a_tile": a_tile,
        "b_tile": b_tile,
        "o_tile": o_tile,
        "bias_tile": bias_tile,
        "total": total,
        "mxu_m_occupancy": min(1.0, m / 128.0),
        "mxu_n_occupancy": min(1.0, n / 128.0),
    }
