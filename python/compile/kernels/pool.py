"""L1 Pallas kernel: non-overlapping max pooling (LeNet-style sub-sampling).

Forward runs as a Pallas kernel (grid over feature maps, each map staged
through VMEM whole — map sizes in the paper's architectures are at most
26x26, trivially VMEM-resident). Backward is a custom VJP in plain jnp: the
pooling backward is a scatter of the incoming gradient to the argmax
positions, which has no MXU work and is a negligible share of operations
(Table VIII: max-pool is <0.5% of backward ops), so it does not warrant a
kernel. Gradient ties split equally, matching jax.grad of the jnp oracle.

interpret=True for CPU-PJRT executability; validated against kernels.ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref, *, k: int):
    """One feature map: (1, H, W) -> (1, H/k, W/k) max reduction."""
    x = x_ref[...]
    _, h, w = x.shape
    x = x.reshape(1, h // k, k, w // k, k)
    o_ref[...] = x.max(axis=(2, 4))


def maxpool_fwd(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Raw Pallas pooling: x (C, H, W) -> (C, H/k, W/k)."""
    c, h, w = x.shape
    assert h % k == 0 and w % k == 0, (x.shape, k)
    ho, wo = h // k, w // k
    return pl.pallas_call(
        functools.partial(_pool_kernel, k=k),
        grid=(c,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, ho, wo), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, ho, wo), jnp.float32),
        interpret=True,
    )(x)


@functools.lru_cache(maxsize=None)
def make_maxpool(k: int):
    """Differentiable pooling for a fixed (static) window k."""

    @jax.custom_vjp
    def pool(x):
        return maxpool_fwd(x, k)

    def fwd(x):
        y = maxpool_fwd(x, k)
        return y, (x, y)

    def bwd(res, g):
        x, y = res
        c, h, w = x.shape
        y_b = jnp.repeat(jnp.repeat(y, k, axis=1), k, axis=2)
        g_b = jnp.repeat(jnp.repeat(g, k, axis=1), k, axis=2)
        mask = (x == y_b).astype(x.dtype)
        # Equal split among ties (matches jax.grad of the jnp reference).
        counts = mask.reshape(c, h // k, k, w // k, k).sum(axis=(2, 4))
        counts_b = jnp.repeat(jnp.repeat(counts, k, axis=1), k, axis=2)
        return (mask * g_b / counts_b,)

    pool.defvjp(fwd, bwd)
    return pool


def maxpool(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Differentiable max pooling on the Pallas forward kernel."""
    return make_maxpool(k)(x)
