"""L2: JAX forward/backward of the paper's three CNN architectures.

The architectures are reconstructed from Fig. 2 of the paper (every quoted
caption quantity is satisfied exactly — see tests/test_model.py):

  small : I(29x29) -> C(5 maps, 4x4) -> M(2x2) -> O(10)
          first conv layer: 5 maps, 26x26 map, 3,380 neurons, 85 weights.
  medium: I(29x29) -> C(20, 4x4) -> M(2) -> C(40, 5x5) -> M(3) -> F(150) -> O(10)
          first conv layer: 20 maps, 26x26 map, 13,520 neurons, 340 weights.
  large : I(29x29) -> C(20, 4x4) -> M(2) -> C(60, 3x3) -> C(100, 6x6)
          -> M(2) -> F(150) -> O(10)
          last conv layer: 100 maps, 6x6 map, 3,600 neurons, 216,100 weights.

Hidden activations are tanh (the Cireşan code's default), output is softmax
cross-entropy. Convolutions run as im2col + the Pallas fused matmul kernel
(kernels.conv_mm) so the MXU contraction dominates the lowered HLO; pooling
runs the Pallas pooling kernel (kernels.pool). The batch dimension is folded
into the matmul M dimension (no vmap over pallas_call), which is also the
TPU-friendly layout: bigger M tiles, weight tile resident across the grid.

This module is build-time only: `aot.py` lowers `train_step` / `predict`
to HLO text once; the Rust runtime executes the artifacts. Python is never
on the request path.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import conv_mm, pool

INPUT_HW = 29
NUM_CLASSES = 10


@dataclasses.dataclass(frozen=True)
class Conv:
    maps: int
    kernel: int
    act: str = "tanh"


@dataclasses.dataclass(frozen=True)
class Pool:
    window: int


@dataclasses.dataclass(frozen=True)
class Dense:
    units: int
    act: str = "tanh"


ARCHS: dict = {
    "small": (Conv(5, 4), Pool(2), Dense(NUM_CLASSES, act="none")),
    "medium": (Conv(20, 4), Pool(2), Conv(40, 5), Pool(3),
               Dense(150), Dense(NUM_CLASSES, act="none")),
    "large": (Conv(20, 4), Pool(2), Conv(60, 3), Conv(100, 6), Pool(2),
              Dense(150), Dense(NUM_CLASSES, act="none")),
}


def layer_shapes(arch: str) -> List[dict]:
    """Static shape walk; returns one record per layer (tests + meta.json)."""
    out = [{"type": "input", "maps": 1, "hw": INPUT_HW,
            "neurons": INPUT_HW * INPUT_HW, "weights": 0}]
    maps, hw = 1, INPUT_HW
    flat = None
    for layer in ARCHS[arch]:
        if isinstance(layer, Conv):
            hw = hw - layer.kernel + 1
            rec = {"type": "conv", "maps": layer.maps, "hw": hw,
                   "kernel": layer.kernel,
                   "neurons": layer.maps * hw * hw,
                   "weights": layer.maps * (maps * layer.kernel ** 2 + 1)}
            maps = layer.maps
        elif isinstance(layer, Pool):
            hw = hw // layer.window
            rec = {"type": "pool", "maps": maps, "hw": hw,
                   "window": layer.window,
                   "neurons": maps * hw * hw, "weights": 0}
        elif isinstance(layer, Dense):
            fan_in = flat if flat is not None else maps * hw * hw
            rec = {"type": "dense", "units": layer.units,
                   "neurons": layer.units,
                   "weights": fan_in * layer.units + layer.units}
            flat = layer.units
        else:
            raise TypeError(layer)
        out.append(rec)
    return out


def param_shapes(arch: str) -> List[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    """[(w_shape, b_shape)] per trainable layer, in forward order."""
    shapes = []
    maps, hw = 1, INPUT_HW
    flat = None
    for layer in ARCHS[arch]:
        if isinstance(layer, Conv):
            shapes.append(((layer.maps, maps, layer.kernel, layer.kernel),
                           (layer.maps,)))
            hw = hw - layer.kernel + 1
            maps = layer.maps
        elif isinstance(layer, Pool):
            hw = hw // layer.window
        elif isinstance(layer, Dense):
            fan_in = flat if flat is not None else maps * hw * hw
            shapes.append(((fan_in, layer.units), (layer.units,)))
            flat = layer.units
    return shapes


def init_params(arch: str, key: jax.Array) -> List[jnp.ndarray]:
    """Uniform(-r, r) with r = 1/sqrt(fan_in), flattened [w0,b0,w1,b1,...].

    The Rust side mirrors this scheme (nn::init) from meta.json shapes; the
    two inits need not be bit-identical, only statistically equivalent.
    """
    flat: List[jnp.ndarray] = []
    for w_shape, b_shape in param_shapes(arch):
        key, kw = jax.random.split(key)
        if len(w_shape) == 4:
            fan_in = w_shape[1] * w_shape[2] * w_shape[3]
        else:
            fan_in = w_shape[0]
        r = 1.0 / jnp.sqrt(float(fan_in))
        flat.append(jax.random.uniform(kw, w_shape, jnp.float32, -r, r))
        flat.append(jnp.zeros(b_shape, jnp.float32))
    return flat


def im2col_batch(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """x (B, Cin, H, W) -> (B, Ho*Wo, Cin*k*k); order matches ref.im2col."""
    b, cin, h, w = x.shape
    ho, wo = h - k + 1, w - k + 1
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(x[:, :, dy:dy + ho, dx:dx + wo])
    patches = jnp.stack(cols, axis=2)            # (B, Cin, k*k, Ho, Wo)
    patches = patches.transpose(0, 3, 4, 1, 2)   # (B, Ho, Wo, Cin, k*k)
    return patches.reshape(b, ho * wo, cin * k * k)


def forward(params: Sequence[jnp.ndarray], x: jnp.ndarray,
            arch: str) -> jnp.ndarray:
    """x (B, 1, 29, 29) float32 -> logits (B, 10)."""
    bsz = x.shape[0]
    hw = INPUT_HW
    idx = 0
    flat = None
    h = x
    for layer in ARCHS[arch]:
        if isinstance(layer, Conv):
            w, b = params[idx], params[idx + 1]
            idx += 2
            k = layer.kernel
            ho = hw - k + 1
            patches = im2col_batch(h, k)                     # (B, Ho*Wo, K)
            kdim = patches.shape[-1]
            a = patches.reshape(bsz * ho * ho, kdim)
            wmat = w.reshape(layer.maps, kdim).T             # (K, Cout)
            out = conv_mm.matmul_bias_act(a, wmat, b, layer.act)
            h = out.reshape(bsz, ho, ho, layer.maps).transpose(0, 3, 1, 2)
            hw = ho
        elif isinstance(layer, Pool):
            c = h.shape[1]
            h = pool.maxpool(h.reshape(bsz * c, hw, hw), layer.window)
            hw = hw // layer.window
            h = h.reshape(bsz, c, hw, hw)
        elif isinstance(layer, Dense):
            w, b = params[idx], params[idx + 1]
            idx += 2
            a = h.reshape(bsz, -1) if flat is None else h
            h = conv_mm.matmul_bias_act(a, w, b, layer.act)
            flat = layer.units
    return h


def loss_fn(params: Sequence[jnp.ndarray], x: jnp.ndarray,
            y: jnp.ndarray, arch: str) -> jnp.ndarray:
    """Mean softmax cross-entropy; y is int32 class labels (B,)."""
    logits = forward(params, x, arch)
    logz = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, NUM_CLASSES, dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * logz, axis=-1))


def train_step(params: Sequence[jnp.ndarray], x: jnp.ndarray, y: jnp.ndarray,
               arch: str, lr: float = 0.05):
    """One SGD step. Returns (new_params..., loss) as a flat tuple."""
    loss, grads = jax.value_and_grad(loss_fn)(list(params), x, y, arch)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return new_params + (loss,)


def predict(params: Sequence[jnp.ndarray], x: jnp.ndarray,
            arch: str) -> jnp.ndarray:
    """Logits for inference (validation / test phases)."""
    return forward(params, x, arch)
