"""Make `pytest python/tests/` work from the repo root: the test modules
import `compile.*`, which lives in this directory."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
