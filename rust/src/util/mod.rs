//! Small self-contained utilities (no external dependencies).
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure available, so the conveniences a crates.io project
//! would pull in are implemented here: a JSON parser/emitter ([`json`],
//! for `artifacts/meta.json` and custom architecture files), a
//! micro-benchmark harness ([`bench`], the criterion stand-in driving
//! `cargo bench`), a sharded single-flight memo table ([`memo`], the
//! concurrency primitive under the sweep cache and calibration facade),
//! and temp-dir helpers for tests ([`tmp`]).

pub mod bench;
pub mod json;
pub mod memo;
pub mod tmp;
