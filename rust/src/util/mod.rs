//! Small self-contained utilities (no external dependencies).
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure available, so the conveniences a crates.io project
//! would pull in are implemented here: a JSON parser/emitter ([`json`],
//! for `artifacts/meta.json` and custom architecture files), a
//! micro-benchmark harness ([`bench`], the criterion stand-in driving
//! `cargo bench`), and temp-dir helpers for tests ([`tmp`]).

pub mod bench;
pub mod json;
pub mod tmp;
