//! Micro-benchmark harness — the criterion stand-in (offline build).
//!
//! `cargo bench` targets use `harness = false` and drive this runner:
//! warmup, timed iterations until a minimum wall budget, and robust stats
//! (median + MAD) so the §Perf pass has stable numbers to compare.
//!
//! Snapshot files (`BENCH_*.json`) are written only through
//! [`Bench::write_snapshot`], which requires [`Provenance`]: every
//! snapshot names who generated it and on which host, and the writer
//! refuses to emit anonymous numbers. CI identifies itself
//! automatically; locally, set `MICDL_BENCH_GENERATED_BY=$(whoami)`
//! (and optionally `MICDL_BENCH_HOST=$(hostname)`).

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Who produced a benchmark snapshot, recorded in the snapshot itself
/// (`generated_by` / `host` fields). Mandatory: a `BENCH_*.json`
/// without provenance cannot be told apart from hand-written numbers,
/// so [`Bench::write_snapshot`] refuses to write without one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance {
    /// Who ran the bench: `MICDL_BENCH_GENERATED_BY` when set, else
    /// `github-actions` on a CI runner (`GITHUB_ACTIONS=true`).
    pub generated_by: String,
    /// The machine it ran on: first non-empty of `MICDL_BENCH_HOST`,
    /// `RUNNER_NAME`, `HOSTNAME`; `unknown` otherwise.
    pub host: String,
}

impl Provenance {
    /// Detect provenance from the environment; `None` means the run is
    /// anonymous and no snapshot may be written.
    pub fn detect() -> Option<Provenance> {
        let generated_by = match std::env::var("MICDL_BENCH_GENERATED_BY") {
            Ok(v) if !v.trim().is_empty() => v.trim().to_string(),
            _ if std::env::var("GITHUB_ACTIONS").as_deref() == Ok("true") => {
                "github-actions".to_string()
            }
            _ => return None,
        };
        let host = ["MICDL_BENCH_HOST", "RUNNER_NAME", "HOSTNAME"]
            .iter()
            .find_map(|k| {
                std::env::var(k)
                    .ok()
                    .map(|v| v.trim().to_string())
                    .filter(|v| !v.is_empty())
            })
            .unwrap_or_else(|| "unknown".to_string());
        Some(Provenance { generated_by, host })
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Median absolute deviation (robust spread).
    pub mad: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} median  {:>12} mean  {:>12} min  (±{:?}, {} iters)",
            self.name,
            format!("{:?}", self.median),
            format!("{:?}", self.mean),
            format!("{:?}", self.min),
            self.mad,
            self.iters
        )
    }
}

/// Benchmark runner with a fixed time budget per case.
pub struct Bench {
    /// Minimum total measured time per case.
    pub budget: Duration,
    /// Warmup time per case.
    pub warmup: Duration,
    /// Hard cap on iterations (for very slow cases).
    pub max_iters: usize,
    pub results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(800),
            warmup: Duration::from_millis(150),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(200),
            warmup: Duration::from_millis(30),
            max_iters: 1_000,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly; `f` must return something observable to keep the
    /// optimizer honest (the value is passed through `std::hint::black_box`).
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let iters = samples.len();
        let median = samples[iters / 2];
        let min = samples[0];
        let max = samples[iters - 1];
        let mean = samples.iter().sum::<Duration>() / iters as u32;
        let mut devs: Vec<Duration> = samples
            .iter()
            .map(|&s| if s > median { s - median } else { median - s })
            .collect();
        devs.sort();
        let mad = devs[iters / 2];
        self.results.push(BenchStats {
            name: name.to_string(),
            iters,
            median,
            mean,
            min,
            max,
            mad,
        });
        self.results.last().unwrap()
    }

    /// Print all collected results.
    pub fn print_report(&self, title: &str) {
        println!("\n=== bench: {title} ===");
        for r in &self.results {
            println!("{}", r.report());
        }
    }

    /// Write the collected results as a `BENCH_*.json` snapshot — but
    /// only with [`Provenance`]: an anonymous run prints how to
    /// identify itself and writes nothing (CI's `if-no-files-found:
    /// error` artifact gate then keeps the breakage visible). `extra`
    /// carries bench-specific scalar fields. Returns whether the file
    /// was written.
    pub fn write_snapshot(&self, path: &str, bench: &str, extra: Vec<(&str, Json)>) -> bool {
        let Some(prov) = Provenance::detect() else {
            eprintln!(
                "refusing to write {path}: anonymous run — set \
                 MICDL_BENCH_GENERATED_BY=$(whoami) (and optionally \
                 MICDL_BENCH_HOST=$(hostname)) to record provenance; \
                 CI runners identify themselves automatically"
            );
            return false;
        };
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("iters", Json::num(r.iters as f64)),
                    ("median_ns", Json::num(r.median.as_nanos() as f64)),
                    ("mean_ns", Json::num(r.mean.as_nanos() as f64)),
                    ("min_ns", Json::num(r.min.as_nanos() as f64)),
                    ("mad_ns", Json::num(r.mad.as_nanos() as f64)),
                ])
            })
            .collect();
        let mut pairs = vec![
            ("bench", Json::str(bench.to_string())),
            ("generated_by", Json::str(prov.generated_by)),
            ("host", Json::str(prov.host)),
        ];
        pairs.extend(extra);
        pairs.push(("cases", Json::Arr(cases)));
        std::fs::write(path, Json::obj(pairs).emit() + "\n")
            .unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("\nwrote {path} ({} cases)", self.results.len());
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bench::quick();
        let s = b.case("noop-ish", || 1 + 1).clone();
        assert!(s.iters >= 1);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn distinguishes_slow_from_fast() {
        let mut b = Bench {
            budget: Duration::from_millis(50),
            warmup: Duration::from_millis(5),
            max_iters: 500,
            results: Vec::new(),
        };
        b.case("fast", || 0u64);
        b.case("slow", || {
            // black_box inside the loop: in release mode LLVM otherwise
            // folds the whole sum to a constant.
            let mut acc = 0u64;
            for i in 0..20_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i) * i);
            }
            acc
        });
        assert!(b.results[1].median >= b.results[0].median);
    }

    #[test]
    fn report_contains_name() {
        let mut b = Bench::quick();
        b.case("my-case", || ());
        assert!(b.results[0].report().contains("my-case"));
    }
}
