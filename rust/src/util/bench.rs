//! Micro-benchmark harness — the criterion stand-in (offline build).
//!
//! `cargo bench` targets use `harness = false` and drive this runner:
//! warmup, timed iterations until a minimum wall budget, and robust stats
//! (median + MAD) so the §Perf pass has stable numbers to compare.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Median absolute deviation (robust spread).
    pub mad: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} median  {:>12} mean  {:>12} min  (±{:?}, {} iters)",
            self.name,
            format!("{:?}", self.median),
            format!("{:?}", self.mean),
            format!("{:?}", self.min),
            self.mad,
            self.iters
        )
    }
}

/// Benchmark runner with a fixed time budget per case.
pub struct Bench {
    /// Minimum total measured time per case.
    pub budget: Duration,
    /// Warmup time per case.
    pub warmup: Duration,
    /// Hard cap on iterations (for very slow cases).
    pub max_iters: usize,
    pub results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(800),
            warmup: Duration::from_millis(150),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(200),
            warmup: Duration::from_millis(30),
            max_iters: 1_000,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly; `f` must return something observable to keep the
    /// optimizer honest (the value is passed through `std::hint::black_box`).
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let iters = samples.len();
        let median = samples[iters / 2];
        let min = samples[0];
        let max = samples[iters - 1];
        let mean = samples.iter().sum::<Duration>() / iters as u32;
        let mut devs: Vec<Duration> = samples
            .iter()
            .map(|&s| if s > median { s - median } else { median - s })
            .collect();
        devs.sort();
        let mad = devs[iters / 2];
        self.results.push(BenchStats {
            name: name.to_string(),
            iters,
            median,
            mean,
            min,
            max,
            mad,
        });
        self.results.last().unwrap()
    }

    /// Print all collected results.
    pub fn print_report(&self, title: &str) {
        println!("\n=== bench: {title} ===");
        for r in &self.results {
            println!("{}", r.report());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bench::quick();
        let s = b.case("noop-ish", || 1 + 1).clone();
        assert!(s.iters >= 1);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn distinguishes_slow_from_fast() {
        let mut b = Bench {
            budget: Duration::from_millis(50),
            warmup: Duration::from_millis(5),
            max_iters: 500,
            results: Vec::new(),
        };
        b.case("fast", || 0u64);
        b.case("slow", || {
            // black_box inside the loop: in release mode LLVM otherwise
            // folds the whole sum to a constant.
            let mut acc = 0u64;
            for i in 0..20_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i) * i);
            }
            acc
        });
        assert!(b.results[1].median >= b.results[0].median);
    }

    #[test]
    fn report_contains_name() {
        let mut b = Bench::quick();
        b.case("my-case", || ());
        assert!(b.results[0].report().contains("my-case"));
    }
}
