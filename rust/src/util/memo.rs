//! Single-flight, sharded memoization — the concurrency primitive under
//! every sweep/serve hot path.
//!
//! The old cache policy was lock-drop-compute-insert: a lookup dropped
//! the map lock before computing, so two workers missing the same key
//! concurrently both computed it (deterministically — the first insert
//! won and results stayed bit-identical), and a miss here is not cheap:
//! it is a probe pass, a 16-round contention calibration, or strategy
//! (c)'s 44-point micsim residual fit. [`Memo`] replaces that policy
//! with **single-flight** semantics:
//!
//! * the key space is split over `N` lock shards (contention on one key
//!   never serializes unrelated keys);
//! * a miss installs a per-key *in-flight* slot before computing, and
//!   runs the closure with **no shard lock held** (nested memo calls —
//!   `measured_s` → `cost` — cannot deadlock);
//! * latecomers that find an in-flight slot block on the shard's condvar
//!   until the leader publishes, then read the shared value instead of
//!   recomputing — so concurrent misses on one key compute **exactly
//!   once** and `misses` counts distinct computed keys exactly;
//! * a leader whose closure fails removes the in-flight slot and wakes
//!   the waiters, which retry (each becoming leader at most once per
//!   attempt). Errors are not cached: every caller either gets a value
//!   or its own deterministic error, and nothing poisons the key. A
//!   leader that *panics* also clears the slot (an RAII guard), so
//!   waiters never hang on a dead computation.
//!
//! Counting contract ([`MemoStats`]): every lookup is exactly one hit
//! (value served, freshly computed by someone else or long since
//! cached) or one miss (this caller computed it). `coalesced` counts
//! the lookups that waited on another worker's in-flight computation —
//! the duplicated work the single-flight layer eliminated. Serial use
//! never waits, so `coalesced == 0` and `hits + misses` equals the
//! lookup count, shard-merge accounting included.

use std::collections::HashMap;
use std::hash::{BuildHasher, Hash, RandomState};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::error::Result;

/// Lock shards per [`Memo`]. Sixteen keeps worst-case memory trivial
/// while exceeding the worker counts the sweep pool and serve engine
/// actually run.
const SHARDS: usize = 16;

/// Hit/miss/coalesced counters for one memo table.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoStats {
    /// Lookups served from a published entry.
    pub hits: u64,
    /// Lookups that computed — exactly one per distinct key on any
    /// error-free run, whatever the concurrency.
    pub misses: u64,
    /// Lookups that blocked on another worker's in-flight computation
    /// instead of duplicating it (always 0 in serial use).
    pub coalesced: u64,
}

/// One key's state: published value, or a computation in flight.
enum Slot<V> {
    Ready(V),
    InFlight,
}

struct Shard<K, V> {
    map: Mutex<HashMap<K, Slot<V>>>,
    cv: Condvar,
}

impl<K, V> Default for Shard<K, V> {
    fn default() -> Self {
        Shard { map: Mutex::new(HashMap::new()), cv: Condvar::new() }
    }
}

/// A sharded single-flight memo table. Cheap to share (`&self` methods,
/// internally synchronized); values must be `Clone` (in practice `Arc`s
/// or `f64`s, so clones are free).
pub struct Memo<K, V> {
    shards: Box<[Shard<K, V>]>,
    hasher: RandomState,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
}

impl<K, V> Default for Memo<K, V> {
    fn default() -> Self {
        Memo::new()
    }
}

impl<K, V> std::fmt::Debug for Memo<K, V> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Memo").field("stats", &self.stats()).finish()
    }
}

/// Clears the in-flight slot if the leader unwinds before publishing,
/// so waiters retry instead of blocking forever.
struct InFlight<'a, K: Eq + Hash, V> {
    shard: &'a Shard<K, V>,
    key: Option<K>,
}

impl<K: Eq + Hash, V> InFlight<'_, K, V> {
    fn take(&mut self) -> K {
        self.key.take().expect("in-flight slot resolved twice")
    }
}

impl<K: Eq + Hash, V> Drop for InFlight<'_, K, V> {
    fn drop(&mut self) {
        if let Some(key) = self.key.take() {
            self.shard.map.lock().unwrap().remove(&key);
            self.shard.cv.notify_all();
        }
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Memo<K, V> {
    /// An empty table.
    pub fn new() -> Memo<K, V> {
        Memo {
            shards: (0..SHARDS).map(|_| Shard::default()).collect(),
            hasher: RandomState::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: &K) -> &Shard<K, V> {
        let h = self.hasher.hash_one(key) as usize;
        &self.shards[h % self.shards.len()]
    }

    /// The single-flight lookup: return the published value for `key`,
    /// or compute it via `f` — exactly once per key across any number of
    /// concurrent callers. `f` runs with no lock held, so it may
    /// re-enter this or another memo. On `Err` the slot is cleared
    /// (errors are never cached) and waiting callers retry.
    pub fn get_or_try_insert_with<F>(&self, key: K, f: F) -> Result<V>
    where
        F: FnOnce() -> Result<V>,
    {
        let shard = self.shard_of(&key);
        let mut waited = false;
        {
            let mut map = shard.map.lock().unwrap();
            loop {
                match map.get(&key) {
                    Some(Slot::Ready(v)) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        if waited {
                            self.coalesced.fetch_add(1, Ordering::Relaxed);
                        }
                        return Ok(v.clone());
                    }
                    Some(Slot::InFlight) => {
                        waited = true;
                        map = shard.cv.wait(map).unwrap();
                    }
                    None => {
                        map.insert(key.clone(), Slot::InFlight);
                        break;
                    }
                }
            }
        }
        // This caller is the leader for `key`: compute outside the lock.
        self.misses.fetch_add(1, Ordering::Relaxed);
        if waited {
            // A previous leader failed and this waiter took over.
            self.coalesced.fetch_add(1, Ordering::Relaxed);
        }
        let mut guard = InFlight { shard, key: Some(key) };
        match f() {
            Ok(v) => {
                let key = guard.take();
                let mut map = shard.map.lock().unwrap();
                map.insert(key, Slot::Ready(v.clone()));
                drop(map);
                shard.cv.notify_all();
                Ok(v)
            }
            Err(e) => {
                drop(guard); // clears the slot + wakes waiters to retry
                Err(e)
            }
        }
    }

    /// Infallible form of [`Memo::get_or_try_insert_with`].
    pub fn get_or_insert_with<F>(&self, key: K, f: F) -> V
    where
        F: FnOnce() -> V,
    {
        match self.get_or_try_insert_with(key, || Ok(f())) {
            Ok(v) => v,
            Err(_) => unreachable!("infallible memo closure"),
        }
    }

    /// Snapshot of every published value, in unspecified order
    /// (in-flight slots are skipped — their values don't exist yet).
    pub fn values(&self) -> Vec<V> {
        let mut out = Vec::new();
        for shard in self.shards.iter() {
            out.extend(shard.map.lock().unwrap().values().filter_map(|slot| match slot {
                Slot::Ready(v) => Some(v.clone()),
                Slot::InFlight => None,
            }));
        }
        out
    }

    /// Drop every entry (published and — there can be none without a
    /// concurrent leader — in-flight). Counters are retained: stats
    /// describe traffic, not contents.
    pub fn clear(&self) {
        for shard in self.shards.iter() {
            shard.map.lock().unwrap().clear();
            shard.cv.notify_all();
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> MemoStats {
        MemoStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Barrier;

    #[test]
    fn serial_lookups_compute_once_per_key() {
        let memo: Memo<u32, u32> = Memo::new();
        let computed = AtomicUsize::new(0);
        for _ in 0..3 {
            for k in 0..4 {
                let v = memo
                    .get_or_try_insert_with(k, || {
                        computed.fetch_add(1, Ordering::Relaxed);
                        Ok(k * 10)
                    })
                    .unwrap();
                assert_eq!(v, k * 10);
            }
        }
        assert_eq!(computed.load(Ordering::Relaxed), 4);
        let stats = memo.stats();
        assert_eq!(stats, MemoStats { hits: 8, misses: 4, coalesced: 0 });
    }

    #[test]
    fn concurrent_misses_on_one_key_compute_exactly_once() {
        const WORKERS: usize = 8;
        for round in 0..20 {
            let memo: Memo<u64, u64> = Memo::new();
            let computed = AtomicUsize::new(0);
            let barrier = Barrier::new(WORKERS);
            std::thread::scope(|scope| {
                for _ in 0..WORKERS {
                    scope.spawn(|| {
                        barrier.wait();
                        let v = memo
                            .get_or_try_insert_with(round, || {
                                computed.fetch_add(1, Ordering::Relaxed);
                                // Widen the race window.
                                std::thread::yield_now();
                                Ok(round * 3)
                            })
                            .unwrap();
                        assert_eq!(v, round * 3);
                    });
                }
            });
            assert_eq!(computed.load(Ordering::Relaxed), 1, "round {round}");
            let stats = memo.stats();
            assert_eq!(stats.misses, 1, "round {round}: {stats:?}");
            assert_eq!(stats.hits, WORKERS as u64 - 1, "round {round}");
            assert_eq!(
                stats.hits + stats.misses,
                WORKERS as u64,
                "round {round}: every lookup is a hit or a miss"
            );
        }
    }

    #[test]
    fn errors_are_not_cached_and_waiters_retry() {
        let memo: Memo<u8, u8> = Memo::new();
        let attempts = AtomicUsize::new(0);
        for _ in 0..2 {
            let err = memo
                .get_or_try_insert_with(7, || {
                    attempts.fetch_add(1, Ordering::Relaxed);
                    Err(Error::Config("boom".into()))
                })
                .unwrap_err();
            assert!(err.to_string().contains("boom"));
        }
        assert_eq!(attempts.load(Ordering::Relaxed), 2, "errors must not stick");
        // After a failure the key computes fresh — and then hits.
        let v = memo.get_or_try_insert_with(7, || Ok(42)).unwrap();
        assert_eq!(v, 42);
        assert_eq!(memo.get_or_try_insert_with(7, || Ok(0)).unwrap(), 42);
    }

    #[test]
    fn concurrent_error_leaders_are_bounded_by_worker_count() {
        const WORKERS: usize = 6;
        let memo: Memo<u8, u8> = Memo::new();
        let attempts = AtomicUsize::new(0);
        let barrier = Barrier::new(WORKERS);
        std::thread::scope(|scope| {
            for _ in 0..WORKERS {
                scope.spawn(|| {
                    barrier.wait();
                    let err = memo
                        .get_or_try_insert_with(1, || {
                            attempts.fetch_add(1, Ordering::Relaxed);
                            std::thread::yield_now();
                            Err(Error::Config("deterministic failure".into()))
                        })
                        .unwrap_err();
                    assert!(err.to_string().contains("deterministic"));
                });
            }
        });
        // Every caller got its own error; nobody looped more than once.
        let n = attempts.load(Ordering::Relaxed);
        assert!((1..=WORKERS).contains(&n), "{n} attempts");
        assert!(
            memo.shards.iter().all(|s| s.map.lock().unwrap().is_empty()),
            "failed computations must leave no slot behind"
        );
    }

    #[test]
    fn nested_lookups_do_not_deadlock() {
        // `measured_s` computes by calling `cost` — model that shape:
        // the outer closure re-enters the memo (possibly the same shard).
        let memo: Memo<u32, u32> = Memo::new();
        let v = memo
            .get_or_try_insert_with(0, || {
                let inner = memo.get_or_try_insert_with(16, || Ok(5))?;
                Ok(inner + 1)
            })
            .unwrap();
        assert_eq!(v, 6);
        assert_eq!(memo.stats().misses, 2);
    }

    #[test]
    fn clear_drops_entries_but_keeps_counters() {
        let memo: Memo<u8, u8> = Memo::new();
        memo.get_or_insert_with(1, || 10);
        memo.get_or_insert_with(1, || 99);
        memo.clear();
        assert_eq!(memo.get_or_insert_with(1, || 20), 20, "cleared key recomputes");
        let stats = memo.stats();
        assert_eq!((stats.hits, stats.misses), (1, 2));
    }

    #[test]
    fn panicking_leader_does_not_strand_waiters() {
        let memo: std::sync::Arc<Memo<u8, u8>> = std::sync::Arc::new(Memo::new());
        let m = std::sync::Arc::clone(&memo);
        let panicker = std::thread::spawn(move || {
            let _ = m.get_or_try_insert_with(3, || panic!("leader died"));
        });
        assert!(panicker.join().is_err());
        // The slot was cleared on unwind: a later caller computes fresh
        // instead of waiting forever.
        assert_eq!(memo.get_or_try_insert_with(3, || Ok(9)).unwrap(), 9);
    }
}
