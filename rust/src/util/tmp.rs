//! Temp-directory helper for tests (tempfile-crate stand-in).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique directory under the system temp root, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "micdl-{tag}-{}-{}",
            std::process::id(),
            n
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let kept;
        {
            let dir = TempDir::new("t").unwrap();
            kept = dir.path().to_path_buf();
            std::fs::write(dir.path().join("f.txt"), "x").unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
