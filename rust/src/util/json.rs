//! Minimal JSON: recursive-descent parser + emitter.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); objects preserve key order. Used for
//! `artifacts/meta.json`, custom [`crate::config::ArchSpec`] files, and
//! experiment output. Not performance-critical — clarity over speed.

use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key-order-preserving object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with context instead of returning None.
    pub fn expect(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- emit ---------------------------------------------------------------

    /// Compact serialization.
    pub fn emit(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- parse ---------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes: Vec<char> = text.chars().collect();
        let mut p = Parser { chars: &bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            return Err(Error::Json(format!(
                "trailing characters at offset {}",
                p.pos
            )));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    chars: &'a [char],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        self.pos += 1;
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect_char(&mut self, want: char) -> Result<()> {
        match self.bump() {
            Some(c) if c == want => Ok(()),
            other => Err(Error::Json(format!(
                "expected {want:?} at offset {}, found {other:?}",
                self.pos
            ))),
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('t') => self.literal("true", Json::Bool(true)),
            Some('f') => self.literal("false", Json::Bool(false)),
            Some('n') => self.literal("null", Json::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {other:?} at offset {}",
                self.pos
            ))),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        for want in word.chars() {
            if self.bump() != Some(want) {
                return Err(Error::Json(format!("bad literal near offset {}", self.pos)));
            }
        }
        Ok(value)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_char('{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_char(':')?;
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some('}') => return Ok(Json::Obj(pairs)),
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at offset {}, found {other:?}",
                        self.pos
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_char('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some(']') => return Ok(Json::Arr(items)),
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at offset {}, found {other:?}",
                        self.pos
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_char('"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(Error::Json("unterminated string".into())),
                Some('"') => return Ok(out),
                Some('\\') => match self.bump() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('r') => out.push('\r'),
                    Some('b') => out.push('\u{8}'),
                    Some('f') => out.push('\u{c}'),
                    Some('u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| {
                                Error::Json("truncated \\u escape".into())
                            })?;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    Error::Json(format!("bad hex digit {c:?}"))
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => {
                        return Err(Error::Json(format!("bad escape {other:?}")))
                    }
                },
                Some(c) => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some('-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some('.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some('e' | 'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some('+' | '-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text: String = self.chars[start..self.pos].iter().collect();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number {text:?}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrips_meta_like_document() {
        let src = r#"{"batch":64,"lr":0.05,"archs":{"small":{"params":[{"w":[5,1,4,4],"b":[5]}],"train_hlo":"train_small_b64.hlo.txt"}}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.emit();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(64));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let emitted = v.emit();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn whitespace_tolerated() {
        let v = Json::parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"k\" 1}", "01x", "nul", "[1 2]",
                    "{}extra"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(64.0).emit(), "64");
        assert_eq!(Json::Num(0.5).emit(), "0.5");
    }

    #[test]
    fn key_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn expect_reports_missing_key() {
        let v = Json::parse("{}").unwrap();
        assert!(v.expect("nope").is_err());
    }
}
