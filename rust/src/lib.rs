//! # micdl — Performance Modelling of Deep Learning on Intel MIC Architectures
//!
//! A full reproduction of Viebke et al., *"Performance Modelling of Deep
//! Learning on Intel Many Integrated Core Architectures"* (HPCS 2019), built
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator and every substrate the paper
//!   depends on: the parallel CNN training orchestrator (Fig. 4), the two
//!   analytic performance models (Tables V and VI), a discrete-event
//!   simulator of the Intel Xeon Phi 7120P ([`simulator`]) that stands in
//!   for the hardware we do not have, the operation counters behind
//!   Tables VII/VIII ([`nn::opcount`]), dataset handling, and the PJRT
//!   runtime that executes the AOT-compiled JAX/Pallas training step.
//! * **L2 (python/compile/model.py)** — the CNN forward/backward in JAX,
//!   lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — the Pallas conv-as-matmul and
//!   pooling kernels inside that HLO.
//!
//! Python never runs on the request path: `make artifacts` emits HLO text,
//! and everything else is this self-contained Rust binary.
//!
//! ## Module map
//!
//! | Module | Role |
//! |---|---|
//! | [`calibration`] | Parameter estimation: paper constants, probes, fitted computed counts |
//! | [`config`] | Architecture / machine / run configuration system |
//! | [`nn`] | Layer graph, shape walk, weight init, operation counting |
//! | [`engine`] | Pure-Rust CNN forward/backward (oracle + fallback backend) |
//! | [`dataset`] | MNIST IDX loader + deterministic synthetic digit corpus |
//! | [`simulator`] | `micsim`: discrete-event Xeon Phi model (cores, SMT/CPI, VPU, ring + memory channels) |
//! | [`perfmodel`] | The paper's contribution: strategies (a) and (b), contention, accuracy |
//! | [`training`] | The Fig. 4 parallel training algorithm over a pluggable backend |
//! | [`coordinator`] | Worker pool, image sharding, epoch barriers, metrics |
//! | [`runtime`] | xla/PJRT client: load HLO text artifacts, compile, execute |
//! | [`report`] | Paper-style table/series rendering + embedded paper data |
//! | [`sweep`] | Parallel scenario-sweep engine (grid × cache × worker pool) |
//! | [`lab`] | Persistent experiment lab: content-addressed disk store + resumable runs |
//! | [`serve`] | Batched what-if prediction engine + embedded HTTP server (`repro predict` / `repro serve`) |
//! | [`experiments`] | One entry per paper table/figure (the reproduction index) |

pub mod calibration;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod lab;
pub mod nn;
pub mod perfmodel;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod simulator;
pub mod sweep;
pub mod training;
pub mod util;

pub use error::{Error, Result};
