//! Lightweight metrics for the coordinator drivers.

use std::time::Instant;

/// Counters + timers for a training run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub images_trained: u64,
    pub images_evaluated: u64,
    pub steps: u64,
    pub train_wall_s: f64,
    pub eval_wall_s: f64,
    pub barrier_wait_s: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure into one of the wall buckets.
    pub fn time<T>(bucket: &mut f64, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *bucket += t0.elapsed().as_secs_f64();
        out
    }

    /// Training throughput, images/second.
    pub fn train_throughput(&self) -> f64 {
        if self.train_wall_s > 0.0 {
            self.images_trained as f64 / self.train_wall_s
        } else {
            0.0
        }
    }

    /// Merge a worker's metrics into the leader's.
    pub fn merge(&mut self, other: &Metrics) {
        self.images_trained += other.images_trained;
        self.images_evaluated += other.images_evaluated;
        self.steps += other.steps;
        // Wall buckets take the max (parallel phases overlap).
        self.train_wall_s = self.train_wall_s.max(other.train_wall_s);
        self.eval_wall_s = self.eval_wall_s.max(other.eval_wall_s);
        self.barrier_wait_s += other.barrier_wait_s;
    }

    pub fn report(&self) -> String {
        format!(
            "trained {} images in {:.2}s ({:.0} img/s), evaluated {} in {:.2}s, \
             {} steps, barrier wait {:.3}s",
            self.images_trained,
            self.train_wall_s,
            self.train_throughput(),
            self.images_evaluated,
            self.eval_wall_s,
            self.steps,
            self.barrier_wait_s
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_accumulates() {
        let mut bucket = 0.0;
        let v = Metrics::time(&mut bucket, || 42);
        assert_eq!(v, 42);
        assert!(bucket >= 0.0);
    }

    #[test]
    fn throughput_guards_zero() {
        let m = Metrics::new();
        assert_eq!(m.train_throughput(), 0.0);
    }

    #[test]
    fn merge_takes_max_wall_and_sums_counts() {
        let mut a = Metrics { images_trained: 10, train_wall_s: 2.0, ..Default::default() };
        let b = Metrics { images_trained: 5, train_wall_s: 3.0, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.images_trained, 15);
        assert_eq!(a.train_wall_s, 3.0);
    }

    #[test]
    fn report_contains_throughput() {
        let m = Metrics { images_trained: 100, train_wall_s: 2.0, ..Default::default() };
        assert!(m.report().contains("50 img/s"));
    }
}
