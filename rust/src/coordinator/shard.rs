//! Contiguous shard arithmetic.
//!
//! The same ⌈n/p⌉/⌊n/p⌋ split as OpenMP static scheduling and as
//! [`crate::simulator::workload::chunk_of`] — the first `n mod p` workers
//! take one extra item. Property tests in `rust/tests/proptests.rs` pin
//! the invariants (conservation, disjointness, balance).

/// A worker's contiguous range of a dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub start: usize,
    pub end: usize,
}

impl Shard {
    /// Shard `t` of `n` items over `p` workers.
    pub fn of(n: usize, p: usize, t: usize) -> Shard {
        assert!(t < p, "worker {t} out of {p}");
        let base = n / p;
        let extra = n % p;
        let start = t * base + t.min(extra);
        let len = base + usize::from(t < extra);
        Shard { start, end: start + len }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn range(&self) -> std::ops::Range<usize> {
        self.start..self.end
    }

    /// All shards for `n` items over `p` workers.
    pub fn all(n: usize, p: usize) -> Vec<Shard> {
        (0..p).map(|t| Shard::of(n, p, t)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_partition_exactly() {
        for (n, p) in [(60_000, 240), (10, 3), (7, 7), (5, 8), (0, 4)] {
            let shards = Shard::all(n, p);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards[p - 1].end, n);
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start, "n={n} p={p}");
            }
            let total: usize = shards.iter().map(|s| s.len()).sum();
            assert_eq!(total, n);
        }
    }

    #[test]
    fn shard_sizes_differ_by_at_most_one() {
        let shards = Shard::all(100, 7);
        let max = shards.iter().map(|s| s.len()).max().unwrap();
        let min = shards.iter().map(|s| s.len()).min().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn agrees_with_simulator_chunks() {
        use crate::simulator::workload::chunk_of;
        for (n, p) in [(60_000, 240), (100, 7), (10_000, 480)] {
            for t in 0..p {
                assert_eq!(Shard::of(n, p, t).len(), chunk_of(n, p, t), "n={n} p={p} t={t}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_worker() {
        Shard::of(10, 2, 2);
    }
}
