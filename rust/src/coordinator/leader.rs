//! The artifact-backed leader loop (PJRT path).
//!
//! The leader owns the PJRT runtime and drives batched SGD through the
//! compiled JAX/Pallas train step: shuffle → batch → execute → log. This
//! is the e2e path proving the three layers compose (Pallas kernel → JAX
//! step → HLO text → Rust PJRT); the per-thread-instance parallel scheme
//! of the paper runs in [`super::pool`] (engine) and in the simulator
//! (timing).

use std::path::Path;
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::dataset::Dataset;
use crate::error::Result;
use crate::nn::init::XorShift64;
use crate::runtime::{ArtifactRegistry, PjrtRuntime, TrainHandle};
use crate::training::{EpochStats, TrainReport};

/// Configuration for the PJRT leader.
#[derive(Debug, Clone)]
pub struct LeaderConfig {
    pub arch: String,
    pub epochs: usize,
    /// Cap on evaluation batches per epoch (0 = all).
    pub eval_cap_batches: usize,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for LeaderConfig {
    fn default() -> Self {
        LeaderConfig {
            arch: "small".into(),
            epochs: 3,
            eval_cap_batches: 8,
            seed: 42,
            verbose: false,
        }
    }
}

/// Leader-driven PJRT trainer.
pub struct PjrtTrainer {
    runtime: PjrtRuntime,
    handle: TrainHandle,
    pub registry: ArtifactRegistry,
    pub cfg: LeaderConfig,
    pub metrics: Metrics,
}

impl std::fmt::Debug for PjrtTrainer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtTrainer")
            .field("arch", &self.cfg.arch)
            .field("batch", &self.registry.batch)
            .finish()
    }
}

impl PjrtTrainer {
    /// Load artifacts from `dir` and prepare the executable + parameters.
    pub fn new(dir: &Path, cfg: LeaderConfig) -> Result<PjrtTrainer> {
        let registry = ArtifactRegistry::load(dir)?;
        registry.check_files()?;
        let mut runtime = PjrtRuntime::cpu()?;
        let arch = registry.arch(&cfg.arch)?.clone();
        let handle =
            runtime.train_handle(&arch, registry.batch, registry.input_hw, cfg.seed)?;
        Ok(PjrtTrainer { runtime, handle, registry, cfg, metrics: Metrics::new() })
    }

    /// One batched step over `indices` of `data`. Short batches wrap.
    fn step(&mut self, data: &Dataset, indices: &[usize]) -> Result<f32> {
        let b = self.registry.batch;
        let hw2 = self.registry.input_hw * self.registry.input_hw;
        let mut xs = Vec::with_capacity(b * hw2);
        let mut ys = Vec::with_capacity(b);
        for k in 0..b {
            let (img, label) = data.sample(indices[k % indices.len()]);
            xs.extend_from_slice(img);
            ys.push(label as i32);
        }
        let loss = self.runtime.train_step(&mut self.handle, &xs, &ys)?;
        self.metrics.steps += 1;
        self.metrics.images_trained += b as u64;
        Ok(loss)
    }

    /// Accuracy over (a capped number of) batches of `data`.
    pub fn accuracy(&mut self, data: &Dataset) -> Result<f64> {
        let b = self.registry.batch;
        let hw2 = self.registry.input_hw * self.registry.input_hw;
        let mut correct = 0usize;
        let mut total = 0usize;
        let max_batches = if self.cfg.eval_cap_batches == 0 {
            usize::MAX
        } else {
            self.cfg.eval_cap_batches
        };
        let mut start = 0usize;
        let mut batches = 0usize;
        while start + b <= data.len() && batches < max_batches {
            let mut xs = Vec::with_capacity(b * hw2);
            let mut ys = Vec::with_capacity(b);
            for k in 0..b {
                let (img, label) = data.sample(start + k);
                xs.extend_from_slice(img);
                ys.push(label);
            }
            let classes = self.runtime.infer(&mut self.handle, &xs)?;
            correct += classes.iter().zip(ys.iter()).filter(|(&c, &y)| c == y).count();
            total += b;
            self.metrics.images_evaluated += b as u64;
            start += b;
            batches += 1;
        }
        Ok(if total == 0 { 0.0 } else { correct as f64 / total as f64 })
    }

    /// Full training run: per epoch, shuffle, sweep batches, evaluate.
    pub fn train(&mut self, train: &Dataset, test: &Dataset) -> Result<TrainReport> {
        let b = self.registry.batch;
        let mut rng = XorShift64::new(self.cfg.seed ^ 0xC0FFEE);
        let mut order: Vec<usize> = (0..train.len()).collect();
        let mut report = TrainReport::default();
        let run_start = Instant::now();

        for epoch in 0..self.cfg.epochs {
            let epoch_start = Instant::now();
            // Fisher-Yates shuffle per epoch.
            for i in (1..order.len()).rev() {
                order.swap(i, rng.next_below(i + 1));
            }
            let mut loss_sum = 0.0f64;
            let mut batches = 0usize;
            for chunk in order.chunks(b) {
                loss_sum += self.step(train, chunk)? as f64;
                batches += 1;
            }
            let train_loss = loss_sum / batches.max(1) as f64;
            let val_accuracy = self.accuracy(train)?;
            let test_accuracy = self.accuracy(test)?;
            let stats = EpochStats {
                epoch,
                train_loss,
                val_loss: 0.0,
                val_accuracy,
                test_accuracy,
                wall_s: epoch_start.elapsed().as_secs_f64(),
            };
            if self.cfg.verbose {
                println!(
                    "epoch {epoch:>3}: loss {train_loss:.4}  val_acc {val_accuracy:.3}  \
                     test_acc {test_accuracy:.3}  ({:.2}s)",
                    stats.wall_s
                );
            }
            report.epochs.push(stats);
        }
        report.total_wall_s = run_start.elapsed().as_secs_f64();
        self.metrics.train_wall_s = report.total_wall_s;
        report.train_throughput =
            self.metrics.images_trained as f64 / report.total_wall_s.max(1e-9);
        Ok(report)
    }

    /// Steps executed so far (delegates to the handle).
    pub fn steps(&self) -> u64 {
        self.handle.steps
    }
}

// PJRT-backed tests live in rust/tests/runtime_e2e.rs and the examples;
// unit-testing here would duplicate them against the same artifacts.
