//! Data-parallel worker pool over engine instances (the OpenMP substitute).
//!
//! Every epoch (Fig. 4): each worker trains its own network replica on its
//! training shard (per-image SGD), then the leader averages the replica
//! weights (the "combine" step), evaluates validation/test accuracy with
//! the combined model, and redistributes it to the replicas. Workers are
//! real `std::thread`s (scoped), so the wall-clock speedup on a multicore
//! host is genuine — the *simulated Phi* timing story lives in
//! [`crate::simulator`], not here.

use std::time::Instant;

use crate::config::ArchSpec;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::shard::Shard;
use crate::dataset::Dataset;
use crate::error::{Error, Result};
use crate::nn::Network;
use crate::training::{evaluate, Backend, EngineBackend, EpochStats, TrainReport};

/// Configuration for the pool driver.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker threads (= network instances, the paper's `ns = p`).
    pub workers: usize,
    pub epochs: usize,
    pub lr: f32,
    /// Evaluate at most this many validation/test images per epoch
    /// (0 = all) — keeps example runtimes sane.
    pub eval_cap: usize,
    pub seed: u64,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            epochs: 5,
            lr: 0.02,
            eval_cap: 1024,
            seed: 42,
            verbose: false,
        }
    }
}

/// The engine-backed data-parallel trainer.
#[derive(Debug)]
pub struct DataParallelTrainer {
    pub arch: ArchSpec,
    pub cfg: PoolConfig,
    pub metrics: Metrics,
    /// The combined (averaged) model after the last epoch.
    pub model: Network,
}

impl DataParallelTrainer {
    pub fn new(arch: ArchSpec, cfg: PoolConfig) -> Result<Self> {
        if cfg.workers == 0 {
            return Err(Error::Config("need at least one worker".into()));
        }
        let model = Network::new(arch.clone(), cfg.seed)?;
        Ok(DataParallelTrainer { arch, cfg, metrics: Metrics::new(), model })
    }

    /// Run the full Fig. 4 loop. Returns per-epoch statistics.
    pub fn train(&mut self, train: &Dataset, test: &Dataset) -> Result<TrainReport> {
        let p = self.cfg.workers.min(train.len().max(1));
        let mut report = TrainReport::default();
        let run_start = Instant::now();

        // Per-worker replicas start from the shared initial model.
        let mut replicas: Vec<Network> = (0..p).map(|_| self.model.clone()).collect();

        for epoch in 0..self.cfg.epochs {
            let epoch_start = Instant::now();
            let lr = self.cfg.lr;
            let shards = Shard::all(train.len(), p);

            // --- train phase (parallel, one replica per worker) ---------
            let losses: Vec<Result<f64>> = std::thread::scope(|scope| {
                let handles: Vec<_> = replicas
                    .iter_mut()
                    .zip(shards.iter())
                    .map(|(net, shard)| {
                        let shard = *shard;
                        scope.spawn(move || -> Result<f64> {
                            let mut sum = 0.0f64;
                            let mut backend = EngineBackend::new(net.clone());
                            for idx in shard.range() {
                                let (img, label) = train.sample(idx);
                                sum += backend.train_image(img, label, lr)? as f64;
                            }
                            *net = backend.net;
                            Ok(sum / shard.len().max(1) as f64)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker panicked"))
                    .collect()
            });
            let mut train_loss = 0.0f64;
            for l in losses {
                train_loss += l?;
            }
            train_loss /= p as f64;
            self.metrics.images_trained += train.len() as u64;

            // --- combine: average replica weights ------------------------
            self.model = Network::average(&replicas)?;
            for r in replicas.iter_mut() {
                *r = self.model.clone();
            }

            // --- validation + test phases (combined model) --------------
            let cap = |n: usize| {
                if self.cfg.eval_cap == 0 { n } else { n.min(self.cfg.eval_cap) }
            };
            let backend = EngineBackend::new(self.model.clone());
            let (val_acc, val_loss) = evaluate(&backend, train, 0..cap(train.len()))?;
            let (test_acc, _) = evaluate(&backend, test, 0..cap(test.len()))?;
            self.metrics.images_evaluated += (cap(train.len()) + cap(test.len())) as u64;

            let stats = EpochStats {
                epoch,
                train_loss,
                val_loss,
                val_accuracy: val_acc,
                test_accuracy: test_acc,
                wall_s: epoch_start.elapsed().as_secs_f64(),
            };
            if self.cfg.verbose {
                println!(
                    "epoch {epoch:>3}: train_loss {train_loss:.4}  val_acc {val_acc:.3}  \
                     test_acc {test_acc:.3}  ({:.2}s)",
                    stats.wall_s
                );
            }
            report.epochs.push(stats);
        }

        report.total_wall_s = run_start.elapsed().as_secs_f64();
        self.metrics.train_wall_s = report.total_wall_s;
        report.train_throughput =
            self.metrics.images_trained as f64 / report.total_wall_s.max(1e-9);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::load_or_synth;

    fn quick_cfg(workers: usize, epochs: usize) -> PoolConfig {
        PoolConfig { workers, epochs, lr: 0.02, eval_cap: 64, seed: 7, verbose: false }
    }

    #[test]
    fn converges_on_synth_corpus() {
        let (train, test) = load_or_synth(None, 200, 40, 3);
        let mut t =
            DataParallelTrainer::new(ArchSpec::small(), quick_cfg(4, 6)).unwrap();
        let report = t.train(&train, &test).unwrap();
        assert_eq!(report.epochs.len(), 6);
        assert!(report.converging(), "loss curve: {:?}", report.loss_curve());
        assert!(report.final_test_accuracy() > 0.15, "acc {}", report.final_test_accuracy());
    }

    #[test]
    fn single_worker_equals_serial_training() {
        let (train, test) = load_or_synth(None, 60, 10, 4);
        let mut t =
            DataParallelTrainer::new(ArchSpec::small(), quick_cfg(1, 2)).unwrap();
        let report = t.train(&train, &test).unwrap();
        assert_eq!(report.epochs.len(), 2);
        assert!(report.epochs[0].train_loss.is_finite());
    }

    #[test]
    fn more_workers_than_images_clamps() {
        let (train, test) = load_or_synth(None, 5, 2, 9);
        let mut t =
            DataParallelTrainer::new(ArchSpec::small(), quick_cfg(16, 1)).unwrap();
        let report = t.train(&train, &test).unwrap();
        assert_eq!(report.epochs.len(), 1);
    }

    #[test]
    fn metrics_accumulate() {
        let (train, test) = load_or_synth(None, 50, 10, 5);
        let mut t =
            DataParallelTrainer::new(ArchSpec::small(), quick_cfg(2, 2)).unwrap();
        t.train(&train, &test).unwrap();
        assert_eq!(t.metrics.images_trained, 100);
        assert!(t.metrics.images_evaluated > 0);
    }

    #[test]
    fn rejects_zero_workers() {
        assert!(DataParallelTrainer::new(ArchSpec::small(), quick_cfg(0, 1)).is_err());
    }
}
