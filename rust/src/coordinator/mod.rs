//! L3 coordinator: sharding, worker pool, leader loop, metrics.
//!
//! The paper's runtime is OpenMP data parallelism — `p` threads, each
//! owning a network instance and a contiguous image shard, with barriers
//! between the train / validation / test phases of every epoch (Fig. 4).
//! This module is that runtime rebuilt on `std::thread`:
//!
//! * [`shard`] — contiguous shard arithmetic (shared with the simulator's
//!   workload mapping so simulated and real partitioning agree).
//! * [`pool`] — [`pool::DataParallelTrainer`]: scoped worker threads over
//!   pure-Rust engine instances, weight averaging between epochs.
//! * [`leader`] — [`leader::PjrtTrainer`]: the artifact-backed leader
//!   loop (batched SGD through the compiled JAX/Pallas step).
//! * [`metrics`] — lightweight counters/timers for both drivers.

pub mod leader;
pub mod metrics;
pub mod pool;
pub mod shard;

pub use leader::PjrtTrainer;
pub use metrics::Metrics;
pub use pool::DataParallelTrainer;
pub use shard::Shard;
