//! Figures 5–7 — predicted vs measured execution times per architecture.
//!
//! For each measured thread count {1, 15, 30, 60, 120, 180, 240}:
//! strategy (a) prediction, strategy (b) prediction, and the micsim
//! "measurement", plus per-point Δ — the per-architecture view behind
//! Table IX. The grid itself is a [`crate::sweep`] definition (one
//! architecture × the measured thread counts × both strategies, with
//! micsim measurement on); this module only formats the results as an
//! aligned table and a log-scale ASCII chart mirroring the paper's
//! figures.

use crate::config::{ArchSpec, RunConfig};
use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::report::{series, Series, Table};
use crate::sweep::{GridSpec, Strategy, SweepRunner};

pub fn run(arch_name: &str, opts: &ExpOptions) -> Result<String> {
    let arch = ArchSpec::by_name(arch_name)?;
    let fig = match arch_name {
        "small" => "Fig. 5",
        "medium" => "Fig. 6",
        _ => "Fig. 7",
    };
    let grid = GridSpec {
        archs: vec![arch],
        threads: RunConfig::MEASURED_THREADS.to_vec(),
        strategies: vec![Strategy::A, Strategy::B],
        params: opts.params,
        measure: true,
        ..GridSpec::default()
    };
    let res = SweepRunner::new(0).run(&grid)?;

    let mut t = Table::new(
        format!(
            "{fig} — {arch_name} CNN: predicted vs measured execution time [s] \
             (ep={}, i=60k, it=10k)",
            RunConfig::paper_default(arch_name, 1).epochs
        ),
        &["threads", "predicted (a)", "predicted (b)", "measured (micsim)",
          "Δa %", "Δb %"],
    );

    let mut pred_a = Series::new("predicted (a)");
    let mut pred_b = Series::new("predicted (b)");
    let mut measured = Series::new("measured");
    for ti in 0..res.grid.threads.len() {
        let ra = res.at(0, 0, 0, 0, ti, 0);
        let rb = res.at(0, 0, 0, 0, ti, 1);
        let p = ra.scenario.threads;
        let a = ra.prediction.total_s;
        let b = rb.prediction.total_s;
        let m = ra.measured_s.expect("measure grid");
        pred_a.push(p as f64, a);
        pred_b.push(p as f64, b);
        measured.push(p as f64, m);
        t.row(vec![
            p.to_string(),
            format!("{a:.0}"),
            format!("{b:.0}"),
            format!("{m:.0}"),
            format!("{:.1}", ra.delta_pct.expect("measure grid")),
            format!("{:.1}", rb.delta_pct.expect("measure grid")),
        ]);
    }

    if opts.csv {
        return Ok(t.to_csv());
    }
    let mut out = t.render();
    out.push_str(&series::render_chart(
        &format!("{fig} ({arch_name})"),
        &[pred_a, pred_b, measured],
        "seconds",
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{both_models, delta_pct, PerfModel};
    use crate::simulator::{probe, SimConfig};

    #[test]
    fn all_three_figures_render() {
        for arch in ["small", "medium", "large"] {
            let out = run(arch, &ExpOptions::default()).unwrap();
            assert!(out.contains("240"));
            assert!(out.contains("Δa"));
            assert!(out.contains("legend"));
        }
    }

    #[test]
    fn predictions_track_measurements_within_30pct() {
        // The "shape holds" criterion: every point within 30% for both
        // models (the paper's own average deviations are 7–17%).
        let cfg = SimConfig::default();
        for name in ["small", "medium", "large"] {
            let arch = ArchSpec::by_name(name).unwrap();
            let (a, b) = both_models(&arch, Default::default()).unwrap();
            for &p in RunConfig::MEASURED_THREADS.iter() {
                let run = RunConfig::paper_default(name, p);
                let m = probe::measured_execution_s(&arch, p, &cfg).unwrap();
                for model in [&a as &dyn PerfModel, &b as &dyn PerfModel] {
                    let pred = model.predict(&run).unwrap().total_s;
                    let d = delta_pct(m, pred);
                    assert!(d < 30.0, "{name} p={p} model {}: Δ={d:.1}%", model.name());
                }
            }
        }
    }

    #[test]
    fn measured_decreases_from_120_to_240_for_large() {
        // The paper's observation: "while the predicted execution time
        // increases between 120 and 240 threads, the measured execution
        // time decreases" (the CPI-ladder flattening the models).
        let cfg = SimConfig::default();
        let arch = ArchSpec::large();
        let m120 = probe::measured_execution_s(&arch, 120, &cfg).unwrap();
        let m240 = probe::measured_execution_s(&arch, 240, &cfg).unwrap();
        assert!(m240 < m120, "measured: {m120} -> {m240}");
    }
}
