//! Cluster extension experiment (the paper's Section VII future work).
//!
//! Predicted training time and parallel efficiency for 1–16 Phi nodes
//! per architecture, over InfiniBand FDR and 10 GbE interconnects
//! ([`crate::perfmodel::cluster`]). Not a paper table — the extension
//! deliverable.

use crate::calibration::Calibration;
use crate::config::{ArchSpec, RunConfig};
use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::perfmodel::cluster::{ClusterModel, Interconnect};
use crate::report::Table;
use crate::simulator::SimConfig;
use crate::sweep::Strategy;

pub fn run(opts: &ExpOptions) -> Result<String> {
    let nodes = [1usize, 2, 4, 8, 16];
    let mut out = String::new();
    for arch in ArchSpec::paper_archs() {
        let mut t = Table::new(
            format!(
                "cluster extension — {} CNN over N Phi nodes (strategy b, 240T/node)",
                arch.name
            ),
            &["nodes", "IB: minutes", "IB: efficiency", "10GbE: minutes", "10GbE: efficiency"],
        );
        let run = RunConfig::paper_default(&arch.name, 240);
        // One resolution feeds both interconnect variants.
        let cal = Calibration::new(opts.params);
        let sim = SimConfig::default();
        let ib = ClusterModel::new(
            &arch,
            cal.strategy(&arch, Strategy::B, &sim)?,
            Interconnect::infiniband_fdr(),
        )?;
        let ge = ClusterModel::new(
            &arch,
            cal.strategy(&arch, Strategy::B, &sim)?,
            Interconnect::ten_gbe(),
        )?;
        for &n in &nodes {
            let a = ib.predict(&run, n)?;
            let b = ge.predict(&run, n)?;
            t.row(vec![
                n.to_string(),
                format!("{:.1}", a.total_s / 60.0),
                format!("{:.3}", a.efficiency),
                format!("{:.1}", b.total_s / 60.0),
                format!("{:.3}", b.efficiency),
            ]);
        }
        out.push_str(&if opts.csv { t.to_csv() } else { t.render() });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_archs_and_node_counts() {
        let out = run(&ExpOptions::default()).unwrap();
        assert!(out.contains("small") && out.contains("large"));
        assert!(out.contains("16"));
    }
}
