//! Fig. 1 — peak performance of many-core processors vs TOP500 #1 systems.

use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::report::{paper, series, Series, Table};

pub fn run(opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Fig. 1 — many-core devices vs TOP500 #1 peak performance (TFLOP/s)",
        &["kind", "system", "year", "peak TFLOP/s"],
    );
    for (name, year, tflops) in paper::FIG1_TOP500 {
        t.row(vec!["top500 #1".into(), name.into(), year.to_string(), format!("{tflops}")]);
    }
    for (name, year, tflops) in paper::FIG1_DEVICES {
        t.row(vec!["device".into(), name.into(), year.to_string(), format!("{tflops}")]);
    }
    if opts.csv {
        return Ok(t.to_csv());
    }
    let mut out = t.render();
    // The figure's point: KNL (2016) ≈ ASCI Red (#1 in 1997/2000).
    let knl = paper::FIG1_DEVICES[2];
    let red = paper::FIG1_TOP500[0];
    out.push_str(&format!(
        "note: {} ({}, {} TFLOP/s) is comparable to {} (#1 {}, {} TFLOP/s)\n",
        knl.0, knl.1, knl.2, red.0, red.1, red.2
    ));
    let top: Series = Series::from_points(
        "top500 #1",
        &paper::FIG1_TOP500.map(|(_, y, v)| (y as f64, v)),
    );
    let dev = Series::from_points(
        "many-core device",
        &paper::FIG1_DEVICES.map(|(_, y, v)| (y as f64, v)),
    );
    out.push_str(&series::render_chart("Fig. 1", &[top, dev], "TFLOP/s"));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_both_series() {
        let out = run(&ExpOptions::default()).unwrap();
        assert!(out.contains("ASCI Red"));
        assert!(out.contains("Xeon Phi"));
        assert!(out.contains("comparable"));
    }
}
