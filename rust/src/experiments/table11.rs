//! Table XI — scaling epochs and images (small CNN, strategy (a)).
//!
//! The grid is a [`crate::sweep`] definition (small CNN × the Table XI
//! image/epoch/thread axes, strategy (a) only); this module formats the
//! results next to the paper's published cells.

use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::report::{paper, Table};
use crate::sweep::{GridSpec, SweepRunner};

/// The Table XI sweep grid ([`GridSpec::table11`], prediction-only) with
/// the experiment's parameter provenance applied.
pub fn grid(opts: &ExpOptions) -> GridSpec {
    GridSpec { params: opts.params, ..GridSpec::table11() }
}

pub fn run(opts: &ExpOptions) -> Result<String> {
    let res = SweepRunner::new(0).run(&grid(opts))?;
    let mut t = Table::new(
        "Table XI — minutes when scaling epochs/images, small CNN, model (a) \
         (ours | paper)",
        &[
            "i", "it",
            "240T ep70", "(paper)", "240T ep140", "(paper)", "240T ep280", "(paper)",
            "480T ep70", "(paper)", "480T ep140", "(paper)", "480T ep280", "(paper)",
        ],
    );
    for (row, &(i, it)) in paper::TABLE11_IMAGES.iter().enumerate() {
        let mut cells = vec![format!("{}k", i / 1000), format!("{}k", it / 1000)];
        for tcol in 0..paper::TABLE11_THREADS.len() {
            for ecol in 0..paper::TABLE11_EPOCHS.len() {
                let got = res.at(0, 0, row, ecol, tcol, 0).prediction.total_s / 60.0;
                cells.push(format!("{got:.1}"));
                cells.push(format!("{:.1}", paper::TABLE11_MINUTES[row][tcol * 3 + ecol]));
            }
        }
        t.row(cells);
    }
    let mut out = if opts.csv { t.to_csv() } else { t.render() };
    if !opts.csv {
        out.push_str(
            "note: doubling images or epochs ≈ doubles time; doubling threads \
             does not halve it (Result 2 of the paper).\n",
        );
    }
    Ok(out)
}

#[cfg(test)]
#[allow(deprecated)] // exercises the deprecated direct constructors
mod tests {
    use super::*;
    use crate::config::{ArchSpec, RunConfig};
    use crate::perfmodel::{ParamSource, PerfModel, StrategyA};

    #[test]
    fn doubling_images_doubles_time() {
        let arch = ArchSpec::small();
        let model = StrategyA::new(&arch, ParamSource::Paper).unwrap();
        let base = RunConfig {
            train_images: 60_000, test_images: 10_000, epochs: 70, threads: 240,
        };
        let t1 = model.predict(&base).unwrap().total_s;
        let t2 = model
            .predict(&RunConfig { train_images: 120_000, test_images: 20_000, ..base })
            .unwrap()
            .total_s;
        let ratio = t2 / t1;
        assert!((ratio - 2.0).abs() < 0.05, "{ratio}");
    }

    #[test]
    fn doubling_threads_does_not_halve_time() {
        let arch = ArchSpec::small();
        let model = StrategyA::new(&arch, ParamSource::Paper).unwrap();
        let base = RunConfig {
            train_images: 60_000, test_images: 10_000, epochs: 70, threads: 240,
        };
        let t240 = model.predict(&base).unwrap().total_s;
        let t480 = model.predict(&base.with_threads(480)).unwrap().total_s;
        assert!(t480 > t240 / 2.0 * 1.2, "{t240} -> {t480}");
    }

    #[test]
    fn renders_with_paper_cells() {
        let out = run(&ExpOptions::default()).unwrap();
        assert!(out.contains("139.3")); // paper 240k/280ep/240T cell
        assert!(out.contains("101.9")); // paper 240k/280ep/480T cell
    }
}
