//! Table IX — average prediction accuracy Δ per strategy and architecture.
//!
//! Ours: each model's predictions vs the micsim "measurements", averaged
//! over the measured thread counts. The paper's Δ (vs its real testbed)
//! is printed alongside — the claim preserved is the *band* (models
//! predict within ~10–20%) and the medium/large ordering (strategy (b)
//! beats (a) where measured parameters matter most).
//!
//! The grid itself is a [`crate::sweep`] definition (all three
//! architectures × the measured thread counts × both strategies, with
//! micsim measurement on) and the averaging is the sweep engine's
//! grid-level aggregation ([`crate::sweep::SweepResults::accuracy`]);
//! this module only formats the aggregates next to the paper's published
//! cells. The numbers are bit-identical to the pointwise
//! [`crate::perfmodel::average_delta`] path the module used before the
//! sweep refactor (`tests::sweep_path_matches_pointwise_average_delta`).

use crate::error::{Error, Result};
use crate::experiments::ExpOptions;
use crate::report::{paper, Table};
use crate::sweep::{GridSpec, Strategy, SweepRunner};

/// The Table IX sweep grid ([`GridSpec::table9`]: paper architectures ×
/// measured thread counts × both strategies, micsim measurement on),
/// with the experiment's parameter provenance applied. The conformance
/// harness (`crate::sweep::conformance`) runs the same canonical grid.
pub fn grid(opts: &ExpOptions) -> GridSpec {
    GridSpec { params: opts.params, ..GridSpec::table9() }
}

pub fn run(opts: &ExpOptions) -> Result<String> {
    let res = SweepRunner::new(0).run(&grid(opts))?;
    let aggregates = res.accuracy();
    let mut t = Table::new(
        "Table IX — average accuracy Δ of the performance models [%]",
        &["arch", "Δa ours", "Δa paper", "Δb ours", "Δb paper"],
    );
    for arch in &res.grid.archs {
        let delta = |s: Strategy| -> Result<f64> {
            aggregates
                .iter()
                .find(|a| a.arch == arch.name && a.strategy == s)
                .map(|a| a.mean_delta_pct)
                .ok_or_else(|| {
                    Error::Config(format!("no measured Δ for arch {:?}", arch.name))
                })
        };
        let (da, db) = (delta(Strategy::A)?, delta(Strategy::B)?);
        let idx = paper::arch_index(&arch.name).unwrap();
        t.row(vec![
            arch.name.clone(),
            format!("{da:.2}"),
            format!("{:.2}", paper::ACCURACY_DELTA_PCT[idx][0]),
            format!("{db:.2}"),
            format!("{:.2}", paper::ACCURACY_DELTA_PCT[idx][1]),
        ]);
    }
    Ok(if opts.csv { t.to_csv() } else { t.render() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchSpec, RunConfig};
    use crate::perfmodel::accuracy::average_delta;
    use crate::perfmodel::both_models;
    use crate::simulator::SimConfig;

    #[test]
    fn renders_all_archs() {
        let out = run(&ExpOptions::default()).unwrap();
        for a in ["small", "medium", "large"] {
            assert!(out.contains(a));
        }
        // Paper reference values present.
        assert!(out.contains("14.57") && out.contains("10.22"));
    }

    #[test]
    fn sweep_path_matches_pointwise_average_delta() {
        // The acceptance criterion of the sweep refactor: Table IX through
        // the sweep grid reproduces the pre-refactor pointwise computation
        // bit-for-bit — same measurements, same predictions, same
        // summation order.
        let opts = ExpOptions::default();
        let res = SweepRunner::new(0).run(&grid(&opts)).unwrap();
        let cfg = SimConfig::default();
        let threads = RunConfig::MEASURED_THREADS;
        for arch in ArchSpec::paper_archs() {
            let (model_a, model_b) = both_models(&arch, opts.params).unwrap();
            let da = average_delta(&arch, &model_a, &threads, &cfg).unwrap();
            let db = average_delta(&arch, &model_b, &threads, &cfg).unwrap();
            let sa = res.accuracy_for(&arch.name, Strategy::A).unwrap();
            let sb = res.accuracy_for(&arch.name, Strategy::B).unwrap();
            assert_eq!(sa.points, threads.len());
            assert_eq!(sb.points, threads.len());
            assert_eq!(
                sa.mean_delta_pct.to_bits(),
                da.to_bits(),
                "{}: sweep Δa {} vs pointwise {}",
                arch.name,
                sa.mean_delta_pct,
                da
            );
            assert_eq!(
                sb.mean_delta_pct.to_bits(),
                db.to_bits(),
                "{}: sweep Δb {} vs pointwise {}",
                arch.name,
                sb.mean_delta_pct,
                db
            );
        }
    }

    #[test]
    fn strategy_b_beats_a_for_medium_and_large() {
        // The paper's Table IX finding: "(b) is better for medium and
        // large CNNs". Against micsim the large-CNN gap narrows to a
        // near-tie (both models share the calibrated contention term), so
        // the assertion is: strictly better for medium, and within a
        // 1-percentage-point tie for large.
        let res = SweepRunner::new(0).run(&grid(&ExpOptions::default())).unwrap();
        for (name, slack) in [("medium", 0.0), ("large", 1.0)] {
            let da = res.accuracy_for(name, Strategy::A).unwrap().mean_delta_pct;
            let db = res.accuracy_for(name, Strategy::B).unwrap().mean_delta_pct;
            assert!(db < da + slack, "{name}: Δb {db:.1} !< Δa {da:.1} + {slack}");
        }
    }

    #[test]
    fn deltas_in_paper_band() {
        // Both models within the paper's accuracy band (≈7–17%, we allow
        // up to 25% — the simulator is not their testbed).
        let res = SweepRunner::new(0).run(&grid(&ExpOptions::default())).unwrap();
        for a in res.accuracy() {
            assert!(
                a.mean_delta_pct < 25.0,
                "{} Δ{} {:.1}",
                a.arch,
                a.strategy,
                a.mean_delta_pct
            );
        }
    }
}
