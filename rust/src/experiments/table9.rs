//! Table IX — average prediction accuracy Δ per strategy and architecture.
//!
//! Ours: each model's predictions vs the micsim "measurements", averaged
//! over the measured thread counts. The paper's Δ (vs its real testbed)
//! is printed alongside — the claim preserved is the *band* (models
//! predict within ~10–20%) and the medium/large ordering (strategy (b)
//! beats (a) where measured parameters matter most).

use crate::config::{ArchSpec, RunConfig};
use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::perfmodel::{accuracy, both_models};
use crate::report::{paper, Table};
use crate::simulator::SimConfig;

pub fn run(opts: &ExpOptions) -> Result<String> {
    let cfg = SimConfig::default();
    let threads = RunConfig::MEASURED_THREADS;
    let mut t = Table::new(
        "Table IX — average accuracy Δ of the performance models [%]",
        &["arch", "Δa ours", "Δa paper", "Δb ours", "Δb paper"],
    );
    for arch in ArchSpec::paper_archs() {
        let (model_a, model_b) = both_models(&arch, opts.params)?;
        let da = accuracy::average_delta(&arch, &model_a, &threads, &cfg)?;
        let db = accuracy::average_delta(&arch, &model_b, &threads, &cfg)?;
        let idx = paper::arch_index(&arch.name).unwrap();
        t.row(vec![
            arch.name.clone(),
            format!("{da:.2}"),
            format!("{:.2}", paper::ACCURACY_DELTA_PCT[idx][0]),
            format!("{db:.2}"),
            format!("{:.2}", paper::ACCURACY_DELTA_PCT[idx][1]),
        ]);
    }
    Ok(if opts.csv { t.to_csv() } else { t.render() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::accuracy::average_delta;

    #[test]
    fn renders_all_archs() {
        let out = run(&ExpOptions::default()).unwrap();
        for a in ["small", "medium", "large"] {
            assert!(out.contains(a));
        }
        // Paper reference values present.
        assert!(out.contains("14.57") && out.contains("10.22"));
    }

    #[test]
    fn strategy_b_beats_a_for_medium_and_large() {
        // The paper's Table IX finding: "(b) is better for medium and
        // large CNNs". Against micsim the large-CNN gap narrows to a
        // near-tie (both models share the calibrated contention term), so
        // the assertion is: strictly better for medium, and within a
        // 1-percentage-point tie for large.
        let cfg = SimConfig::default();
        let threads = RunConfig::MEASURED_THREADS;
        for (name, slack) in [("medium", 0.0), ("large", 1.0)] {
            let arch = ArchSpec::by_name(name).unwrap();
            let (a, b) = both_models(&arch, Default::default()).unwrap();
            let da = average_delta(&arch, &a, &threads, &cfg).unwrap();
            let db = average_delta(&arch, &b, &threads, &cfg).unwrap();
            assert!(db < da + slack, "{name}: Δb {db:.1} !< Δa {da:.1} + {slack}");
        }
    }

    #[test]
    fn deltas_in_paper_band() {
        // Both models within the paper's accuracy band (≈7–17%, we allow
        // up to 25% — the simulator is not their testbed).
        let cfg = SimConfig::default();
        let threads = RunConfig::MEASURED_THREADS;
        for arch in ArchSpec::paper_archs() {
            let (a, b) = both_models(&arch, Default::default()).unwrap();
            let da = average_delta(&arch, &a, &threads, &cfg).unwrap();
            let db = average_delta(&arch, &b, &threads, &cfg).unwrap();
            assert!(da < 25.0, "{}: Δa {da:.1}", arch.name);
            assert!(db < 25.0, "{}: Δb {db:.1}", arch.name);
        }
    }
}
