//! One module per paper table/figure — the reproduction index (DESIGN.md §4).
//!
//! Every experiment renders a [`crate::report::Table`] (and, for figures,
//! an ASCII chart) containing **our** numbers next to the **paper's**
//! published values, so the comparison is in the output itself, not in
//! prose. `repro exp <id>` runs one; `repro exp all` runs the lot; the
//! bench harness (`cargo bench`) times them.

pub mod ablations;
pub mod cluster;
pub mod fig1;
pub mod figs567;
pub mod table10;
pub mod table11;
pub mod table4;
pub mod table7_8;
pub mod roofline;
pub mod table9;

use crate::error::{Error, Result};
use crate::perfmodel::ParamSource;

/// Options shared by all experiments.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExpOptions {
    /// Emit CSV instead of aligned text.
    pub csv: bool,
    /// Parameter provenance for the models (paper tables vs simulator).
    pub params: ParamSource,
}

/// All experiment ids, in paper order.
pub const ALL: [&str; 9] = [
    "fig1", "table4", "table7", "table8", "fig5", "fig6", "fig7", "table9",
    "table10",
];
/// table11 is included in `all` too; listed separately because it is the
/// scaling study (longer to print).
pub const ALL_WITH_SCALING: [&str; 10] = [
    "fig1", "table4", "table7", "table8", "fig5", "fig6", "fig7", "table9",
    "table10", "table11",
];

/// Extension experiments (not paper artifacts): ablations over micsim
/// mechanisms, the multi-node future-work model, roofline/MXU analysis.
pub const EXTENSIONS: [&str; 3] = ["ablations", "cluster", "roofline"];

/// Run one experiment by id, returning its rendered output.
pub fn run(id: &str, opts: &ExpOptions) -> Result<String> {
    match id {
        "fig1" => fig1::run(opts),
        "table4" => table4::run(opts),
        "table7" => table7_8::run_fprop(opts),
        "table8" => table7_8::run_bprop(opts),
        "fig5" => figs567::run("small", opts),
        "fig6" => figs567::run("medium", opts),
        "fig7" => figs567::run("large", opts),
        "table9" => table9::run(opts),
        "ablations" => ablations::run(opts),
        "cluster" => cluster::run(opts),
        "roofline" => roofline::run(opts),
        "table10" => table10::run(opts),
        "table11" => table11::run(opts),
        "all" => {
            let mut out = String::new();
            for id in ALL_WITH_SCALING {
                out.push_str(&run(id, opts)?);
                out.push('\n');
            }
            Ok(out)
        }
        "extensions" => {
            let mut out = String::new();
            for id in EXTENSIONS {
                out.push_str(&run(id, opts)?);
                out.push('\n');
            }
            Ok(out)
        }
        other => Err(Error::Config(format!(
            "unknown experiment {other:?}; available: {ALL_WITH_SCALING:?} or 'all'"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_experiment_runs() {
        let opts = ExpOptions::default();
        for id in ALL_WITH_SCALING.iter().chain(EXTENSIONS.iter()).copied() {
            let out = run(id, &opts).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(!out.is_empty(), "{id}");
        }
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert!(run("table99", &ExpOptions::default()).is_err());
    }

    #[test]
    fn csv_mode_produces_commas() {
        let opts = ExpOptions { csv: true, ..Default::default() };
        let out = run("table10", &opts).unwrap();
        assert!(out.contains(','));
    }
}
