//! Roofline experiment: KNC efficiency + the Pallas/MXU mapping.
//!
//! Part of the §Perf deliverable: situates the paper's measured per-image
//! times against the KNC roofline (how far from peak the original code
//! ran) and reports the MXU-tile occupancy + VMEM residency of every
//! matmul the Pallas kernel executes (the TPU Hardware-Adaptation view —
//! interpret-mode wallclock is not a TPU proxy, DESIGN.md).

use crate::config::{ArchSpec, MachineConfig};
use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::nn::roofline;
use crate::report::{paper, Table};

pub fn run(opts: &ExpOptions) -> Result<String> {
    let machine = MachineConfig::xeon_phi_7120p();
    let mut out = String::new();

    // --- KNC roofline ---------------------------------------------------
    let mut t = Table::new(
        "KNC roofline — forward pass per image vs Table III measurement",
        &["arch", "roofline ms", "measured ms (Table III)", "efficiency"],
    );
    for arch in ArchSpec::paper_archs() {
        let idx = paper::arch_index(&arch.name).unwrap();
        let measured = paper::T_FPROP_S[idx];
        let rt = roofline::knc_roofline_time_s(&arch, &machine)?;
        t.row(vec![
            arch.name.clone(),
            format!("{:.4}", rt * 1e3),
            format!("{:.2}", measured * 1e3),
            format!("{:.2e}", rt / measured),
        ]);
    }
    out.push_str(&if opts.csv { t.to_csv() } else { t.render() });

    // --- per-layer intensity for the large CNN ---------------------------
    let mut t = Table::new(
        "per-layer roofline — large CNN",
        &["layer", "MFLOPs/img", "KB/img", "flop/byte", "attainable GF/s"],
    );
    for l in roofline::knc_roofline(&ArchSpec::large(), &machine)? {
        t.row(vec![
            l.name.clone(),
            format!("{:.2}", l.flops / 1e6),
            format!("{:.1}", l.bytes / 1e3),
            format!("{:.1}", l.intensity),
            format!("{:.0}", l.attainable_gflops),
        ]);
    }
    out.push_str(&if opts.csv { t.to_csv() } else { t.render() });

    // --- Pallas/MXU mapping ----------------------------------------------
    let mut t = Table::new(
        "Pallas kernel MXU mapping (batch 64 folded into M) — large CNN",
        &["matmul", "M", "K", "N", "MXU occupancy", "VMEM KiB/step"],
    );
    for m in roofline::mxu_mapping(&ArchSpec::large(), 64)? {
        t.row(vec![
            m.name.clone(),
            m.m.to_string(),
            m.k.to_string(),
            m.n.to_string(),
            format!("{:.3}", m.mxu_occupancy),
            format!("{:.0}", m.vmem_bytes as f64 / 1024.0),
        ]);
    }
    out.push_str(&if opts.csv { t.to_csv() } else { t.render() });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_three_sections() {
        let out = run(&ExpOptions::default()).unwrap();
        assert!(out.contains("KNC roofline"));
        assert!(out.contains("per-layer roofline"));
        assert!(out.contains("MXU mapping"));
        assert!(out.contains("conv6x6x100"));
    }
}
