//! Table X — predicted execution times beyond the hardware thread count.

use crate::config::{ArchSpec, RunConfig};
use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::perfmodel::{both_models, PerfModel};
use crate::report::{paper, Table};

pub fn run(opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Table X — predicted minutes for 480–3,840 threads (ours | paper)",
        &[
            "threads",
            "small a", "(paper)", "small b", "(paper)",
            "medium a", "(paper)", "medium b", "(paper)",
            "large a", "(paper)", "large b", "(paper)",
        ],
    );
    for (row, &p) in paper::TABLE10_THREADS.iter().enumerate() {
        let mut cells = vec![p.to_string()];
        for (col, arch) in ArchSpec::paper_archs().iter().enumerate() {
            let (a, b) = both_models(arch, opts.params)?;
            let run = RunConfig::paper_default(&arch.name, p);
            let ta = a.predict(&run)?.total_s / 60.0;
            let tb = b.predict(&run)?.total_s / 60.0;
            cells.push(format!("{ta:.1}"));
            cells.push(format!("{:.1}", paper::TABLE10_MINUTES[row][col * 2]));
            cells.push(format!("{tb:.1}"));
            cells.push(format!("{:.1}", paper::TABLE10_MINUTES[row][col * 2 + 1]));
        }
        t.row(cells);
    }
    Ok(if opts.csv { t.to_csv() } else { t.render() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_thread_rows() {
        let out = run(&ExpOptions::default()).unwrap();
        for p in ["480", "960", "1920", "3840"] {
            assert!(out.contains(p));
        }
    }

    #[test]
    fn paper_small_3840_value_present() {
        // small b @ 3840 = 4.6 minutes in the paper; our prediction is in
        // the same cell format.
        let out = run(&ExpOptions::default()).unwrap();
        assert!(out.contains("4.6"));
    }
}
