//! Table X — predicted execution times beyond the hardware thread count.
//!
//! The grid is a [`crate::sweep`] definition (all three architectures ×
//! the Table X thread counts × both strategies); this module formats the
//! results next to the paper's published cells.

use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::report::{paper, Table};
use crate::sweep::{GridSpec, SweepRunner};

/// The Table X sweep grid ([`GridSpec::table10`], prediction-only) with
/// the experiment's parameter provenance applied.
pub fn grid(opts: &ExpOptions) -> GridSpec {
    GridSpec { params: opts.params, ..GridSpec::table10() }
}

pub fn run(opts: &ExpOptions) -> Result<String> {
    let res = SweepRunner::new(0).run(&grid(opts))?;
    let mut t = Table::new(
        "Table X — predicted minutes for 480–3,840 threads (ours | paper)",
        &[
            "threads",
            "small a", "(paper)", "small b", "(paper)",
            "medium a", "(paper)", "medium b", "(paper)",
            "large a", "(paper)", "large b", "(paper)",
        ],
    );
    for (row, &p) in paper::TABLE10_THREADS.iter().enumerate() {
        let mut cells = vec![p.to_string()];
        for col in 0..res.grid.archs.len() {
            let ta = res.at(col, 0, 0, 0, row, 0).prediction.total_s / 60.0;
            let tb = res.at(col, 0, 0, 0, row, 1).prediction.total_s / 60.0;
            cells.push(format!("{ta:.1}"));
            cells.push(format!("{:.1}", paper::TABLE10_MINUTES[row][col * 2]));
            cells.push(format!("{tb:.1}"));
            cells.push(format!("{:.1}", paper::TABLE10_MINUTES[row][col * 2 + 1]));
        }
        t.row(cells);
    }
    Ok(if opts.csv { t.to_csv() } else { t.render() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_thread_rows() {
        let out = run(&ExpOptions::default()).unwrap();
        for p in ["480", "960", "1920", "3840"] {
            assert!(out.contains(p));
        }
    }

    #[test]
    fn paper_small_3840_value_present() {
        // small b @ 3840 = 4.6 minutes in the paper; our prediction is in
        // the same cell format.
        let out = run(&ExpOptions::default()).unwrap();
        assert!(out.contains("4.6"));
    }
}
