//! Table IV — measured and predicted memory contention, seconds.
//!
//! "Ours" is the micsim contention probe ([`crate::simulator::probe`]);
//! "paper" is the published Table IV (rows above 240 threads were
//! model-predicted in the paper too — starred here as there).

use crate::config::ArchSpec;
use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::report::{paper, table, Table};
use crate::simulator::{probe, SimConfig};

pub fn run(opts: &ExpOptions) -> Result<String> {
    let cfg = SimConfig::default();
    let archs = ArchSpec::paper_archs();
    let mut t = Table::new(
        "Table IV — memory contention [s] (ours = micsim probe | paper)",
        &[
            "# threads",
            "small ours", "small paper",
            "medium ours", "medium paper",
            "large ours", "large paper",
        ],
    );
    for (row, &p) in paper::CONTENTION_THREADS.iter().enumerate() {
        let star = if row >= paper::CONTENTION_PREDICTED_FROM { "*" } else { "" };
        let mut cells = vec![format!("{p}{star}")];
        for (col, arch) in archs.iter().enumerate() {
            let ours = probe::contention_probe(arch, p, &cfg)?;
            cells.push(table::sci(ours));
            cells.push(table::sci(paper::CONTENTION_S[row][col]));
        }
        t.row(cells);
    }
    Ok(if opts.csv { t.to_csv() } else { t.render() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_all_11_thread_rows() {
        let out = run(&ExpOptions::default()).unwrap();
        for p in ["1 ", "240", "3840*"] {
            assert!(out.contains(p), "{p}");
        }
        assert_eq!(out.lines().count(), 14); // title + header + rule + 11 rows
    }

    #[test]
    fn anchors_match_paper_at_240() {
        // The calibrated probe must agree with the paper at the anchor.
        let out = run(&ExpOptions::default()).unwrap();
        let row240: Vec<&str> = out
            .lines()
            .find(|l| l.trim_start().starts_with("240"))
            .unwrap()
            .split_whitespace()
            .collect();
        // small ours vs small paper
        assert_eq!(row240[2], row240[2]);
        let ours: f64 = row240[1].parse().unwrap();
        let paper_v: f64 = row240[2].parse().unwrap();
        assert!((ours - paper_v).abs() / paper_v < 0.02);
    }
}
