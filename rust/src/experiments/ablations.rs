//! Ablation studies over the simulator's design choices (DESIGN.md §7).
//!
//! Each ablation switches one micsim mechanism off (or sweeps it) and
//! reports how the models' average accuracy Δ responds — quantifying how
//! much each modelled effect contributes to the "measured" behaviour the
//! analytic models miss:
//!
//! * CPI ladder (SMT round-robin)  → flat ladder
//! * L2 sharing pressure           → α = 0
//! * Ring/tag-directory growth     → β = 0
//! * Channel contention            → traffic = 0 (floor only)
//! * exec/mem split sweep          → exec_fraction ∈ {0.6, 0.75, 0.9}

use crate::config::{ArchSpec, RunConfig};
use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::perfmodel::{accuracy, both_models};
use crate::report::Table;
use crate::simulator::SimConfig;

fn delta_pair(arch: &ArchSpec, cfg: &SimConfig, opts: &ExpOptions) -> Result<(f64, f64)> {
    let (a, b) = both_models(arch, opts.params)?;
    let threads = RunConfig::MEASURED_THREADS;
    Ok((
        accuracy::average_delta(arch, &a, &threads, cfg)?,
        accuracy::average_delta(arch, &b, &threads, cfg)?,
    ))
}

pub fn run(opts: &ExpOptions) -> Result<String> {
    let mut variants: Vec<(String, SimConfig)> = vec![
        ("baseline".into(), SimConfig::default()),
    ];
    {
        let mut c = SimConfig::default();
        c.machine.cpi_ladder = vec![1.0, 1.0, 1.0, 1.0];
        variants.push(("no CPI ladder".into(), c));
    }
    {
        let mut c = SimConfig::default();
        c.l2_alpha = 0.0;
        variants.push(("no L2 sharing".into(), c));
    }
    {
        let mut c = SimConfig::default();
        c.ring_beta = 0.0;
        variants.push(("no ring growth".into(), c));
    }
    for frac in [0.6, 0.9] {
        let mut c = SimConfig::default();
        c.exec_fraction = frac;
        variants.push((format!("exec fraction {frac}"), c));
    }

    let mut t = Table::new(
        "Ablations — average model accuracy Δ [%] per simulator variant",
        &[
            "variant",
            "small Δa", "small Δb",
            "medium Δa", "medium Δb",
            "large Δa", "large Δb",
        ],
    );
    for (name, cfg) in &variants {
        let mut cells = vec![name.clone()];
        for arch in ArchSpec::paper_archs() {
            let (da, db) = delta_pair(&arch, cfg, opts)?;
            cells.push(format!("{da:.1}"));
            cells.push(format!("{db:.1}"));
        }
        t.row(cells);
    }
    let mut out = if opts.csv { t.to_csv() } else { t.render() };
    if !opts.csv {
        out.push_str(
            "reading: each row disables/sweeps one micsim mechanism; the Δ \
             shift shows how much of the model-vs-machine gap that mechanism \
             explains.\n",
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_variants() {
        let out = run(&ExpOptions::default()).unwrap();
        for v in ["baseline", "no CPI ladder", "no L2 sharing", "no ring growth"] {
            assert!(out.contains(v), "{v}");
        }
    }

    #[test]
    fn disabling_cpi_ladder_changes_deltas() {
        // The CPI ladder is a first-order effect: removing it must move
        // the small-CNN Δ for strategy (b) noticeably.
        let opts = ExpOptions::default();
        let base = delta_pair(&ArchSpec::small(), &SimConfig::default(), &opts).unwrap();
        let mut flat = SimConfig::default();
        flat.machine.cpi_ladder = vec![1.0, 1.0, 1.0, 1.0];
        let ablated = delta_pair(&ArchSpec::small(), &flat, &opts).unwrap();
        assert!((base.1 - ablated.1).abs() > 1.0,
                "Δb insensitive to CPI ladder: {base:?} vs {ablated:?}");
    }
}
