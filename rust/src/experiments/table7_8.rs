//! Tables VII & VIII — operations per image (FProp / BProp).
//!
//! Prints the paper's counts next to our first-principles counts
//! ([`crate::nn::opcount`]) with the medium/small and large/medium ratios
//! the paper reports, making the approximation gap explicit (the paper
//! itself: "the constants are approximations … far from precise").

use crate::config::ArchSpec;
use crate::error::Result;
use crate::experiments::ExpOptions;
use crate::nn::opcount;
use crate::report::{paper, Table};

fn run_direction(opts: &ExpOptions, bprop: bool) -> Result<String> {
    let title = if bprop {
        "Table VIII — BProp operations / image (paper | computed)"
    } else {
        "Table VII — FProp operations / image (paper | computed)"
    };
    let mut t = Table::new(
        title,
        &[
            "arch",
            "max pool (paper)", "fully con. (paper)", "conv (paper)", "total (paper)",
            "total (computed)", "ratio to prev (paper)", "ratio (computed)",
        ],
    );
    let mut prev_paper: Option<f64> = None;
    let mut prev_ours: Option<f64> = None;
    for arch in ArchSpec::paper_archs() {
        let idx = paper::arch_index(&arch.name).unwrap();
        let p = if bprop { paper::BPROP_OPS[idx] } else { paper::FPROP_OPS[idx] };
        let paper_total = (p[0] + p[1] + p[2]) as f64;
        let ours = opcount::count(&arch)?;
        let ours_total = if bprop {
            ours.bprop.total() as f64
        } else {
            ours.fprop.total() as f64
        };
        let ratio_paper = prev_paper
            .map(|x| format!("{:.2}", paper_total / x))
            .unwrap_or_else(|| "-".into());
        let ratio_ours = prev_ours
            .map(|x| format!("{:.2}", ours_total / x))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            arch.name.clone(),
            format!("{}k", p[0] / 1000),
            format!("{}k", p[1] / 1000),
            format!("{}k", p[2] / 1000),
            format!("{}k", (p[0] + p[1] + p[2]) / 1000),
            format!("{}k", (ours_total as u64) / 1000),
            ratio_paper,
            ratio_ours,
        ]);
        prev_paper = Some(paper_total);
        prev_ours = Some(ours_total);
    }
    Ok(if opts.csv { t.to_csv() } else { t.render() })
}

pub fn run_fprop(opts: &ExpOptions) -> Result<String> {
    run_direction(opts, false)
}

pub fn run_bprop(opts: &ExpOptions) -> Result<String> {
    run_direction(opts, true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fprop_table_shows_paper_ratios() {
        let out = run_fprop(&ExpOptions::default()).unwrap();
        assert!(out.contains("9.64"), "{out}");
        assert!(out.contains("9.57"), "{out}");
        assert!(out.contains("58k"));
        assert!(out.contains("5349k") || out.contains("5,349"));
    }

    #[test]
    fn bprop_table_shows_paper_ratios() {
        let out = run_bprop(&ExpOptions::default()).unwrap();
        assert!(out.contains("11.68"));
        assert!(out.contains("11.96"));
        assert!(out.contains("524k"));
    }
}
