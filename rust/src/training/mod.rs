//! The Fig. 4 parallel training algorithm, over pluggable compute backends.
//!
//! The paper's scheme is model-replica data parallelism: `ns` network
//! instances (one per processing unit) each train on their image chunk
//! every epoch; validation and test run forward passes over the shards;
//! instance weights are combined between epochs. This module defines the
//! backend abstraction and the per-epoch bookkeeping; the actual parallel
//! drivers live in [`crate::coordinator`]:
//!
//! * [`crate::coordinator::pool::DataParallelTrainer`] — real
//!   `std::thread` workers, each owning a pure-Rust [`crate::engine`]
//!   network instance (the OpenMP-substitute path).
//! * [`crate::coordinator::leader::PjrtTrainer`] — the AOT path: the
//!   leader drives batched train steps through the compiled JAX/Pallas
//!   artifact ([`crate::runtime`]).

use crate::dataset::Dataset;
use crate::engine;
use crate::error::Result;
use crate::nn::Network;

/// A compute backend that can train and classify single images.
pub trait Backend: Send {
    fn train_image(&mut self, image: &[f32], label: usize, lr: f32) -> Result<f32>;
    fn classify(&self, image: &[f32], label: usize) -> Result<(usize, f32)>;
}

/// The pure-Rust engine backend: one owned network instance.
#[derive(Debug, Clone)]
pub struct EngineBackend {
    pub net: Network,
}

impl EngineBackend {
    pub fn new(net: Network) -> Self {
        EngineBackend { net }
    }
}

impl Backend for EngineBackend {
    fn train_image(&mut self, image: &[f32], label: usize, lr: f32) -> Result<f32> {
        engine::train_image(&mut self.net, image, label, lr)
    }

    fn classify(&self, image: &[f32], label: usize) -> Result<(usize, f32)> {
        engine::classify(&self.net, image, label)
    }
}

/// Statistics of one epoch.
#[derive(Debug, Clone, Copy, Default)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub val_loss: f64,
    pub val_accuracy: f64,
    pub test_accuracy: f64,
    /// Wall seconds for the epoch (train + val + test).
    pub wall_s: f64,
}

/// Full training report.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub epochs: Vec<EpochStats>,
    pub total_wall_s: f64,
    /// Images trained per wall second (training phase only).
    pub train_throughput: f64,
}

impl TrainReport {
    pub fn final_test_accuracy(&self) -> f64 {
        self.epochs.last().map(|e| e.test_accuracy).unwrap_or(0.0)
    }

    /// Loss curve as (epoch, train_loss) pairs.
    pub fn loss_curve(&self) -> Vec<(f64, f64)> {
        self.epochs
            .iter()
            .map(|e| (e.epoch as f64, e.train_loss))
            .collect()
    }

    /// True iff the train loss decreased from first to last epoch.
    pub fn converging(&self) -> bool {
        match (self.epochs.first(), self.epochs.last()) {
            (Some(a), Some(b)) if self.epochs.len() >= 2 => {
                b.train_loss < a.train_loss
            }
            _ => false,
        }
    }
}

/// Evaluate accuracy + mean loss of a backend over a dataset slice.
pub fn evaluate(
    backend: &dyn Backend,
    data: &Dataset,
    range: std::ops::Range<usize>,
) -> Result<(f64, f64)> {
    let mut correct = 0usize;
    let mut loss_sum = 0.0f64;
    let n = range.len().max(1);
    for idx in range {
        let (img, label) = data.sample(idx);
        let (pred, loss) = backend.classify(img, label)?;
        if pred == label {
            correct += 1;
        }
        loss_sum += loss as f64;
    }
    Ok((correct as f64 / n as f64, loss_sum / n as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::dataset::load_or_synth;

    #[test]
    fn engine_backend_trains() {
        let net = Network::new(ArchSpec::small(), 1).unwrap();
        let mut b = EngineBackend::new(net);
        let (data, _) = load_or_synth(None, 10, 2, 3);
        let (img, label) = data.sample(0);
        let l1 = b.train_image(img, label, 0.02).unwrap();
        for _ in 0..10 {
            b.train_image(img, label, 0.02).unwrap();
        }
        let (_, l2) = b.classify(img, label).unwrap();
        assert!(l2 < l1);
    }

    #[test]
    fn evaluate_counts_correctly() {
        let net = Network::new(ArchSpec::small(), 2).unwrap();
        let b = EngineBackend::new(net);
        let (data, _) = load_or_synth(None, 20, 2, 5);
        let (acc, loss) = evaluate(&b, &data, 0..20).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert!(loss > 0.0);
    }

    #[test]
    fn report_converging_logic() {
        let mut r = TrainReport::default();
        assert!(!r.converging());
        r.epochs.push(EpochStats { epoch: 0, train_loss: 2.0, ..Default::default() });
        r.epochs.push(EpochStats { epoch: 1, train_loss: 1.0, ..Default::default() });
        assert!(r.converging());
        assert_eq!(r.loss_curve(), vec![(0.0, 2.0), (1.0, 1.0)]);
    }
}
