//! The sweep worker pool: evaluate a scenario grid concurrently.
//!
//! Workers pull scenario indices from a shared atomic cursor (work
//! stealing over a pre-enumerated list) and write results into the slot
//! matching the scenario id. Because every evaluation is a pure function
//! of the scenario (the cache only memoizes deterministic values),
//! results are **bit-identical** regardless of worker count or
//! scheduling — asserted by `tests/sweep.rs`. Error paths are
//! deterministic too: the pool reports the lowest-id failure, which is
//! exactly the error a serial run of the same grid surfaces.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::lab::Store;
use crate::perfmodel::delta_pct;
use crate::sweep::cache::SweepCache;
use crate::sweep::grid::{GridSpec, Scenario};
use crate::sweep::summary::{ScenarioResult, SweepResults};

/// Concurrency policy (plus optional persistence) for one sweep.
#[derive(Debug, Clone)]
pub struct SweepRunner {
    /// Worker thread count (≥ 1; see [`SweepRunner::new`]).
    pub workers: usize,
    /// Optional [`crate::lab`] store attached to every run's cache
    /// ([`SweepRunner::with_store`]).
    store: Option<Arc<Store>>,
}

impl SweepRunner {
    /// `workers == 0` picks one worker per available CPU.
    pub fn new(workers: usize) -> SweepRunner {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            workers
        };
        SweepRunner { workers, store: None }
    }

    /// Single-threaded reference runner.
    pub fn serial() -> SweepRunner {
        SweepRunner { workers: 1, store: None }
    }

    /// Persist through a [`crate::lab`] store: every run's cache serves
    /// cells/params/measurements from it and writes computed values
    /// through. [`SweepResults::store`] then carries the run's disk
    /// hit/miss delta.
    pub fn with_store(mut self, store: Arc<Store>) -> SweepRunner {
        self.store = Some(store);
        self
    }

    /// Evaluate every scenario of `grid`.
    pub fn run(&self, grid: &GridSpec) -> Result<SweepResults> {
        self.run_with_cache(grid, SweepCache::new(), None)
    }

    /// Evaluate only shard `k` of `n` ([`GridSpec::shard`]). The results
    /// keep their parent-grid scenario ids in enumeration order, so
    /// per-shard outputs reassemble into the unsharded payload with
    /// [`crate::sweep::merge_shards`]. Shard runs are how independent
    /// worker processes split one grid over a shared [`crate::lab`]
    /// store (`repro sweep run --shard k/n`).
    pub fn run_shard(&self, grid: &GridSpec, k: usize, n: usize) -> Result<SweepResults> {
        self.run_with_cache(grid, SweepCache::new(), Some((k, n)))
    }

    /// Evaluate with an explicit **base** simulator configuration — the
    /// grid's machine axis and sim-variant overrides
    /// ([`crate::sweep::SimVariant`]) apply per scenario on top of it.
    /// Micsim memoization keys include the resolved config's
    /// [`crate::simulator::SimConfig::fingerprint`], so sweeps under
    /// different simulator settings never share stale measurements.
    pub fn run_with_sim(
        &self,
        grid: &GridSpec,
        sim: &crate::simulator::SimConfig,
    ) -> Result<SweepResults> {
        self.run_with_cache(grid, SweepCache::with_sim(sim.clone()), None)
    }

    fn run_with_cache(
        &self,
        grid: &GridSpec,
        mut cache: SweepCache,
        shard: Option<(usize, usize)>,
    ) -> Result<SweepResults> {
        grid.validate()?;
        if let Some(store) = &self.store {
            cache.set_store(Arc::clone(store));
        }
        // Store counters are store-lifetime monotonic; report this run's
        // delta (a coherent snapshot — see `Store::stats`).
        let store_before = self.store.as_ref().map(|s| s.stats());
        let scenarios = match shard {
            None => grid.enumerate(),
            Some((k, n)) => grid.shard(k, n)?,
        };
        let started = Instant::now();
        let results = if self.workers <= 1 || scenarios.len() < 2 {
            let mut out = Vec::with_capacity(scenarios.len());
            for scn in &scenarios {
                out.push(evaluate(grid, &cache, scn)?);
            }
            out
        } else {
            run_pool(grid, &cache, &scenarios, self.workers)?
        };
        Ok(SweepResults {
            grid: grid.clone(),
            results,
            cache: cache.stats(),
            store: self
                .store
                .as_ref()
                .zip(store_before)
                .map(|(s, before)| s.stats().since(&before)),
            wall_s: started.elapsed().as_secs_f64(),
            workers: self.workers,
        })
    }
}

/// Evaluate one scenario against the shared cache. A persisted cell
/// (store attached, entry present and — on measuring grids — carrying a
/// measurement) short-circuits the whole evaluation: no model build, no
/// cost model, no simulation.
pub(crate) fn evaluate(grid: &GridSpec, cache: &SweepCache, scn: &Scenario) -> Result<ScenarioResult> {
    if let Some((prediction, measured_s, delta)) = cache.stored_cell(grid, scn) {
        return Ok(ScenarioResult {
            scenario: scn.clone(),
            prediction,
            measured_s,
            delta_pct: delta,
        });
    }
    let model = cache.model(grid, scn)?;
    let prediction = model.predict(&scn.run())?;
    let (measured_s, delta) = if grid.measure {
        let m = cache.measured_s(grid, scn)?;
        (Some(m), Some(delta_pct(m, prediction.total_s)))
    } else {
        (None, None)
    };
    cache.put_cell(grid, scn, &prediction, measured_s, delta)?;
    Ok(ScenarioResult {
        scenario: scn.clone(),
        prediction,
        measured_s,
        delta_pct: delta,
    })
}

/// Fan the scenario list over `workers` scoped threads.
///
/// Error determinism: workers claim indices from the cursor in order, a
/// claimed index always evaluates to completion, and every failure is
/// recorded as `(scenario.id, error)` with the lowest id winning. Since
/// an index is only claimed after every lower index has been claimed,
/// the lowest failing scenario is always claimed before the stop flag
/// rises — so the pool returns exactly the error a serial run surfaces,
/// under any scheduling. The stop flag is checked *before* claiming, so
/// doomed iterations never burn the cursor.
fn run_pool(
    grid: &GridSpec,
    cache: &SweepCache,
    scenarios: &[Scenario],
    workers: usize,
) -> Result<Vec<ScenarioResult>> {
    let cursor = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let slots: Mutex<Vec<Option<ScenarioResult>>> =
        Mutex::new(scenarios.iter().map(|_| None).collect());
    let failure: Mutex<Option<(usize, Error)>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for _ in 0..workers.min(scenarios.len()) {
            scope.spawn(|| loop {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                let idx = cursor.fetch_add(1, Ordering::Relaxed);
                if idx >= scenarios.len() {
                    break;
                }
                match evaluate(grid, cache, &scenarios[idx]) {
                    Ok(result) => {
                        slots.lock().unwrap()[idx] = Some(result);
                    }
                    Err(e) => {
                        let id = scenarios[idx].id;
                        let mut held = failure.lock().unwrap();
                        match held.as_ref() {
                            Some((lowest, _)) if *lowest <= id => {}
                            _ => *held = Some((id, e)),
                        }
                        drop(held);
                        stop.store(true, Ordering::Release);
                        break;
                    }
                }
            });
        }
    });

    if let Some((_, e)) = failure.into_inner().unwrap() {
        return Err(e);
    }
    Ok(slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|slot| slot.expect("worker pool completed every scenario"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::sweep::grid::Strategy;

    #[test]
    fn serial_run_produces_one_result_per_scenario() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 15, 240],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        assert_eq!(res.len(), 6);
        for (i, r) in res.results.iter().enumerate() {
            assert_eq!(r.scenario.id, i);
            assert!(r.prediction.total_s.is_finite() && r.prediction.total_s > 0.0);
            assert!(r.measured_s.is_none());
        }
        // 6 model lookups over 2 distinct (arch, strategy, machine) keys.
        assert_eq!(res.cache.misses, 2);
        assert_eq!(res.cache.hits, 4);
    }

    #[test]
    fn measured_grid_reports_delta() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![61],
            strategies: vec![Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        let r = &res.results[0];
        let m = r.measured_s.unwrap();
        assert!(m > 0.0);
        let d = r.delta_pct.unwrap();
        assert!((0.0..100.0).contains(&d), "Δ = {d}");
    }

    #[test]
    fn run_with_sim_drives_the_measured_path() {
        use crate::simulator::SimConfig;
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![15],
            strategies: vec![Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let runner = SweepRunner::serial();
        let default = runner.run(&grid).unwrap();
        let mut slower = SimConfig::default();
        slower.fwd_cycles_per_op *= 2.0;
        let slow = runner.run_with_sim(&grid, &slower).unwrap();
        assert!(
            slow.results[0].measured_s.unwrap() > default.results[0].measured_s.unwrap()
        );
        // With the default config it is bit-identical to plain run().
        let same = runner.run_with_sim(&grid, &SimConfig::default()).unwrap();
        assert_eq!(
            same.results[0].measured_s.unwrap().to_bits(),
            default.results[0].measured_s.unwrap().to_bits()
        );
    }

    #[test]
    fn invalid_grid_is_rejected_before_spawning() {
        let grid = GridSpec { threads: vec![], ..GridSpec::default() };
        assert!(SweepRunner::new(4).run(&grid).is_err());
    }

    #[test]
    fn worker_error_surfaces_not_panics() {
        // A custom arch under ParamSource::Paper has no Table VII/VIII
        // entry → model construction fails; the pool must report it.
        let mut weird = ArchSpec::small();
        weird.name = "not-in-the-paper".into();
        let grid = GridSpec {
            archs: vec![weird],
            threads: vec![1, 2, 3, 4],
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        };
        let err = SweepRunner::new(2).run(&grid);
        assert!(err.is_err());
    }

    #[test]
    fn parallel_error_matches_serial_under_multiple_failures() {
        // Regression: the pool used to surface whichever worker's error
        // won the mutex race. With several distinct failing scenarios on
        // the grid, the parallel run must still report the error of the
        // lowest-id failure — the one the serial reference stops at.
        let mut bad_z = ArchSpec::small();
        bad_z.name = "zzz-not-in-the-paper".into();
        let mut bad_a = ArchSpec::medium();
        bad_a.name = "aaa-not-in-the-paper".into();
        let grid = GridSpec {
            // A healthy arch first, then two distinct failing ones: the
            // lowest failing id belongs to bad_z, not to whichever fails
            // fastest.
            archs: vec![ArchSpec::small(), bad_z, bad_a],
            threads: vec![1, 2, 3, 4],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        let serial = SweepRunner::serial().run(&grid).unwrap_err().to_string();
        assert!(serial.contains("zzz-not-in-the-paper"), "{serial}");
        for workers in [2, 4, 8] {
            for _ in 0..5 {
                let parallel =
                    SweepRunner::new(workers).run(&grid).unwrap_err().to_string();
                assert_eq!(parallel, serial, "{workers} workers");
            }
        }
    }

    #[test]
    fn shard_runs_carry_parent_ids_and_cover_the_grid() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 15, 61, 240],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        let full = SweepRunner::serial().run(&grid).unwrap();
        let mut seen = vec![false; grid.len()];
        for k in 0..3 {
            let shard = SweepRunner::serial().run_shard(&grid, k, 3).unwrap();
            for r in &shard.results {
                assert_eq!(r.scenario.id % 3, k);
                assert!(!seen[r.scenario.id], "id {} twice", r.scenario.id);
                seen[r.scenario.id] = true;
                // Bit-identical to the unsharded evaluation of the same id.
                let reference = &full.results[r.scenario.id];
                assert_eq!(r.scenario, reference.scenario);
                assert_eq!(
                    r.prediction.total_s.to_bits(),
                    reference.prediction.total_s.to_bits()
                );
            }
        }
        assert!(seen.iter().all(|&s| s), "shards must cover every scenario");
        assert!(SweepRunner::serial().run_shard(&grid, 3, 3).is_err());
    }
}
