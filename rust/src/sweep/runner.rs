//! The sweep worker pool: evaluate a scenario grid concurrently.
//!
//! Workers pull claims from a shared atomic cursor over a **cost-sorted
//! claim order** — heaviest cells first (a longest-processing-time
//! heuristic over images × epochs × fidelity, see [`claim_weight`]) —
//! and write results into the slot matching the scenario id, so output
//! order never depends on claim order. Because every evaluation is a
//! pure function of the scenario (the cache only memoizes deterministic
//! values, single-flight), results are **bit-identical** regardless of
//! worker count, claim order, or scheduling — asserted by
//! `tests/sweep.rs`. Error paths are deterministic too: on any failure
//! the pool re-derives the error a serial run of the same grid
//! surfaces (the lowest-id failure) by walking the scenarios in id
//! order over the now-warm cache — see [`canonical_error`].

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::lab::Store;
use crate::perfmodel::delta_pct;
use crate::simulator::Fidelity;
use crate::sweep::cache::SweepCache;
use crate::sweep::grid::{GridSpec, Scenario};
use crate::sweep::summary::{ScenarioResult, SweepResults};

/// Concurrency policy (plus optional persistence) for one sweep.
#[derive(Debug, Clone)]
pub struct SweepRunner {
    /// Worker thread count (≥ 1; see [`SweepRunner::new`]).
    pub workers: usize,
    /// Optional [`crate::lab`] store attached to every run's cache
    /// ([`SweepRunner::with_store`]).
    store: Option<Arc<Store>>,
}

impl SweepRunner {
    /// `workers == 0` picks one worker per available CPU.
    pub fn new(workers: usize) -> SweepRunner {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            workers
        };
        SweepRunner { workers, store: None }
    }

    /// Single-threaded reference runner.
    pub fn serial() -> SweepRunner {
        SweepRunner { workers: 1, store: None }
    }

    /// Persist through a [`crate::lab`] store: every run's cache serves
    /// cells/params/measurements from it and writes computed values
    /// through. [`SweepResults::store`] then carries the run's disk
    /// hit/miss delta.
    pub fn with_store(mut self, store: Arc<Store>) -> SweepRunner {
        self.store = Some(store);
        self
    }

    /// Evaluate every scenario of `grid`.
    pub fn run(&self, grid: &GridSpec) -> Result<SweepResults> {
        self.run_with_cache(grid, SweepCache::new(), None)
    }

    /// Evaluate only shard `k` of `n` ([`GridSpec::shard`]). The results
    /// keep their parent-grid scenario ids in enumeration order, so
    /// per-shard outputs reassemble into the unsharded payload with
    /// [`crate::sweep::merge_shards`]. Shard runs are how independent
    /// worker processes split one grid over a shared [`crate::lab`]
    /// store (`repro sweep run --shard k/n`).
    pub fn run_shard(&self, grid: &GridSpec, k: usize, n: usize) -> Result<SweepResults> {
        self.run_with_cache(grid, SweepCache::new(), Some((k, n)))
    }

    /// Evaluate with an explicit **base** simulator configuration — the
    /// grid's machine axis and sim-variant overrides
    /// ([`crate::sweep::SimVariant`]) apply per scenario on top of it.
    /// Micsim memoization keys include the resolved config's
    /// [`crate::simulator::SimConfig::fingerprint`], so sweeps under
    /// different simulator settings never share stale measurements.
    pub fn run_with_sim(
        &self,
        grid: &GridSpec,
        sim: &crate::simulator::SimConfig,
    ) -> Result<SweepResults> {
        self.run_with_cache(grid, SweepCache::with_sim(sim.clone()), None)
    }

    fn run_with_cache(
        &self,
        grid: &GridSpec,
        mut cache: SweepCache,
        shard: Option<(usize, usize)>,
    ) -> Result<SweepResults> {
        grid.validate()?;
        if let Some(store) = &self.store {
            cache.set_store(Arc::clone(store));
        }
        // Store counters are store-lifetime monotonic; report this run's
        // delta (a coherent snapshot — see `Store::stats`).
        let store_before = self.store.as_ref().map(|s| s.stats());
        let scenarios = match shard {
            None => grid.enumerate(),
            Some((k, n)) => grid.shard(k, n)?,
        };
        let started = Instant::now();
        // `workers` below is the *effective* count — what actually ran,
        // not what was requested: the serial fallback is 1 worker and
        // the pool never spawns more threads than scenarios.
        let (results, workers) = if self.workers <= 1 || scenarios.len() < 2 {
            let mut out = Vec::with_capacity(scenarios.len());
            for scn in &scenarios {
                out.push(evaluate(grid, &cache, scn)?);
            }
            (out, 1)
        } else {
            let workers = self.workers.min(scenarios.len());
            (run_pool(grid, &cache, &scenarios, workers)?, workers)
        };
        Ok(SweepResults {
            grid: grid.clone(),
            results,
            cache: cache.stats(),
            store: self
                .store
                .as_ref()
                .zip(store_before)
                .map(|(s, before)| s.stats().since(&before)),
            wall_s: started.elapsed().as_secs_f64(),
            workers,
        })
    }
}

/// Evaluate one scenario against the shared cache. A persisted cell
/// (store attached, entry present and — on measuring grids — carrying a
/// measurement) short-circuits the whole evaluation: no model build, no
/// cost model, no simulation.
pub(crate) fn evaluate(grid: &GridSpec, cache: &SweepCache, scn: &Scenario) -> Result<ScenarioResult> {
    if let Some((prediction, measured_s, delta)) = cache.stored_cell(grid, scn) {
        return Ok(ScenarioResult {
            scenario: scn.clone(),
            prediction,
            measured_s,
            delta_pct: delta,
        });
    }
    let model = cache.model(grid, scn)?;
    let prediction = model.predict(&scn.run())?;
    let (measured_s, delta) = if grid.measure {
        let m = cache.measured_s(grid, scn)?;
        (Some(m), Some(delta_pct(m, prediction.total_s)))
    } else {
        (None, None)
    };
    cache.put_cell(grid, scn, &prediction, measured_s, delta)?;
    Ok(ScenarioResult {
        scenario: scn.clone(),
        prediction,
        measured_s,
        delta_pct: delta,
    })
}

/// Relative evaluation cost of one scenario, used only to **order**
/// worker claims (heaviest first — the classic longest-processing-time
/// heuristic, which keeps the pool's tail short when a grid mixes
/// cheap chunked cells with expensive per-image micsim cells). On
/// measuring grids the micsim run dominates: per-image fidelity costs
/// O(images · epochs) simulated events where chunked fidelity is
/// near-constant per epoch, so per-image cells are weighted far
/// heavier. The weight never affects results or errors — output is
/// slotted by scenario id and the error contract is re-derived
/// serially ([`canonical_error`]).
fn claim_weight(grid: &GridSpec, cache: &SweepCache, scn: &Scenario) -> f64 {
    let images = (scn.train_images + scn.test_images) as f64;
    let fidelity_w = if grid.measure {
        // `resolved_sim` is memoized per (machine, sim) axis pair, so
        // this probe is free for every scenario after the first.
        match cache.resolved_sim(grid, scn).0.fidelity {
            Fidelity::PerImage => 64.0,
            Fidelity::Chunked => 8.0,
        }
    } else {
        1.0
    };
    images * scn.epochs as f64 * fidelity_w
}

/// Re-derive the error a serial run of this grid surfaces: the failure
/// with the lowest scenario id. Heavy-first claiming means the failure
/// the pool recorded is whichever doomed cell happened to be claimed,
/// not necessarily the lowest-id one — so walk the scenarios in id
/// order over the now-warm cache (completed cells are memo hits; the
/// failing computation re-runs because errors are never cached) and
/// return the first error. Evaluations are deterministic, so the walk
/// must fail; `recorded` is a defensive fallback only.
fn canonical_error(
    grid: &GridSpec,
    cache: &SweepCache,
    scenarios: &[Scenario],
    recorded: Error,
) -> Error {
    for scn in scenarios {
        if let Err(e) = evaluate(grid, cache, scn) {
            return e;
        }
    }
    recorded
}

/// Fan the scenario list over `workers` scoped threads.
///
/// Workers claim from a shared cursor over the cost-sorted order
/// ([`claim_weight`], heaviest first, ties by ascending index so the
/// order is deterministic). Results are slotted by the original index,
/// so output order is independent of claim order. On failure the pool
/// raises the stop flag (checked *before* claiming, so doomed
/// iterations never burn the cursor), then reports the canonical
/// serial error via [`canonical_error`] — under any scheduling, the
/// pool returns exactly the error a serial run surfaces.
fn run_pool(
    grid: &GridSpec,
    cache: &SweepCache,
    scenarios: &[Scenario],
    workers: usize,
) -> Result<Vec<ScenarioResult>> {
    let weights: Vec<f64> =
        scenarios.iter().map(|scn| claim_weight(grid, cache, scn)).collect();
    let mut order: Vec<usize> = (0..scenarios.len()).collect();
    order.sort_by(|&a, &b| {
        weights[b]
            .partial_cmp(&weights[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });

    let cursor = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let slots: Mutex<Vec<Option<ScenarioResult>>> =
        Mutex::new(scenarios.iter().map(|_| None).collect());
    let failure: Mutex<Option<Error>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for _ in 0..workers.min(scenarios.len()) {
            scope.spawn(|| loop {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                let at = cursor.fetch_add(1, Ordering::Relaxed);
                if at >= order.len() {
                    break;
                }
                let idx = order[at];
                match evaluate(grid, cache, &scenarios[idx]) {
                    Ok(result) => {
                        slots.lock().unwrap()[idx] = Some(result);
                    }
                    Err(e) => {
                        let mut held = failure.lock().unwrap();
                        if held.is_none() {
                            *held = Some(e);
                        }
                        drop(held);
                        stop.store(true, Ordering::Release);
                        break;
                    }
                }
            });
        }
    });

    if let Some(recorded) = failure.into_inner().unwrap() {
        return Err(canonical_error(grid, cache, scenarios, recorded));
    }
    Ok(slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|slot| slot.expect("worker pool completed every scenario"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::sweep::grid::Strategy;

    #[test]
    fn serial_run_produces_one_result_per_scenario() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 15, 240],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        assert_eq!(res.len(), 6);
        for (i, r) in res.results.iter().enumerate() {
            assert_eq!(r.scenario.id, i);
            assert!(r.prediction.total_s.is_finite() && r.prediction.total_s > 0.0);
            assert!(r.measured_s.is_none());
        }
        // 6 model lookups over 2 distinct (arch, strategy, machine) keys.
        assert_eq!(res.cache.misses, 2);
        assert_eq!(res.cache.hits, 4);
    }

    #[test]
    fn reported_workers_reflect_what_actually_ran() {
        // Regression: a single-scenario grid under `--workers 8` used to
        // report `workers: 8` even though the serial fallback ran it on
        // one thread. The telemetry must report the effective count.
        let tiny = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![240],
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        };
        let res = SweepRunner::new(8).run(&tiny).unwrap();
        assert_eq!(res.workers, 1, "serial fallback must report 1 worker");
        // The pool caps spawned threads at the scenario count.
        let pair = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 240],
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        };
        let res = SweepRunner::new(8).run(&pair).unwrap();
        assert_eq!(res.workers, 2, "pool must report min(workers, scenarios)");
        let res = SweepRunner::new(2).run(&pair).unwrap();
        assert_eq!(res.workers, 2);
        assert_eq!(SweepRunner::serial().run(&pair).unwrap().workers, 1);
    }

    #[test]
    fn claim_weights_order_heavy_cells_first() {
        // The cost-sorted claim order ranks heavy measured cells ahead
        // of cheap predict-only ones, with ties broken by ascending
        // index so the order stays deterministic.
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 61, 240],
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        let weights: Vec<f64> = scenarios
            .iter()
            .map(|scn| claim_weight(&grid, &cache, scn))
            .collect();
        // Same workload everywhere on this grid → all weights equal →
        // the tie-break keeps the order the identity permutation.
        assert!(weights.windows(2).all(|w| w[0] == w[1]));
        assert!(weights[0] > 0.0);
        // A bigger workload must weigh more than a smaller one.
        let mut big = scenarios[0].clone();
        big.train_images *= 10;
        assert!(claim_weight(&grid, &cache, &big) > weights[0]);
        // Non-measuring grids weigh by workload only.
        let predict_only = GridSpec { measure: false, ..grid.clone() };
        let w = claim_weight(&predict_only, &cache, &scenarios[0]);
        assert!(w > 0.0 && w < weights[0]);
    }

    #[test]
    fn measured_grid_reports_delta() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![61],
            strategies: vec![Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        let r = &res.results[0];
        let m = r.measured_s.unwrap();
        assert!(m > 0.0);
        let d = r.delta_pct.unwrap();
        assert!((0.0..100.0).contains(&d), "Δ = {d}");
    }

    #[test]
    fn run_with_sim_drives_the_measured_path() {
        use crate::simulator::SimConfig;
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![15],
            strategies: vec![Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let runner = SweepRunner::serial();
        let default = runner.run(&grid).unwrap();
        let mut slower = SimConfig::default();
        slower.fwd_cycles_per_op *= 2.0;
        let slow = runner.run_with_sim(&grid, &slower).unwrap();
        assert!(
            slow.results[0].measured_s.unwrap() > default.results[0].measured_s.unwrap()
        );
        // With the default config it is bit-identical to plain run().
        let same = runner.run_with_sim(&grid, &SimConfig::default()).unwrap();
        assert_eq!(
            same.results[0].measured_s.unwrap().to_bits(),
            default.results[0].measured_s.unwrap().to_bits()
        );
    }

    #[test]
    fn invalid_grid_is_rejected_before_spawning() {
        let grid = GridSpec { threads: vec![], ..GridSpec::default() };
        assert!(SweepRunner::new(4).run(&grid).is_err());
    }

    #[test]
    fn worker_error_surfaces_not_panics() {
        // A custom arch under ParamSource::Paper has no Table VII/VIII
        // entry → model construction fails; the pool must report it.
        let mut weird = ArchSpec::small();
        weird.name = "not-in-the-paper".into();
        let grid = GridSpec {
            archs: vec![weird],
            threads: vec![1, 2, 3, 4],
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        };
        let err = SweepRunner::new(2).run(&grid);
        assert!(err.is_err());
    }

    #[test]
    fn parallel_error_matches_serial_under_multiple_failures() {
        // Regression: the pool used to surface whichever worker's error
        // won the mutex race. With several distinct failing scenarios on
        // the grid, the parallel run must still report the error of the
        // lowest-id failure — the one the serial reference stops at.
        let mut bad_z = ArchSpec::small();
        bad_z.name = "zzz-not-in-the-paper".into();
        let mut bad_a = ArchSpec::medium();
        bad_a.name = "aaa-not-in-the-paper".into();
        let grid = GridSpec {
            // A healthy arch first, then two distinct failing ones: the
            // lowest failing id belongs to bad_z, not to whichever fails
            // fastest.
            archs: vec![ArchSpec::small(), bad_z, bad_a],
            threads: vec![1, 2, 3, 4],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        let serial = SweepRunner::serial().run(&grid).unwrap_err().to_string();
        assert!(serial.contains("zzz-not-in-the-paper"), "{serial}");
        for workers in [2, 4, 8] {
            for _ in 0..5 {
                let parallel =
                    SweepRunner::new(workers).run(&grid).unwrap_err().to_string();
                assert_eq!(parallel, serial, "{workers} workers");
            }
        }
    }

    #[test]
    fn shard_runs_carry_parent_ids_and_cover_the_grid() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 15, 61, 240],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        let full = SweepRunner::serial().run(&grid).unwrap();
        let mut seen = vec![false; grid.len()];
        for k in 0..3 {
            let shard = SweepRunner::serial().run_shard(&grid, k, 3).unwrap();
            for r in &shard.results {
                assert_eq!(r.scenario.id % 3, k);
                assert!(!seen[r.scenario.id], "id {} twice", r.scenario.id);
                seen[r.scenario.id] = true;
                // Bit-identical to the unsharded evaluation of the same id.
                let reference = &full.results[r.scenario.id];
                assert_eq!(r.scenario, reference.scenario);
                assert_eq!(
                    r.prediction.total_s.to_bits(),
                    reference.prediction.total_s.to_bits()
                );
            }
        }
        assert!(seen.iter().all(|&s| s), "shards must cover every scenario");
        assert!(SweepRunner::serial().run_shard(&grid, 3, 3).is_err());
    }
}
