//! The sweep worker pool: evaluate a scenario grid concurrently.
//!
//! Workers pull scenario indices from a shared atomic cursor (work
//! stealing over a pre-enumerated list) and write results into the slot
//! matching the scenario id. Because every evaluation is a pure function
//! of the scenario (the cache only memoizes deterministic values),
//! results are **bit-identical** regardless of worker count or
//! scheduling — asserted by `tests/sweep.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::lab::Store;
use crate::perfmodel::delta_pct;
use crate::sweep::cache::SweepCache;
use crate::sweep::grid::{GridSpec, Scenario};
use crate::sweep::summary::{ScenarioResult, SweepResults};

/// Concurrency policy (plus optional persistence) for one sweep.
#[derive(Debug, Clone)]
pub struct SweepRunner {
    /// Worker thread count (≥ 1; see [`SweepRunner::new`]).
    pub workers: usize,
    /// Optional [`crate::lab`] store attached to every run's cache
    /// ([`SweepRunner::with_store`]).
    store: Option<Arc<Store>>,
}

impl SweepRunner {
    /// `workers == 0` picks one worker per available CPU.
    pub fn new(workers: usize) -> SweepRunner {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            workers
        };
        SweepRunner { workers, store: None }
    }

    /// Single-threaded reference runner.
    pub fn serial() -> SweepRunner {
        SweepRunner { workers: 1, store: None }
    }

    /// Persist through a [`crate::lab`] store: every run's cache serves
    /// cells/params/measurements from it and writes computed values
    /// through. [`SweepResults::store`] then carries the run's disk
    /// hit/miss delta.
    pub fn with_store(mut self, store: Arc<Store>) -> SweepRunner {
        self.store = Some(store);
        self
    }

    /// Evaluate every scenario of `grid`.
    pub fn run(&self, grid: &GridSpec) -> Result<SweepResults> {
        self.run_with_cache(grid, SweepCache::new())
    }

    /// Evaluate with an explicit **base** simulator configuration — the
    /// grid's machine axis and sim-variant overrides
    /// ([`crate::sweep::SimVariant`]) apply per scenario on top of it.
    /// Micsim memoization keys include the resolved config's
    /// [`crate::simulator::SimConfig::fingerprint`], so sweeps under
    /// different simulator settings never share stale measurements.
    pub fn run_with_sim(
        &self,
        grid: &GridSpec,
        sim: &crate::simulator::SimConfig,
    ) -> Result<SweepResults> {
        self.run_with_cache(grid, SweepCache::with_sim(sim.clone()))
    }

    fn run_with_cache(&self, grid: &GridSpec, mut cache: SweepCache) -> Result<SweepResults> {
        grid.validate()?;
        if let Some(store) = &self.store {
            cache.set_store(Arc::clone(store));
        }
        // Store counters are store-lifetime monotonic; report this run's
        // delta.
        let store_before = self.store.as_ref().map(|s| s.stats());
        let scenarios = grid.enumerate();
        let started = Instant::now();
        let results = if self.workers <= 1 || scenarios.len() < 2 {
            let mut out = Vec::with_capacity(scenarios.len());
            for scn in &scenarios {
                out.push(evaluate(grid, &cache, scn)?);
            }
            out
        } else {
            run_pool(grid, &cache, &scenarios, self.workers)?
        };
        Ok(SweepResults {
            grid: grid.clone(),
            results,
            cache: cache.stats(),
            store: self
                .store
                .as_ref()
                .zip(store_before)
                .map(|(s, before)| s.stats().since(&before)),
            wall_s: started.elapsed().as_secs_f64(),
            workers: self.workers,
        })
    }
}

/// Evaluate one scenario against the shared cache. A persisted cell
/// (store attached, entry present and — on measuring grids — carrying a
/// measurement) short-circuits the whole evaluation: no model build, no
/// cost model, no simulation.
fn evaluate(grid: &GridSpec, cache: &SweepCache, scn: &Scenario) -> Result<ScenarioResult> {
    if let Some((prediction, measured_s, delta)) = cache.stored_cell(grid, scn) {
        return Ok(ScenarioResult {
            scenario: scn.clone(),
            prediction,
            measured_s,
            delta_pct: delta,
        });
    }
    let model = cache.model(grid, scn)?;
    let prediction = model.predict(&scn.run())?;
    let (measured_s, delta) = if grid.measure {
        let m = cache.measured_s(grid, scn)?;
        (Some(m), Some(delta_pct(m, prediction.total_s)))
    } else {
        (None, None)
    };
    cache.put_cell(grid, scn, &prediction, measured_s, delta)?;
    Ok(ScenarioResult {
        scenario: scn.clone(),
        prediction,
        measured_s,
        delta_pct: delta,
    })
}

/// Fan the scenario list over `workers` scoped threads.
fn run_pool(
    grid: &GridSpec,
    cache: &SweepCache,
    scenarios: &[Scenario],
    workers: usize,
) -> Result<Vec<ScenarioResult>> {
    let cursor = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<ScenarioResult>>> =
        Mutex::new(scenarios.iter().map(|_| None).collect());
    let failure: Mutex<Option<Error>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for _ in 0..workers.min(scenarios.len()) {
            scope.spawn(|| loop {
                let idx = cursor.fetch_add(1, Ordering::Relaxed);
                if idx >= scenarios.len() {
                    break;
                }
                if failure.lock().unwrap().is_some() {
                    break;
                }
                match evaluate(grid, cache, &scenarios[idx]) {
                    Ok(result) => {
                        slots.lock().unwrap()[idx] = Some(result);
                    }
                    Err(e) => {
                        failure.lock().unwrap().get_or_insert(e);
                        break;
                    }
                }
            });
        }
    });

    if let Some(e) = failure.into_inner().unwrap() {
        return Err(e);
    }
    Ok(slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|slot| slot.expect("worker pool completed every scenario"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::sweep::grid::Strategy;

    #[test]
    fn serial_run_produces_one_result_per_scenario() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 15, 240],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        assert_eq!(res.len(), 6);
        for (i, r) in res.results.iter().enumerate() {
            assert_eq!(r.scenario.id, i);
            assert!(r.prediction.total_s.is_finite() && r.prediction.total_s > 0.0);
            assert!(r.measured_s.is_none());
        }
        // 6 model lookups over 2 distinct (arch, strategy, machine) keys.
        assert_eq!(res.cache.misses, 2);
        assert_eq!(res.cache.hits, 4);
    }

    #[test]
    fn measured_grid_reports_delta() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![61],
            strategies: vec![Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        let r = &res.results[0];
        let m = r.measured_s.unwrap();
        assert!(m > 0.0);
        let d = r.delta_pct.unwrap();
        assert!((0.0..100.0).contains(&d), "Δ = {d}");
    }

    #[test]
    fn run_with_sim_drives_the_measured_path() {
        use crate::simulator::SimConfig;
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![15],
            strategies: vec![Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let runner = SweepRunner::serial();
        let default = runner.run(&grid).unwrap();
        let mut slower = SimConfig::default();
        slower.fwd_cycles_per_op *= 2.0;
        let slow = runner.run_with_sim(&grid, &slower).unwrap();
        assert!(
            slow.results[0].measured_s.unwrap() > default.results[0].measured_s.unwrap()
        );
        // With the default config it is bit-identical to plain run().
        let same = runner.run_with_sim(&grid, &SimConfig::default()).unwrap();
        assert_eq!(
            same.results[0].measured_s.unwrap().to_bits(),
            default.results[0].measured_s.unwrap().to_bits()
        );
    }

    #[test]
    fn invalid_grid_is_rejected_before_spawning() {
        let grid = GridSpec { threads: vec![], ..GridSpec::default() };
        assert!(SweepRunner::new(4).run(&grid).is_err());
    }

    #[test]
    fn worker_error_surfaces_not_panics() {
        // A custom arch under ParamSource::Paper has no Table VII/VIII
        // entry → model construction fails; the pool must report it.
        let mut weird = ArchSpec::small();
        weird.name = "not-in-the-paper".into();
        let grid = GridSpec {
            archs: vec![weird],
            threads: vec![1, 2, 3, 4],
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        };
        let err = SweepRunner::new(2).run(&grid);
        assert!(err.is_err());
    }
}
