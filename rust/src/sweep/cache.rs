//! Memoization shared across the scenarios of one sweep.
//!
//! The expensive sub-computations of a scenario depend on far fewer axes
//! than the scenario itself:
//!
//! * model construction (op-count resolution via [`crate::nn::opcount`],
//!   probe measurement, contention calibration) depends only on
//!   (architecture, strategy, resolved simulator configuration) — not on
//!   threads/images/epochs;
//! * the micsim cost model ([`crate::simulator::cost`]) depends only on
//!   (architecture, resolved simulator configuration) — and is held as a
//!   shared [`CostTable`], so the per-occupancy-class cost terms of a
//!   thread ladder are computed once per (arch, fingerprint) across all
//!   of its points and workers (the ladder fast path, docs/PERF.md);
//! * a micsim "measurement" depends on the workload but not the strategy.
//!
//! "Resolved" means the base [`SimConfig`] with the scenario's machine
//! axis substituted and its sim-axis variant applied
//! ([`GridSpec::resolved_sim`]); entries are keyed by
//! [`SimConfig::fingerprint`], so ablation sweeps over simulator
//! constants share every entry within a variant and can never leak
//! values across variants.
//!
//! The cache keys each by exactly its inputs, so a 10k-scenario sweep
//! builds each model once and spends the rest of its time in the cheap
//! closed-form `predict`. Every map is a single-flight
//! [`crate::util::memo::Memo`]: concurrent misses on one key compute
//! **exactly once** — latecomers block on the in-flight computation and
//! share its result instead of redoing a probe pass or residual fit.
//! That makes [`CacheStats`] exact: `misses` equals the number of
//! distinct computed keys on any error-free run, whatever the worker
//! count, and `coalesced` counts the duplicate computations the
//! single-flight layer absorbed (always 0 in serial runs).

use std::sync::Arc;

use crate::calibration::Calibration;
use crate::error::Result;
use crate::lab::{self, Store};
use crate::perfmodel::{ParamSource, PerfModel, Prediction};
use crate::simulator::{simulate_training_shared, CostModel, CostTable, SimConfig};
use crate::sweep::grid::{GridSpec, Scenario, Strategy};
use crate::util::json::Json;
use crate::util::memo::Memo;

/// A model usable from any sweep worker.
pub type SharedModel = Arc<dyn PerfModel + Send + Sync>;

/// Hit/miss counters for one sweep run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a memoized entry.
    pub hits: u64,
    /// Lookups that computed. Exact under the single-flight memo: equal
    /// to the number of distinct computed keys on any error-free run,
    /// for any worker count.
    pub misses: u64,
    /// Lookups that blocked on another worker's in-flight computation
    /// instead of duplicating it — the waits the single-flight layer
    /// turned into shared results. Always 0 in serial runs. Counted
    /// *inside* `hits`/`misses` (a coalesced lookup still resolves as
    /// one or the other), so [`CacheStats::lookups`] stays
    /// `hits + misses`.
    pub coalesced: u64,
}

impl CacheStats {
    /// Total counted lookups.
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the memo (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// Sum with another run's counters — how
    /// [`crate::sweep::merge_shards`] folds per-shard memo traffic into
    /// the merged run's accounting.
    pub fn merged(&self, other: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            coalesced: self.coalesced + other.coalesced,
        }
    }
}

/// The per-sweep memo: models, cost tables, and micsim measurements.
///
/// Every entry that depends on the simulator is keyed by the
/// [`SimConfig::fingerprint`] of the scenario's **resolved** simulator
/// configuration — the cache's base `sim` with the scenario's machine
/// substituted and its sim-axis variant ([`crate::sweep::SimVariant`])
/// applied on top. Cells sharing a (machine, variant) pair therefore
/// share cost-table and measurement entries, while [`SweepCache::set_sim`]
/// and differing variants can never serve each other stale values — a
/// changed simulator is a changed key.
pub struct SweepCache {
    /// Base simulator configuration for the measured path; per scenario
    /// the grid's machine axis and sim-variant overrides apply on top.
    sim: SimConfig,
    /// Resolved (config, fingerprint) per (machine, sim) axis pair —
    /// internal plumbing, not counted in the hit/miss telemetry.
    resolved: Memo<(usize, usize), (Arc<SimConfig>, u64)>,
    /// One [`Calibration`] per parameter source (grids carry one source,
    /// but the cache does not assume it): parameter resolution is
    /// memoized per (arch, fingerprint), so the (a) and (b) models of a
    /// cell share one probe/fit pass — internal plumbing, like
    /// `resolved`, not counted in the hit/miss telemetry.
    calibrations: Memo<u8, Arc<Calibration>>,
    models: Memo<(String, Strategy, u64), SharedModel>,
    costs: Memo<(String, u64), Arc<CostTable>>,
    measured: Memo<(String, usize, usize, usize, usize, u64), f64>,
    /// Optional disk layer ([`crate::lab`]): evaluated cells, resolved
    /// parameters and measurements are served from it on in-process
    /// misses and written through on compute. Disk traffic is counted in
    /// the store's own [`lab::StoreStats`], not in [`CacheStats`].
    store: Option<Arc<Store>>,
}

impl SweepCache {
    /// A cache whose measured path runs under [`SimConfig::default`].
    pub fn new() -> SweepCache {
        SweepCache::with_sim(SimConfig::default())
    }

    /// A cache whose measured path runs under `sim` (the
    /// `SweepRunner::run_with_sim` hook). Grid sim variants apply on top
    /// of this base.
    pub fn with_sim(sim: SimConfig) -> SweepCache {
        SweepCache {
            sim,
            resolved: Memo::new(),
            calibrations: Memo::new(),
            models: Memo::new(),
            costs: Memo::new(),
            measured: Memo::new(),
            store: None,
        }
    }

    /// Attach a disk store (builder form of [`SweepCache::set_store`]).
    pub fn with_store(mut self, store: Arc<Store>) -> SweepCache {
        self.set_store(store);
        self
    }

    /// Attach a disk store. Calibrations built before the attach carry
    /// no store, so the (lazily built) per-source entries are reset.
    pub fn set_store(&mut self, store: Arc<Store>) {
        self.store = Some(store);
        self.calibrations.clear();
    }

    /// The attached disk store, if any.
    pub fn store(&self) -> Option<&Arc<Store>> {
        self.store.as_ref()
    }

    /// The base simulator configuration the measured path runs under.
    pub fn sim(&self) -> &SimConfig {
        &self.sim
    }

    /// Swap the base simulator configuration. Memoized cost tables and
    /// measurements keyed under the old fingerprints become unreachable
    /// (but are retained: switching back re-hits them).
    pub fn set_sim(&mut self, sim: SimConfig) {
        self.sim = sim;
        self.resolved.clear();
    }

    /// The resolved simulator configuration (+ fingerprint) for one
    /// scenario, memoized per (machine, sim) axis pair. `pub(crate)` so
    /// the runner's cost-aware scheduler can read a scenario's fidelity
    /// without re-resolving.
    pub(crate) fn resolved_sim(&self, grid: &GridSpec, scn: &Scenario) -> (Arc<SimConfig>, u64) {
        self.resolved.get_or_insert_with((scn.machine, scn.sim), || {
            let sim = Arc::new(grid.resolved_sim(&self.sim, scn));
            let fp = sim.fingerprint();
            (sim, fp)
        })
    }

    /// The shared [`Calibration`] for one parameter source (lazily
    /// built, one per source for the cache's lifetime).
    fn calibration(&self, source: ParamSource) -> Arc<Calibration> {
        let key = match source {
            ParamSource::Paper => 0u8,
            ParamSource::Simulator => 1u8,
        };
        self.calibrations.get_or_insert_with(key, || {
            let mut cal = Calibration::new(source);
            if let Some(store) = &self.store {
                cal = cal.with_store(Arc::clone(store));
            }
            Arc::new(cal)
        })
    }

    /// The performance model for a scenario, built at most once per
    /// (architecture, strategy, resolved sim config) — the fingerprint
    /// covers the machine, like the cost/measured keys, and the
    /// single-flight memo makes "at most once" hold under any worker
    /// count. Models are constructed from the scenario's [`Calibration`]
    /// resolution against the resolved simulator — under
    /// [`crate::perfmodel::ParamSource::Simulator`] every parameter is
    /// estimated from exactly the configuration that produces the
    /// measurements (the closed loop), and the (a)/(b) rows of a cell
    /// share one resolution (probe pass + contention memo).
    pub fn model(&self, grid: &GridSpec, scn: &Scenario) -> Result<SharedModel> {
        let arch = &grid.archs[scn.arch];
        let (sim, fp) = self.resolved_sim(grid, scn);
        let key = (arch.name.clone(), scn.strategy, fp);
        self.models.get_or_try_insert_with(key, || {
            Ok(Arc::from(
                self.calibration(grid.params)
                    .strategy(arch, scn.strategy, &sim)?,
            ))
        })
    }

    /// The shared micsim cost table for (architecture, resolved sim
    /// config) — one [`CostTable`] per pair, so every measured workload
    /// on that pair (and every point of a thread ladder) shares both the
    /// resolved [`CostModel`] and the per-occupancy-class memo. The
    /// fingerprint covers the machine, so cells sharing a sim variant
    /// share entries.
    pub fn cost(&self, grid: &GridSpec, scn: &Scenario) -> Result<Arc<CostTable>> {
        let arch = &grid.archs[scn.arch];
        let (sim, fp) = self.resolved_sim(grid, scn);
        let key = (arch.name.clone(), fp);
        self.costs.get_or_try_insert_with(key, || {
            Ok(Arc::new(CostTable::new(Arc::new(CostModel::new(arch, &sim)?))))
        })
    }

    /// Micsim execution seconds for a scenario's workload (strategy-
    /// independent: the (a) and (b) rows of one point share it).
    ///
    /// The whole resolution — store probe, cost-table build, simulation,
    /// write-through — runs inside the single-flight slot, so concurrent
    /// workers asking for one workload perform it exactly once (and the
    /// store is written exactly once per key).
    pub fn measured_s(&self, grid: &GridSpec, scn: &Scenario) -> Result<f64> {
        let arch = &grid.archs[scn.arch];
        let (sim, fp) = self.resolved_sim(grid, scn);
        let key = (
            arch.name.clone(),
            scn.threads,
            scn.train_images,
            scn.test_images,
            scn.epochs,
            fp,
        );
        self.measured.get_or_try_insert_with(key, || {
            // Disk first: a persisted measurement skips the cost-table
            // build entirely (f64s round-trip bit-exactly through the
            // store).
            let skey = lab::measured_key(
                &arch.name,
                scn.threads,
                scn.train_images,
                scn.test_images,
                scn.epochs,
                fp,
            );
            if let Some(store) = &self.store {
                if let Some(v) = store
                    .get(lab::Kind::Measured, &skey)
                    .and_then(|p| p.get("execution_s").and_then(Json::as_f64))
                {
                    return Ok(v);
                }
            }
            let table = self.cost(grid, scn)?;
            let v = simulate_training_shared(&table, &scn.run(), &sim)?.execution_s;
            if let Some(store) = &self.store {
                store.put(
                    lab::Kind::Measured,
                    &skey,
                    Json::obj(vec![("execution_s", Json::num(v))]),
                )?;
            }
            Ok(v)
        })
    }

    /// The persisted evaluation of a whole cell, when a store is
    /// attached and holds one: `(prediction, measured_s, delta_pct)`.
    ///
    /// On a measuring grid an entry without a measurement counts as a
    /// store miss (the cell must be recomputed and overwritten); on a
    /// non-measuring grid an entry's measurement is *not* served, so
    /// results stay identical to a storeless run of the same grid.
    pub fn stored_cell(
        &self,
        grid: &GridSpec,
        scn: &Scenario,
    ) -> Option<(Prediction, Option<f64>, Option<f64>)> {
        let store = self.store.as_ref()?;
        let (_, fp) = self.resolved_sim(grid, scn);
        let key = Self::cell_key_for(grid, scn, fp);
        let cell = store
            .peek(lab::Kind::Cells, &key)
            .and_then(|payload| Self::cell_from_payload(&payload, grid.measure));
        store.record(cell.is_some());
        cell
    }

    fn cell_from_payload(
        payload: &Json,
        measure: bool,
    ) -> Option<(Prediction, Option<f64>, Option<f64>)> {
        let p = payload.get("prediction")?;
        let prediction = Prediction {
            prep_s: p.get("prep_s")?.as_f64()?,
            train_s: p.get("train_s")?.as_f64()?,
            test_s: p.get("test_s")?.as_f64()?,
            mem_s: p.get("mem_s")?.as_f64()?,
            total_s: p.get("total_s")?.as_f64()?,
        };
        if !measure {
            return Some((prediction, None, None));
        }
        let measured_s = payload.get("measured_s").and_then(Json::as_f64)?;
        let delta_pct = payload.get("delta_pct").and_then(Json::as_f64)?;
        Some((prediction, Some(measured_s), Some(delta_pct)))
    }

    /// Write a fully evaluated cell through to the store (no-op without
    /// one), carrying its calibration provenance.
    pub fn put_cell(
        &self,
        grid: &GridSpec,
        scn: &Scenario,
        prediction: &Prediction,
        measured_s: Option<f64>,
        delta_pct: Option<f64>,
    ) -> Result<()> {
        let Some(store) = self.store.as_ref() else {
            return Ok(());
        };
        let (_, fp) = self.resolved_sim(grid, scn);
        let key = Self::cell_key_for(grid, scn, fp);
        let mut pairs = vec![
            (
                "prediction",
                Json::obj(vec![
                    ("prep_s", Json::num(prediction.prep_s)),
                    ("train_s", Json::num(prediction.train_s)),
                    ("test_s", Json::num(prediction.test_s)),
                    ("mem_s", Json::num(prediction.mem_s)),
                    ("total_s", Json::num(prediction.total_s)),
                ]),
            ),
            (
                "calibrator",
                Json::str(self.calibration(grid.params).calibrator_name()),
            ),
            ("source", Json::str(lab::source_tag(grid.params))),
        ];
        if let Some(m) = measured_s {
            pairs.push(("measured_s", Json::num(m)));
        }
        if let Some(d) = delta_pct {
            pairs.push(("delta_pct", Json::num(d)));
        }
        store.put(lab::Kind::Cells, &key, Json::obj(pairs))
    }

    fn cell_key_for(grid: &GridSpec, scn: &Scenario, fp: u64) -> String {
        lab::cell_key(
            &grid.archs[scn.arch].name,
            scn.strategy.as_str(),
            scn.threads,
            scn.train_images,
            scn.test_images,
            scn.epochs,
            grid.params,
            fp,
        )
    }

    /// Total `Calibration::resolve` parameter resolutions performed so
    /// far, summed over every parameter source this cache has touched.
    /// The serve engine's core invariant rides on this: a batch must
    /// resolve at most once per distinct (arch, sim fingerprint) pair,
    /// and a warm-store batch must resolve zero times.
    pub fn calibration_resolutions(&self) -> u64 {
        self.calibrations.values().iter().map(|cal| cal.resolutions()).sum()
    }

    /// Total strategy-(c) residual fits performed so far, summed over
    /// every parameter source — the warm-lab invariant's (c) half: a
    /// warm rerun of a (c) grid must fit zero times.
    pub fn residual_fits(&self) -> u64 {
        self.calibrations.values().iter().map(|cal| cal.residual_fits()).sum()
    }

    /// Hit/miss counters accumulated so far: the sum over the three
    /// counted memo tables (models, cost tables, measurements). The
    /// `resolved`/`calibrations` plumbing tables are deliberately
    /// uncounted, as before the single-flight rework.
    pub fn stats(&self) -> CacheStats {
        let mut out = CacheStats::default();
        for s in [self.models.stats(), self.costs.stats(), self.measured.stats()] {
            out.hits += s.hits;
            out.misses += s.misses;
            out.coalesced += s.coalesced;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    fn tiny_grid() -> GridSpec {
        GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 240],
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        }
    }

    #[test]
    fn model_is_built_once_per_arch_strategy_machine() {
        let grid = tiny_grid();
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 2);
        let m0 = cache.model(&grid, &scenarios[0]).unwrap();
        let m1 = cache.model(&grid, &scenarios[1]).unwrap();
        // Same Arc: the second lookup hit.
        assert!(Arc::ptr_eq(&m0, &m1));
        let stats = cache.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 1);
    }

    #[test]
    fn measured_workload_shared_across_strategies() {
        let grid = GridSpec {
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..tiny_grid()
        };
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        // Scenarios 0 and 1 differ only in strategy → same workload key.
        let a = cache.measured_s(&grid, &scenarios[0]).unwrap();
        let b = cache.measured_s(&grid, &scenarios[1]).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
        // First call: measured miss + cost miss; second call: measured hit.
        let stats = cache.stats();
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.hits, 1);
    }

    #[test]
    fn hit_rate_is_well_defined_when_empty() {
        let cache = SweepCache::new();
        assert_eq!(cache.stats().hit_rate(), 0.0);
    }

    #[test]
    fn measured_hit_miss_accounting_across_cells_sharing_workload() {
        // 2 thread counts × 2 strategies: 4 cells, but only 2 distinct
        // (arch, machine, workload) measurement keys and 1 cost table.
        let grid = GridSpec {
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..tiny_grid()
        };
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 4);
        for scn in &scenarios {
            cache.measured_s(&grid, scn).unwrap();
        }
        // Lookups: 4 measured probes + 2 cost probes (only inside the two
        // measured-miss computations). Misses: 2 measured + 1 cost; hits:
        // 2 measured (strategy b re-reads strategy a's workload) + 1 cost.
        let stats = cache.stats();
        assert_eq!(stats.lookups(), 6);
        assert_eq!(stats.misses, 3);
        assert_eq!(stats.hits, 3);
        assert_eq!(stats.coalesced, 0, "serial runs never wait");
        // Same workload key → bit-identical value, across strategies.
        let a = cache.measured_s(&grid, &scenarios[0]).unwrap();
        let b = cache.measured_s(&grid, &scenarios[1]).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn sim_config_change_invalidates_measured_entries() {
        let grid = GridSpec { measure: true, ..tiny_grid() };
        let scenarios = grid.enumerate();
        let scn = &scenarios[0];
        let mut cache = SweepCache::new();

        let base = cache.measured_s(&grid, scn).unwrap();
        cache.measured_s(&grid, scn).unwrap();
        // Miss (measured + cost) then one measured hit.
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 2, coalesced: 0 });

        // A doubled per-op cost is a different simulator: stale entries
        // must not serve it.
        let mut slower = SimConfig::default();
        slower.fwd_cycles_per_op *= 2.0;
        slower.bwd_cycles_per_op *= 2.0;
        cache.set_sim(slower);
        let slow = cache.measured_s(&grid, scn).unwrap();
        assert!(slow > base, "{slow} !> {base}");
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 4, coalesced: 0 });

        // Switching back re-hits the original entries bit-for-bit.
        cache.set_sim(SimConfig::default());
        let back = cache.measured_s(&grid, scn).unwrap();
        assert_eq!(back.to_bits(), base.to_bits());
        assert_eq!(cache.stats(), CacheStats { hits: 2, misses: 4, coalesced: 0 });
    }

    #[test]
    fn sim_axis_cells_share_within_and_never_across_variants() {
        use crate::sweep::grid::SimVariant;
        // 2 variants × 2 threads × 2 strategies, measured: within each
        // variant the (a, b) rows share the measurement and all cells
        // share one cost table; across variants nothing is shared.
        let grid = GridSpec {
            strategies: vec![Strategy::A, Strategy::B],
            sims: vec![
                SimVariant { name: "slow".into(), clock_ghz: Some(1.0), ..Default::default() },
                SimVariant { name: "fast".into(), clock_ghz: Some(1.5), ..Default::default() },
            ],
            measure: true,
            ..tiny_grid()
        };
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 8);
        for scn in &scenarios {
            cache.measured_s(&grid, scn).unwrap();
        }
        // Per variant: 2 measured misses + 1 cost miss, 2 measured hits
        // + 1 cost hit — identical accounting to the non-ablation grid,
        // doubled.
        let stats = cache.stats();
        assert_eq!(stats.misses, 2 * 3);
        assert_eq!(stats.hits, 2 * 3);
        // Different clocks produce different values (no cross-variant
        // leakage), and 1.5 GHz beats 1.0 GHz.
        let slow = cache.measured_s(&grid, &scenarios[0]).unwrap();
        let fast = cache.measured_s(&grid, &scenarios[4]).unwrap();
        assert!(fast < slow, "{fast} !< {slow}");
    }

    #[test]
    fn identical_variant_values_share_entries_across_names() {
        use crate::sweep::grid::SimVariant;
        // Two differently-named variants with identical overrides resolve
        // to the same fingerprint: the second variant's cells hit.
        let grid = GridSpec {
            sims: vec![
                SimVariant { name: "x".into(), seed: Some(9), ..Default::default() },
                SimVariant { name: "y".into(), seed: Some(9), ..Default::default() },
            ],
            measure: true,
            ..tiny_grid()
        };
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 4);
        for scn in &scenarios {
            cache.measured_s(&grid, scn).unwrap();
        }
        // Variant x: 2 measured misses + 1 cost miss; variant y: 2
        // measured hits and no cost probe (hits happen on the measured
        // table before cost is consulted).
        let stats = cache.stats();
        assert_eq!(stats.misses, 3);
        assert_eq!(stats.hits, 3);
    }

    #[test]
    fn closed_loop_models_probe_the_variant_simulator() {
        use crate::perfmodel::ParamSource;
        use crate::sweep::grid::SimVariant;
        // Under --params sim, a variant that slows the simulator must
        // slow the *model's* probed parameters too (the closed loop):
        // predictions differ across variants.
        let grid = GridSpec {
            params: ParamSource::Simulator,
            sims: vec![
                SimVariant { name: "base".into(), ..Default::default() },
                SimVariant {
                    name: "slow".into(),
                    fwd_cycles_per_op: Some(62.0),
                    ..Default::default()
                },
            ],
            ..tiny_grid()
        };
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        let run = scenarios[0].run();
        let base = cache.model(&grid, &scenarios[0]).unwrap().predict(&run).unwrap();
        let slow = cache.model(&grid, &scenarios[2]).unwrap().predict(&run).unwrap();
        assert!(
            slow.total_s > base.total_s,
            "{} !> {}",
            slow.total_s,
            base.total_s
        );
    }

    #[test]
    fn closed_loop_cell_shares_one_calibration_resolution() {
        use crate::perfmodel::ParamSource;
        // 2 strategies × 2 thread counts over one (arch, sim): both
        // models must come out of a single Calibration::resolve (one
        // probe/fit pass, one shared contention memo).
        let grid = GridSpec {
            strategies: vec![Strategy::A, Strategy::B],
            params: ParamSource::Simulator,
            ..tiny_grid()
        };
        let cache = SweepCache::new();
        for scn in &grid.enumerate() {
            cache.model(&grid, scn).unwrap();
        }
        let cal = cache.calibration(ParamSource::Simulator);
        assert_eq!(cal.resolutions(), 1, "a/b must share one resolution");
    }

    #[test]
    fn seed_only_change_invalidates_keys_but_not_values() {
        // The measured path is seed-stable: a different seed is a
        // different cache key (conservative invalidation) but the chunked
        // simulation is deterministic and seed-independent.
        let grid = GridSpec { measure: true, ..tiny_grid() };
        let scenarios = grid.enumerate();
        let scn = &scenarios[0];
        let mut cache = SweepCache::new();
        let a = cache.measured_s(&grid, scn).unwrap();
        let mut reseeded = SimConfig::default();
        reseeded.seed ^= 0xDEAD_BEEF;
        cache.set_sim(reseeded);
        let b = cache.measured_s(&grid, scn).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
        // Both were misses on their own key.
        assert_eq!(cache.stats().misses, 4);
    }

    #[test]
    fn concurrent_workers_compute_each_key_exactly_once() {
        // The tentpole invariant at the cache level: W workers hammering
        // the same tiny measured grid perform exactly D expensive
        // computations — D_model + D_cost + D_measured misses — for any
        // W, with the duplicates absorbed as coalesced waits or plain
        // hits.
        let grid = GridSpec {
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..tiny_grid()
        };
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 4);
        for workers in [2usize, 4, 8] {
            let cache = SweepCache::new();
            let barrier = std::sync::Barrier::new(workers);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| {
                        barrier.wait();
                        for scn in &scenarios {
                            cache.model(&grid, scn).unwrap();
                            cache.measured_s(&grid, scn).unwrap();
                        }
                    });
                }
            });
            let stats = cache.stats();
            // Distinct keys: 2 models (a, b) + 1 cost + 2 measured = 5.
            assert_eq!(stats.misses, 5, "workers={workers}: {stats:?}");
            // Every lookup is a hit or a miss: workers × 8 model/measured
            // calls, plus exactly 2 cost lookups (one inside each of the
            // two measured-miss computations — never more).
            assert_eq!(
                stats.lookups(),
                (workers * 2 * scenarios.len() + 2) as u64,
                "workers={workers}: {stats:?}"
            );
        }
    }
}
