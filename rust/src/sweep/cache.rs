//! Memoization shared across the scenarios of one sweep.
//!
//! The expensive sub-computations of a scenario depend on far fewer axes
//! than the scenario itself:
//!
//! * model construction (op-count resolution via [`crate::nn::opcount`],
//!   probe measurement, contention calibration) depends only on
//!   (architecture, strategy, machine) — not on threads/images/epochs;
//! * the micsim cost model ([`crate::simulator::cost`]) depends only on
//!   (architecture, machine);
//! * a micsim "measurement" depends on the workload but not the strategy.
//!
//! The cache keys each by exactly its inputs, so a 10k-scenario sweep
//! builds each model once and spends the rest of its time in the cheap
//! closed-form `predict`. All maps are `Mutex`-guarded: lookups are
//! lock-drop-compute-insert, so a concurrent miss may compute a value
//! twice, but every computation is deterministic and the first insert
//! wins — parallel sweeps stay bit-identical to serial ones.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::Result;
use crate::perfmodel::{PerfModel, StrategyA, StrategyB};
use crate::simulator::{simulate_training_with, CostModel, SimConfig};
use crate::sweep::grid::{GridSpec, Scenario, Strategy};

/// A model usable from any sweep worker.
pub type SharedModel = Arc<dyn PerfModel + Send + Sync>;

/// Hit/miss counters for one sweep run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// The per-sweep memo: models, cost models, and micsim measurements.
pub struct SweepCache {
    models: Mutex<HashMap<(String, Strategy, usize), SharedModel>>,
    costs: Mutex<HashMap<(String, usize), Arc<CostModel>>>,
    measured: Mutex<HashMap<(String, usize, usize, usize, usize, usize), f64>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SweepCache {
    pub fn new() -> SweepCache {
        SweepCache {
            models: Mutex::new(HashMap::new()),
            costs: Mutex::new(HashMap::new()),
            measured: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Counted map probe (any table).
    fn probe<K: Eq + Hash, V: Clone>(&self, map: &Mutex<HashMap<K, V>>, key: &K) -> Option<V> {
        let got = map.lock().unwrap().get(key).cloned();
        if got.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// The performance model for a scenario, built at most once per
    /// (architecture, strategy, machine).
    pub fn model(&self, grid: &GridSpec, scn: &Scenario) -> Result<SharedModel> {
        let arch = &grid.archs[scn.arch];
        let key = (arch.name.clone(), scn.strategy, scn.machine);
        if let Some(model) = self.probe(&self.models, &key) {
            return Ok(model);
        }
        let machine = grid.machines[scn.machine].clone();
        let built: SharedModel = match scn.strategy {
            Strategy::A => Arc::new(StrategyA::new(arch, grid.params)?.with_machine(machine)),
            Strategy::B => Arc::new(StrategyB::new(arch, grid.params)?.with_machine(machine)),
        };
        Ok(self
            .models
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(built)
            .clone())
    }

    /// The micsim cost model for (architecture, machine), shared by every
    /// measured workload on that pair.
    pub fn cost(&self, grid: &GridSpec, scn: &Scenario, sim: &SimConfig) -> Result<Arc<CostModel>> {
        let arch = &grid.archs[scn.arch];
        let key = (arch.name.clone(), scn.machine);
        if let Some(cost) = self.probe(&self.costs, &key) {
            return Ok(cost);
        }
        let built = Arc::new(CostModel::new(arch, sim)?);
        Ok(self
            .costs
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(built)
            .clone())
    }

    /// Micsim execution seconds for a scenario's workload (strategy-
    /// independent: the (a) and (b) rows of one point share it).
    pub fn measured_s(&self, grid: &GridSpec, scn: &Scenario) -> Result<f64> {
        let arch = &grid.archs[scn.arch];
        let key = (
            arch.name.clone(),
            scn.machine,
            scn.threads,
            scn.train_images,
            scn.test_images,
            scn.epochs,
        );
        if let Some(v) = self.probe(&self.measured, &key) {
            return Ok(v);
        }
        let sim = SimConfig {
            machine: grid.machines[scn.machine].clone(),
            ..SimConfig::default()
        };
        let cost = self.cost(grid, scn, &sim)?;
        let v = simulate_training_with(&cost, &scn.run(), &sim)?.execution_s;
        Ok(*self.measured.lock().unwrap().entry(key).or_insert(v))
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    fn tiny_grid() -> GridSpec {
        GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 240],
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        }
    }

    #[test]
    fn model_is_built_once_per_arch_strategy_machine() {
        let grid = tiny_grid();
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 2);
        let m0 = cache.model(&grid, &scenarios[0]).unwrap();
        let m1 = cache.model(&grid, &scenarios[1]).unwrap();
        // Same Arc: the second lookup hit.
        assert!(Arc::ptr_eq(&m0, &m1));
        let stats = cache.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 1);
    }

    #[test]
    fn measured_workload_shared_across_strategies() {
        let grid = GridSpec {
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..tiny_grid()
        };
        let cache = SweepCache::new();
        let scenarios = grid.enumerate();
        // Scenarios 0 and 1 differ only in strategy → same workload key.
        let a = cache.measured_s(&grid, &scenarios[0]).unwrap();
        let b = cache.measured_s(&grid, &scenarios[1]).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
        // First call: measured miss + cost miss; second call: measured hit.
        let stats = cache.stats();
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.hits, 1);
    }

    #[test]
    fn hit_rate_is_well_defined_when_empty() {
        let cache = SweepCache::new();
        assert_eq!(cache.stats().hit_rate(), 0.0);
    }
}
