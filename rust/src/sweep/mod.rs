//! The scenario-sweep engine: evaluate the performance models over a
//! declarative grid of scenarios, concurrently, with shared-computation
//! memoization.
//!
//! The paper's whole evaluation is a grid — three CNN architectures ×
//! thread counts (1..244 and beyond) × workload sizes × two model
//! strategies — yet the rest of the crate evaluates one point per call.
//! This module makes "evaluate 10k scenarios fast" the default shape:
//!
//! * [`grid`] — [`GridSpec`], the declarative cross-product, with a
//!   deterministic enumeration order, a JSON spec format, and the
//!   **sim axis** ([`SimVariant`]): named simulator-configuration
//!   overrides that turn the simulator's constants (clock, core/thread
//!   counts, cycle and cache/latency constants, fidelity, seed) into an
//!   ablation dimension;
//! * [`cache`] — [`SweepCache`], memoizing model construction, micsim
//!   cost models, and measurements by exactly their input axes (the
//!   resolved simulator's [`crate::simulator::SimConfig::fingerprint`]
//!   included, so variants share within and never leak across);
//! * [`runner`] — [`SweepRunner`], the scoped-thread worker pool whose
//!   parallel results are bit-identical to a serial run, optionally
//!   persisting every cell through a [`crate::lab`] disk store
//!   ([`SweepRunner::with_store`]) so repeated runs are pure store hits
//!   and interrupted sweeps resume from the last persisted cell; grids
//!   also split into deterministic shards ([`GridSpec::shard`],
//!   [`SweepRunner::run_shard`]) that independent processes execute
//!   against one shared store and [`merge_shards`] reassembles
//!   byte-identically to the unsharded run (`repro sweep run
//!   --shard k/n` / `--shards n`);
//! * [`summary`] — [`SweepResults`], O(1) stride addressing, grid-level
//!   accuracy aggregation (mean/max Δ per sim variant × architecture ×
//!   strategy — the sweep-native Table IX), JSON dump, and paper-style
//!   tables;
//! * [`baseline`] — [`Baseline`]/[`DiffReport`], the golden-baseline
//!   regression mode behind `repro sweep --compare`/`--write-baseline`
//!   (ablation grids pin with their sim-variant keys);
//! * [`conformance`] — the measured-mode conformance harness: Δ-band
//!   golden baselines over the Tables IX–XI grids plus the paper's
//!   ≈ 15 %/11 % mean-Δ claims, behind `repro conformance`, and the
//!   closed-loop grid (`--params sim`, model parameters calibrated
//!   against the measuring simulator via [`crate::calibration`]) behind
//!   `repro conformance --closed-loop`;
//! * [`sensitivity`] — ∂Δ/∂constant analysis over a one-at-a-time
//!   ablation grid of the simulator constants, ranked per constant
//!   (`repro sensitivity`).
//!
//! The `repro sweep`/`repro conformance` subcommands drive it from the
//! CLI, and the `experiments` table/figure entries for Figs. 5–7 and
//! Tables IX/X/XI are thin grid definitions executed here. See
//! `docs/SWEEP.md` for the full CLI reference.

#![warn(missing_docs)]

pub mod baseline;
pub mod cache;
pub mod conformance;
pub mod grid;
pub mod runner;
pub mod sensitivity;
pub mod summary;

pub use baseline::{Baseline, BaselineCell, CellDiff, DiffReport};
pub use cache::{CacheStats, SweepCache};
pub use crate::lab::StoreStats;
pub use conformance::{
    BandCheck, BandSpec, ClaimCheck, ClaimSpec, ConformanceBaseline, ConformanceReport,
};
pub use grid::{parse_axis, threads_range_from_json, GridSpec, Scenario, SimVariant, Strategy};
pub use runner::SweepRunner;
pub use sensitivity::{
    RankedConstant, SensitivityEntry, SensitivityReport, SensitivitySpec, SimConstant,
};
pub use summary::{merge_shards, AccuracyAggregate, ScenarioResult, SweepResults};
