//! The scenario-sweep engine: evaluate the performance models over a
//! declarative grid of scenarios, concurrently, with shared-computation
//! memoization.
//!
//! The paper's whole evaluation is a grid — three CNN architectures ×
//! thread counts (1..244 and beyond) × workload sizes × two model
//! strategies — yet the rest of the crate evaluates one point per call.
//! This module makes "evaluate 10k scenarios fast" the default shape:
//!
//! * [`grid`] — [`GridSpec`], the declarative cross-product, with a
//!   deterministic enumeration order and a JSON spec format;
//! * [`cache`] — [`SweepCache`], memoizing model construction, micsim
//!   cost models, and measurements by exactly their input axes;
//! * [`runner`] — [`SweepRunner`], the scoped-thread worker pool whose
//!   parallel results are bit-identical to a serial run;
//! * [`summary`] — [`SweepResults`], O(1) stride addressing, grid-level
//!   accuracy aggregation (mean/max Δ per architecture × strategy — the
//!   sweep-native Table IX), JSON dump, and paper-style tables;
//! * [`baseline`] — [`Baseline`]/[`DiffReport`], the golden-baseline
//!   regression mode behind `repro sweep --compare`/`--write-baseline`;
//! * [`conformance`] — the measured-mode conformance harness: Δ-band
//!   golden baselines over the Tables IX–XI grids plus the paper's
//!   ≈ 15 %/11 % mean-Δ claims, behind `repro conformance`.
//!
//! The `repro sweep`/`repro conformance` subcommands drive it from the
//! CLI, and the `experiments` table/figure entries for Figs. 5–7 and
//! Tables IX/X/XI are thin grid definitions executed here.

pub mod baseline;
pub mod cache;
pub mod conformance;
pub mod grid;
pub mod runner;
pub mod summary;

pub use baseline::{Baseline, BaselineCell, CellDiff, DiffReport};
pub use cache::{CacheStats, SweepCache};
pub use conformance::{
    BandCheck, BandSpec, ClaimCheck, ClaimSpec, ConformanceBaseline, ConformanceReport,
};
pub use grid::{parse_axis, GridSpec, Scenario, Strategy};
pub use runner::SweepRunner;
pub use summary::{AccuracyAggregate, ScenarioResult, SweepResults};
