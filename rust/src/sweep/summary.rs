//! Sweep results: O(1) addressing, accuracy aggregation, JSON emission,
//! paper-style tables.

use crate::error::{Error, Result};
use crate::lab::StoreStats;
use crate::perfmodel::{DeltaAccumulator, Prediction};
use crate::report::Table;
use crate::sweep::cache::CacheStats;
use crate::sweep::grid::{GridSpec, Scenario, Strategy};
use crate::util::json::Json;

/// Grid-level prediction accuracy for one (sim variant, architecture,
/// strategy) group — one Table IX cell, computed over every measured
/// scenario of the group in enumeration order (so the mean is
/// bit-identical to [`crate::perfmodel::average_delta`] over the same
/// points).
#[derive(Debug, Clone)]
pub struct AccuracyAggregate {
    /// Sim-variant name (`None` when the grid has no sim axis).
    pub sim: Option<String>,
    /// Architecture name.
    pub arch: String,
    /// Model strategy of the group.
    pub strategy: Strategy,
    /// Measured scenarios folded into this group.
    pub points: usize,
    /// Mean Δ over the group, percent.
    pub mean_delta_pct: f64,
    /// Worst-point Δ over the group, percent.
    pub max_delta_pct: f64,
    /// Thread count of the worst point.
    pub max_at_threads: usize,
}

/// One evaluated scenario.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// The grid point this row evaluates.
    pub scenario: Scenario,
    /// The model's term-level prediction.
    pub prediction: Prediction,
    /// Micsim execution seconds (grids with `measure = true` only).
    pub measured_s: Option<f64>,
    /// Prediction accuracy Δ vs the measurement, percent.
    pub delta_pct: Option<f64>,
}

/// Everything one sweep produced, in enumeration order.
#[derive(Debug)]
pub struct SweepResults {
    /// The grid that was evaluated.
    pub grid: GridSpec,
    /// One result per scenario, in enumeration order.
    pub results: Vec<ScenarioResult>,
    /// Cache hit/miss telemetry for the run.
    pub cache: CacheStats,
    /// Disk-store hit/miss telemetry for the run (`None` unless the
    /// runner had a [`crate::lab`] store attached).
    pub store: Option<crate::lab::StoreStats>,
    /// Worker threads the sweep *actually* ran on — the effective
    /// count, not the requested one: 1 on the serial fallback (a
    /// single-scenario grid under `--workers 8` reports 1), and at most
    /// one per scenario on pool runs. [`merge_shards`] sums this across
    /// shards.
    pub workers: usize,
    /// Wall-clock seconds the sweep took.
    pub wall_s: f64,
}

impl SweepResults {
    /// Number of evaluated scenarios.
    pub fn len(&self) -> usize {
        self.results.len()
    }

    /// True when the sweep produced no results.
    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    /// O(1) lookup by axis indices (the enumeration-order strides),
    /// within the first sim-axis block — equivalent to
    /// [`SweepResults::at_sim`] with `sim = 0`, which is the whole grid
    /// whenever the sim axis is empty (every experiment definition).
    ///
    /// Panics if an index is out of range for its axis — the experiment
    /// definitions address only points they put into the grid.
    pub fn at(
        &self,
        arch: usize,
        machine: usize,
        image: usize,
        epoch: usize,
        thread: usize,
        strategy: usize,
    ) -> &ScenarioResult {
        self.at_sim(0, arch, machine, image, epoch, thread, strategy)
    }

    /// O(1) lookup by axis indices including the sim axis (index 0 is
    /// valid for grids without one).
    pub fn at_sim(
        &self,
        sim: usize,
        arch: usize,
        machine: usize,
        image: usize,
        epoch: usize,
        thread: usize,
        strategy: usize,
    ) -> &ScenarioResult {
        let g = &self.grid;
        let (na, nm, ni, ne, nt, ns) = (
            g.archs.len(),
            g.machines.len(),
            g.images.len(),
            g.epochs.len().max(1),
            g.threads.len(),
            g.strategies.len(),
        );
        assert!(
            sim < g.sim_count()
                && arch < na
                && machine < nm
                && image < ni
                && epoch < ne
                && thread < nt
                && strategy < ns,
            "axis index out of range"
        );
        let id = (((((sim * na + arch) * nm + machine) * ni + image) * ne + epoch) * nt
            + thread)
            * ns
            + strategy;
        let result = &self.results[id];
        debug_assert_eq!(result.scenario.id, id);
        result
    }

    /// Linear-scan convenience lookup by value (first match).
    pub fn find(
        &self,
        arch_name: &str,
        threads: usize,
        strategy: Strategy,
    ) -> Option<&ScenarioResult> {
        self.results.iter().find(|r| {
            self.grid.archs[r.scenario.arch].name == arch_name
                && r.scenario.threads == threads
                && r.scenario.strategy == strategy
        })
    }

    /// Fold one (architecture, strategy) group's Δ values across the
    /// whole sim axis, in enumeration order (`None` when the group has
    /// no measured points).
    fn fold_group(&self, ai: usize, strategy: Strategy) -> Option<AccuracyAggregate> {
        let mut acc = DeltaAccumulator::default();
        for r in &self.results {
            if r.scenario.arch != ai || r.scenario.strategy != strategy {
                continue;
            }
            if let Some(d) = r.delta_pct {
                acc.push(d, r.scenario.threads);
            }
        }
        let (mean, (max, max_at)) = (acc.mean_pct()?, acc.max_pct()?);
        Some(AccuracyAggregate {
            sim: None,
            arch: self.grid.archs[ai].name.clone(),
            strategy,
            points: acc.count(),
            mean_delta_pct: mean,
            max_delta_pct: max,
            max_at_threads: max_at,
        })
    }

    /// The flat group slot for one scenario: (sim, arch, strategy) in
    /// axis order — shared by [`SweepResults::accuracy`] and the summary
    /// table so both make one pass over the results instead of one scan
    /// per group (the sim axis multiplies the group count).
    fn group_slot(&self, scn: &Scenario) -> usize {
        let g = &self.grid;
        let sti = g
            .strategies
            .iter()
            .position(|&s| s == scn.strategy)
            .expect("scenario strategy is on the grid's strategy axis");
        (scn.sim * g.archs.len() + scn.arch) * g.strategies.len() + sti
    }

    /// Grid-level accuracy aggregation: mean/max Δ per (sim variant,
    /// architecture, strategy), in axis order. Empty unless the grid
    /// measured (`measure = true`) — prediction-only sweeps have no Δ to
    /// aggregate. This is the sweep-native Table IX; on ablation grids
    /// each sim variant gets its own row set. Single pass over the
    /// results in enumeration order, so every group's mean is
    /// bit-identical to the per-group fold.
    pub fn accuracy(&self) -> Vec<AccuracyAggregate> {
        let g = &self.grid;
        let groups = g.sim_count() * g.archs.len() * g.strategies.len();
        let mut accs = vec![DeltaAccumulator::default(); groups];
        for r in &self.results {
            if let Some(d) = r.delta_pct {
                accs[self.group_slot(&r.scenario)].push(d, r.scenario.threads);
            }
        }
        let mut out = Vec::new();
        let mut slot = 0;
        for si in 0..g.sim_count() {
            for ai in 0..g.archs.len() {
                for &strategy in &g.strategies {
                    let acc = &accs[slot];
                    slot += 1;
                    let (Some(mean), Some((max, max_at))) = (acc.mean_pct(), acc.max_pct())
                    else {
                        continue;
                    };
                    out.push(AccuracyAggregate {
                        sim: g.sims.get(si).map(|v| v.name.clone()),
                        arch: g.archs[ai].name.clone(),
                        strategy,
                        points: acc.count(),
                        mean_delta_pct: mean,
                        max_delta_pct: max,
                        max_at_threads: max_at,
                    });
                }
            }
        }
        out
    }

    /// The whole-grid aggregate for one strategy: every architecture's
    /// (and sim variant's) measured Δ folded in enumeration order. This is the per-strategy
    /// headline statistic the paper's accuracy claim quotes (mean Δ
    /// ≈ 15 % for model (a), ≈ 11 % for model (b)) and what
    /// [`crate::sweep::conformance`] checks claim ceilings against.
    /// `None` for prediction-only grids or strategies without points.
    pub fn accuracy_overall(&self, strategy: Strategy) -> Option<AccuracyAggregate> {
        let mut acc = DeltaAccumulator::default();
        for r in &self.results {
            if r.scenario.strategy != strategy {
                continue;
            }
            if let Some(d) = r.delta_pct {
                acc.push(d, r.scenario.threads);
            }
        }
        let (mean, (max, max_at)) = (acc.mean_pct()?, acc.max_pct()?);
        Some(AccuracyAggregate {
            sim: None,
            arch: "all".into(),
            strategy,
            points: acc.count(),
            mean_delta_pct: mean,
            max_delta_pct: max,
            max_at_threads: max_at,
        })
    }

    /// The aggregate for one (architecture, strategy) group, if measured,
    /// folded across the whole sim axis. Folds only the requested group —
    /// callers wanting every group should use [`SweepResults::accuracy`]
    /// once instead of repeated lookups.
    pub fn accuracy_for(&self, arch_name: &str, strategy: Strategy) -> Option<AccuracyAggregate> {
        let ai = self.grid.archs.iter().position(|a| a.name == arch_name)?;
        self.fold_group(ai, strategy)
    }

    /// Full machine-readable dump (the `repro sweep --json` payload).
    /// On ablation grids (non-empty sim axis) every `results[]` and
    /// `accuracy[]` row carries a `sim` key naming its variant.
    pub fn to_json(&self) -> Json {
        let g = &self.grid;
        let rows: Vec<Json> = self
            .results
            .iter()
            .map(|r| result_row_json(g, r))
            .collect();
        let mut grid_pairs = vec![
            (
                "archs",
                Json::Arr(g.archs.iter().map(|a| Json::str(a.name.clone())).collect()),
            ),
            (
                "machines",
                Json::Arr(g.machines.iter().map(|m| Json::str(m.name.clone())).collect()),
            ),
            ("threads", Json::arr_usize(&g.threads)),
            (
                "images",
                Json::Arr(
                    g.images
                        .iter()
                        .map(|&(i, it)| Json::arr_usize(&[i, it]))
                        .collect(),
                ),
            ),
            ("epochs", Json::arr_usize(&g.epochs)),
            (
                "strategies",
                Json::Arr(g.strategies.iter().map(|s| Json::str(s.as_str())).collect()),
            ),
        ];
        if !g.sims.is_empty() {
            grid_pairs.push((
                "sims",
                Json::Arr(g.sims.iter().map(|v| Json::str(v.name.clone())).collect()),
            ));
        }
        grid_pairs.push(("measure", Json::Bool(g.measure)));
        let mut top = vec![
            ("grid", Json::obj(grid_pairs)),
            ("scenarios", Json::num(self.len() as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("wall_s", Json::num(self.wall_s)),
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::num(self.cache.hits as f64)),
                    ("misses", Json::num(self.cache.misses as f64)),
                    ("coalesced", Json::num(self.cache.coalesced as f64)),
                ]),
            ),
        ];
        if let Some(store) = &self.store {
            top.push((
                "store",
                Json::obj(vec![
                    ("hits", Json::num(store.hits as f64)),
                    ("misses", Json::num(store.misses as f64)),
                ]),
            ));
        }
        top.extend([
            (
                "accuracy",
                Json::Arr(
                    self.accuracy()
                        .iter()
                        .map(|a| {
                            let mut pairs = Vec::with_capacity(7);
                            if let Some(sim) = &a.sim {
                                pairs.push(("sim", Json::str(sim.clone())));
                            }
                            pairs.extend([
                                ("arch", Json::str(a.arch.clone())),
                                ("strategy", Json::str(a.strategy.as_str())),
                                ("points", Json::num(a.points as f64)),
                                ("mean_delta_pct", Json::num(a.mean_delta_pct)),
                                ("max_delta_pct", Json::num(a.max_delta_pct)),
                                ("max_at_threads", Json::num(a.max_at_threads as f64)),
                            ]);
                            Json::obj(pairs)
                        })
                        .collect(),
                ),
            ),
            ("results", Json::Arr(rows)),
        ]);
        Json::obj(top)
    }

    /// Paper-style table: every scenario when `full`, otherwise one
    /// summary row per (architecture, strategy).
    pub fn table(&self, full: bool) -> Table {
        if full {
            self.table_full()
        } else {
            self.table_summary()
        }
    }

    fn table_full(&self) -> Table {
        let g = &self.grid;
        let ablation = !g.sims.is_empty();
        let mut cols = vec![];
        if ablation {
            cols.push("sim");
        }
        cols.extend([
            "arch", "machine", "p", "i", "it", "ep", "strat", "prep s", "train+val s",
            "test s", "T_mem s", "total s", "min", "measured s", "Δ %",
        ]);
        let mut t = Table::new(format!("sweep — {} scenarios", self.len()), &cols);
        for r in &self.results {
            let s = &r.scenario;
            let mut row = Vec::with_capacity(cols.len());
            if ablation {
                row.push(g.sim_name(s).unwrap_or("default").to_string());
            }
            row.extend([
                g.archs[s.arch].name.clone(),
                g.machines[s.machine].name.clone(),
                s.threads.to_string(),
                s.train_images.to_string(),
                s.test_images.to_string(),
                s.epochs.to_string(),
                s.strategy.as_str().into(),
                format!("{:.2}", r.prediction.prep_s),
                format!("{:.1}", r.prediction.train_s),
                format!("{:.1}", r.prediction.test_s),
                format!("{:.1}", r.prediction.mem_s),
                format!("{:.1}", r.prediction.total_s),
                format!("{:.1}", r.prediction.total_s / 60.0),
                r.measured_s.map(|m| format!("{m:.1}")).unwrap_or_else(|| "-".into()),
                r.delta_pct.map(|d| format!("{d:.1}")).unwrap_or_else(|| "-".into()),
            ]);
            t.row(row);
        }
        t
    }

    fn table_summary(&self) -> Table {
        let g = &self.grid;
        let ablation = !g.sims.is_empty();
        let mut cols = vec![];
        if ablation {
            cols.push("sim");
        }
        cols.extend([
            "arch", "strat", "points", "best total [min]", "at p", "worst total [min]",
            "at p", "mean Δ %", "max Δ %", "at p",
        ]);
        let mut t = Table::new(
            format!("sweep summary — {} scenarios", self.len()),
            &cols,
        );
        // One pass over the results, accumulating into (sim, arch,
        // strategy) group slots — see [`SweepResults::group_slot`].
        struct Group<'a> {
            best: Option<&'a ScenarioResult>,
            worst: Option<&'a ScenarioResult>,
            count: usize,
            acc: DeltaAccumulator,
        }
        let groups = g.sim_count() * g.archs.len() * g.strategies.len();
        let mut state: Vec<Group<'_>> = (0..groups)
            .map(|_| Group {
                best: None,
                worst: None,
                count: 0,
                acc: DeltaAccumulator::default(),
            })
            .collect();
        for r in &self.results {
            let slot = &mut state[self.group_slot(&r.scenario)];
            slot.count += 1;
            slot.best = match slot.best {
                Some(b) if b.prediction.total_s <= r.prediction.total_s => Some(b),
                _ => Some(r),
            };
            slot.worst = match slot.worst {
                Some(w) if w.prediction.total_s >= r.prediction.total_s => Some(w),
                _ => Some(r),
            };
            if let Some(d) = r.delta_pct {
                slot.acc.push(d, r.scenario.threads);
            }
        }
        let mut slot = 0;
        for si in 0..g.sim_count() {
            for arch in &g.archs {
                for &strat in &g.strategies {
                    let group = &state[slot];
                    slot += 1;
                    let (Some(best), Some(worst)) = (group.best, group.worst) else {
                        continue;
                    };
                    let mut row = Vec::with_capacity(cols.len());
                    if ablation {
                        row.push(
                            g.sims.get(si).map(|v| v.name.clone()).unwrap_or_default(),
                        );
                    }
                    row.extend([
                        arch.name.clone(),
                        strat.as_str().into(),
                        group.count.to_string(),
                        format!("{:.1}", best.prediction.total_s / 60.0),
                        best.scenario.threads.to_string(),
                        format!("{:.1}", worst.prediction.total_s / 60.0),
                        worst.scenario.threads.to_string(),
                        group
                            .acc
                            .mean_pct()
                            .map(|d| format!("{d:.1}"))
                            .unwrap_or_else(|| "-".into()),
                        group
                            .acc
                            .max_pct()
                            .map(|(d, _)| format!("{d:.1}"))
                            .unwrap_or_else(|| "-".into()),
                        group
                            .acc
                            .max_pct()
                            .map(|(_, p)| p.to_string())
                            .unwrap_or_else(|| "-".into()),
                    ]);
                    t.row(row);
                }
            }
        }
        t
    }

    /// Render a table plus the run footer (wall time + cache telemetry;
    /// store telemetry too when a lab store was attached).
    pub fn render(&self, full: bool) -> String {
        let mut out = self.table(full).render();
        out.push_str(&format!(
            "{} scenarios in {:.3}s ({} workers) | cache: {} hits / {} misses \
             ({:.0}% hit rate, {} coalesced)",
            self.len(),
            self.wall_s,
            self.workers,
            self.cache.hits,
            self.cache.misses,
            self.cache.hit_rate() * 100.0,
            self.cache.coalesced,
        ));
        if let Some(store) = &self.store {
            out.push_str(&format!(
                " | store: {} hits / {} misses",
                store.hits, store.misses
            ));
        }
        out.push('\n');
        out
    }
}

/// One `results[]` row of the machine-readable dump. Shared with the
/// serve engine ([`crate::serve`]) so `repro predict` rows are
/// bit-identical to the corresponding sweep cells — there is exactly
/// one place that turns a [`ScenarioResult`] into JSON.
pub(crate) fn result_row_json(g: &GridSpec, r: &ScenarioResult) -> Json {
    let s = &r.scenario;
    let mut pairs = Vec::with_capacity(16);
    if let Some(sim) = g.sim_name(s) {
        pairs.push(("sim", Json::str(sim.to_string())));
    }
    pairs.extend([
        ("arch", Json::str(g.archs[s.arch].name.clone())),
        ("machine", Json::str(g.machines[s.machine].name.clone())),
        ("threads", Json::num(s.threads as f64)),
        ("train_images", Json::num(s.train_images as f64)),
        ("test_images", Json::num(s.test_images as f64)),
        ("epochs", Json::num(s.epochs as f64)),
        ("strategy", Json::str(s.strategy.as_str())),
        ("prep_s", Json::num(r.prediction.prep_s)),
        ("train_s", Json::num(r.prediction.train_s)),
        ("test_s", Json::num(r.prediction.test_s)),
        ("mem_s", Json::num(r.prediction.mem_s)),
        ("total_s", Json::num(r.prediction.total_s)),
        ("total_min", Json::num(r.prediction.total_s / 60.0)),
    ]);
    if let Some(m) = r.measured_s {
        pairs.push(("measured_s", Json::num(m)));
    }
    if let Some(d) = r.delta_pct {
        pairs.push(("delta_pct", Json::num(d)));
    }
    Json::obj(pairs)
}

/// Reassemble per-shard results (from
/// [`crate::sweep::SweepRunner::run_shard`] over [`GridSpec::shard`])
/// into one [`SweepResults`] for the whole grid.
///
/// Scenarios slot back by their parent-grid ids, so the merged
/// `results` vector, accuracy aggregation, tables, and JSON dump are
/// byte-identical to what an unsharded run of `grid` produces — shard
/// evaluation is deterministic per scenario, and every downstream
/// surface is a pure function of the ordered results. Telemetry is
/// folded, not recomputed: cache and store counters sum across shards
/// ([`CacheStats::merged`] / [`StoreStats::merged`]), `wall_s` is the
/// slowest shard (shards run concurrently under `--shards n`), and
/// `workers` sums.
///
/// Errors if a shard was run against a different grid, or if the
/// shards do not partition the grid exactly (a missing, duplicate, or
/// out-of-range scenario id) — e.g. merging `k of n` shards from
/// mismatched `n`s.
pub fn merge_shards(grid: &GridSpec, shards: Vec<SweepResults>) -> Result<SweepResults> {
    let spec = grid.to_spec_json()?.emit();
    let mut slots: Vec<Option<ScenarioResult>> = (0..grid.len()).map(|_| None).collect();
    let mut cache = CacheStats::default();
    let mut store: Option<StoreStats> = None;
    let mut wall_s = 0.0_f64;
    let mut workers = 0;
    for shard in shards {
        if shard.grid.to_spec_json()?.emit() != spec {
            return Err(Error::Config(
                "cannot merge shards: shard was run against a different grid".into(),
            ));
        }
        cache = cache.merged(&shard.cache);
        if let Some(s) = &shard.store {
            store = Some(store.unwrap_or_default().merged(s));
        }
        wall_s = wall_s.max(shard.wall_s);
        workers += shard.workers;
        for result in shard.results {
            let id = result.scenario.id;
            let slot = slots.get_mut(id).ok_or_else(|| {
                Error::Config(format!(
                    "cannot merge shards: scenario id {id} is outside the {}-cell grid",
                    grid.len()
                ))
            })?;
            if slot.is_some() {
                return Err(Error::Config(format!(
                    "cannot merge shards: scenario id {id} appears in more than one shard"
                )));
            }
            *slot = Some(result);
        }
    }
    let results = slots
        .into_iter()
        .enumerate()
        .map(|(id, slot)| {
            slot.ok_or_else(|| {
                Error::Config(format!(
                    "cannot merge shards: no shard covered scenario id {id}"
                ))
            })
        })
        .collect::<Result<Vec<ScenarioResult>>>()?;
    Ok(SweepResults {
        grid: grid.clone(),
        results,
        cache,
        store,
        wall_s,
        workers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::sweep::runner::SweepRunner;

    fn run_small() -> SweepResults {
        let grid = GridSpec {
            archs: vec![ArchSpec::small(), ArchSpec::medium()],
            threads: vec![15, 240],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        SweepRunner::serial().run(&grid).unwrap()
    }

    #[test]
    fn stride_lookup_matches_linear_find() {
        let res = run_small();
        for (ai, arch) in res.grid.archs.iter().enumerate() {
            for (ti, &p) in res.grid.threads.iter().enumerate() {
                for (si, &s) in res.grid.strategies.iter().enumerate() {
                    let by_stride = res.at(ai, 0, 0, 0, ti, si);
                    let by_find = res.find(&arch.name, p, s).unwrap();
                    assert_eq!(by_stride.scenario.id, by_find.scenario.id);
                }
            }
        }
    }

    #[test]
    fn json_dump_roundtrips_and_has_all_rows() {
        let res = run_small();
        let doc = Json::parse(&res.to_json().emit()).unwrap();
        assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(8));
        assert_eq!(doc.get("results").unwrap().as_arr().unwrap().len(), 8);
        let first = &doc.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("arch").unwrap().as_str(), Some("small"));
        assert!(first.get("total_s").unwrap().as_f64().unwrap() > 0.0);
    }

    fn run_measured() -> SweepResults {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 15, 240],
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        SweepRunner::serial().run(&grid).unwrap()
    }

    #[test]
    fn accuracy_empty_without_measurement() {
        let res = run_small();
        assert!(res.accuracy().is_empty());
        assert!(res.accuracy_for("small", Strategy::A).is_none());
        // The JSON surface still carries the (empty) aggregation array.
        let doc = Json::parse(&res.to_json().emit()).unwrap();
        assert_eq!(doc.get("accuracy").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn accuracy_aggregates_per_arch_strategy() {
        let res = run_measured();
        let acc = res.accuracy();
        // 1 arch × 2 strategies, 3 measured points each.
        assert_eq!(acc.len(), 2);
        for a in &acc {
            assert_eq!(a.arch, "small");
            assert_eq!(a.points, 3);
            assert!(a.mean_delta_pct.is_finite() && a.mean_delta_pct >= 0.0);
            assert!(a.max_delta_pct >= a.mean_delta_pct);
            assert!([1, 15, 240].contains(&a.max_at_threads));
        }
        assert_eq!(acc[0].strategy, Strategy::A);
        assert_eq!(acc[1].strategy, Strategy::B);
        // The group mean equals the hand-fold over the same scenarios.
        let by_hand: f64 = res
            .results
            .iter()
            .filter(|r| r.scenario.strategy == Strategy::A)
            .map(|r| r.delta_pct.unwrap())
            .sum::<f64>()
            / 3.0;
        assert_eq!(acc[0].mean_delta_pct.to_bits(), by_hand.to_bits());
    }

    #[test]
    fn accuracy_overall_folds_all_archs_in_enumeration_order() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small(), ArchSpec::medium()],
            threads: vec![1, 240],
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        let overall = res.accuracy_overall(Strategy::A).unwrap();
        assert_eq!(overall.arch, "all");
        assert_eq!(overall.points, 4);
        // Mean equals the enumeration-order fold over both archs.
        let by_hand: f64 = res
            .results
            .iter()
            .filter(|r| r.scenario.strategy == Strategy::A)
            .map(|r| r.delta_pct.unwrap())
            .sum::<f64>()
            / 4.0;
        assert_eq!(overall.mean_delta_pct.to_bits(), by_hand.to_bits());
        // Max is the worst per-group max.
        let worst = res
            .accuracy()
            .iter()
            .filter(|a| a.strategy == Strategy::A)
            .map(|a| a.max_delta_pct)
            .fold(0.0f64, f64::max);
        assert_eq!(overall.max_delta_pct, worst);
        // Prediction-only grids have no overall aggregate.
        assert!(run_small().accuracy_overall(Strategy::A).is_none());
    }

    #[test]
    fn accuracy_appears_in_json_dump() {
        let res = run_measured();
        let doc = Json::parse(&res.to_json().emit()).unwrap();
        let acc = doc.get("accuracy").unwrap().as_arr().unwrap();
        assert_eq!(acc.len(), 2);
        assert_eq!(acc[0].get("arch").unwrap().as_str(), Some("small"));
        assert_eq!(acc[0].get("strategy").unwrap().as_str(), Some("a"));
        assert_eq!(acc[0].get("points").unwrap().as_usize(), Some(3));
        assert!(acc[0].get("mean_delta_pct").unwrap().as_f64().unwrap() >= 0.0);
        assert!(acc[0].get("max_at_threads").unwrap().as_usize().is_some());
    }

    #[test]
    fn summary_table_reports_max_delta_for_measured_grids() {
        let res = run_measured();
        let out = res.render(false);
        assert!(out.contains("max Δ %"), "{out}");
        let unmeasured = run_small().render(false);
        // Prediction-only grids render dashes in the Δ columns.
        assert!(unmeasured.contains('-'), "{unmeasured}");
    }

    fn run_ablation() -> SweepResults {
        use crate::sweep::grid::SimVariant;
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![15, 240],
            strategies: vec![Strategy::A],
            sims: vec![
                SimVariant { name: "slow".into(), clock_ghz: Some(1.0), ..Default::default() },
                SimVariant { name: "fast".into(), clock_ghz: Some(1.5), ..Default::default() },
            ],
            measure: true,
            ..GridSpec::default()
        };
        SweepRunner::serial().run(&grid).unwrap()
    }

    #[test]
    fn ablation_rows_carry_the_sim_variant_key() {
        let res = run_ablation();
        assert_eq!(res.len(), 4);
        let doc = Json::parse(&res.to_json().emit()).unwrap();
        let rows = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("sim").unwrap().as_str(), Some("slow"));
        assert_eq!(rows[3].get("sim").unwrap().as_str(), Some("fast"));
        let acc = doc.get("accuracy").unwrap().as_arr().unwrap();
        assert_eq!(acc.len(), 2); // one group per sim variant
        assert_eq!(acc[0].get("sim").unwrap().as_str(), Some("slow"));
        assert_eq!(acc[1].get("sim").unwrap().as_str(), Some("fast"));
        assert_eq!(
            doc.get("grid").unwrap().get("sims").unwrap().as_arr().unwrap().len(),
            2
        );
        // Sim-free grids keep the pre-ablation JSON shape (no sim keys).
        let plain = Json::parse(&run_measured().to_json().emit()).unwrap();
        assert!(plain.get("results").unwrap().as_arr().unwrap()[0].get("sim").is_none());
        assert!(plain.get("grid").unwrap().get("sims").is_none());
    }

    #[test]
    fn at_sim_addresses_every_variant_block() {
        let res = run_ablation();
        for si in 0..2 {
            for ti in 0..2 {
                let r = res.at_sim(si, 0, 0, 0, 0, ti, 0);
                assert_eq!(r.scenario.sim, si);
                assert_eq!(r.scenario.threads, res.grid.threads[ti]);
            }
        }
        // at() is the sim-0 block.
        assert_eq!(res.at(0, 0, 0, 0, 1, 0).scenario.id, 1);
        // The clock ablation orders the measured times.
        let slow = res.at_sim(0, 0, 0, 0, 0, 1, 0).measured_s.unwrap();
        let fast = res.at_sim(1, 0, 0, 0, 0, 1, 0).measured_s.unwrap();
        assert!(fast < slow);
    }

    #[test]
    fn ablation_tables_have_a_sim_column() {
        let res = run_ablation();
        let full = res.render(true);
        assert!(full.contains("sim"), "{full}");
        assert!(full.contains("slow") && full.contains("fast"), "{full}");
        let summary = res.render(false);
        assert!(summary.contains("slow") && summary.contains("fast"), "{summary}");
        // Sim-free tables keep their pre-ablation header.
        let plain = run_measured().render(false);
        assert!(!plain.contains("sim"), "{plain}");
    }

    #[test]
    fn tables_render_both_shapes() {
        let res = run_small();
        let full = res.render(true);
        assert!(full.contains("total s"));
        // One line per scenario + title/header/rule + footer.
        assert_eq!(full.lines().count(), 8 + 4);
        let summary = res.render(false);
        assert!(summary.contains("best total"));
        assert!(summary.contains("hit rate"));
    }

    fn measured_grid() -> GridSpec {
        GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 15, 240],
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..GridSpec::default()
        }
    }

    #[test]
    fn merged_shards_match_the_unsharded_run_byte_for_byte() {
        let grid = measured_grid();
        let whole = SweepRunner::serial().run(&grid).unwrap();
        for n in [1, 2, 3, 6] {
            let shards: Vec<SweepResults> = (0..n)
                .map(|k| SweepRunner::serial().run_shard(&grid, k, n).unwrap())
                .collect();
            let merged = merge_shards(&grid, shards).unwrap();
            // Stable payload (grid, scenario rows, accuracy) is
            // byte-identical; wall/cache/workers are per-run telemetry.
            let m = Json::parse(&merged.to_json().emit()).unwrap();
            let w = Json::parse(&whole.to_json().emit()).unwrap();
            for key in ["grid", "scenarios", "accuracy", "results"] {
                assert_eq!(
                    m.get(key).unwrap().emit(),
                    w.get(key).unwrap().emit(),
                    "{key}, n = {n}"
                );
            }
            assert_eq!(merged.table(true).render(), whole.table(true).render());
            // Telemetry folds: each scenario makes a fixed number of
            // counted probes, so summed lookups are conserved even
            // though cold shard memos turn some cross-scenario hits
            // into misses.
            assert_eq!(merged.cache.lookups(), whole.cache.lookups(), "n = {n}");
            assert_eq!(merged.workers, n);
        }
    }

    #[test]
    fn merge_rejects_incomplete_overlapping_or_foreign_shards() {
        let grid = measured_grid();
        let s0 = || SweepRunner::serial().run_shard(&grid, 0, 2).unwrap();
        let s1 = || SweepRunner::serial().run_shard(&grid, 1, 2).unwrap();
        // Missing shard.
        let err = merge_shards(&grid, vec![s0()]).unwrap_err();
        assert!(err.to_string().contains("no shard covered"), "{err}");
        // Duplicate shard.
        let err = merge_shards(&grid, vec![s0(), s0(), s1()]).unwrap_err();
        assert!(err.to_string().contains("more than one shard"), "{err}");
        // Shard of some other grid.
        let other = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 15, 240, 244],
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let foreign = SweepRunner::serial().run_shard(&other, 0, 2).unwrap();
        let err = merge_shards(&grid, vec![foreign, s1()]).unwrap_err();
        assert!(err.to_string().contains("different grid"), "{err}");
    }
}
