//! Sweep results: O(1) addressing, JSON emission, paper-style tables.

use crate::perfmodel::Prediction;
use crate::report::Table;
use crate::sweep::cache::CacheStats;
use crate::sweep::grid::{GridSpec, Scenario, Strategy};
use crate::util::json::Json;

/// One evaluated scenario.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub prediction: Prediction,
    /// Micsim execution seconds (grids with `measure = true` only).
    pub measured_s: Option<f64>,
    /// Prediction accuracy Δ vs the measurement, percent.
    pub delta_pct: Option<f64>,
}

/// Everything one sweep produced, in enumeration order.
#[derive(Debug)]
pub struct SweepResults {
    pub grid: GridSpec,
    pub results: Vec<ScenarioResult>,
    pub cache: CacheStats,
    pub wall_s: f64,
    pub workers: usize,
}

impl SweepResults {
    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    /// O(1) lookup by axis indices (the enumeration-order strides).
    ///
    /// Panics if an index is out of range for its axis — the experiment
    /// definitions address only points they put into the grid.
    pub fn at(
        &self,
        arch: usize,
        machine: usize,
        image: usize,
        epoch: usize,
        thread: usize,
        strategy: usize,
    ) -> &ScenarioResult {
        let g = &self.grid;
        let (nm, ni, ne, nt, ns) = (
            g.machines.len(),
            g.images.len(),
            g.epochs.len().max(1),
            g.threads.len(),
            g.strategies.len(),
        );
        assert!(
            machine < nm && image < ni && epoch < ne && thread < nt && strategy < ns,
            "axis index out of range"
        );
        let id = ((((arch * nm + machine) * ni + image) * ne + epoch) * nt + thread) * ns
            + strategy;
        let result = &self.results[id];
        debug_assert_eq!(result.scenario.id, id);
        result
    }

    /// Linear-scan convenience lookup by value (first match).
    pub fn find(
        &self,
        arch_name: &str,
        threads: usize,
        strategy: Strategy,
    ) -> Option<&ScenarioResult> {
        self.results.iter().find(|r| {
            self.grid.archs[r.scenario.arch].name == arch_name
                && r.scenario.threads == threads
                && r.scenario.strategy == strategy
        })
    }

    /// Full machine-readable dump (the `repro sweep --json` payload).
    pub fn to_json(&self) -> Json {
        let g = &self.grid;
        let rows: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let s = &r.scenario;
                let mut pairs = vec![
                    ("arch", Json::str(g.archs[s.arch].name.clone())),
                    ("machine", Json::str(g.machines[s.machine].name.clone())),
                    ("threads", Json::num(s.threads as f64)),
                    ("train_images", Json::num(s.train_images as f64)),
                    ("test_images", Json::num(s.test_images as f64)),
                    ("epochs", Json::num(s.epochs as f64)),
                    ("strategy", Json::str(s.strategy.as_str())),
                    ("prep_s", Json::num(r.prediction.prep_s)),
                    ("train_s", Json::num(r.prediction.train_s)),
                    ("test_s", Json::num(r.prediction.test_s)),
                    ("mem_s", Json::num(r.prediction.mem_s)),
                    ("total_s", Json::num(r.prediction.total_s)),
                    ("total_min", Json::num(r.prediction.total_s / 60.0)),
                ];
                if let Some(m) = r.measured_s {
                    pairs.push(("measured_s", Json::num(m)));
                }
                if let Some(d) = r.delta_pct {
                    pairs.push(("delta_pct", Json::num(d)));
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            (
                "grid",
                Json::obj(vec![
                    (
                        "archs",
                        Json::Arr(
                            g.archs.iter().map(|a| Json::str(a.name.clone())).collect(),
                        ),
                    ),
                    (
                        "machines",
                        Json::Arr(
                            g.machines.iter().map(|m| Json::str(m.name.clone())).collect(),
                        ),
                    ),
                    ("threads", Json::arr_usize(&g.threads)),
                    (
                        "images",
                        Json::Arr(
                            g.images
                                .iter()
                                .map(|&(i, it)| Json::arr_usize(&[i, it]))
                                .collect(),
                        ),
                    ),
                    ("epochs", Json::arr_usize(&g.epochs)),
                    (
                        "strategies",
                        Json::Arr(
                            g.strategies
                                .iter()
                                .map(|s| Json::str(s.as_str()))
                                .collect(),
                        ),
                    ),
                    ("measure", Json::Bool(g.measure)),
                ]),
            ),
            ("scenarios", Json::num(self.len() as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("wall_s", Json::num(self.wall_s)),
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::num(self.cache.hits as f64)),
                    ("misses", Json::num(self.cache.misses as f64)),
                ]),
            ),
            ("results", Json::Arr(rows)),
        ])
    }

    /// Paper-style table: every scenario when `full`, otherwise one
    /// summary row per (architecture, strategy).
    pub fn table(&self, full: bool) -> Table {
        if full {
            self.table_full()
        } else {
            self.table_summary()
        }
    }

    fn table_full(&self) -> Table {
        let g = &self.grid;
        let mut t = Table::new(
            format!("sweep — {} scenarios", self.len()),
            &[
                "arch", "machine", "p", "i", "it", "ep", "strat", "prep s", "train+val s",
                "test s", "T_mem s", "total s", "min", "measured s", "Δ %",
            ],
        );
        for r in &self.results {
            let s = &r.scenario;
            t.row(vec![
                g.archs[s.arch].name.clone(),
                g.machines[s.machine].name.clone(),
                s.threads.to_string(),
                s.train_images.to_string(),
                s.test_images.to_string(),
                s.epochs.to_string(),
                s.strategy.as_str().into(),
                format!("{:.2}", r.prediction.prep_s),
                format!("{:.1}", r.prediction.train_s),
                format!("{:.1}", r.prediction.test_s),
                format!("{:.1}", r.prediction.mem_s),
                format!("{:.1}", r.prediction.total_s),
                format!("{:.1}", r.prediction.total_s / 60.0),
                r.measured_s.map(|m| format!("{m:.1}")).unwrap_or_else(|| "-".into()),
                r.delta_pct.map(|d| format!("{d:.1}")).unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    fn table_summary(&self) -> Table {
        let g = &self.grid;
        let mut t = Table::new(
            format!("sweep summary — {} scenarios", self.len()),
            &[
                "arch", "strat", "points", "best total [min]", "at p", "worst total [min]",
                "at p", "mean Δ %",
            ],
        );
        for (ai, arch) in g.archs.iter().enumerate() {
            for &strat in &g.strategies {
                let mut best: Option<&ScenarioResult> = None;
                let mut worst: Option<&ScenarioResult> = None;
                let mut count = 0usize;
                let mut delta_sum = 0.0f64;
                let mut delta_n = 0usize;
                for r in &self.results {
                    if r.scenario.arch != ai || r.scenario.strategy != strat {
                        continue;
                    }
                    count += 1;
                    best = match best {
                        Some(b) if b.prediction.total_s <= r.prediction.total_s => Some(b),
                        _ => Some(r),
                    };
                    worst = match worst {
                        Some(w) if w.prediction.total_s >= r.prediction.total_s => Some(w),
                        _ => Some(r),
                    };
                    if let Some(d) = r.delta_pct {
                        delta_sum += d;
                        delta_n += 1;
                    }
                }
                let (Some(best), Some(worst)) = (best, worst) else { continue };
                t.row(vec![
                    arch.name.clone(),
                    strat.as_str().into(),
                    count.to_string(),
                    format!("{:.1}", best.prediction.total_s / 60.0),
                    best.scenario.threads.to_string(),
                    format!("{:.1}", worst.prediction.total_s / 60.0),
                    worst.scenario.threads.to_string(),
                    if delta_n > 0 {
                        format!("{:.1}", delta_sum / delta_n as f64)
                    } else {
                        "-".into()
                    },
                ]);
            }
        }
        t
    }

    /// Render a table plus the run footer (wall time + cache telemetry).
    pub fn render(&self, full: bool) -> String {
        let mut out = self.table(full).render();
        out.push_str(&format!(
            "{} scenarios in {:.3}s ({} workers) | cache: {} hits / {} misses \
             ({:.0}% hit rate)\n",
            self.len(),
            self.wall_s,
            self.workers,
            self.cache.hits,
            self.cache.misses,
            self.cache.hit_rate() * 100.0,
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::sweep::runner::SweepRunner;

    fn run_small() -> SweepResults {
        let grid = GridSpec {
            archs: vec![ArchSpec::small(), ArchSpec::medium()],
            threads: vec![15, 240],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        SweepRunner::serial().run(&grid).unwrap()
    }

    #[test]
    fn stride_lookup_matches_linear_find() {
        let res = run_small();
        for (ai, arch) in res.grid.archs.iter().enumerate() {
            for (ti, &p) in res.grid.threads.iter().enumerate() {
                for (si, &s) in res.grid.strategies.iter().enumerate() {
                    let by_stride = res.at(ai, 0, 0, 0, ti, si);
                    let by_find = res.find(&arch.name, p, s).unwrap();
                    assert_eq!(by_stride.scenario.id, by_find.scenario.id);
                }
            }
        }
    }

    #[test]
    fn json_dump_roundtrips_and_has_all_rows() {
        let res = run_small();
        let doc = Json::parse(&res.to_json().emit()).unwrap();
        assert_eq!(doc.get("scenarios").unwrap().as_usize(), Some(8));
        assert_eq!(doc.get("results").unwrap().as_arr().unwrap().len(), 8);
        let first = &doc.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("arch").unwrap().as_str(), Some("small"));
        assert!(first.get("total_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn tables_render_both_shapes() {
        let res = run_small();
        let full = res.render(true);
        assert!(full.contains("total s"));
        // One line per scenario + title/header/rule + footer.
        assert_eq!(full.lines().count(), 8 + 4);
        let summary = res.render(false);
        assert!(summary.contains("best total"));
        assert!(summary.contains("hit rate"));
    }
}
