//! Golden-baseline regression mode: pin a sweep's numbers in a JSON file
//! and diff fresh runs against it.
//!
//! A [`Baseline`] captures one sweep — the grid (as a
//! [`crate::sweep::GridSpec`] spec document, so `--compare` can re-run
//! the exact grid) and one cell per scenario holding the values worth
//! pinning: `total_s`, plus `measured_s`/`delta_pct` for measured grids.
//! [`Baseline::compare`] matches cells by their full axis key (not by
//! enumeration index, so reordered or partially-overlapping grids diff
//! meaningfully) and checks every pinned value under a per-cell relative
//! tolerance, producing a machine-readable [`DiffReport`].
//!
//! The CLI surface is `repro sweep --write-baseline FILE` /
//! `--compare FILE [--tolerance F]`; CI pins `baselines/ci_smoke.json`
//! so any drift in the models' numbers blocks merges. CHAOS
//! (1702.07908) and ResPerfNet (2012.01671) track measured-vs-predicted
//! error as the artifact that must not regress over time; this module
//! makes that stance executable here.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::sweep::grid::{GridSpec, Strategy};
use crate::sweep::summary::SweepResults;
use crate::util::json::Json;

/// Baseline file format version (bumped on incompatible change).
pub const BASELINE_VERSION: u64 = 1;

/// Default per-cell relative tolerance for [`Baseline::compare`]: far
/// above cross-platform float noise (≲1e-15), far below any genuine
/// model change.
pub const DEFAULT_TOLERANCE: f64 = 1e-6;

/// One pinned scenario: the full axis key plus the pinned values.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineCell {
    /// Sim-variant name (`None` for grids without a sim axis — the
    /// pre-ablation cell format, which older baselines keep).
    pub sim: Option<String>,
    /// Architecture name.
    pub arch: String,
    /// Machine-configuration name.
    pub machine: String,
    /// Processing units `p`.
    pub threads: usize,
    /// Training image count.
    pub train_images: usize,
    /// Test image count.
    pub test_images: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Model strategy.
    pub strategy: Strategy,
    /// Predicted total execution time, seconds.
    pub total_s: f64,
    /// Micsim measurement (measured grids only).
    pub measured_s: Option<f64>,
    /// Prediction accuracy Δ vs the measurement, percent.
    pub delta_pct: Option<f64>,
}

impl BaselineCell {
    /// The cell's identity: every axis value, human-readable. Used both
    /// as the diff-report identifier and as the matching key in
    /// [`Baseline::compare`] — one encoding, so reports always name
    /// cells by exactly the identity they matched under.
    pub fn key(&self) -> String {
        let mut key = format!(
            "{}/{}/p={}/i={}/it={}/ep={}/strat={}",
            self.arch,
            self.machine,
            self.threads,
            self.train_images,
            self.test_images,
            self.epochs,
            self.strategy
        );
        if let Some(sim) = &self.sim {
            key.push_str(&format!("/sim={sim}"));
        }
        key
    }

    fn to_json(&self) -> Json {
        let mut pairs = Vec::with_capacity(11);
        if let Some(sim) = &self.sim {
            pairs.push(("sim", Json::str(sim.clone())));
        }
        pairs.extend([
            ("arch", Json::str(self.arch.clone())),
            ("machine", Json::str(self.machine.clone())),
            ("threads", Json::num(self.threads as f64)),
            ("train_images", Json::num(self.train_images as f64)),
            ("test_images", Json::num(self.test_images as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("strategy", Json::str(self.strategy.as_str())),
            ("total_s", Json::num(self.total_s)),
        ]);
        if let Some(m) = self.measured_s {
            pairs.push(("measured_s", Json::num(m)));
        }
        if let Some(d) = self.delta_pct {
            pairs.push(("delta_pct", Json::num(d)));
        }
        Json::obj(pairs)
    }

    fn from_json(node: &Json) -> Result<BaselineCell> {
        let field_str = |key: &str| -> Result<String> {
            node.expect(key)?
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| Error::Json(format!("baseline cell {key} must be a string")))
        };
        let field_usize = |key: &str| -> Result<usize> {
            node.expect(key)?
                .as_usize()
                .ok_or_else(|| Error::Json(format!("baseline cell {key} must be an integer")))
        };
        let field_f64 = |key: &str| -> Result<f64> {
            node.expect(key)?
                .as_f64()
                .ok_or_else(|| Error::Json(format!("baseline cell {key} must be a number")))
        };
        // The shared strategy grammar (Strategy::parse_token), so a
        // pinned strategy-(c) sweep round-trips like any other.
        let strategy = Strategy::parse_token(
            node.expect("strategy")?
                .as_str()
                .ok_or_else(|| Error::Json("baseline cell strategy must be a string".into()))?,
        )?;
        let sim = match node.get("sim") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Json("baseline cell sim must be a string".into()))?,
            ),
        };
        Ok(BaselineCell {
            sim,
            arch: field_str("arch")?,
            machine: field_str("machine")?,
            threads: field_usize("threads")?,
            train_images: field_usize("train_images")?,
            test_images: field_usize("test_images")?,
            epochs: field_usize("epochs")?,
            strategy,
            total_s: field_f64("total_s")?,
            measured_s: node.get("measured_s").and_then(Json::as_f64),
            delta_pct: node.get("delta_pct").and_then(Json::as_f64),
        })
    }
}

/// A checked-in golden sweep: the grid spec plus one cell per scenario.
#[derive(Debug, Clone)]
pub struct Baseline {
    /// Spec document re-runnable via [`GridSpec::from_json`].
    pub grid_spec: Json,
    /// One pinned cell per scenario, in enumeration order.
    pub cells: Vec<BaselineCell>,
}

/// The pinned cells of one result set (shared by baseline capture and
/// the compare path, which needs no grid spec).
fn cells_of(results: &SweepResults) -> Vec<BaselineCell> {
    let g = &results.grid;
    results
        .results
        .iter()
        .map(|r| {
            let s = &r.scenario;
            BaselineCell {
                sim: g.sim_name(s).map(str::to_string),
                arch: g.archs[s.arch].name.clone(),
                machine: g.machines[s.machine].name.clone(),
                threads: s.threads,
                train_images: s.train_images,
                test_images: s.test_images,
                epochs: s.epochs,
                strategy: s.strategy,
                total_s: r.prediction.total_s,
                measured_s: r.measured_s,
                delta_pct: r.delta_pct,
            }
        })
        .collect()
}

impl Baseline {
    /// Capture a sweep's results as a baseline.
    ///
    /// Fails when the grid does not round-trip through its spec document
    /// (machine configs beyond the 7120P clock variants the spec format
    /// carries): such a baseline would make a later `--compare` silently
    /// re-run a *different* grid and report every cell as regressed.
    pub fn from_results(results: &SweepResults) -> Result<Baseline> {
        let g = &results.grid;
        let spec = g.to_spec_json()?;
        let back = GridSpec::from_json(&spec.emit())?;
        if back != *g {
            return Err(Error::Config(
                "grid does not round-trip through its spec document (machine \
                 configs beyond 7120P clock variants cannot be baselined — \
                 `--compare` would re-run a different grid)"
                    .into(),
            ));
        }
        Ok(Baseline { grid_spec: spec, cells: cells_of(results) })
    }

    /// The grid this baseline was written from.
    pub fn grid(&self) -> Result<GridSpec> {
        GridSpec::from_json(&self.grid_spec.emit())
    }

    /// Serialize as the committed baseline file format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("micdl-sweep-baseline")),
            ("version", Json::num(BASELINE_VERSION as f64)),
            ("grid", self.grid_spec.clone()),
            (
                "cells",
                Json::Arr(self.cells.iter().map(BaselineCell::to_json).collect()),
            ),
        ])
    }

    /// Parse a baseline file (version-checked).
    pub fn parse(text: &str) -> Result<Baseline> {
        let doc = Json::parse(text)?;
        match doc.get("version").and_then(Json::as_usize) {
            Some(v) if v as u64 == BASELINE_VERSION => {}
            other => {
                return Err(Error::Json(format!(
                    "baseline version {other:?} unsupported (want {BASELINE_VERSION})"
                )))
            }
        }
        let cells = doc
            .expect("cells")?
            .as_arr()
            .ok_or_else(|| Error::Json("baseline cells must be an array".into()))?
            .iter()
            .map(BaselineCell::from_json)
            .collect::<Result<Vec<_>>>()?;
        if cells.is_empty() {
            return Err(Error::Json("baseline has no cells".into()));
        }
        Ok(Baseline { grid_spec: doc.expect("grid")?.clone(), cells })
    }

    /// Load a baseline file.
    pub fn load(path: &std::path::Path) -> Result<Baseline> {
        Baseline::parse(&std::fs::read_to_string(path)?)
    }

    /// Diff a fresh sweep against this baseline under a per-cell
    /// relative tolerance (`|a−b| ≤ tol · max(|a|, |b|)`).
    pub fn compare(&self, results: &SweepResults, tolerance: f64) -> Result<DiffReport> {
        let current = cells_of(results);
        let mut index: HashMap<String, &BaselineCell> = HashMap::with_capacity(current.len());
        for cell in &current {
            index.insert(cell.key(), cell);
        }
        let mut report = DiffReport {
            tolerance,
            cells_compared: 0,
            mismatches: Vec::new(),
            missing_in_run: Vec::new(),
            missing_in_baseline: Vec::new(),
        };
        for want in &self.cells {
            let Some(got) = index.get(&want.key()) else {
                report.missing_in_run.push(want.key());
                continue;
            };
            report.cells_compared += 1;
            let fields = [
                ("total_s", Some(want.total_s), Some(got.total_s)),
                ("measured_s", want.measured_s, got.measured_s),
                ("delta_pct", want.delta_pct, got.delta_pct),
            ];
            for (field, base, cur) in fields {
                match (base, cur) {
                    (None, None) => {}
                    (Some(b), Some(c)) => {
                        if !within(b, c, tolerance) {
                            report.mismatches.push(CellDiff {
                                cell: want.key(),
                                field,
                                baseline: b,
                                current: c,
                                rel_err: rel_err(b, c),
                            });
                        }
                    }
                    // A pinned value the run no longer produces (or vice
                    // versa) is a structural regression, not noise.
                    (Some(b), None) => report.mismatches.push(CellDiff {
                        cell: want.key(),
                        field,
                        baseline: b,
                        current: f64::NAN,
                        rel_err: f64::INFINITY,
                    }),
                    (None, Some(c)) => report.mismatches.push(CellDiff {
                        cell: want.key(),
                        field,
                        baseline: f64::NAN,
                        current: c,
                        rel_err: f64::INFINITY,
                    }),
                }
            }
        }
        let baseline_keys: std::collections::HashSet<String> =
            self.cells.iter().map(BaselineCell::key).collect();
        for cell in &current {
            if !baseline_keys.contains(&cell.key()) {
                report.missing_in_baseline.push(cell.key());
            }
        }
        Ok(report)
    }
}

/// `|a−b| ≤ tol · max(|a|, |b|)` — symmetric relative closeness; exact
/// equality (including 0 vs 0) always passes, NaN never does.
fn within(a: f64, b: f64, tol: f64) -> bool {
    a == b || (a - b).abs() <= tol * a.abs().max(b.abs())
}

fn rel_err(a: f64, b: f64) -> f64 {
    if a == b {
        0.0
    } else {
        (a - b).abs() / a.abs().max(b.abs())
    }
}

/// One out-of-tolerance value.
#[derive(Debug, Clone)]
pub struct CellDiff {
    /// The offending scenario, as [`BaselineCell::key`].
    pub cell: String,
    /// Which pinned value drifted (`total_s` / `measured_s` / `delta_pct`).
    pub field: &'static str,
    /// The pinned value (NaN for a structurally missing side).
    pub baseline: f64,
    /// The freshly computed value (NaN for a structurally missing side).
    pub current: f64,
    /// Symmetric relative error between the two (∞ for structural).
    pub rel_err: f64,
}

/// The machine-readable outcome of [`Baseline::compare`].
#[derive(Debug, Clone)]
pub struct DiffReport {
    /// The per-cell relative tolerance the diff ran under.
    pub tolerance: f64,
    /// Cells present on both sides and value-compared.
    pub cells_compared: usize,
    /// Values outside the tolerance.
    pub mismatches: Vec<CellDiff>,
    /// Baseline cells the fresh sweep did not produce.
    pub missing_in_run: Vec<String>,
    /// Fresh cells the baseline does not pin.
    pub missing_in_baseline: Vec<String>,
}

impl DiffReport {
    /// No regression: every baseline cell matched within tolerance and
    /// the grids covered each other exactly.
    pub fn is_clean(&self) -> bool {
        self.mismatches.is_empty()
            && self.missing_in_run.is_empty()
            && self.missing_in_baseline.is_empty()
    }

    /// Serialize the diff as the machine-readable stdout payload.
    pub fn to_json(&self) -> Json {
        let strs = |v: &[String]| Json::Arr(v.iter().map(|s| Json::str(s.clone())).collect());
        // Structural mismatches carry NaN/∞ sentinels, which JSON cannot
        // represent — emit null instead of an unparseable literal.
        let num_or_null = |v: f64| if v.is_finite() { Json::num(v) } else { Json::Null };
        Json::obj(vec![
            ("clean", Json::Bool(self.is_clean())),
            ("tolerance", Json::num(self.tolerance)),
            ("cells_compared", Json::num(self.cells_compared as f64)),
            (
                "mismatches",
                Json::Arr(
                    self.mismatches
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("cell", Json::str(m.cell.clone())),
                                ("field", Json::str(m.field)),
                                ("baseline", num_or_null(m.baseline)),
                                ("current", num_or_null(m.current)),
                                ("rel_err", num_or_null(m.rel_err)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("missing_in_run", strs(&self.missing_in_run)),
            ("missing_in_baseline", strs(&self.missing_in_baseline)),
        ])
    }

    /// Human-readable summary, one line per finding.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for m in &self.mismatches {
            out.push_str(&format!(
                "REGRESSION {} {}: baseline {} vs current {} (rel err {:.3e} > tol {:.1e})\n",
                m.cell, m.field, m.baseline, m.current, m.rel_err, self.tolerance
            ));
        }
        for k in &self.missing_in_run {
            out.push_str(&format!("MISSING in run: {k}\n"));
        }
        for k in &self.missing_in_baseline {
            out.push_str(&format!("MISSING in baseline: {k}\n"));
        }
        out.push_str(&format!(
            "baseline compare: {} cells, {} mismatches, {} missing in run, \
             {} missing in baseline (tolerance {:.1e})\n",
            self.cells_compared,
            self.mismatches.len(),
            self.missing_in_run.len(),
            self.missing_in_baseline.len(),
            self.tolerance,
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::sweep::runner::SweepRunner;

    fn small_results() -> SweepResults {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![1, 240],
            strategies: vec![Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        SweepRunner::serial().run(&grid).unwrap()
    }

    #[test]
    fn fresh_baseline_compares_clean() {
        let res = small_results();
        let base = Baseline::from_results(&res).unwrap();
        let report = base.compare(&res, DEFAULT_TOLERANCE).unwrap();
        assert!(report.is_clean(), "{}", report.render());
        assert_eq!(report.cells_compared, 4);
    }

    #[test]
    fn json_round_trip_preserves_cells_and_grid() {
        let res = small_results();
        let base = Baseline::from_results(&res).unwrap();
        let back = Baseline::parse(&base.to_json().emit()).unwrap();
        assert_eq!(back.cells, base.cells);
        let grid = back.grid().unwrap();
        assert_eq!(grid.threads, vec![1, 240]);
        assert!(back.compare(&res, DEFAULT_TOLERANCE).unwrap().is_clean());
    }

    #[test]
    fn perturbed_cell_is_reported_with_its_key() {
        let res = small_results();
        let mut base = Baseline::from_results(&res).unwrap();
        base.cells[2].total_s *= 1.05;
        let report = base.compare(&res, DEFAULT_TOLERANCE).unwrap();
        assert!(!report.is_clean());
        assert_eq!(report.mismatches.len(), 1);
        let m = &report.mismatches[0];
        assert_eq!(m.field, "total_s");
        assert_eq!(m.cell, base.cells[2].key());
        assert!(m.cell.contains("p=240") && m.cell.contains("strat=a"), "{}", m.cell);
        assert!((m.rel_err - 0.05 / 1.05).abs() < 1e-3, "{}", m.rel_err);
        assert!(report.render().contains("REGRESSION"));
        // The machine-readable report names the same cell.
        let doc = report.to_json();
        assert_eq!(doc.get("clean").unwrap().as_bool(), Some(false));
        let mm = doc.get("mismatches").unwrap().as_arr().unwrap();
        assert_eq!(mm[0].get("cell").unwrap().as_str(), Some(m.cell.as_str()));
    }

    #[test]
    fn grid_mismatch_shows_up_as_missing_cells() {
        let res = small_results();
        let mut base = Baseline::from_results(&res).unwrap();
        // Pretend the baseline pinned a thread count the run lacks.
        base.cells[0].threads = 61;
        let report = base.compare(&res, DEFAULT_TOLERANCE).unwrap();
        assert!(!report.is_clean());
        assert_eq!(report.missing_in_run.len(), 1);
        assert_eq!(report.missing_in_baseline.len(), 1);
        assert!(report.missing_in_run[0].contains("p=61"));
    }

    #[test]
    fn measured_fields_are_pinned_and_compared() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![15],
            strategies: vec![Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        let mut base = Baseline::from_results(&res).unwrap();
        assert!(base.cells[0].measured_s.is_some());
        assert!(base.cells[0].delta_pct.is_some());
        assert!(base.compare(&res, DEFAULT_TOLERANCE).unwrap().is_clean());
        base.cells[0].delta_pct = Some(base.cells[0].delta_pct.unwrap() + 1.0);
        let report = base.compare(&res, DEFAULT_TOLERANCE).unwrap();
        assert_eq!(report.mismatches.len(), 1);
        assert_eq!(report.mismatches[0].field, "delta_pct");
        // A baseline pinning a field the run no longer produces is a
        // structural regression.
        let prediction_only = GridSpec { measure: false, ..grid };
        let pred_res = SweepRunner::serial().run(&prediction_only).unwrap();
        let base2 = Baseline::from_results(&res).unwrap();
        let report = base2.compare(&pred_res, DEFAULT_TOLERANCE).unwrap();
        assert!(!report.is_clean());
        assert!(report.mismatches.iter().any(|m| m.field == "measured_s"));
        // Structural mismatches (NaN/∞ sentinels) must still emit valid
        // JSON (null, not a bare NaN literal).
        let doc = Json::parse(&report.to_json().emit()).unwrap();
        let mm = doc.get("mismatches").unwrap().as_arr().unwrap();
        assert!(mm.iter().any(|m| m.get("current") == Some(&Json::Null)));
    }

    #[test]
    fn non_round_tripping_grid_is_rejected_at_capture() {
        // A machine differing from the 7120P in anything the spec format
        // cannot carry (here: memory bandwidth) must not be baselined —
        // `--compare` would silently re-run the stock machine.
        let mut machine = crate::config::MachineConfig::xeon_phi_7120p();
        machine.memory_bw_bytes /= 2.0;
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            machines: vec![machine],
            threads: vec![1],
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        let err = Baseline::from_results(&res);
        assert!(err.is_err(), "non-round-tripping grid must be rejected");
        assert!(err.unwrap_err().to_string().contains("round-trip"));
        // But comparing such a run against a valid baseline still works
        // (compare never needs the current run's spec).
        let clock_variant = GridSpec {
            machines: vec![crate::config::MachineConfig::xeon_phi_7120p_at_ghz(1.0)],
            ..grid
        };
        let res = SweepRunner::serial().run(&clock_variant).unwrap();
        let base = Baseline::from_results(&res).unwrap();
        assert!(base.compare(&res, DEFAULT_TOLERANCE).unwrap().is_clean());
    }

    #[test]
    fn version_and_shape_validation() {
        assert!(Baseline::parse("{}").is_err());
        assert!(Baseline::parse(r#"{"version": 99, "grid": {}, "cells": []}"#).is_err());
        assert!(Baseline::parse(r#"{"version": 1, "grid": {}, "cells": []}"#).is_err());
    }

    #[test]
    fn ablation_grids_baseline_with_sim_keyed_cells() {
        use crate::sweep::grid::SimVariant;
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![15],
            strategies: vec![Strategy::A],
            sims: vec![
                SimVariant { name: "slow".into(), clock_ghz: Some(1.0), ..Default::default() },
                SimVariant { name: "fast".into(), clock_ghz: Some(1.5), ..Default::default() },
            ],
            measure: true,
            ..GridSpec::default()
        };
        let res = SweepRunner::serial().run(&grid).unwrap();
        let base = Baseline::from_results(&res).unwrap();
        assert_eq!(base.cells.len(), 2);
        assert_eq!(base.cells[0].sim.as_deref(), Some("slow"));
        assert_eq!(base.cells[1].sim.as_deref(), Some("fast"));
        assert!(base.cells[0].key().ends_with("/sim=slow"));
        // Same workload, different variants → distinct keys, no collision.
        assert_ne!(base.cells[0].key(), base.cells[1].key());
        // File round-trip preserves the sim key and compares clean
        // against a fresh run of the embedded (ablation) grid.
        let back = Baseline::parse(&base.to_json().emit()).unwrap();
        assert_eq!(back.cells, base.cells);
        let regrid = back.grid().unwrap();
        assert_eq!(regrid, grid);
        let fresh = SweepRunner::serial().run(&regrid).unwrap();
        assert!(back.compare(&fresh, DEFAULT_TOLERANCE).unwrap().is_clean());
        // A variant mismatch is structural, not silent.
        let mut renamed = back.clone();
        renamed.cells[0].sim = Some("renamed".into());
        let report = renamed.compare(&fresh, DEFAULT_TOLERANCE).unwrap();
        assert!(!report.is_clean());
        assert_eq!(report.missing_in_run.len(), 1);
    }

    #[test]
    fn tolerance_is_respected() {
        let res = small_results();
        let mut base = Baseline::from_results(&res).unwrap();
        base.cells[0].total_s *= 1.0 + 1e-9;
        assert!(base.compare(&res, 1e-6).unwrap().is_clean());
        assert!(!base.compare(&res, 1e-12).unwrap().is_clean());
    }
}
