//! Declarative scenario grids: the cross-product the paper's evaluation
//! ranges over, as data.
//!
//! A [`GridSpec`] names one value list per axis — architecture, machine
//! configuration, (train, test) image counts, epochs, thread count, model
//! strategy — and [`GridSpec::enumerate`] expands the cross-product into a
//! deterministic, stably-ordered scenario list. The order is lexicographic
//! in axis position (arch → machine → images → epochs → threads →
//! strategy), so a scenario's id is pure stride arithmetic over the axis
//! indices and results can be addressed in O(1)
//! ([`crate::sweep::SweepResults::at`]).

use crate::config::{ArchSpec, MachineConfig, RunConfig};
use crate::error::{Error, Result};
use crate::perfmodel::ParamSource;
use crate::util::json::Json;

/// Which analytic model evaluates a scenario (paper Tables V / VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Strategy (a): minimal measurement, op-count driven.
    A,
    /// Strategy (b): measured per-image times.
    B,
}

impl Strategy {
    pub fn as_str(self) -> &'static str {
        match self {
            Strategy::A => "a",
            Strategy::B => "b",
        }
    }

    /// Parse a `--strategy` value: `a`, `b`, or `both`.
    pub fn parse_list(text: &str) -> Result<Vec<Strategy>> {
        match text {
            "a" => Ok(vec![Strategy::A]),
            "b" => Ok(vec![Strategy::B]),
            "both" | "ab" | "a,b" => Ok(vec![Strategy::A, Strategy::B]),
            other => Err(Error::Config(format!(
                "strategy must be a|b|both, got {other:?}"
            ))),
        }
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One point of the grid, with every axis resolved to a concrete value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    /// Stable index into the enumeration order (also the result slot).
    pub id: usize,
    /// Index into [`GridSpec::archs`].
    pub arch: usize,
    /// Index into [`GridSpec::machines`].
    pub machine: usize,
    pub train_images: usize,
    pub test_images: usize,
    pub epochs: usize,
    pub threads: usize,
    pub strategy: Strategy,
}

impl Scenario {
    /// The workload this scenario evaluates.
    pub fn run(&self) -> RunConfig {
        RunConfig {
            train_images: self.train_images,
            test_images: self.test_images,
            epochs: self.epochs,
            threads: self.threads,
        }
    }
}

/// A declarative scenario grid (one value list per axis).
#[derive(Debug, Clone, PartialEq)]
pub struct GridSpec {
    /// Architecture axis. Names must be unique (they key the sweep cache).
    pub archs: Vec<ArchSpec>,
    /// Machine-configuration axis (defaults to the paper's 7120P).
    pub machines: Vec<MachineConfig>,
    /// (train images, test images) axis.
    pub images: Vec<(usize, usize)>,
    /// Epoch axis; empty means "the paper default for each architecture"
    /// (70 for small/medium, 15 for large).
    pub epochs: Vec<usize>,
    /// Thread-count axis.
    pub threads: Vec<usize>,
    /// Model strategy axis.
    pub strategies: Vec<Strategy>,
    /// Parameter provenance for every model in the grid.
    pub params: ParamSource,
    /// Also "measure" each (arch, machine, workload) point on micsim and
    /// report the Δ accuracy next to the predictions.
    pub measure: bool,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec {
            archs: ArchSpec::paper_archs(),
            machines: vec![MachineConfig::xeon_phi_7120p()],
            images: vec![(60_000, 10_000)],
            epochs: Vec::new(),
            threads: RunConfig::MEASURED_THREADS.to_vec(),
            strategies: vec![Strategy::A, Strategy::B],
            params: ParamSource::Paper,
            measure: false,
        }
    }
}

/// Drop duplicate entries, keeping the first occurrence of each.
fn dedup_preserve<T: PartialEq + Clone>(values: &mut Vec<T>) {
    let mut seen: Vec<T> = Vec::with_capacity(values.len());
    values.retain(|v| {
        if seen.contains(v) {
            false
        } else {
            seen.push(v.clone());
            true
        }
    });
}

impl GridSpec {
    /// Number of scenarios the grid expands to.
    pub fn len(&self) -> usize {
        self.archs.len()
            * self.machines.len()
            * self.images.len()
            * self.epochs.len().max(1)
            * self.threads.len()
            * self.strategies.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reject grids the runner cannot evaluate.
    pub fn validate(&self) -> Result<()> {
        if self.archs.is_empty() {
            return Err(Error::Config("sweep grid has no architectures".into()));
        }
        let mut names: Vec<&str> = self.archs.iter().map(|a| a.name.as_str()).collect();
        names.sort_unstable();
        if names.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Config(
                "sweep grid architecture names must be unique (they key the cache)".into(),
            ));
        }
        if self.machines.is_empty() {
            return Err(Error::Config("sweep grid has no machine configs".into()));
        }
        if self.images.is_empty() {
            return Err(Error::Config("sweep grid has no image counts".into()));
        }
        if self.threads.is_empty() {
            return Err(Error::Config("sweep grid has no thread counts".into()));
        }
        if self.strategies.is_empty() {
            return Err(Error::Config("sweep grid has no strategies".into()));
        }
        if self.threads.iter().any(|&p| p == 0) {
            return Err(Error::Config("thread counts must be >= 1".into()));
        }
        if self.epochs.iter().any(|&e| e == 0) {
            return Err(Error::Config("epoch counts must be >= 1".into()));
        }
        if self.images.iter().any(|&(i, _)| i == 0) {
            return Err(Error::Config("train image counts must be >= 1".into()));
        }
        for m in &self.machines {
            if !(m.clock_hz.is_finite() && m.clock_hz > 0.0) {
                return Err(Error::Config(format!(
                    "machine {:?} has invalid clock {} Hz (must be finite and > 0)",
                    m.name, m.clock_hz
                )));
            }
            if m.cores == 0 || m.threads_per_core == 0 || m.cpi_ladder.is_empty() {
                return Err(Error::Config(format!(
                    "machine {:?} needs cores, threads_per_core, and a CPI ladder",
                    m.name
                )));
            }
        }
        for arch in &self.archs {
            arch.validate()?;
        }
        Ok(())
    }

    /// Dedup every axis in place, preserving first-occurrence order (so a
    /// user-supplied `--threads 1,15,15,1` grid stays `[1, 15]`).
    pub fn normalize(&mut self) {
        let mut seen_names: Vec<String> = Vec::new();
        self.archs.retain(|a| {
            if seen_names.contains(&a.name) {
                false
            } else {
                seen_names.push(a.name.clone());
                true
            }
        });
        dedup_preserve(&mut self.machines);
        dedup_preserve(&mut self.images);
        dedup_preserve(&mut self.epochs);
        dedup_preserve(&mut self.threads);
        dedup_preserve(&mut self.strategies);
    }

    /// Epoch values for one architecture (the paper default when the axis
    /// is empty).
    fn epochs_for(&self, arch: &ArchSpec) -> Vec<usize> {
        if self.epochs.is_empty() {
            vec![RunConfig::paper_default(&arch.name, 1).epochs]
        } else {
            self.epochs.clone()
        }
    }

    /// Expand the cross-product in deterministic lexicographic order.
    pub fn enumerate(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        let mut id = 0;
        for (ai, arch) in self.archs.iter().enumerate() {
            let epochs = self.epochs_for(arch);
            for mi in 0..self.machines.len() {
                for &(i, it) in &self.images {
                    for &ep in &epochs {
                        for &p in &self.threads {
                            for &s in &self.strategies {
                                out.push(Scenario {
                                    id,
                                    arch: ai,
                                    machine: mi,
                                    train_images: i,
                                    test_images: it,
                                    epochs: ep,
                                    threads: p,
                                    strategy: s,
                                });
                                id += 1;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The Table IX evaluation grid: the three paper architectures × the
    /// measured thread counts × both strategies, micsim measurement on
    /// (42 cells). The canonical measured domain — `repro exp table9`
    /// and the conformance harness both run exactly this grid.
    pub fn table9() -> GridSpec {
        GridSpec {
            threads: RunConfig::MEASURED_THREADS.to_vec(),
            measure: true,
            ..GridSpec::default()
        }
    }

    /// The Table X grid: extrapolation beyond the 244 hardware threads
    /// (24 cells). Prediction-only by default — the paper had no testbed
    /// measurements past 244 threads; the conformance harness turns
    /// `measure` on to pin micsim's stand-in numbers instead.
    pub fn table10() -> GridSpec {
        GridSpec {
            threads: crate::report::paper::TABLE10_THREADS.to_vec(),
            ..GridSpec::default()
        }
    }

    /// The Table XI grid: workload scaling — small CNN × the Table XI
    /// image/epoch/thread axes, strategy (a) only (18 cells),
    /// prediction-only by default like [`GridSpec::table10`].
    pub fn table11() -> GridSpec {
        use crate::report::paper;
        GridSpec {
            archs: vec![ArchSpec::small()],
            images: paper::TABLE11_IMAGES.to_vec(),
            epochs: paper::TABLE11_EPOCHS.to_vec(),
            threads: paper::TABLE11_THREADS.to_vec(),
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        }
    }

    /// Build a grid from a JSON spec document. Every key is optional and
    /// falls back to the paper defaults; unknown keys are rejected (a
    /// typo must not silently sweep the wrong grid). `threads` and
    /// `threads_range` are mutually exclusive ways to give the thread
    /// axis:
    ///
    /// ```json
    /// {
    ///   "archs": ["small", {"name": "tiny", "layers": [...]}],
    ///   "threads_range": {"from": 1, "to": 244, "step": 1},
    ///   "images": [[60000, 10000]],
    ///   "epochs": [70, 140],
    ///   "strategies": ["a", "b"],
    ///   "params": "paper",
    ///   "clock_ghz": [1.238],
    ///   "measure": false
    /// }
    /// ```
    pub fn from_json(text: &str) -> Result<GridSpec> {
        const KNOWN_KEYS: [&str; 9] = [
            "archs", "threads", "threads_range", "images", "epochs", "strategies",
            "params", "clock_ghz", "measure",
        ];
        let doc = Json::parse(text)?;
        let Some(pairs) = doc.as_obj() else {
            return Err(Error::Config("sweep spec must be a JSON object".into()));
        };
        for (key, _) in pairs {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "unknown sweep spec key {key:?} (known keys: {KNOWN_KEYS:?})"
                )));
            }
        }
        if doc.get("threads").is_some() && doc.get("threads_range").is_some() {
            return Err(Error::Config(
                "sweep spec gives both \"threads\" and \"threads_range\" — pick one".into(),
            ));
        }
        let mut grid = GridSpec::default();
        if let Some(archs) = doc.get("archs").and_then(Json::as_arr) {
            grid.archs = archs
                .iter()
                .map(|node| match node.as_str() {
                    Some(name) => ArchSpec::by_name(name),
                    None => ArchSpec::from_json(&node.emit()),
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(threads) = doc.get("threads").and_then(Json::as_arr) {
            grid.threads = usize_list(threads, "threads")?;
        }
        if let Some(range) = doc.get("threads_range") {
            let field = |key: &str, default: usize| -> Result<usize> {
                match range.get(key) {
                    None => Ok(default),
                    Some(v) => v.as_usize().ok_or_else(|| {
                        Error::Config(format!("threads_range.{key} must be an integer"))
                    }),
                }
            };
            let (from, to, step) = (field("from", 1)?, field("to", 244)?, field("step", 1)?);
            grid.threads = expand_range(from, to, step)?;
        }
        if let Some(images) = doc.get("images").and_then(Json::as_arr) {
            grid.images = images
                .iter()
                .map(|pair| {
                    let pair = pair.as_arr().unwrap_or(&[]);
                    match (
                        pair.first().and_then(Json::as_usize),
                        pair.get(1).and_then(Json::as_usize),
                    ) {
                        (Some(i), Some(it)) if pair.len() == 2 => Ok((i, it)),
                        _ => Err(Error::Config(
                            "images entries must be [train, test] integer pairs".into(),
                        )),
                    }
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(epochs) = doc.get("epochs").and_then(Json::as_arr) {
            grid.epochs = usize_list(epochs, "epochs")?;
        }
        if let Some(strategies) = doc.get("strategies").and_then(Json::as_arr) {
            let mut out = Vec::new();
            for s in strategies {
                match s.as_str() {
                    Some("a") => out.push(Strategy::A),
                    Some("b") => out.push(Strategy::B),
                    other => {
                        return Err(Error::Config(format!(
                            "strategies entries must be \"a\" or \"b\", got {other:?}"
                        )))
                    }
                }
            }
            grid.strategies = out;
        }
        if let Some(params) = doc.get("params").and_then(Json::as_str) {
            grid.params = match params {
                "paper" => ParamSource::Paper,
                "sim" | "simulator" => ParamSource::Simulator,
                other => {
                    return Err(Error::Config(format!(
                        "params must be paper|sim, got {other:?}"
                    )))
                }
            };
        }
        if let Some(clocks) = doc.get("clock_ghz").and_then(Json::as_arr) {
            grid.machines = clocks
                .iter()
                .map(|c| {
                    let ghz = c.as_f64().ok_or_else(|| {
                        Error::Config("clock_ghz entries must be numbers".into())
                    })?;
                    Ok(MachineConfig::xeon_phi_7120p_at_ghz(ghz))
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(measure) = doc.get("measure").and_then(Json::as_bool) {
            grid.measure = measure;
        }
        Ok(grid)
    }
}

impl GridSpec {
    /// Emit the grid as a spec document [`GridSpec::from_json`] parses
    /// back to an equivalent grid — the form a sweep baseline embeds so
    /// `repro sweep --compare baseline.json` can re-run the exact grid
    /// the baseline was written from.
    ///
    /// Paper architectures are emitted by name, custom ones inline.
    /// Machines are emitted as a `clock_ghz` axis (the only machine axis
    /// the spec format carries), and only when they differ from the
    /// default 7120P — grids built programmatically around other machine
    /// configs do not round-trip and should not be baselined.
    pub fn to_spec_json(&self) -> Result<Json> {
        let archs = self
            .archs
            .iter()
            .map(|arch| {
                if ArchSpec::by_name(&arch.name).ok().as_ref() == Some(arch) {
                    Ok(Json::str(arch.name.clone()))
                } else {
                    Json::parse(&arch.to_json())
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let mut pairs = vec![
            ("archs", Json::Arr(archs)),
            ("threads", Json::arr_usize(&self.threads)),
            (
                "images",
                Json::Arr(
                    self.images
                        .iter()
                        .map(|&(i, it)| Json::arr_usize(&[i, it]))
                        .collect(),
                ),
            ),
        ];
        if !self.epochs.is_empty() {
            pairs.push(("epochs", Json::arr_usize(&self.epochs)));
        }
        pairs.push((
            "strategies",
            Json::Arr(self.strategies.iter().map(|s| Json::str(s.as_str())).collect()),
        ));
        pairs.push((
            "params",
            Json::str(match self.params {
                ParamSource::Paper => "paper",
                ParamSource::Simulator => "sim",
            }),
        ));
        if self.machines != vec![MachineConfig::xeon_phi_7120p()] {
            pairs.push((
                "clock_ghz",
                Json::Arr(
                    self.machines
                        .iter()
                        .map(|m| Json::num(m.clock_hz / 1e9))
                        .collect(),
                ),
            ));
        }
        pairs.push(("measure", Json::Bool(self.measure)));
        Ok(Json::obj(pairs))
    }
}

fn usize_list(values: &[Json], key: &str) -> Result<Vec<usize>> {
    values
        .iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| Error::Config(format!("{key} entries must be integers")))
        })
        .collect()
}

fn expand_range(from: usize, to: usize, step: usize) -> Result<Vec<usize>> {
    if step == 0 {
        return Err(Error::Config("range step must be >= 1".into()));
    }
    if to < from {
        return Err(Error::Config(format!(
            "range end {to} is below range start {from}"
        )));
    }
    Ok((from..=to).step_by(step).collect())
}

/// Parse one integer-axis value: comma-separated items, each a single
/// value `n` or an inclusive range `a..b` / `a..b..step`.
pub fn parse_axis(text: &str) -> Result<Vec<usize>> {
    let parse_num = |s: &str| -> Result<usize> {
        s.trim()
            .parse()
            .map_err(|_| Error::Config(format!("axis wants integers, got {s:?} in {text:?}")))
    };
    let mut out = Vec::new();
    for item in text.split(',') {
        let item = item.trim();
        if item.is_empty() {
            return Err(Error::Config(format!("empty item in axis {text:?}")));
        }
        match item.split_once("..") {
            None => out.push(parse_num(item)?),
            Some((a, rest)) => {
                let (b, step) = match rest.split_once("..") {
                    None => (rest, 1),
                    Some((b, s)) => (b, parse_num(s)?),
                };
                out.extend(expand_range(parse_num(a)?, parse_num(b)?, step)?);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_covers_paper_evaluation() {
        let grid = GridSpec::default();
        // 3 archs × 1 machine × 1 image pair × default epochs × 7 thread
        // counts × 2 strategies.
        assert_eq!(grid.len(), 42);
        assert!(grid.validate().is_ok());
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 42);
        // Large CNN gets its own paper epoch default.
        let large = scenarios.iter().find(|s| s.arch == 2).unwrap();
        assert_eq!(large.epochs, 15);
        assert_eq!(scenarios[0].epochs, 70);
    }

    #[test]
    fn paper_grids_have_table_shapes_and_round_trip() {
        let t9 = GridSpec::table9();
        assert_eq!(t9.len(), 42);
        assert!(t9.measure, "Table IX is the measured evaluation");
        let t10 = GridSpec::table10();
        assert_eq!(t10.len(), 24);
        assert!(!t10.measure);
        assert_eq!(t10.threads, vec![480, 960, 1920, 3840]);
        let t11 = GridSpec::table11();
        assert_eq!(t11.len(), 18);
        assert_eq!(t11.strategies, vec![Strategy::A]);
        for grid in [t9, t10, t11] {
            assert!(grid.validate().is_ok());
            // All three must baseline: spec round-trip is exact.
            let back = GridSpec::from_json(&grid.to_spec_json().unwrap().emit()).unwrap();
            assert_eq!(back, grid);
        }
    }

    #[test]
    fn enumeration_ids_are_sequential_and_stable() {
        let grid = GridSpec::default();
        let a = grid.enumerate();
        let b = grid.enumerate();
        assert_eq!(a, b);
        for (i, s) in a.iter().enumerate() {
            assert_eq!(s.id, i);
        }
    }

    #[test]
    fn normalize_dedups_preserving_first_occurrence() {
        let mut grid = GridSpec {
            threads: vec![240, 1, 240, 61, 1],
            epochs: vec![70, 70, 15],
            strategies: vec![Strategy::A, Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        grid.normalize();
        assert_eq!(grid.threads, vec![240, 1, 61]);
        assert_eq!(grid.epochs, vec![70, 15]);
        assert_eq!(grid.strategies, vec![Strategy::A, Strategy::B]);
    }

    #[test]
    fn validate_rejects_bad_grids() {
        let empty = GridSpec { threads: Vec::new(), ..GridSpec::default() };
        assert!(empty.validate().is_err());
        let zero = GridSpec { threads: vec![0], ..GridSpec::default() };
        assert!(zero.validate().is_err());
        let dup = GridSpec {
            archs: vec![ArchSpec::small(), ArchSpec::small()],
            ..GridSpec::default()
        };
        assert!(dup.validate().is_err());
        let bad_clock = GridSpec {
            machines: vec![MachineConfig::xeon_phi_7120p_at_ghz(0.0)],
            ..GridSpec::default()
        };
        assert!(bad_clock.validate().is_err());
        let nan_clock = GridSpec {
            machines: vec![MachineConfig::xeon_phi_7120p_at_ghz(f64::NAN)],
            ..GridSpec::default()
        };
        assert!(nan_clock.validate().is_err());
    }

    #[test]
    fn axis_parser_accepts_lists_and_ranges() {
        assert_eq!(parse_axis("1,15,30").unwrap(), vec![1, 15, 30]);
        assert_eq!(parse_axis("1..5").unwrap(), vec![1, 2, 3, 4, 5]);
        assert_eq!(parse_axis("10..30..10").unwrap(), vec![10, 20, 30]);
        assert_eq!(parse_axis("1, 8..10").unwrap(), vec![1, 8, 9, 10]);
        assert!(parse_axis("").is_err());
        assert!(parse_axis("5..1").is_err());
        assert!(parse_axis("1..10..0").is_err());
        assert!(parse_axis("x").is_err());
    }

    #[test]
    fn strategy_parse_list() {
        assert_eq!(Strategy::parse_list("a").unwrap(), vec![Strategy::A]);
        assert_eq!(
            Strategy::parse_list("both").unwrap(),
            vec![Strategy::A, Strategy::B]
        );
        assert!(Strategy::parse_list("c").is_err());
    }

    #[test]
    fn json_spec_roundtrip() {
        let grid = GridSpec::from_json(
            r#"{
                "archs": ["small", "medium"],
                "threads_range": {"from": 10, "to": 30, "step": 10},
                "images": [[1000, 100]],
                "epochs": [2],
                "strategies": ["a"],
                "params": "sim",
                "measure": true
            }"#,
        )
        .unwrap();
        assert_eq!(grid.archs.len(), 2);
        assert_eq!(grid.threads, vec![10, 20, 30]);
        assert_eq!(grid.images, vec![(1000, 100)]);
        assert_eq!(grid.epochs, vec![2]);
        assert_eq!(grid.strategies, vec![Strategy::A]);
        assert_eq!(grid.params, ParamSource::Simulator);
        assert!(grid.measure);
        // 2 archs × 3 thread counts, all other axes singleton.
        assert_eq!(grid.len(), 6);
    }

    #[test]
    fn spec_emission_round_trips() {
        let grids = [
            GridSpec::default(),
            GridSpec {
                archs: vec![ArchSpec::small()],
                threads: vec![1, 61, 240],
                images: vec![(1_000, 100), (2_000, 200)],
                epochs: vec![2, 4],
                strategies: vec![Strategy::B],
                params: ParamSource::Simulator,
                machines: vec![
                    MachineConfig::xeon_phi_7120p_at_ghz(1.0),
                    MachineConfig::xeon_phi_7120p_at_ghz(1.5),
                ],
                measure: true,
            },
        ];
        for grid in grids {
            let spec = grid.to_spec_json().unwrap().emit();
            let back = GridSpec::from_json(&spec).unwrap();
            assert_eq!(back, grid, "{spec}");
        }
    }

    #[test]
    fn spec_emission_inlines_custom_archs() {
        let custom = ArchSpec::from_json(
            r#"{"name":"tiny","layers":[
                {"type":"conv","maps":4,"kernel":4},
                {"type":"pool","window":2},
                {"type":"dense","units":10}]}"#,
        )
        .unwrap();
        let grid = GridSpec { archs: vec![custom.clone()], ..GridSpec::default() };
        let spec = grid.to_spec_json().unwrap().emit();
        let back = GridSpec::from_json(&spec).unwrap();
        assert_eq!(back.archs, vec![custom]);
    }

    #[test]
    fn json_spec_rejects_garbage() {
        assert!(GridSpec::from_json("{").is_err());
        assert!(GridSpec::from_json(r#"{"strategies": ["z"]}"#).is_err());
        assert!(GridSpec::from_json(r#"{"images": [[1]]}"#).is_err());
        assert!(GridSpec::from_json(r#"{"threads": ["x"]}"#).is_err());
        // Non-object top level, typo'd keys, and ambiguous thread axes
        // must error instead of silently sweeping the default grid.
        assert!(GridSpec::from_json("[1, 2]").is_err());
        assert!(GridSpec::from_json(r#"{"thread": [1, 2]}"#).is_err());
        assert!(GridSpec::from_json(
            r#"{"threads": [1], "threads_range": {"from": 1, "to": 2}}"#
        )
        .is_err());
    }
}
