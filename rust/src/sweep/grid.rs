//! Declarative scenario grids: the cross-product the paper's evaluation
//! ranges over, as data.
//!
//! A [`GridSpec`] names one value list per axis — architecture, machine
//! configuration, (train, test) image counts, epochs, thread count, model
//! strategy, and (optionally) simulator configuration — and
//! [`GridSpec::enumerate`] expands the cross-product into a deterministic,
//! stably-ordered scenario list. The order is lexicographic in axis
//! position (sim → arch → machine → images → epochs → threads →
//! strategy), so a scenario's id is pure stride arithmetic over the axis
//! indices and results can be addressed in O(1)
//! ([`crate::sweep::SweepResults::at`]).
//!
//! The **sim axis** ([`SimVariant`]) makes the simulator configuration a
//! first-class sweep dimension: each variant is a named set of overrides
//! on [`SimConfig`] (clock, core/thread counts, cycle and cache/latency
//! constants, fidelity, seed), applied on top of the scenario's machine.
//! An empty axis means "the default simulator" and reproduces the
//! pre-ablation grid exactly.

use crate::config::{ArchSpec, MachineConfig, RunConfig};
use crate::error::{Error, Result};
use crate::perfmodel::ParamSource;
use crate::simulator::{Fidelity, SimConfig};
use crate::util::json::Json;

/// Which analytic model evaluates a scenario (paper Tables V / VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Strategy (a): minimal measurement, op-count driven.
    A,
    /// Strategy (b): measured per-image times.
    B,
    /// Strategy (c): strategy (b) corrected by a sweep-trained residual
    /// regressor ([`crate::calibration::ResidualModel`]).
    C,
}

impl Strategy {
    /// Lower-case paper label ("a" / "b" / "c") — the JSON/CSV encoding.
    pub fn as_str(self) -> &'static str {
        match self {
            Strategy::A => "a",
            Strategy::B => "b",
            Strategy::C => "c",
        }
    }

    /// Parse one strategy token. The **single** strategy-name grammar:
    /// CLI flags, JSON sweep specs, and serve batch queries all route
    /// here, so the three surfaces accept and reject identically with
    /// one error message.
    pub fn parse_token(token: &str) -> Result<Strategy> {
        match token {
            "a" => Ok(Strategy::A),
            "b" => Ok(Strategy::B),
            "c" => Ok(Strategy::C),
            other => Err(Error::Config(format!(
                "strategy must be a|b|c|both, got {other:?}"
            ))),
        }
    }

    /// Parse a `--strategy` value: a comma-separated token list
    /// ([`Strategy::parse_token`]), or the shorthands `both`/`ab` (= a,b)
    /// and `all`/`abc` (= a,b,c).
    pub fn parse_list(text: &str) -> Result<Vec<Strategy>> {
        match text {
            "both" | "ab" => Ok(vec![Strategy::A, Strategy::B]),
            "all" | "abc" => Ok(vec![Strategy::A, Strategy::B, Strategy::C]),
            list => list.split(',').map(|t| Strategy::parse_token(t.trim())).collect(),
        }
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One named simulator-configuration override set — a point on the grid's
/// sim axis.
///
/// Every field is optional: `None` inherits the base [`SimConfig`] (and,
/// for the machine fields, the grid's machine axis). Overrides **win**
/// over the machine axis: a variant that sets `clock_ghz` pins the
/// simulated clock for its cells regardless of `--clock-ghz` machine
/// variants — [`GridSpec::sim_machine_conflicts`] names such collisions
/// so the CLI can warn instead of silently dropping one side.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimVariant {
    /// Unique axis label (keys output rows and baseline cells).
    pub name: String,
    /// Override the simulated core clock, GHz (machine field — sim wins).
    pub clock_ghz: Option<f64>,
    /// Override the simulated physical core count (machine field).
    pub cores: Option<usize>,
    /// Override hardware threads per core (machine field).
    pub threads_per_core: Option<usize>,
    /// Override calibrated cycles per abstract forward operation.
    pub fwd_cycles_per_op: Option<f64>,
    /// Override calibrated cycles per abstract backward operation.
    pub bwd_cycles_per_op: Option<f64>,
    /// Override the issue-bound fraction of per-image cycles.
    pub exec_fraction: Option<f64>,
    /// Override the L2-sharing pressure coefficient α.
    pub l2_alpha: Option<f64>,
    /// Override the cap on the L2 working-set pressure ratio.
    pub l2_ratio_cap: Option<f64>,
    /// Override the ring/tag-directory latency coefficient β.
    pub ring_beta: Option<f64>,
    /// Override the per-software-thread oversubscription overhead.
    pub oversub_overhead: Option<f64>,
    /// Override the simulation granularity.
    pub fidelity: Option<Fidelity>,
    /// Override the simulator's deterministic jitter seed.
    pub seed: Option<u64>,
}

impl SimVariant {
    /// The JSON keys a variant object may carry (unknown keys are
    /// rejected — a typo must not silently ablate nothing).
    const KNOWN_KEYS: [&'static str; 13] = [
        "name",
        "clock_ghz",
        "cores",
        "threads_per_core",
        "fwd_cycles_per_op",
        "bwd_cycles_per_op",
        "exec_fraction",
        "l2_alpha",
        "l2_ratio_cap",
        "ring_beta",
        "oversub_overhead",
        "fidelity",
        "seed",
    ];

    /// Does this variant override any simulated-machine field (clock,
    /// cores, threads per core)? Such overrides win over the grid's
    /// machine axis.
    pub fn overrides_machine(&self) -> bool {
        self.clock_ghz.is_some() || self.cores.is_some() || self.threads_per_core.is_some()
    }

    /// Apply the overrides on top of `base` (whose `machine` is already
    /// the scenario's machine-axis value). Machine-field overrides
    /// replace the corresponding machine fields — **sim wins** over the
    /// machine axis.
    pub fn apply(&self, base: &SimConfig) -> SimConfig {
        let mut sim = base.clone();
        if let Some(ghz) = self.clock_ghz {
            sim.machine.clock_hz = ghz * 1e9;
        }
        if let Some(cores) = self.cores {
            sim.machine.cores = cores;
        }
        if let Some(tpc) = self.threads_per_core {
            sim.machine.threads_per_core = tpc;
        }
        if let Some(v) = self.fwd_cycles_per_op {
            sim.fwd_cycles_per_op = v;
        }
        if let Some(v) = self.bwd_cycles_per_op {
            sim.bwd_cycles_per_op = v;
        }
        if let Some(v) = self.exec_fraction {
            sim.exec_fraction = v;
        }
        if let Some(v) = self.l2_alpha {
            sim.l2_alpha = v;
        }
        if let Some(v) = self.l2_ratio_cap {
            sim.l2_ratio_cap = v;
        }
        if let Some(v) = self.ring_beta {
            sim.ring_beta = v;
        }
        if let Some(v) = self.oversub_overhead {
            sim.oversub_overhead = v;
        }
        if let Some(f) = self.fidelity {
            sim.fidelity = f;
        }
        if let Some(s) = self.seed {
            sim.seed = s;
        }
        sim
    }

    /// A compact name derived from the set overrides (used when a spec or
    /// the CLI gives none): `"clock=1.5,seed=7"`, or `"default"` for a
    /// no-op variant.
    pub fn auto_name(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some(v) = self.clock_ghz {
            parts.push(format!("clock={v}"));
        }
        if let Some(v) = self.cores {
            parts.push(format!("cores={v}"));
        }
        if let Some(v) = self.threads_per_core {
            parts.push(format!("tpc={v}"));
        }
        if let Some(v) = self.fwd_cycles_per_op {
            parts.push(format!("fwd={v}"));
        }
        if let Some(v) = self.bwd_cycles_per_op {
            parts.push(format!("bwd={v}"));
        }
        if let Some(v) = self.exec_fraction {
            parts.push(format!("exec={v}"));
        }
        if let Some(v) = self.l2_alpha {
            parts.push(format!("l2a={v}"));
        }
        if let Some(v) = self.l2_ratio_cap {
            parts.push(format!("l2cap={v}"));
        }
        if let Some(v) = self.ring_beta {
            parts.push(format!("ring={v}"));
        }
        if let Some(v) = self.oversub_overhead {
            parts.push(format!("oversub={v}"));
        }
        if let Some(f) = self.fidelity {
            parts.push(format!("fidelity={}", f.as_str()));
        }
        if let Some(s) = self.seed {
            parts.push(format!("seed={s}"));
        }
        if parts.is_empty() {
            "default".to_string()
        } else {
            parts.join(",")
        }
    }

    /// Reject override values the simulator cannot run under.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            return Err(Error::Config("sim variant has an empty name".into()));
        }
        let finite_pos = |key: &str, v: Option<f64>| -> Result<()> {
            match v {
                Some(v) if !(v.is_finite() && v > 0.0) => Err(Error::Config(format!(
                    "sim variant {:?}: {key} must be finite and > 0, got {v}",
                    self.name
                ))),
                _ => Ok(()),
            }
        };
        finite_pos("clock_ghz", self.clock_ghz)?;
        finite_pos("fwd_cycles_per_op", self.fwd_cycles_per_op)?;
        finite_pos("bwd_cycles_per_op", self.bwd_cycles_per_op)?;
        finite_pos("l2_ratio_cap", self.l2_ratio_cap)?;
        if let Some(v) = self.exec_fraction {
            if !(v.is_finite() && v > 0.0 && v <= 1.0) {
                return Err(Error::Config(format!(
                    "sim variant {:?}: exec_fraction must be in (0, 1], got {v}",
                    self.name
                )));
            }
        }
        let finite_nonneg = |key: &str, v: Option<f64>| -> Result<()> {
            match v {
                Some(v) if !(v.is_finite() && v >= 0.0) => Err(Error::Config(format!(
                    "sim variant {:?}: {key} must be finite and >= 0, got {v}",
                    self.name
                ))),
                _ => Ok(()),
            }
        };
        finite_nonneg("l2_alpha", self.l2_alpha)?;
        finite_nonneg("ring_beta", self.ring_beta)?;
        finite_nonneg("oversub_overhead", self.oversub_overhead)?;
        if self.cores == Some(0) || self.threads_per_core == Some(0) {
            return Err(Error::Config(format!(
                "sim variant {:?}: cores/threads_per_core must be >= 1",
                self.name
            )));
        }
        // The ring factor divides by (cores − 1): a single simulated core
        // is not a machine micsim models.
        if self.cores == Some(1) {
            return Err(Error::Config(format!(
                "sim variant {:?}: micsim needs >= 2 cores (ring model)",
                self.name
            )));
        }
        // The spec document stores the seed as a JSON number (f64);
        // beyond 2^53 the round-trip would silently alter it.
        if self.seed.map(|s| s > (1 << 53)).unwrap_or(false) {
            return Err(Error::Config(format!(
                "sim variant {:?}: seed must be <= 2^53 (it round-trips \
                 through a JSON number)",
                self.name
            )));
        }
        Ok(())
    }

    /// Emit as a spec-document object ([`SimVariant::from_json`] inverse).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("name", Json::str(self.name.clone()))];
        let mut num = |key: &'static str, v: Option<f64>| {
            if let Some(v) = v {
                pairs.push((key, Json::num(v)));
            }
        };
        num("clock_ghz", self.clock_ghz);
        num("fwd_cycles_per_op", self.fwd_cycles_per_op);
        num("bwd_cycles_per_op", self.bwd_cycles_per_op);
        num("exec_fraction", self.exec_fraction);
        num("l2_alpha", self.l2_alpha);
        num("l2_ratio_cap", self.l2_ratio_cap);
        num("ring_beta", self.ring_beta);
        num("oversub_overhead", self.oversub_overhead);
        if let Some(v) = self.cores {
            pairs.push(("cores", Json::num(v as f64)));
        }
        if let Some(v) = self.threads_per_core {
            pairs.push(("threads_per_core", Json::num(v as f64)));
        }
        if let Some(f) = self.fidelity {
            pairs.push(("fidelity", Json::str(f.as_str())));
        }
        if let Some(s) = self.seed {
            pairs.push(("seed", Json::num(s as f64)));
        }
        Json::obj(pairs)
    }

    /// Parse one variant object of a spec document's `sim` array. The
    /// `name` key is optional ([`SimVariant::auto_name`] fills it in).
    pub fn from_json(node: &Json) -> Result<SimVariant> {
        let Some(pairs) = node.as_obj() else {
            return Err(Error::Config("sim entries must be JSON objects".into()));
        };
        for (key, _) in pairs {
            if !Self::KNOWN_KEYS.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "unknown sim variant key {key:?} (known keys: {:?})",
                    Self::KNOWN_KEYS
                )));
            }
        }
        let num = |key: &str| -> Result<Option<f64>> {
            match node.get(key) {
                None => Ok(None),
                Some(v) => v.as_f64().map(Some).ok_or_else(|| {
                    Error::Config(format!("sim variant {key} must be a number"))
                }),
            }
        };
        let int = |key: &str| -> Result<Option<usize>> {
            match node.get(key) {
                None => Ok(None),
                Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                    Error::Config(format!("sim variant {key} must be an integer"))
                }),
            }
        };
        let fidelity = match node.get("fidelity") {
            None => None,
            Some(v) => {
                let text = v.as_str().ok_or_else(|| {
                    Error::Config("sim variant fidelity must be a string".into())
                })?;
                Some(Fidelity::parse(text)?)
            }
        };
        let mut variant = SimVariant {
            name: String::new(),
            clock_ghz: num("clock_ghz")?,
            cores: int("cores")?,
            threads_per_core: int("threads_per_core")?,
            fwd_cycles_per_op: num("fwd_cycles_per_op")?,
            bwd_cycles_per_op: num("bwd_cycles_per_op")?,
            exec_fraction: num("exec_fraction")?,
            l2_alpha: num("l2_alpha")?,
            l2_ratio_cap: num("l2_ratio_cap")?,
            ring_beta: num("ring_beta")?,
            oversub_overhead: num("oversub_overhead")?,
            fidelity,
            seed: int("seed")?.map(|s| s as u64),
        };
        variant.name = match node.get("name").map(|n| n.as_str()) {
            None => variant.auto_name(),
            Some(Some(name)) => name.to_string(),
            Some(None) => {
                return Err(Error::Config("sim variant name must be a string".into()))
            }
        };
        Ok(variant)
    }
}

/// One point of the grid, with every axis resolved to a concrete value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    /// Stable index into the enumeration order (also the result slot).
    pub id: usize,
    /// Index into [`GridSpec::sims`] (0 when the sim axis is empty — the
    /// implicit default-simulator variant).
    pub sim: usize,
    /// Index into [`GridSpec::archs`].
    pub arch: usize,
    /// Index into [`GridSpec::machines`].
    pub machine: usize,
    /// Training (and validation) image count.
    pub train_images: usize,
    /// Test image count.
    pub test_images: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Processing units `p`.
    pub threads: usize,
    /// Which analytic model evaluates this point.
    pub strategy: Strategy,
}

impl Scenario {
    /// The workload this scenario evaluates.
    pub fn run(&self) -> RunConfig {
        RunConfig {
            train_images: self.train_images,
            test_images: self.test_images,
            epochs: self.epochs,
            threads: self.threads,
        }
    }
}

/// A declarative scenario grid (one value list per axis).
///
/// ```
/// use micdl::sweep::{GridSpec, SimVariant, Strategy, SweepRunner};
///
/// // An ablation grid: the small CNN at two clock speeds, measured.
/// let grid = GridSpec {
///     archs: vec![micdl::config::ArchSpec::small()],
///     threads: vec![15, 240],
///     strategies: vec![Strategy::B],
///     sims: vec![
///         SimVariant { name: "slow".into(), clock_ghz: Some(1.0), ..Default::default() },
///         SimVariant { name: "fast".into(), clock_ghz: Some(1.5), ..Default::default() },
///     ],
///     measure: true,
///     ..GridSpec::default()
/// };
/// assert_eq!(grid.len(), 4); // 2 sim variants × 2 thread counts
/// let results = SweepRunner::serial().run(&grid).unwrap();
/// assert_eq!(results.len(), 4);
/// // The "fast" variant's simulated time beats the "slow" one.
/// let slow = results.at_sim(0, 0, 0, 0, 0, 1, 0).measured_s.unwrap();
/// let fast = results.at_sim(1, 0, 0, 0, 0, 1, 0).measured_s.unwrap();
/// assert!(fast < slow);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct GridSpec {
    /// Architecture axis. Names must be unique (they key the sweep cache).
    pub archs: Vec<ArchSpec>,
    /// Machine-configuration axis (defaults to the paper's 7120P).
    pub machines: Vec<MachineConfig>,
    /// (train images, test images) axis.
    pub images: Vec<(usize, usize)>,
    /// Epoch axis; empty means "the paper default for each architecture"
    /// (70 for small/medium, 15 for large).
    pub epochs: Vec<usize>,
    /// Thread-count axis.
    pub threads: Vec<usize>,
    /// Model strategy axis.
    pub strategies: Vec<Strategy>,
    /// Simulator-configuration (ablation) axis. Empty means "the default
    /// simulator" — a single implicit variant; names must be unique.
    pub sims: Vec<SimVariant>,
    /// Parameter provenance for every model in the grid.
    pub params: ParamSource,
    /// Also "measure" each (arch, machine, workload) point on micsim and
    /// report the Δ accuracy next to the predictions.
    pub measure: bool,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec {
            archs: ArchSpec::paper_archs(),
            machines: vec![MachineConfig::xeon_phi_7120p()],
            images: vec![(60_000, 10_000)],
            epochs: Vec::new(),
            threads: RunConfig::MEASURED_THREADS.to_vec(),
            strategies: vec![Strategy::A, Strategy::B],
            sims: Vec::new(),
            params: ParamSource::Paper,
            measure: false,
        }
    }
}

/// Drop duplicate entries, keeping the first occurrence of each.
fn dedup_preserve<T: PartialEq + Clone>(values: &mut Vec<T>) {
    let mut seen: Vec<T> = Vec::with_capacity(values.len());
    values.retain(|v| {
        if seen.contains(v) {
            false
        } else {
            seen.push(v.clone());
            true
        }
    });
}

impl GridSpec {
    /// Number of scenarios the grid expands to.
    pub fn len(&self) -> usize {
        self.sims.len().max(1)
            * self.archs.len()
            * self.machines.len()
            * self.images.len()
            * self.epochs.len().max(1)
            * self.threads.len()
            * self.strategies.len()
    }

    /// True when the grid expands to no scenarios.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sim-axis length including the implicit default variant (≥ 1).
    pub fn sim_count(&self) -> usize {
        self.sims.len().max(1)
    }

    /// The sim-axis label for one scenario (`None` when the axis is
    /// empty — the implicit default simulator).
    pub fn sim_name(&self, scn: &Scenario) -> Option<&str> {
        self.sims.get(scn.sim).map(|v| v.name.as_str())
    }

    /// The effective simulator configuration for one scenario: `base`
    /// with the scenario's machine-axis value substituted in, then the
    /// scenario's sim-variant overrides applied on top (**sim wins** over
    /// the machine axis on conflicting fields).
    pub fn resolved_sim(&self, base: &SimConfig, scn: &Scenario) -> SimConfig {
        let sim = SimConfig {
            machine: self.machines[scn.machine].clone(),
            ..base.clone()
        };
        match self.sims.get(scn.sim) {
            Some(variant) => variant.apply(&sim),
            None => sim,
        }
    }

    /// Machine-axis values that a sim variant will override (the
    /// composition is explicit: sim wins). One human-readable finding per
    /// (variant, machine) collision — the CLI prints these as warnings so
    /// `--clock-ghz 1.0 --sim-clock-ghz 1.5` is never silent.
    pub fn sim_machine_conflicts(&self) -> Vec<String> {
        let mut out = Vec::new();
        for v in &self.sims {
            if !v.overrides_machine() {
                continue;
            }
            for m in &self.machines {
                let clock_clash = v
                    .clock_ghz
                    .map(|ghz| (ghz * 1e9 - m.clock_hz).abs() > 1e-3)
                    .unwrap_or(false);
                let cores_clash = v.cores.map(|c| c != m.cores).unwrap_or(false);
                let tpc_clash = v
                    .threads_per_core
                    .map(|t| t != m.threads_per_core)
                    .unwrap_or(false);
                if clock_clash || cores_clash || tpc_clash {
                    out.push(format!(
                        "sim variant {:?} overrides machine {:?} \
                         (sim axis wins over the machine axis)",
                        v.name, m.name
                    ));
                }
            }
        }
        out
    }

    /// Reject grids the runner cannot evaluate.
    pub fn validate(&self) -> Result<()> {
        if self.archs.is_empty() {
            return Err(Error::Config("sweep grid has no architectures".into()));
        }
        let mut names: Vec<&str> = self.archs.iter().map(|a| a.name.as_str()).collect();
        names.sort_unstable();
        if names.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Config(
                "sweep grid architecture names must be unique (they key the cache)".into(),
            ));
        }
        if self.machines.is_empty() {
            return Err(Error::Config("sweep grid has no machine configs".into()));
        }
        if self.images.is_empty() {
            return Err(Error::Config("sweep grid has no image counts".into()));
        }
        if self.threads.is_empty() {
            return Err(Error::Config("sweep grid has no thread counts".into()));
        }
        if self.strategies.is_empty() {
            return Err(Error::Config("sweep grid has no strategies".into()));
        }
        if self.threads.iter().any(|&p| p == 0) {
            return Err(Error::Config("thread counts must be >= 1".into()));
        }
        if self.epochs.iter().any(|&e| e == 0) {
            return Err(Error::Config("epoch counts must be >= 1".into()));
        }
        if self.images.iter().any(|&(i, _)| i == 0) {
            return Err(Error::Config("train image counts must be >= 1".into()));
        }
        for m in &self.machines {
            if !(m.clock_hz.is_finite() && m.clock_hz > 0.0) {
                return Err(Error::Config(format!(
                    "machine {:?} has invalid clock {} Hz (must be finite and > 0)",
                    m.name, m.clock_hz
                )));
            }
            if m.cores == 0 || m.threads_per_core == 0 || m.cpi_ladder.is_empty() {
                return Err(Error::Config(format!(
                    "machine {:?} needs cores, threads_per_core, and a CPI ladder",
                    m.name
                )));
            }
        }
        for arch in &self.archs {
            arch.validate()?;
        }
        let mut sim_names: Vec<&str> = self.sims.iter().map(|v| v.name.as_str()).collect();
        sim_names.sort_unstable();
        if sim_names.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Config(
                "sim variant names must be unique (they key output rows and baselines)"
                    .into(),
            ));
        }
        for variant in &self.sims {
            variant.validate()?;
        }
        Ok(())
    }

    /// Dedup every axis in place, preserving first-occurrence order (so a
    /// user-supplied `--threads 1,15,15,1` grid stays `[1, 15]`).
    pub fn normalize(&mut self) {
        let mut seen_names: Vec<String> = Vec::new();
        self.archs.retain(|a| {
            if seen_names.contains(&a.name) {
                false
            } else {
                seen_names.push(a.name.clone());
                true
            }
        });
        dedup_preserve(&mut self.machines);
        dedup_preserve(&mut self.images);
        dedup_preserve(&mut self.epochs);
        dedup_preserve(&mut self.threads);
        dedup_preserve(&mut self.strategies);
        dedup_preserve(&mut self.sims);
    }

    /// Epoch values for one architecture (the paper default when the axis
    /// is empty).
    fn epochs_for(&self, arch: &ArchSpec) -> Vec<usize> {
        if self.epochs.is_empty() {
            vec![RunConfig::paper_default(&arch.name, 1).epochs]
        } else {
            self.epochs.clone()
        }
    }

    /// Expand the cross-product in deterministic lexicographic order
    /// (sim outermost, so a sim-free grid enumerates exactly as before
    /// the axis existed).
    pub fn enumerate(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        let mut id = 0;
        for si in 0..self.sim_count() {
            for (ai, arch) in self.archs.iter().enumerate() {
                let epochs = self.epochs_for(arch);
                for mi in 0..self.machines.len() {
                    for &(i, it) in &self.images {
                        for &ep in &epochs {
                            for &p in &self.threads {
                                for &s in &self.strategies {
                                    out.push(Scenario {
                                        id,
                                        sim: si,
                                        arch: ai,
                                        machine: mi,
                                        train_images: i,
                                        test_images: it,
                                        epochs: ep,
                                        threads: p,
                                        strategy: s,
                                    });
                                    id += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Scenarios of shard `k` of `n`: the enumeration filtered to ids
    /// with `id % n == k`. Scenarios keep their **parent-grid** ids and
    /// enumeration order, so shards are stable under re-enumeration,
    /// pairwise disjoint, cover the grid exactly once, and merge back by
    /// id ([`crate::sweep::merge_shards`]). The modulo split interleaves
    /// neighbouring scenarios across shards, which balances work even
    /// when one architecture is much more expensive than another.
    ///
    /// `n` may exceed [`GridSpec::len`]; the surplus shards are empty.
    pub fn shard(&self, k: usize, n: usize) -> Result<Vec<Scenario>> {
        if n == 0 {
            return Err(Error::Config("shard count must be >= 1".into()));
        }
        if k >= n {
            return Err(Error::Config(format!(
                "shard index {k} is out of range for {n} shards (0..{n})"
            )));
        }
        Ok(self.enumerate().into_iter().filter(|s| s.id % n == k).collect())
    }

    /// The Table IX evaluation grid: the three paper architectures × the
    /// measured thread counts × both strategies, micsim measurement on
    /// (42 cells). The canonical measured domain — `repro exp table9`
    /// and the conformance harness both run exactly this grid.
    pub fn table9() -> GridSpec {
        GridSpec {
            threads: RunConfig::MEASURED_THREADS.to_vec(),
            measure: true,
            ..GridSpec::default()
        }
    }

    /// The Table X grid: extrapolation beyond the 244 hardware threads
    /// (24 cells). Prediction-only by default — the paper had no testbed
    /// measurements past 244 threads; the conformance harness turns
    /// `measure` on to pin micsim's stand-in numbers instead.
    pub fn table10() -> GridSpec {
        GridSpec {
            threads: crate::report::paper::TABLE10_THREADS.to_vec(),
            ..GridSpec::default()
        }
    }

    /// The Table XI grid: workload scaling — small CNN × the Table XI
    /// image/epoch/thread axes, strategy (a) only (18 cells),
    /// prediction-only by default like [`GridSpec::table10`].
    pub fn table11() -> GridSpec {
        use crate::report::paper;
        GridSpec {
            archs: vec![ArchSpec::small()],
            images: paper::TABLE11_IMAGES.to_vec(),
            epochs: paper::TABLE11_EPOCHS.to_vec(),
            threads: paper::TABLE11_THREADS.to_vec(),
            strategies: vec![Strategy::A],
            ..GridSpec::default()
        }
    }

    /// The closed-loop conformance grid: the Table IX evaluation domain
    /// with `params = sim` — every model parameter (op counts, per-image
    /// times, contention) is probed from the **same** simulator that
    /// produces the measurements, the way the paper's authors measured
    /// theirs on the real testbed. The resulting Δ isolates the models'
    /// *structural* error (fractional vs ceiling division, L2/ring
    /// effects the analytic forms lack) from parameter error; `repro
    /// conformance` pins it against `baselines/closed_loop_smoke.json`.
    pub fn table9_closed_loop() -> GridSpec {
        GridSpec {
            params: ParamSource::Simulator,
            ..GridSpec::table9()
        }
    }

    /// Build a grid from a JSON spec document. Every key is optional and
    /// falls back to the paper defaults; unknown keys are rejected (a
    /// typo must not silently sweep the wrong grid). `threads` and
    /// `threads_range` are mutually exclusive ways to give the thread
    /// axis:
    ///
    /// ```json
    /// {
    ///   "archs": ["small", {"name": "tiny", "layers": [...]}],
    ///   "threads_range": {"from": 1, "to": 244, "step": 1},
    ///   "images": [[60000, 10000]],
    ///   "epochs": [70, 140],
    ///   "strategies": ["a", "b"],
    ///   "params": "paper",
    ///   "clock_ghz": [1.238],
    ///   "sim": [{"name": "hot", "clock_ghz": 1.5, "seed": 7}],
    ///   "measure": false
    /// }
    /// ```
    pub fn from_json(text: &str) -> Result<GridSpec> {
        const KNOWN_KEYS: [&str; 10] = [
            "archs", "threads", "threads_range", "images", "epochs", "strategies",
            "params", "clock_ghz", "sim", "measure",
        ];
        let doc = Json::parse(text)?;
        let Some(pairs) = doc.as_obj() else {
            return Err(Error::Config("sweep spec must be a JSON object".into()));
        };
        for (key, _) in pairs {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "unknown sweep spec key {key:?} (known keys: {KNOWN_KEYS:?})"
                )));
            }
        }
        if doc.get("threads").is_some() && doc.get("threads_range").is_some() {
            return Err(Error::Config(
                "sweep spec gives both \"threads\" and \"threads_range\" — pick one".into(),
            ));
        }
        let mut grid = GridSpec::default();
        if let Some(archs) = doc.get("archs").and_then(Json::as_arr) {
            grid.archs = archs
                .iter()
                .map(|node| match node.as_str() {
                    Some(name) => ArchSpec::by_name(name),
                    None => ArchSpec::from_json(&node.emit()),
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(threads) = doc.get("threads").and_then(Json::as_arr) {
            grid.threads = usize_list(threads, "threads")?;
        }
        if let Some(range) = doc.get("threads_range") {
            grid.threads = threads_range_from_json(range, "threads_range")?;
        }
        if let Some(images) = doc.get("images").and_then(Json::as_arr) {
            grid.images = images
                .iter()
                .map(|pair| {
                    let pair = pair.as_arr().unwrap_or(&[]);
                    match (
                        pair.first().and_then(Json::as_usize),
                        pair.get(1).and_then(Json::as_usize),
                    ) {
                        (Some(i), Some(it)) if pair.len() == 2 => Ok((i, it)),
                        _ => Err(Error::Config(
                            "images entries must be [train, test] integer pairs".into(),
                        )),
                    }
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(epochs) = doc.get("epochs").and_then(Json::as_arr) {
            grid.epochs = usize_list(epochs, "epochs")?;
        }
        if let Some(strategies) = doc.get("strategies").and_then(Json::as_arr) {
            let mut out = Vec::new();
            for s in strategies {
                let token = s.as_str().ok_or_else(|| {
                    Error::Config("strategies entries must be strings".into())
                })?;
                out.push(Strategy::parse_token(token)?);
            }
            grid.strategies = out;
        }
        if let Some(params) = doc.get("params").and_then(Json::as_str) {
            grid.params = match params {
                "paper" => ParamSource::Paper,
                "sim" | "simulator" => ParamSource::Simulator,
                other => {
                    return Err(Error::Config(format!(
                        "params must be paper|sim, got {other:?}"
                    )))
                }
            };
        }
        if let Some(clocks) = doc.get("clock_ghz").and_then(Json::as_arr) {
            grid.machines = clocks
                .iter()
                .map(|c| {
                    let ghz = c.as_f64().ok_or_else(|| {
                        Error::Config("clock_ghz entries must be numbers".into())
                    })?;
                    Ok(MachineConfig::xeon_phi_7120p_at_ghz(ghz))
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(sims) = doc.get("sim") {
            let arr = sims.as_arr().ok_or_else(|| {
                Error::Config("sim must be an array of variant objects".into())
            })?;
            grid.sims = arr
                .iter()
                .map(SimVariant::from_json)
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(measure) = doc.get("measure").and_then(Json::as_bool) {
            grid.measure = measure;
        }
        Ok(grid)
    }
}

impl GridSpec {
    /// Emit the grid as a spec document [`GridSpec::from_json`] parses
    /// back to an equivalent grid — the form a sweep baseline embeds so
    /// `repro sweep --compare baseline.json` can re-run the exact grid
    /// the baseline was written from.
    ///
    /// Paper architectures are emitted by name, custom ones inline.
    /// Machines are emitted as a `clock_ghz` axis (the only machine axis
    /// the spec format carries), and only when they differ from the
    /// default 7120P — grids built programmatically around other machine
    /// configs do not round-trip and should not be baselined.
    pub fn to_spec_json(&self) -> Result<Json> {
        let archs = self
            .archs
            .iter()
            .map(|arch| {
                if ArchSpec::by_name(&arch.name).ok().as_ref() == Some(arch) {
                    Ok(Json::str(arch.name.clone()))
                } else {
                    Json::parse(&arch.to_json())
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let mut pairs = vec![
            ("archs", Json::Arr(archs)),
            ("threads", Json::arr_usize(&self.threads)),
            (
                "images",
                Json::Arr(
                    self.images
                        .iter()
                        .map(|&(i, it)| Json::arr_usize(&[i, it]))
                        .collect(),
                ),
            ),
        ];
        if !self.epochs.is_empty() {
            pairs.push(("epochs", Json::arr_usize(&self.epochs)));
        }
        pairs.push((
            "strategies",
            Json::Arr(self.strategies.iter().map(|s| Json::str(s.as_str())).collect()),
        ));
        pairs.push((
            "params",
            Json::str(match self.params {
                ParamSource::Paper => "paper",
                ParamSource::Simulator => "sim",
            }),
        ));
        if self.machines != vec![MachineConfig::xeon_phi_7120p()] {
            pairs.push((
                "clock_ghz",
                Json::Arr(
                    self.machines
                        .iter()
                        .map(|m| Json::num(m.clock_hz / 1e9))
                        .collect(),
                ),
            ));
        }
        if !self.sims.is_empty() {
            pairs.push((
                "sim",
                Json::Arr(self.sims.iter().map(SimVariant::to_json).collect()),
            ));
        }
        pairs.push(("measure", Json::Bool(self.measure)));
        Ok(Json::obj(pairs))
    }
}

fn usize_list(values: &[Json], key: &str) -> Result<Vec<usize>> {
    values
        .iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| Error::Config(format!("{key} entries must be integers")))
        })
        .collect()
}

fn expand_range(from: usize, to: usize, step: usize) -> Result<Vec<usize>> {
    if step == 0 {
        return Err(Error::Config("range step must be >= 1".into()));
    }
    // A reversed range must error, never quietly expand to an empty
    // axis: an empty `threads` list would otherwise enumerate a 0-cell
    // grid that "succeeds" while sweeping nothing.
    if to < from {
        return Err(Error::Config(format!(
            "range end {to} is below range start {from} (an empty axis sweeps nothing)"
        )));
    }
    Ok((from..=to).step_by(step).collect())
}

/// Parse a `{"from": a, "to": b, "step": s}` JSON range object into a
/// thread ladder (defaults: `from` 1, `to` 244, `step` 1 — the paper's
/// full hardware-thread range). `axis` names the owning key in error
/// messages, so a reversed range in a sweep spec reports
/// `threads_range: ...` while a serve query reports the query's field.
/// Shared by [`GridSpec::from_json`] and the serve batch parser
/// ([`crate::serve`]) — one grammar, one validation path.
pub fn threads_range_from_json(range: &Json, axis: &str) -> Result<Vec<usize>> {
    let field = |key: &str, default: usize| -> Result<usize> {
        match range.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_usize()
                .ok_or_else(|| Error::Config(format!("{axis}.{key} must be an integer"))),
        }
    };
    let (from, to, step) = (field("from", 1)?, field("to", 244)?, field("step", 1)?);
    expand_range(from, to, step).map_err(|e| match e {
        Error::Config(m) => Error::Config(format!("{axis}: {m}")),
        other => other,
    })
}

/// Parse one integer-axis value: comma-separated items, each a single
/// value `n` or an inclusive range `a..b` / `a..b..step`.
pub fn parse_axis(text: &str) -> Result<Vec<usize>> {
    let parse_num = |s: &str| -> Result<usize> {
        s.trim()
            .parse()
            .map_err(|_| Error::Config(format!("axis wants integers, got {s:?} in {text:?}")))
    };
    let mut out = Vec::new();
    for item in text.split(',') {
        let item = item.trim();
        if item.is_empty() {
            return Err(Error::Config(format!("empty item in axis {text:?}")));
        }
        match item.split_once("..") {
            None => out.push(parse_num(item)?),
            Some((a, rest)) => {
                let (b, step) = match rest.split_once("..") {
                    None => (rest, 1),
                    Some((b, s)) => (b, parse_num(s)?),
                };
                out.extend(expand_range(parse_num(a)?, parse_num(b)?, step)?);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_covers_paper_evaluation() {
        let grid = GridSpec::default();
        // 3 archs × 1 machine × 1 image pair × default epochs × 7 thread
        // counts × 2 strategies.
        assert_eq!(grid.len(), 42);
        assert!(grid.validate().is_ok());
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 42);
        // Large CNN gets its own paper epoch default.
        let large = scenarios.iter().find(|s| s.arch == 2).unwrap();
        assert_eq!(large.epochs, 15);
        assert_eq!(scenarios[0].epochs, 70);
    }

    #[test]
    fn paper_grids_have_table_shapes_and_round_trip() {
        let t9 = GridSpec::table9();
        assert_eq!(t9.len(), 42);
        assert!(t9.measure, "Table IX is the measured evaluation");
        let t10 = GridSpec::table10();
        assert_eq!(t10.len(), 24);
        assert!(!t10.measure);
        assert_eq!(t10.threads, vec![480, 960, 1920, 3840]);
        let t11 = GridSpec::table11();
        assert_eq!(t11.len(), 18);
        assert_eq!(t11.strategies, vec![Strategy::A]);
        for grid in [t9, t10, t11] {
            assert!(grid.validate().is_ok());
            // All three must baseline: spec round-trip is exact.
            let back = GridSpec::from_json(&grid.to_spec_json().unwrap().emit()).unwrap();
            assert_eq!(back, grid);
        }
    }

    #[test]
    fn enumeration_ids_are_sequential_and_stable() {
        let grid = GridSpec::default();
        let a = grid.enumerate();
        let b = grid.enumerate();
        assert_eq!(a, b);
        for (i, s) in a.iter().enumerate() {
            assert_eq!(s.id, i);
        }
    }

    #[test]
    fn normalize_dedups_preserving_first_occurrence() {
        let mut grid = GridSpec {
            threads: vec![240, 1, 240, 61, 1],
            epochs: vec![70, 70, 15],
            strategies: vec![Strategy::A, Strategy::A, Strategy::B],
            ..GridSpec::default()
        };
        grid.normalize();
        assert_eq!(grid.threads, vec![240, 1, 61]);
        assert_eq!(grid.epochs, vec![70, 15]);
        assert_eq!(grid.strategies, vec![Strategy::A, Strategy::B]);
    }

    #[test]
    fn validate_rejects_bad_grids() {
        let empty = GridSpec { threads: Vec::new(), ..GridSpec::default() };
        assert!(empty.validate().is_err());
        let zero = GridSpec { threads: vec![0], ..GridSpec::default() };
        assert!(zero.validate().is_err());
        let dup = GridSpec {
            archs: vec![ArchSpec::small(), ArchSpec::small()],
            ..GridSpec::default()
        };
        assert!(dup.validate().is_err());
        let bad_clock = GridSpec {
            machines: vec![MachineConfig::xeon_phi_7120p_at_ghz(0.0)],
            ..GridSpec::default()
        };
        assert!(bad_clock.validate().is_err());
        let nan_clock = GridSpec {
            machines: vec![MachineConfig::xeon_phi_7120p_at_ghz(f64::NAN)],
            ..GridSpec::default()
        };
        assert!(nan_clock.validate().is_err());
    }

    #[test]
    fn axis_parser_accepts_lists_and_ranges() {
        assert_eq!(parse_axis("1,15,30").unwrap(), vec![1, 15, 30]);
        assert_eq!(parse_axis("1..5").unwrap(), vec![1, 2, 3, 4, 5]);
        assert_eq!(parse_axis("10..30..10").unwrap(), vec![10, 20, 30]);
        assert_eq!(parse_axis("1, 8..10").unwrap(), vec![1, 8, 9, 10]);
        assert!(parse_axis("").is_err());
        assert!(parse_axis("5..1").is_err());
        assert!(parse_axis("1..10..0").is_err());
        assert!(parse_axis("x").is_err());
    }

    #[test]
    fn strategy_parse_list() {
        assert_eq!(Strategy::parse_list("a").unwrap(), vec![Strategy::A]);
        assert_eq!(Strategy::parse_list("c").unwrap(), vec![Strategy::C]);
        assert_eq!(
            Strategy::parse_list("both").unwrap(),
            vec![Strategy::A, Strategy::B]
        );
        assert_eq!(
            Strategy::parse_list("all").unwrap(),
            vec![Strategy::A, Strategy::B, Strategy::C]
        );
        assert_eq!(
            Strategy::parse_list("b,c").unwrap(),
            vec![Strategy::B, Strategy::C]
        );
        // One grammar, one message — CLI, JSON specs, and serve queries
        // all report the offending token the same way.
        let err = Strategy::parse_list("z").unwrap_err().to_string();
        assert!(err.contains("a|b|c|both") && err.contains("\"z\""), "{err}");
        let err = Strategy::parse_token("z").unwrap_err().to_string();
        assert!(err.contains("a|b|c|both") && err.contains("\"z\""), "{err}");
    }

    #[test]
    fn json_spec_roundtrip() {
        let grid = GridSpec::from_json(
            r#"{
                "archs": ["small", "medium"],
                "threads_range": {"from": 10, "to": 30, "step": 10},
                "images": [[1000, 100]],
                "epochs": [2],
                "strategies": ["a"],
                "params": "sim",
                "measure": true
            }"#,
        )
        .unwrap();
        assert_eq!(grid.archs.len(), 2);
        assert_eq!(grid.threads, vec![10, 20, 30]);
        assert_eq!(grid.images, vec![(1000, 100)]);
        assert_eq!(grid.epochs, vec![2]);
        assert_eq!(grid.strategies, vec![Strategy::A]);
        assert_eq!(grid.params, ParamSource::Simulator);
        assert!(grid.measure);
        // 2 archs × 3 thread counts, all other axes singleton.
        assert_eq!(grid.len(), 6);
    }

    #[test]
    fn spec_emission_round_trips() {
        let grids = [
            GridSpec::default(),
            GridSpec {
                archs: vec![ArchSpec::small()],
                threads: vec![1, 61, 240],
                images: vec![(1_000, 100), (2_000, 200)],
                epochs: vec![2, 4],
                strategies: vec![Strategy::B],
                params: ParamSource::Simulator,
                machines: vec![
                    MachineConfig::xeon_phi_7120p_at_ghz(1.0),
                    MachineConfig::xeon_phi_7120p_at_ghz(1.5),
                ],
                measure: true,
            },
        ];
        for grid in grids {
            let spec = grid.to_spec_json().unwrap().emit();
            let back = GridSpec::from_json(&spec).unwrap();
            assert_eq!(back, grid, "{spec}");
        }
    }

    #[test]
    fn spec_emission_inlines_custom_archs() {
        let custom = ArchSpec::from_json(
            r#"{"name":"tiny","layers":[
                {"type":"conv","maps":4,"kernel":4},
                {"type":"pool","window":2},
                {"type":"dense","units":10}]}"#,
        )
        .unwrap();
        let grid = GridSpec { archs: vec![custom.clone()], ..GridSpec::default() };
        let spec = grid.to_spec_json().unwrap().emit();
        let back = GridSpec::from_json(&spec).unwrap();
        assert_eq!(back.archs, vec![custom]);
    }

    fn two_clock_variants() -> Vec<SimVariant> {
        vec![
            SimVariant {
                name: "slow".into(),
                clock_ghz: Some(1.0),
                ..Default::default()
            },
            SimVariant {
                name: "fast".into(),
                clock_ghz: Some(1.5),
                ..Default::default()
            },
        ]
    }

    #[test]
    fn sim_axis_multiplies_grid_and_is_outermost() {
        let grid = GridSpec { sims: two_clock_variants(), ..GridSpec::default() };
        assert_eq!(grid.len(), 84); // 2 × the 42-cell default grid
        assert!(grid.validate().is_ok());
        let scenarios = grid.enumerate();
        assert_eq!(scenarios.len(), 84);
        // Sim is the outermost axis: the first block is variant 0 and its
        // inner enumeration matches the sim-free grid exactly.
        assert!(scenarios.iter().take(42).all(|s| s.sim == 0));
        assert!(scenarios.iter().skip(42).all(|s| s.sim == 1));
        let plain = GridSpec::default().enumerate();
        for (a, b) in plain.iter().zip(scenarios.iter()) {
            assert_eq!((a.arch, a.threads, a.strategy), (b.arch, b.threads, b.strategy));
        }
        assert_eq!(grid.sim_name(&scenarios[0]), Some("slow"));
        assert_eq!(grid.sim_name(&scenarios[83]), Some("fast"));
        assert_eq!(GridSpec::default().sim_name(&plain[0]), None);
    }

    #[test]
    fn variant_apply_overrides_exactly_the_set_fields() {
        let base = SimConfig::default();
        let v = SimVariant {
            name: "x".into(),
            clock_ghz: Some(2.0),
            seed: Some(7),
            l2_alpha: Some(0.5),
            fidelity: Some(Fidelity::PerImage),
            ..Default::default()
        };
        let out = v.apply(&base);
        assert_eq!(out.machine.clock_hz, 2.0e9);
        assert_eq!(out.seed, 7);
        assert_eq!(out.l2_alpha, 0.5);
        assert_eq!(out.fidelity, Fidelity::PerImage);
        // Untouched fields inherit the base.
        assert_eq!(out.fwd_cycles_per_op, base.fwd_cycles_per_op);
        assert_eq!(out.machine.cores, base.machine.cores);
        // A no-op variant is the identity (same fingerprint).
        let noop = SimVariant { name: "noop".into(), ..Default::default() };
        assert_eq!(noop.apply(&base).fingerprint(), base.fingerprint());
        assert!(!noop.overrides_machine());
        assert!(v.overrides_machine());
    }

    #[test]
    fn sim_override_wins_over_machine_axis_and_conflict_is_named() {
        // The composition bugfix: --clock-ghz 1.0 with --sim-clock-ghz
        // 1.5 must resolve to 1.5 GHz (sim wins), and the grid must be
        // able to name the collision for a CLI warning.
        let grid = GridSpec {
            machines: vec![MachineConfig::xeon_phi_7120p_at_ghz(1.0)],
            sims: vec![SimVariant {
                name: "fast".into(),
                clock_ghz: Some(1.5),
                ..Default::default()
            }],
            ..GridSpec::default()
        };
        let scn = &grid.enumerate()[0];
        let resolved = grid.resolved_sim(&SimConfig::default(), scn);
        assert_eq!(resolved.machine.clock_hz, 1.5e9, "sim override must win");
        let conflicts = grid.sim_machine_conflicts();
        assert_eq!(conflicts.len(), 1, "{conflicts:?}");
        assert!(conflicts[0].contains("fast") && conflicts[0].contains("wins"));
        // Agreeing values are not a conflict.
        let agree = GridSpec {
            machines: vec![MachineConfig::xeon_phi_7120p_at_ghz(1.5)],
            ..grid.clone()
        };
        assert!(agree.sim_machine_conflicts().is_empty());
        // A non-machine override never conflicts.
        let seed_only = GridSpec {
            sims: vec![SimVariant { name: "s".into(), seed: Some(1), ..Default::default() }],
            ..agree
        };
        assert!(seed_only.sim_machine_conflicts().is_empty());
    }

    #[test]
    fn sim_spec_round_trips_and_auto_names() {
        let grid = GridSpec {
            archs: vec![ArchSpec::small()],
            threads: vec![15],
            strategies: vec![Strategy::A],
            sims: two_clock_variants(),
            measure: true,
            ..GridSpec::default()
        };
        let spec = grid.to_spec_json().unwrap().emit();
        let back = GridSpec::from_json(&spec).unwrap();
        assert_eq!(back, grid, "{spec}");
        // A nameless variant object gets its auto-derived name.
        let parsed = GridSpec::from_json(
            r#"{"sim": [{"clock_ghz": 1.5, "seed": 7}, {}]}"#,
        )
        .unwrap();
        assert_eq!(parsed.sims[0].name, "clock=1.5,seed=7");
        assert_eq!(parsed.sims[1].name, "default");
        // Unknown variant keys are rejected like unknown spec keys.
        assert!(GridSpec::from_json(r#"{"sim": [{"clokc_ghz": 1.5}]}"#).is_err());
        assert!(GridSpec::from_json(r#"{"sim": [1]}"#).is_err());
        assert!(GridSpec::from_json(r#"{"sim": [{"fidelity": "x"}]}"#).is_err());
    }

    #[test]
    fn fully_populated_variant_exercises_every_parallel_list() {
        // SimVariant's field set is mirrored in KNOWN_KEYS, apply,
        // auto_name, validate, to_json, and from_json. This literal sets
        // every override, so a field added to the struct but missed in
        // one of those lists fails here (once added to this literal —
        // the struct-update syntax below refuses to compile if the
        // literal itself falls behind the struct... it has no ..rest).
        let v = SimVariant {
            name: "full".into(),
            clock_ghz: Some(1.1),
            cores: Some(32),
            threads_per_core: Some(2),
            fwd_cycles_per_op: Some(20.0),
            bwd_cycles_per_op: Some(10.0),
            exec_fraction: Some(0.5),
            l2_alpha: Some(0.2),
            l2_ratio_cap: Some(2.0),
            ring_beta: Some(0.1),
            oversub_overhead: Some(0.02),
            fidelity: Some(Fidelity::PerImage),
            seed: Some(123),
        };
        assert!(v.validate().is_ok());
        // JSON round-trip preserves every override, and the emitted
        // object carries every known key.
        let emitted = v.to_json();
        assert_eq!(SimVariant::from_json(&emitted).unwrap(), v);
        for key in SimVariant::KNOWN_KEYS {
            assert!(emitted.get(key).is_some(), "to_json dropped {key:?}");
        }
        // auto_name derives one part per non-name override.
        let unnamed = SimVariant { name: String::new(), ..v.clone() };
        assert_eq!(
            unnamed.auto_name().split(',').count(),
            SimVariant::KNOWN_KEYS.len() - 1,
            "{}",
            unnamed.auto_name()
        );
        // apply() rewrites every corresponding resolved field.
        let base = SimConfig::default();
        let out = v.apply(&base);
        assert_eq!(out.machine.clock_hz, 1.1e9);
        assert_eq!(out.machine.cores, 32);
        assert_eq!(out.machine.threads_per_core, 2);
        assert_eq!(out.fwd_cycles_per_op, 20.0);
        assert_eq!(out.bwd_cycles_per_op, 10.0);
        assert_eq!(out.exec_fraction, 0.5);
        assert_eq!(out.l2_alpha, 0.2);
        assert_eq!(out.l2_ratio_cap, 2.0);
        assert_eq!(out.ring_beta, 0.1);
        assert_eq!(out.oversub_overhead, 0.02);
        assert_eq!(out.fidelity, Fidelity::PerImage);
        assert_eq!(out.seed, 123);
    }

    #[test]
    fn validate_rejects_bad_sim_axes() {
        let dup = GridSpec {
            sims: vec![
                SimVariant { name: "x".into(), ..Default::default() },
                SimVariant { name: "x".into(), seed: Some(1), ..Default::default() },
            ],
            ..GridSpec::default()
        };
        assert!(dup.validate().is_err());
        for bad in [
            SimVariant { name: "".into(), ..Default::default() },
            SimVariant { name: "z".into(), clock_ghz: Some(0.0), ..Default::default() },
            SimVariant { name: "z".into(), clock_ghz: Some(f64::NAN), ..Default::default() },
            SimVariant { name: "z".into(), exec_fraction: Some(1.5), ..Default::default() },
            SimVariant { name: "z".into(), cores: Some(1), ..Default::default() },
            SimVariant { name: "z".into(), threads_per_core: Some(0), ..Default::default() },
            SimVariant { name: "z".into(), l2_alpha: Some(-1.0), ..Default::default() },
            SimVariant { name: "z".into(), seed: Some(1 << 54), ..Default::default() },
        ] {
            let grid = GridSpec { sims: vec![bad.clone()], ..GridSpec::default() };
            assert!(grid.validate().is_err(), "{bad:?} must be rejected");
        }
        // Exact-duplicate variants are dropped by normalize (first wins).
        let mut dup_value = GridSpec {
            sims: vec![
                SimVariant { name: "x".into(), ..Default::default() },
                SimVariant { name: "x".into(), ..Default::default() },
            ],
            ..GridSpec::default()
        };
        dup_value.normalize();
        assert_eq!(dup_value.sims.len(), 1);
        assert!(dup_value.validate().is_ok());
    }

    #[test]
    fn closed_loop_grid_is_table9_under_sim_params() {
        let grid = GridSpec::table9_closed_loop();
        assert_eq!(grid.len(), 42);
        assert!(grid.measure);
        assert_eq!(grid.params, ParamSource::Simulator);
        assert!(grid.validate().is_ok());
        // It baselines: the spec document round-trips exactly.
        let back = GridSpec::from_json(&grid.to_spec_json().unwrap().emit()).unwrap();
        assert_eq!(back, grid);
    }

    #[test]
    fn shards_partition_the_enumeration_by_id() {
        let grid = GridSpec::default();
        for n in [1usize, 2, 3, 7, 41, 42, 43] {
            let mut ids = Vec::new();
            for k in 0..n {
                let shard = grid.shard(k, n).unwrap();
                assert!(shard.iter().all(|s| s.id % n == k), "n={n} k={k}");
                assert!(shard.windows(2).all(|w| w[0].id < w[1].id), "n={n} k={k}");
                ids.extend(shard.iter().map(|s| s.id));
            }
            ids.sort_unstable();
            assert_eq!(ids, (0..grid.len()).collect::<Vec<_>>(), "n={n}");
        }
        // The shard scenarios are the enumeration's, ids included.
        let all = grid.enumerate();
        for s in grid.shard(1, 3).unwrap() {
            assert_eq!(all[s.id], s);
        }
        assert!(grid.shard(0, 0).is_err());
        assert!(grid.shard(3, 3).is_err());
        // More shards than cells: the surplus shards are empty.
        assert!(grid.shard(43, 44).unwrap().is_empty());
    }

    #[test]
    fn json_spec_rejects_garbage() {
        assert!(GridSpec::from_json("{").is_err());
        assert!(GridSpec::from_json(r#"{"strategies": ["z"]}"#).is_err());
        assert!(GridSpec::from_json(r#"{"images": [[1]]}"#).is_err());
        assert!(GridSpec::from_json(r#"{"threads": ["x"]}"#).is_err());
        // Non-object top level, typo'd keys, and ambiguous thread axes
        // must error instead of silently sweeping the default grid.
        assert!(GridSpec::from_json("[1, 2]").is_err());
        assert!(GridSpec::from_json(r#"{"thread": [1, 2]}"#).is_err());
        assert!(GridSpec::from_json(
            r#"{"threads": [1], "threads_range": {"from": 1, "to": 2}}"#
        )
        .is_err());
    }

    #[test]
    fn reversed_or_degenerate_ranges_error_instead_of_emptying_the_axis() {
        // The silent-empty-grid bugfix: a reversed range must be a
        // config error naming the axis, not a 0-cell sweep.
        let err = GridSpec::from_json(r#"{"threads_range": {"from": 30, "to": 10}}"#)
            .expect_err("reversed threads_range must be rejected");
        let msg = err.to_string();
        assert!(msg.contains("threads_range"), "{msg}");
        assert!(msg.contains("below range start"), "{msg}");
        // Same guard through the CLI axis grammar (`--threads 30..10`).
        let err = parse_axis("30..10").expect_err("reversed CLI range must be rejected");
        assert!(err.to_string().contains("below range start"), "{err}");
        // Zero step errors with the axis context too.
        let err = GridSpec::from_json(
            r#"{"threads_range": {"from": 1, "to": 10, "step": 0}}"#,
        )
        .expect_err("zero step must be rejected");
        assert!(err.to_string().contains("threads_range"), "{err}");
        // The shared helper applies defaults and validates types.
        let range = Json::parse(r#"{"from": 10, "to": 30, "step": 10}"#).unwrap();
        assert_eq!(
            threads_range_from_json(&range, "threads_range").unwrap(),
            vec![10, 20, 30]
        );
        let bad = Json::parse(r#"{"from": "x"}"#).unwrap();
        let err = threads_range_from_json(&bad, "threads").unwrap_err();
        assert!(err.to_string().contains("threads.from"), "{err}");
    }
}
