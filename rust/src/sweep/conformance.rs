//! Measured-mode conformance: the paper's accuracy claim as a
//! regression-guarded artifact.
//!
//! The paper's headline result is *measured* — mean prediction error
//! Δ ≈ 15 % for model (a) and ≈ 11 % for model (b) against a real Xeon
//! Phi (Tables IX–XI) — yet the prediction-side golden baseline
//! (`baselines/ci_smoke.json`) pins only the closed-form side of every
//! Δ. This module guards the measured side:
//!
//! * [`paper_grids`] — the Tables IX/X/XI evaluation grids, measured on
//!   micsim (the testbed stand-in);
//! * [`BandSpec`] — one pinned Δ band per (grid × architecture ×
//!   strategy): mean/max Δ with absolute percentage-point tolerances;
//! * [`ClaimSpec`] — the per-strategy paper claim itself (mean Δ over
//!   the Table IX domain), as an [`crate::perfmodel::Band`] ceiling;
//! * [`ConformanceBaseline`] — bands + claims + re-runnable grid specs,
//!   serialized as `baselines/measured_smoke.json`;
//! * [`ConformanceReport`] — the machine-readable outcome of re-running
//!   every grid and checking each band and claim.
//!
//! The CLI surface is `repro conformance --baseline FILE` (exit 2 on
//! regression) / `--write-baseline FILE`; CI runs the check in the
//! tier-1 gate and uploads the report as a workflow artifact. The
//! Table X/XI bands run far above the paper claims (hundreds of
//! percent): beyond 244 threads the models extrapolate optimistically
//! while micsim pays oversubscription, so those bands pin the
//! divergence itself rather than any published accuracy number.
//!
//! A second, **closed-loop** grid set ([`closed_loop_grids`]) re-runs
//! the Table IX domain with `--params sim`
//! ([`GridSpec::table9_closed_loop`]): every model parameter is probed
//! from the same simulator that produces the measurements, so the
//! pinned Δ isolates the models' structural error (fractional vs
//! ceiling division, the L2/ring memory effects the closed forms lack)
//! from parameter error. `repro conformance --closed-loop FILE` checks
//! it against `baselines/closed_loop_smoke.json` the same way.
//!
//! A third, **residual** grid set ([`residual_grids`]) re-runs the
//! Tables IX–XI domains with strategies (b, c): the sweep-trained
//! residual regressor ([`crate::calibration::ResidualModel`]) against
//! the strategy-(b) base it corrects. Its baseline
//! (`baselines/residual_smoke.json`, `repro conformance --residual
//! FILE`) pins both strategies' bands *and* the ordering claim — on
//! every grid where an architecture has both bands, the fresh (c) mean
//! Δ must stay strictly below the fresh (b) mean Δ
//! ([`ConformanceBaseline::check_results`] reports a violation as a
//! finding, so the check exits 2).

use crate::error::{Error, Result};
use crate::perfmodel::Band;
use crate::report::paper;
use crate::sweep::grid::{GridSpec, Strategy};
use crate::sweep::runner::SweepRunner;
use crate::sweep::summary::SweepResults;
use crate::util::json::Json;

/// Baseline file format version (bumped on incompatible change).
pub const BASELINE_VERSION: u64 = 1;

/// The grid claims are evaluated on: Table IX is the paper's measured
/// accuracy domain.
pub const CLAIM_GRID: &str = "table9";

/// The claim grid of the closed-loop baseline: the Table IX domain with
/// every model parameter probed from the measuring simulator.
pub const CLOSED_LOOP_CLAIM_GRID: &str = "table9_closed_loop";

/// The claim grid of the residual baseline: the Table IX domain under
/// strategies (b, c).
pub const RESIDUAL_CLAIM_GRID: &str = "table9_residual";

/// Band-tolerance policy for [`ConformanceBaseline::capture`], matching
/// `baselines/generate_measured_smoke.py`: ±max(floor, 2 % relative)
/// percentage points on the mean. The floors dominate at the Table IX
/// scale (Δ ≈ 5–25 %); the relative term takes over on the
/// extrapolation grids where Δ runs to hundreds of percent.
pub const MEAN_TOL_PP_FLOOR: f64 = 1.0;
/// Percentage-point tolerance floor on a band's max Δ (see
/// [`MEAN_TOL_PP_FLOOR`]).
pub const MAX_TOL_PP_FLOOR: f64 = 2.0;
/// Relative tolerance term: ±2 % of the pinned value, whichever of
/// floor/relative is larger.
pub const TOL_REL: f64 = 0.02;

/// Headroom over the observed overall mean when writing a claim whose
/// observation already exceeds the paper value.
pub const CLAIM_HEADROOM_PP: f64 = 3.0;

/// The paper's headline mean Δ for one strategy: the mean of its
/// Table IX column (≈ 14.9 % for (a), ≈ 11.4 % for (b)). Strategy (c)
/// has no published column — the paper bar it must clear is (b)'s, the
/// model it corrects, so it maps to the same column.
pub fn paper_claim_mean_pct(strategy: Strategy) -> f64 {
    let col = match strategy {
        Strategy::A => 0,
        Strategy::B | Strategy::C => 1,
    };
    let sum: f64 = paper::ACCURACY_DELTA_PCT.iter().map(|row| row[col]).sum();
    sum / paper::ACCURACY_DELTA_PCT.len() as f64
}

/// The Tables IX–XI evaluation grids, measurement on — what
/// `repro conformance` runs end-to-end.
pub fn paper_grids() -> Vec<(&'static str, GridSpec)> {
    vec![
        ("table9", GridSpec::table9()),
        ("table10", GridSpec { measure: true, ..GridSpec::table10() }),
        ("table11", GridSpec { measure: true, ..GridSpec::table11() }),
    ]
}

/// Run every paper grid, labelled.
pub fn run_paper_grids(runner: &SweepRunner) -> Result<Vec<(String, SweepResults)>> {
    run_labelled(runner, paper_grids())
}

/// The closed-loop grid set: the Table IX domain under `--params sim`
/// ([`GridSpec::table9_closed_loop`]).
pub fn closed_loop_grids() -> Vec<(&'static str, GridSpec)> {
    vec![(CLOSED_LOOP_CLAIM_GRID, GridSpec::table9_closed_loop())]
}

/// Run every closed-loop grid, labelled.
pub fn run_closed_loop_grids(runner: &SweepRunner) -> Result<Vec<(String, SweepResults)>> {
    run_labelled(runner, closed_loop_grids())
}

/// The residual grid set: the Tables IX–XI domains under strategies
/// (b, c), measurement on — the (c)-beats-(b) evaluation surface.
pub fn residual_grids() -> Vec<(&'static str, GridSpec)> {
    let bc = vec![Strategy::B, Strategy::C];
    vec![
        (
            RESIDUAL_CLAIM_GRID,
            GridSpec { strategies: bc.clone(), ..GridSpec::table9() },
        ),
        (
            "table10_residual",
            GridSpec { strategies: bc.clone(), measure: true, ..GridSpec::table10() },
        ),
        (
            "table11_residual",
            GridSpec { strategies: bc, measure: true, ..GridSpec::table11() },
        ),
    ]
}

/// Run every residual grid, labelled.
pub fn run_residual_grids(runner: &SweepRunner) -> Result<Vec<(String, SweepResults)>> {
    run_labelled(runner, residual_grids())
}

fn run_labelled(
    runner: &SweepRunner,
    grids: Vec<(&'static str, GridSpec)>,
) -> Result<Vec<(String, SweepResults)>> {
    grids
        .into_iter()
        .map(|(id, grid)| Ok((id.to_string(), runner.run(&grid)?)))
        .collect()
}

fn strategy_from_json(node: &Json, what: &str) -> Result<Strategy> {
    let token = node
        .expect("strategy")?
        .as_str()
        .ok_or_else(|| Error::Json(format!("{what} strategy must be a string")))?;
    // The shared strategy grammar (Strategy::parse_token): baseline
    // files reject exactly what CLI flags and sweep specs reject.
    Strategy::parse_token(token)
}

fn field_f64(node: &Json, key: &str, what: &str) -> Result<f64> {
    node.expect(key)?
        .as_f64()
        .ok_or_else(|| Error::Json(format!("{what} {key} must be a number")))
}

fn field_usize(node: &Json, key: &str, what: &str) -> Result<usize> {
    node.expect(key)?
        .as_usize()
        .ok_or_else(|| Error::Json(format!("{what} {key} must be an integer")))
}

/// One pinned Δ band: an (architecture × strategy) group's mean/max Δ
/// with absolute percentage-point tolerances.
#[derive(Debug, Clone, PartialEq)]
pub struct BandSpec {
    /// Architecture name of the pinned group.
    pub arch: String,
    /// Model strategy of the pinned group.
    pub strategy: Strategy,
    /// Measured points the group must contain.
    pub points: usize,
    /// Pinned mean Δ over the group, percent.
    pub mean_delta_pct: f64,
    /// Pinned worst-point Δ over the group, percent.
    pub max_delta_pct: f64,
    /// Thread count of the pinned worst point (informational).
    pub max_at_threads: usize,
    /// Allowed |observed − pinned| drift of the mean, percentage points.
    pub mean_tol_pp: f64,
    /// Allowed drift of the max, percentage points.
    pub max_tol_pp: f64,
}

impl BandSpec {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::str(self.arch.clone())),
            ("strategy", Json::str(self.strategy.as_str())),
            ("points", Json::num(self.points as f64)),
            ("mean_delta_pct", Json::num(self.mean_delta_pct)),
            ("max_delta_pct", Json::num(self.max_delta_pct)),
            ("max_at_threads", Json::num(self.max_at_threads as f64)),
            ("mean_tol_pp", Json::num(self.mean_tol_pp)),
            ("max_tol_pp", Json::num(self.max_tol_pp)),
        ])
    }

    fn from_json(node: &Json) -> Result<BandSpec> {
        const WHAT: &str = "conformance band";
        let arch = node
            .expect("arch")?
            .as_str()
            .ok_or_else(|| Error::Json("conformance band arch must be a string".into()))?
            .to_string();
        let band = BandSpec {
            arch,
            strategy: strategy_from_json(node, WHAT)?,
            points: field_usize(node, "points", WHAT)?,
            mean_delta_pct: field_f64(node, "mean_delta_pct", WHAT)?,
            max_delta_pct: field_f64(node, "max_delta_pct", WHAT)?,
            max_at_threads: field_usize(node, "max_at_threads", WHAT)?,
            mean_tol_pp: field_f64(node, "mean_tol_pp", WHAT)?,
            max_tol_pp: field_f64(node, "max_tol_pp", WHAT)?,
        };
        if !(band.mean_tol_pp.is_finite() && band.mean_tol_pp >= 0.0)
            || !(band.max_tol_pp.is_finite() && band.max_tol_pp >= 0.0)
        {
            return Err(Error::Json(format!(
                "conformance band {}/{} tolerances must be finite and >= 0",
                band.arch, band.strategy
            )));
        }
        Ok(band)
    }
}

/// A per-strategy paper-claim ceiling, evaluated over one grid's whole
/// measured point set ([`SweepResults::accuracy_overall`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ClaimSpec {
    /// Strategy the claim constrains.
    pub strategy: Strategy,
    /// Grid id the claim folds over ([`CLAIM_GRID`] or
    /// [`CLOSED_LOOP_CLAIM_GRID`]).
    pub grid: String,
    /// Paper value + ceiling the observed mean must stay under.
    pub band: Band,
}

impl ClaimSpec {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", Json::str(self.strategy.as_str())),
            ("grid", Json::str(self.grid.clone())),
            ("paper_mean_pct", Json::num(self.band.paper_pct)),
            ("ceiling_pct", Json::num(self.band.ceiling_pct)),
        ])
    }

    fn from_json(node: &Json) -> Result<ClaimSpec> {
        const WHAT: &str = "conformance claim";
        Ok(ClaimSpec {
            strategy: strategy_from_json(node, WHAT)?,
            grid: node
                .expect("grid")?
                .as_str()
                .ok_or_else(|| Error::Json("conformance claim grid must be a string".into()))?
                .to_string(),
            band: Band {
                paper_pct: field_f64(node, "paper_mean_pct", WHAT)?,
                ceiling_pct: field_f64(node, "ceiling_pct", WHAT)?,
            },
        })
    }
}

/// One grid's pinned bands plus its re-runnable spec document.
#[derive(Debug, Clone)]
pub struct GridBands {
    /// Grid label (`table9` / `table10` / `table11` /
    /// `table9_closed_loop`).
    pub id: String,
    /// Spec document re-runnable via [`GridSpec::from_json`].
    pub spec: Json,
    /// One pinned band per measured (architecture × strategy) group.
    pub bands: Vec<BandSpec>,
}

/// The measured golden baseline: Δ bands over the paper grids plus the
/// per-strategy paper claims (`baselines/measured_smoke.json`).
#[derive(Debug, Clone)]
pub struct ConformanceBaseline {
    /// The per-strategy paper-claim ceilings.
    pub claims: Vec<ClaimSpec>,
    /// The pinned grids with their Δ bands.
    pub grids: Vec<GridBands>,
}

impl ConformanceBaseline {
    /// Run the paper grids and pin the observed bands — the
    /// `repro conformance --write-baseline` path. Tolerances follow the
    /// committed policy (±max(floor, 2 % relative)); claim ceilings are
    /// the paper value or the observation plus headroom, whichever is
    /// larger, so a regenerated baseline documents any divergence from
    /// the paper claim instead of hiding it.
    pub fn capture(runner: &SweepRunner) -> Result<ConformanceBaseline> {
        ConformanceBaseline::from_runs(&run_paper_grids(runner)?)
    }

    /// Run the closed-loop grid set ([`closed_loop_grids`]) and pin the
    /// observed bands — the `repro conformance --write-closed-loop`
    /// path. Claims fold over [`CLOSED_LOOP_CLAIM_GRID`].
    pub fn capture_closed_loop(runner: &SweepRunner) -> Result<ConformanceBaseline> {
        ConformanceBaseline::from_runs_with_claim(
            &run_closed_loop_grids(runner)?,
            CLOSED_LOOP_CLAIM_GRID,
        )
    }

    /// Run the residual grid set ([`residual_grids`]) and pin the
    /// observed bands — the `repro conformance --write-residual` path.
    /// Claims fold over [`RESIDUAL_CLAIM_GRID`]; a freshly captured
    /// baseline must already satisfy the (c)-below-(b) ordering, so a
    /// capture whose fit regressed refuses to write instead of pinning
    /// the regression.
    pub fn capture_residual(runner: &SweepRunner) -> Result<ConformanceBaseline> {
        let runs = run_residual_grids(runner)?;
        let base =
            ConformanceBaseline::from_runs_with_claim(&runs, RESIDUAL_CLAIM_GRID)?;
        let report = base.check_results(&runs);
        if !report.is_clean() {
            return Err(Error::Config(format!(
                "residual capture does not satisfy its own bands/ordering:\n{}",
                report.render()
            )));
        }
        Ok(base)
    }

    /// Build a baseline from already-evaluated labelled runs, folding
    /// the per-strategy claims over [`CLAIM_GRID`].
    pub fn from_runs(runs: &[(String, SweepResults)]) -> Result<ConformanceBaseline> {
        ConformanceBaseline::from_runs_with_claim(runs, CLAIM_GRID)
    }

    /// [`ConformanceBaseline::from_runs`] with an explicit claim grid
    /// (the closed-loop baseline folds its claims over
    /// [`CLOSED_LOOP_CLAIM_GRID`] instead).
    pub fn from_runs_with_claim(
        runs: &[(String, SweepResults)],
        claim_grid: &str,
    ) -> Result<ConformanceBaseline> {
        let mut grids = Vec::with_capacity(runs.len());
        for (id, res) in runs {
            // Conformance bands key groups by (arch, strategy) alone;
            // ablation grids would alias groups across sim variants —
            // pin those with `repro sweep --write-baseline` instead.
            if !res.grid.sims.is_empty() {
                return Err(Error::Config(format!(
                    "conformance grid {id:?} has a sim axis — ablation grids \
                     are pinned via sweep baselines, not conformance bands"
                )));
            }
            let bands: Vec<BandSpec> = res
                .accuracy()
                .iter()
                .map(|a| BandSpec {
                    arch: a.arch.clone(),
                    strategy: a.strategy,
                    points: a.points,
                    mean_delta_pct: a.mean_delta_pct,
                    max_delta_pct: a.max_delta_pct,
                    max_at_threads: a.max_at_threads,
                    mean_tol_pp: MEAN_TOL_PP_FLOOR.max(TOL_REL * a.mean_delta_pct),
                    max_tol_pp: MAX_TOL_PP_FLOOR.max(TOL_REL * a.max_delta_pct),
                })
                .collect();
            if bands.is_empty() {
                return Err(Error::Config(format!(
                    "conformance grid {id:?} produced no measured Δ groups \
                     (was it run with measure off?)"
                )));
            }
            grids.push(GridBands {
                id: id.clone(),
                spec: res.grid.to_spec_json()?,
                bands,
            });
        }
        let (_, claim_run) = runs
            .iter()
            .find(|(id, _)| id == claim_grid)
            .ok_or_else(|| {
                Error::Config(format!(
                    "conformance runs lack the claim grid {claim_grid:?}"
                ))
            })?;
        let mut claims = Vec::new();
        for &strategy in &claim_run.grid.strategies {
            let Some(overall) = claim_run.accuracy_overall(strategy) else {
                continue;
            };
            let paper_pct = paper_claim_mean_pct(strategy);
            claims.push(ClaimSpec {
                strategy,
                grid: claim_grid.to_string(),
                band: Band {
                    paper_pct,
                    ceiling_pct: paper_pct
                        .max(overall.mean_delta_pct + CLAIM_HEADROOM_PP),
                },
            });
        }
        if claims.is_empty() {
            return Err(Error::Config(
                "conformance claim grid produced no measured Δ".into(),
            ));
        }
        Ok(ConformanceBaseline { claims, grids })
    }

    /// Serialize as the committed baseline file format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("micdl-conformance-baseline")),
            ("version", Json::num(BASELINE_VERSION as f64)),
            (
                "claims",
                Json::Arr(self.claims.iter().map(ClaimSpec::to_json).collect()),
            ),
            (
                "grids",
                Json::Arr(
                    self.grids
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("id", Json::str(g.id.clone())),
                                ("spec", g.spec.clone()),
                                (
                                    "bands",
                                    Json::Arr(
                                        g.bands.iter().map(BandSpec::to_json).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a baseline file (version- and shape-checked).
    pub fn parse(text: &str) -> Result<ConformanceBaseline> {
        let doc = Json::parse(text)?;
        match doc.get("version").and_then(Json::as_usize) {
            Some(v) if v as u64 == BASELINE_VERSION => {}
            other => {
                return Err(Error::Json(format!(
                    "conformance baseline version {other:?} unsupported \
                     (want {BASELINE_VERSION})"
                )))
            }
        }
        let claims = doc
            .expect("claims")?
            .as_arr()
            .ok_or_else(|| Error::Json("conformance claims must be an array".into()))?
            .iter()
            .map(ClaimSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        if claims.is_empty() {
            // The write path never produces this (from_runs requires a
            // claim); a hand-edited file must not silently drop the
            // paper-claim gate while is_clean still reports PASS.
            return Err(Error::Json("conformance baseline has no claims".into()));
        }
        let mut grids = Vec::new();
        for node in doc
            .expect("grids")?
            .as_arr()
            .ok_or_else(|| Error::Json("conformance grids must be an array".into()))?
        {
            let id = node
                .expect("id")?
                .as_str()
                .ok_or_else(|| Error::Json("conformance grid id must be a string".into()))?
                .to_string();
            let bands = node
                .expect("bands")?
                .as_arr()
                .ok_or_else(|| Error::Json("conformance bands must be an array".into()))?
                .iter()
                .map(BandSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            if bands.is_empty() {
                return Err(Error::Json(format!(
                    "conformance grid {id:?} has no bands"
                )));
            }
            grids.push(GridBands { id, spec: node.expect("spec")?.clone(), bands });
        }
        if grids.is_empty() {
            return Err(Error::Json("conformance baseline has no grids".into()));
        }
        Ok(ConformanceBaseline { claims, grids })
    }

    /// Load a baseline file.
    pub fn load(path: &std::path::Path) -> Result<ConformanceBaseline> {
        ConformanceBaseline::parse(&std::fs::read_to_string(path)?)
    }

    /// Re-run every embedded grid and check all bands and claims.
    pub fn check(&self, runner: &SweepRunner) -> Result<ConformanceReport> {
        let mut runs = Vec::with_capacity(self.grids.len());
        for g in &self.grids {
            let grid = GridSpec::from_json(&g.spec.emit())?;
            runs.push((g.id.clone(), runner.run(&grid)?));
        }
        Ok(self.check_results(&runs))
    }

    /// Pure check against already-evaluated labelled runs.
    pub fn check_results(&self, runs: &[(String, SweepResults)]) -> ConformanceReport {
        let mut report = ConformanceReport {
            bands: Vec::new(),
            claims: Vec::new(),
            problems: Vec::new(),
            scenarios: 0,
        };
        for g in &self.grids {
            let Some((_, res)) = runs.iter().find(|(id, _)| *id == g.id) else {
                report.problems.push(format!("grid {:?} was not run", g.id));
                continue;
            };
            // Mirror the capture-side rejection: bands address groups by
            // (arch, strategy) alone, so on an ablation grid only the
            // first variant's groups would ever be compared — a silent
            // pass for every other variant. Surface it structurally.
            if !res.grid.sims.is_empty() {
                report.problems.push(format!(
                    "grid {}: run has a sim axis — conformance bands cannot \
                     address sim-variant groups (pin ablation grids with \
                     sweep baselines)",
                    g.id
                ));
                continue;
            }
            report.scenarios += res.len();
            let observed = res.accuracy();
            for band in &g.bands {
                let Some(obs) = observed
                    .iter()
                    .find(|a| a.arch == band.arch && a.strategy == band.strategy)
                else {
                    report.problems.push(format!(
                        "grid {}: no measured Δ group for {}/{}",
                        g.id, band.arch, band.strategy
                    ));
                    continue;
                };
                report.bands.push(BandCheck {
                    grid: g.id.clone(),
                    band: band.clone(),
                    observed_mean_pct: obs.mean_delta_pct,
                    observed_max_pct: obs.max_delta_pct,
                    observed_points: obs.points,
                    // NaN drift compares false: never a pass.
                    mean_ok: (obs.mean_delta_pct - band.mean_delta_pct).abs()
                        <= band.mean_tol_pp,
                    max_ok: (obs.max_delta_pct - band.max_delta_pct).abs()
                        <= band.max_tol_pp,
                    points_ok: obs.points == band.points,
                });
            }
            // A measured group the baseline does not pin is a coverage
            // gap, not silence.
            for obs in &observed {
                if !g
                    .bands
                    .iter()
                    .any(|b| b.arch == obs.arch && b.strategy == obs.strategy)
                {
                    report.problems.push(format!(
                        "grid {}: measured group {}/{} has no pinned band",
                        g.id, obs.arch, obs.strategy
                    ));
                }
            }
            // The residual ordering claim: wherever one grid pins both
            // the (b) and (c) bands of an architecture, the *fresh* (c)
            // mean Δ must sit strictly below the fresh (b) mean Δ — the
            // learned correction earning its keep is part of the pinned
            // contract, not just the band positions.
            for band in &g.bands {
                if band.strategy != Strategy::C {
                    continue;
                }
                if !g
                    .bands
                    .iter()
                    .any(|b| b.arch == band.arch && b.strategy == Strategy::B)
                {
                    continue;
                }
                let of = |s: Strategy| {
                    observed
                        .iter()
                        .find(|a| a.arch == band.arch && a.strategy == s)
                };
                // Missing groups were already reported above.
                let (Some(c_obs), Some(b_obs)) = (of(Strategy::C), of(Strategy::B))
                else {
                    continue;
                };
                // NaN compares false: never a pass.
                if !(c_obs.mean_delta_pct < b_obs.mean_delta_pct) {
                    report.problems.push(format!(
                        "grid {}: strategy (c) mean Δ {:.3} % must stay strictly \
                         below strategy (b)'s {:.3} % for arch {}",
                        g.id, c_obs.mean_delta_pct, b_obs.mean_delta_pct, band.arch
                    ));
                }
            }
        }
        for claim in &self.claims {
            let Some((_, res)) = runs.iter().find(|(id, _)| *id == claim.grid) else {
                report.problems.push(format!(
                    "claim {}: grid {:?} was not run",
                    claim.strategy, claim.grid
                ));
                continue;
            };
            match res.accuracy_overall(claim.strategy) {
                None => report.problems.push(format!(
                    "claim {}: grid {:?} has no measured Δ",
                    claim.strategy, claim.grid
                )),
                Some(overall) => report.claims.push(ClaimCheck {
                    claim: claim.clone(),
                    observed_mean_pct: overall.mean_delta_pct,
                    pass: claim.band.admits(overall.mean_delta_pct),
                }),
            }
        }
        report
    }
}

/// One band compared against a fresh run.
#[derive(Debug, Clone)]
pub struct BandCheck {
    /// Grid the band belongs to.
    pub grid: String,
    /// The pinned band.
    pub band: BandSpec,
    /// Freshly observed mean Δ, percent.
    pub observed_mean_pct: f64,
    /// Freshly observed max Δ, percent.
    pub observed_max_pct: f64,
    /// Freshly observed measured-point count.
    pub observed_points: usize,
    /// Mean drift within tolerance.
    pub mean_ok: bool,
    /// Max drift within tolerance.
    pub max_ok: bool,
    /// Point count matches the pin.
    pub points_ok: bool,
}

impl BandCheck {
    /// All three sub-checks hold.
    pub fn pass(&self) -> bool {
        self.mean_ok && self.max_ok && self.points_ok
    }
}

/// One paper claim compared against a fresh run.
#[derive(Debug, Clone)]
pub struct ClaimCheck {
    /// The pinned claim.
    pub claim: ClaimSpec,
    /// Freshly observed overall mean Δ, percent.
    pub observed_mean_pct: f64,
    /// Observation stayed under the ceiling.
    pub pass: bool,
}

/// The machine-readable outcome of a conformance check.
#[derive(Debug, Clone)]
pub struct ConformanceReport {
    /// One check per pinned band.
    pub bands: Vec<BandCheck>,
    /// One check per pinned claim.
    pub claims: Vec<ClaimCheck>,
    /// Structural findings: grids not run, groups without bands, bands
    /// without groups.
    pub problems: Vec<String>,
    /// Scenarios evaluated across all checked grids.
    pub scenarios: usize,
}

impl ConformanceReport {
    /// Conformance holds: every band and claim passed, nothing
    /// structural, and at least one band was actually checked.
    pub fn is_clean(&self) -> bool {
        !self.bands.is_empty()
            && self.problems.is_empty()
            && self.bands.iter().all(BandCheck::pass)
            && self.claims.iter().all(|c| c.pass)
    }

    /// Serialize as the machine-readable stdout payload.
    pub fn to_json(&self) -> Json {
        let bands = self
            .bands
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("grid", Json::str(b.grid.clone())),
                    ("arch", Json::str(b.band.arch.clone())),
                    ("strategy", Json::str(b.band.strategy.as_str())),
                    (
                        "points",
                        Json::obj(vec![
                            ("pinned", Json::num(b.band.points as f64)),
                            ("observed", Json::num(b.observed_points as f64)),
                        ]),
                    ),
                    (
                        "mean_delta_pct",
                        Json::obj(vec![
                            ("pinned", Json::num(b.band.mean_delta_pct)),
                            ("observed", Json::num(b.observed_mean_pct)),
                            ("tol_pp", Json::num(b.band.mean_tol_pp)),
                            ("ok", Json::Bool(b.mean_ok)),
                        ]),
                    ),
                    (
                        "max_delta_pct",
                        Json::obj(vec![
                            ("pinned", Json::num(b.band.max_delta_pct)),
                            ("observed", Json::num(b.observed_max_pct)),
                            ("tol_pp", Json::num(b.band.max_tol_pp)),
                            ("ok", Json::Bool(b.max_ok)),
                        ]),
                    ),
                    ("pass", Json::Bool(b.pass())),
                ])
            })
            .collect();
        let claims = self
            .claims
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("strategy", Json::str(c.claim.strategy.as_str())),
                    ("grid", Json::str(c.claim.grid.clone())),
                    ("paper_mean_pct", Json::num(c.claim.band.paper_pct)),
                    ("ceiling_pct", Json::num(c.claim.band.ceiling_pct)),
                    ("observed_mean_pct", Json::num(c.observed_mean_pct)),
                    ("pass", Json::Bool(c.pass)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("kind", Json::str("micdl-conformance-report")),
            ("clean", Json::Bool(self.is_clean())),
            ("scenarios", Json::num(self.scenarios as f64)),
            ("bands", Json::Arr(bands)),
            ("claims", Json::Arr(claims)),
            (
                "problems",
                Json::Arr(self.problems.iter().map(|p| Json::str(p.clone())).collect()),
            ),
        ])
    }

    /// Human-readable findings, one line per failure, plus a summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for b in &self.bands {
            if b.pass() {
                continue;
            }
            if !b.mean_ok {
                out.push_str(&format!(
                    "BAND REGRESSION {} {}/{} mean Δ: pinned {:.3} ± {:.2} pp, \
                     observed {:.3}\n",
                    b.grid,
                    b.band.arch,
                    b.band.strategy,
                    b.band.mean_delta_pct,
                    b.band.mean_tol_pp,
                    b.observed_mean_pct,
                ));
            }
            if !b.max_ok {
                out.push_str(&format!(
                    "BAND REGRESSION {} {}/{} max Δ: pinned {:.3} ± {:.2} pp, \
                     observed {:.3}\n",
                    b.grid,
                    b.band.arch,
                    b.band.strategy,
                    b.band.max_delta_pct,
                    b.band.max_tol_pp,
                    b.observed_max_pct,
                ));
            }
            if !b.points_ok {
                out.push_str(&format!(
                    "BAND REGRESSION {} {}/{} points: pinned {}, observed {}\n",
                    b.grid,
                    b.band.arch,
                    b.band.strategy,
                    b.band.points,
                    b.observed_points,
                ));
            }
        }
        for c in &self.claims {
            if !c.pass {
                out.push_str(&format!(
                    "CLAIM REGRESSION model ({}) over {}: mean Δ {:.3} % exceeds \
                     ceiling {:.3} % (paper ≈ {:.2} %)\n",
                    c.claim.strategy,
                    c.claim.grid,
                    c.observed_mean_pct,
                    c.claim.band.ceiling_pct,
                    c.claim.band.paper_pct,
                ));
            }
        }
        for p in &self.problems {
            out.push_str(&format!("STRUCTURAL: {p}\n"));
        }
        let failed_bands = self.bands.iter().filter(|b| !b.pass()).count();
        let failed_claims = self.claims.iter().filter(|c| !c.pass).count();
        out.push_str(&format!(
            "conformance: {} bands ({} failed), {} claims ({} failed), \
             {} structural problems over {} scenarios — {}\n",
            self.bands.len(),
            failed_bands,
            self.claims.len(),
            failed_claims,
            self.problems.len(),
            self.scenarios,
            if self.is_clean() { "PASS" } else { "FAIL" },
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_runs() -> Vec<(String, SweepResults)> {
        // A scaled-down claim grid: one arch, two thread counts, both
        // strategies, measured — enough structure for bands + claims.
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::small()],
            threads: vec![1, 15],
            strategies: vec![Strategy::A, Strategy::B],
            measure: true,
            ..GridSpec::default()
        };
        vec![(
            CLAIM_GRID.to_string(),
            SweepRunner::serial().run(&grid).unwrap(),
        )]
    }

    #[test]
    fn paper_claim_means_match_table9_columns() {
        let a = paper_claim_mean_pct(Strategy::A);
        let b = paper_claim_mean_pct(Strategy::B);
        assert!((a - 14.896666666666667).abs() < 1e-12, "{a}");
        assert!((b - 11.35).abs() < 1e-9, "{b}");
    }

    #[test]
    fn paper_grids_are_the_three_tables_measured() {
        let grids = paper_grids();
        assert_eq!(grids.len(), 3);
        let ids: Vec<&str> = grids.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec!["table9", "table10", "table11"]);
        for (id, grid) in &grids {
            assert!(grid.measure, "{id} must measure");
            assert!(grid.validate().is_ok(), "{id}");
        }
        assert_eq!(grids[0].1.len() + grids[1].1.len() + grids[2].1.len(), 84);
    }

    #[test]
    fn from_runs_pins_observed_bands_and_claims() {
        let runs = small_runs();
        let base = ConformanceBaseline::from_runs(&runs).unwrap();
        assert_eq!(base.grids.len(), 1);
        assert_eq!(base.grids[0].bands.len(), 2);
        assert_eq!(base.claims.len(), 2);
        for band in &base.grids[0].bands {
            assert_eq!(band.points, 2);
            assert!(band.mean_tol_pp >= MEAN_TOL_PP_FLOOR);
            assert!(band.max_tol_pp >= MAX_TOL_PP_FLOOR);
        }
        for claim in &base.claims {
            assert_eq!(claim.grid, CLAIM_GRID);
            assert!(claim.band.ceiling_pct >= claim.band.paper_pct);
        }
        // Checking against the very runs it was built from is clean.
        let report = base.check_results(&runs);
        assert!(report.is_clean(), "{}", report.render());
        assert_eq!(report.scenarios, 4);
    }

    #[test]
    fn json_round_trip_preserves_bands_and_claims() {
        let base = ConformanceBaseline::from_runs(&small_runs()).unwrap();
        let back = ConformanceBaseline::parse(&base.to_json().emit()).unwrap();
        assert_eq!(back.claims, base.claims);
        assert_eq!(back.grids.len(), base.grids.len());
        assert_eq!(back.grids[0].bands, base.grids[0].bands);
        assert_eq!(back.grids[0].id, base.grids[0].id);
        // The embedded spec still parses to the original grid.
        let grid = GridSpec::from_json(&back.grids[0].spec.emit()).unwrap();
        assert_eq!(grid.threads, vec![1, 15]);
        assert!(grid.measure);
    }

    #[test]
    fn drifted_band_and_claim_fail_with_named_findings() {
        let runs = small_runs();
        let mut base = ConformanceBaseline::from_runs(&runs).unwrap();
        base.grids[0].bands[0].mean_delta_pct += 50.0;
        base.claims[0].band.ceiling_pct = 0.01;
        let report = base.check_results(&runs);
        assert!(!report.is_clean());
        assert!(!report.bands[0].mean_ok);
        assert!(report.bands[1].pass());
        assert!(!report.claims[0].pass);
        assert!(report.claims[1].pass);
        let text = report.render();
        assert!(text.contains("BAND REGRESSION"), "{text}");
        assert!(text.contains("CLAIM REGRESSION"), "{text}");
        assert!(text.contains("FAIL"), "{text}");
        let doc = Json::parse(&report.to_json().emit()).unwrap();
        assert_eq!(doc.get("clean").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn structural_gaps_are_reported() {
        let runs = small_runs();
        let mut base = ConformanceBaseline::from_runs(&runs).unwrap();
        // A band for a group the run lacks, and a missing grid.
        base.grids[0].bands[0].arch = "phantom".into();
        base.grids.push(GridBands {
            id: "missing".into(),
            spec: base.grids[0].spec.clone(),
            bands: base.grids[0].bands.clone(),
        });
        let report = base.check_results(&runs);
        assert!(!report.is_clean());
        // phantom band has no group; small/a group lost its band; the
        // extra grid was never run.
        assert!(report.problems.iter().any(|p| p.contains("phantom")));
        assert!(report.problems.iter().any(|p| p.contains("no pinned band")));
        assert!(report.problems.iter().any(|p| p.contains("was not run")));
        assert!(report.render().contains("STRUCTURAL"));
    }

    #[test]
    fn version_and_shape_validation() {
        assert!(ConformanceBaseline::parse("{}").is_err());
        assert!(ConformanceBaseline::parse(
            r#"{"version": 99, "claims": [], "grids": []}"#
        )
        .is_err());
        assert!(ConformanceBaseline::parse(
            r#"{"version": 1, "claims": [], "grids": []}"#
        )
        .is_err());
        // Dropping the claims (grids intact) must not parse — it would
        // silently disarm the paper-claim gate.
        let mut base = ConformanceBaseline::from_runs(&small_runs()).unwrap();
        base.claims.clear();
        let err = ConformanceBaseline::parse(&base.to_json().emit());
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("no claims"));
    }

    #[test]
    fn closed_loop_capture_checks_clean_and_round_trips() {
        // A scaled-down closed-loop claim grid: params = sim, measured.
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::small()],
            threads: vec![1, 15],
            strategies: vec![Strategy::A, Strategy::B],
            params: crate::perfmodel::ParamSource::Simulator,
            measure: true,
            ..GridSpec::default()
        };
        let runs = vec![(
            CLOSED_LOOP_CLAIM_GRID.to_string(),
            SweepRunner::serial().run(&grid).unwrap(),
        )];
        let base =
            ConformanceBaseline::from_runs_with_claim(&runs, CLOSED_LOOP_CLAIM_GRID).unwrap();
        assert_eq!(base.claims.len(), 2);
        for claim in &base.claims {
            assert_eq!(claim.grid, CLOSED_LOOP_CLAIM_GRID);
        }
        // The embedded spec re-runs under sim params.
        let back = ConformanceBaseline::parse(&base.to_json().emit()).unwrap();
        let regrid = GridSpec::from_json(&back.grids[0].spec.emit()).unwrap();
        assert_eq!(regrid.params, crate::perfmodel::ParamSource::Simulator);
        let report = back.check_results(&runs);
        assert!(report.is_clean(), "{}", report.render());
        // Using the wrong claim grid errors instead of silently pinning
        // nothing.
        assert!(ConformanceBaseline::from_runs(&runs).is_err());
    }

    #[test]
    fn closed_loop_grid_set_is_table9_under_sim_params() {
        let grids = closed_loop_grids();
        assert_eq!(grids.len(), 1);
        assert_eq!(grids[0].0, CLOSED_LOOP_CLAIM_GRID);
        assert_eq!(grids[0].1.len(), 42);
        assert!(grids[0].1.measure);
        assert_eq!(grids[0].1.params, crate::perfmodel::ParamSource::Simulator);
    }

    #[test]
    fn residual_grid_set_is_tables9_to_11_under_bc() {
        let grids = residual_grids();
        assert_eq!(grids.len(), 3);
        let ids: Vec<&str> = grids.iter().map(|(id, _)| *id).collect();
        assert_eq!(
            ids,
            vec!["table9_residual", "table10_residual", "table11_residual"]
        );
        assert_eq!(grids[0].0, RESIDUAL_CLAIM_GRID);
        for (id, grid) in &grids {
            assert!(grid.measure, "{id} must measure");
            assert_eq!(grid.strategies, vec![Strategy::B, Strategy::C], "{id}");
            assert!(grid.validate().is_ok(), "{id}");
        }
        assert_eq!(grids[0].1.len(), 42);
        assert_eq!(grids[1].1.len(), 24);
        assert_eq!(grids[2].1.len(), 36);
    }

    #[test]
    fn residual_runs_pin_bc_bands_and_order_c_below_b() {
        // The claim grid restricted to one architecture — the full
        // three-grid capture is pinned by tests/conformance.rs against
        // the committed baseline.
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::small()],
            strategies: vec![Strategy::B, Strategy::C],
            measure: true,
            ..GridSpec::default()
        };
        let runs = vec![(
            RESIDUAL_CLAIM_GRID.to_string(),
            SweepRunner::serial().run(&grid).unwrap(),
        )];
        let base =
            ConformanceBaseline::from_runs_with_claim(&runs, RESIDUAL_CLAIM_GRID).unwrap();
        assert_eq!(base.grids[0].bands.len(), 2);
        assert_eq!(base.claims.len(), 2);
        for claim in &base.claims {
            assert!(
                (claim.band.paper_pct - 11.35).abs() < 1e-9,
                "the paper bar for both (b) and (c) is (b)'s Table IX mean: {claim:?}"
            );
        }
        let report = base.check_results(&runs);
        assert!(report.is_clean(), "{}", report.render());

        // Flipping the strategy labels swaps the observed groups, so the
        // ordering claim — (c) strictly below (b) — must fail loudly.
        let mut flipped = runs;
        for r in &mut flipped[0].1.results {
            r.scenario.strategy = match r.scenario.strategy {
                Strategy::B => Strategy::C,
                Strategy::C => Strategy::B,
                s => s,
            };
        }
        let report = base.check_results(&flipped);
        assert!(!report.is_clean());
        assert!(
            report.problems.iter().any(|p| p.contains("strictly")),
            "{:?}",
            report.problems
        );
    }

    #[test]
    fn ablation_grids_are_rejected_by_conformance_capture() {
        use crate::sweep::grid::SimVariant;
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::small()],
            threads: vec![1],
            strategies: vec![Strategy::A],
            sims: vec![SimVariant { name: "x".into(), seed: Some(1), ..Default::default() }],
            measure: true,
            ..GridSpec::default()
        };
        let runs = vec![(
            CLAIM_GRID.to_string(),
            SweepRunner::serial().run(&grid).unwrap(),
        )];
        let err = ConformanceBaseline::from_runs(&runs);
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("sim axis"));
    }

    #[test]
    fn ablation_runs_fail_the_check_structurally() {
        use crate::sweep::grid::SimVariant;
        // The check side mirrors the capture-side rejection: a baseline
        // whose embedded spec grows a sim axis (hand-edited — the spec
        // format accepts one) must fail structurally, not silently check
        // only the first variant's groups.
        let runs = small_runs();
        let base = ConformanceBaseline::from_runs(&runs).unwrap();
        let ablated_grid = GridSpec {
            archs: vec![crate::config::ArchSpec::small()],
            threads: vec![1, 15],
            strategies: vec![Strategy::A, Strategy::B],
            sims: vec![
                SimVariant { name: "x".into(), ..Default::default() },
                SimVariant { name: "y".into(), seed: Some(9), ..Default::default() },
            ],
            measure: true,
            ..GridSpec::default()
        };
        let ablated_runs = vec![(
            CLAIM_GRID.to_string(),
            SweepRunner::serial().run(&ablated_grid).unwrap(),
        )];
        let report = base.check_results(&ablated_runs);
        assert!(!report.is_clean());
        assert!(
            report.problems.iter().any(|p| p.contains("sim axis")),
            "{:?}",
            report.problems
        );
        // No band was (mis)compared against a variant group.
        assert!(report.bands.is_empty());
    }

    #[test]
    fn points_mismatch_fails_the_band() {
        let runs = small_runs();
        let mut base = ConformanceBaseline::from_runs(&runs).unwrap();
        base.grids[0].bands[0].points = 7;
        let report = base.check_results(&runs);
        assert!(!report.is_clean());
        assert!(!report.bands[0].points_ok);
        assert!(report.render().contains("points"));
    }
}
