//! Sensitivity analysis: rank the simulator constants by how hard they
//! drive prediction error — `∂Δ/∂constant` over a one-at-a-time
//! ablation grid (the ResPerfNet-style "which model constants matter"
//! report, `repro sensitivity`).
//!
//! For every [`SimConstant`] `c` with base value `c₀` and relative step
//! `h`, the spec builds two [`SimVariant`]s pinning `c` to `c₀·(1−h)`
//! and `c₀·(1+h)` (every other constant inherited), plus one unmodified
//! `base` variant, and runs them as a single measured sweep grid — so
//! the whole analysis flows through the fingerprint-keyed
//! [`crate::sweep::SweepCache`]: cells within a variant share cost
//! models and measurements, variants never leak into each other, and
//! parallel results are bit-identical to serial ones. The per-(variant ×
//! architecture × strategy) mean Δ aggregation then yields a central
//! difference per (constant, architecture, strategy):
//!
//! ```text
//! ∂Δ/∂c · c₀/100 ≈ (Δ₊ − Δ₋) / (2·h·100)    [pp per +1 % of c]
//! ```
//!
//! reported per group and ranked overall (mean |gradient| across
//! groups). Under `--params paper` the models keep predicting the
//! calibration-point simulator while the measurement drifts — the
//! gradient says how fast each constant degrades the paper-parameter
//! accuracy. Under `--params sim` the models re-calibrate against every
//! perturbed variant ([`crate::calibration`]), so the gradient isolates
//! what the closed loop cannot absorb (structural sensitivity).

use crate::config::{ArchSpec, RunConfig};
use crate::error::{Error, Result};
use crate::perfmodel::ParamSource;
use crate::report::Table;
use crate::simulator::SimConfig;
use crate::sweep::cache::CacheStats;
use crate::sweep::grid::{GridSpec, SimVariant, Strategy};
use crate::sweep::runner::SweepRunner;
use crate::sweep::summary::SweepResults;
use crate::util::json::Json;

/// Name of the unperturbed variant on the ablation grid.
pub const BASE_VARIANT: &str = "base";

/// The `±h` suffix of a perturbed variant's name (`"+10%"` / `"-10%"`),
/// rounded to 2 decimals so float noise (0.1 × 100 ≠ 10 exactly) never
/// leaks into variant names. One helper shared by grid construction and
/// the fold, so the two cannot drift.
fn pct_label(step: f64, sign: f64) -> String {
    let pct = (step * 1e4).round() / 100.0;
    format!("{:+}%", sign * pct)
}

/// A tunable simulator constant the sensitivity sweep can ablate — the
/// `--sim-*` f64 axes of `repro sweep`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SimConstant {
    /// Simulated core clock, GHz ([`crate::config::MachineConfig::clock_hz`]).
    ClockGhz,
    /// Calibrated cycles per abstract forward operation.
    FwdCyclesPerOp,
    /// Calibrated cycles per abstract backward operation.
    BwdCyclesPerOp,
    /// Issue-bound fraction of per-image cycles.
    ExecFraction,
    /// L2-sharing pressure coefficient α.
    L2Alpha,
    /// Cap on the L2 working-set pressure ratio.
    L2RatioCap,
    /// Ring/tag-directory latency coefficient β.
    RingBeta,
    /// Per-software-thread oversubscription overhead.
    OversubOverhead,
}

impl SimConstant {
    /// Every ablatable constant, in the canonical report order.
    pub const ALL: [SimConstant; 8] = [
        SimConstant::ClockGhz,
        SimConstant::FwdCyclesPerOp,
        SimConstant::BwdCyclesPerOp,
        SimConstant::ExecFraction,
        SimConstant::L2Alpha,
        SimConstant::L2RatioCap,
        SimConstant::RingBeta,
        SimConstant::OversubOverhead,
    ];

    /// Stable key used in reports and `--constants` parsing (matches the
    /// [`SimConfig`] field names).
    pub fn key(self) -> &'static str {
        match self {
            SimConstant::ClockGhz => "clock_ghz",
            SimConstant::FwdCyclesPerOp => "fwd_cycles_per_op",
            SimConstant::BwdCyclesPerOp => "bwd_cycles_per_op",
            SimConstant::ExecFraction => "exec_fraction",
            SimConstant::L2Alpha => "l2_alpha",
            SimConstant::L2RatioCap => "l2_ratio_cap",
            SimConstant::RingBeta => "ring_beta",
            SimConstant::OversubOverhead => "oversub_overhead",
        }
    }

    /// Parse one `--constants` item (a [`SimConstant::key`]).
    pub fn parse(text: &str) -> Result<SimConstant> {
        SimConstant::ALL
            .into_iter()
            .find(|c| c.key() == text)
            .ok_or_else(|| {
                Error::Config(format!(
                    "unknown sim constant {text:?} (known: {})",
                    SimConstant::ALL.map(|c| c.key()).join(", ")
                ))
            })
    }

    /// The constant's value in `sim` (the perturbation center `c₀`).
    pub fn base_value(self, sim: &SimConfig) -> f64 {
        match self {
            SimConstant::ClockGhz => sim.machine.clock_hz / 1e9,
            SimConstant::FwdCyclesPerOp => sim.fwd_cycles_per_op,
            SimConstant::BwdCyclesPerOp => sim.bwd_cycles_per_op,
            SimConstant::ExecFraction => sim.exec_fraction,
            SimConstant::L2Alpha => sim.l2_alpha,
            SimConstant::L2RatioCap => sim.l2_ratio_cap,
            SimConstant::RingBeta => sim.ring_beta,
            SimConstant::OversubOverhead => sim.oversub_overhead,
        }
    }

    /// A [`SimVariant`] pinning only this constant to `value`.
    pub fn variant(self, name: String, value: f64) -> SimVariant {
        let mut v = SimVariant { name, ..SimVariant::default() };
        match self {
            SimConstant::ClockGhz => v.clock_ghz = Some(value),
            SimConstant::FwdCyclesPerOp => v.fwd_cycles_per_op = Some(value),
            SimConstant::BwdCyclesPerOp => v.bwd_cycles_per_op = Some(value),
            SimConstant::ExecFraction => v.exec_fraction = Some(value),
            SimConstant::L2Alpha => v.l2_alpha = Some(value),
            SimConstant::L2RatioCap => v.l2_ratio_cap = Some(value),
            SimConstant::RingBeta => v.ring_beta = Some(value),
            SimConstant::OversubOverhead => v.oversub_overhead = Some(value),
        }
        v
    }
}

impl std::fmt::Display for SimConstant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

/// What to ablate and over which evaluation domain.
#[derive(Debug, Clone)]
pub struct SensitivitySpec {
    /// Architectures to evaluate (Δ groups are per architecture).
    pub archs: Vec<ArchSpec>,
    /// Thread counts of the measured domain (default: the paper's
    /// Table IX measured thread set).
    pub threads: Vec<usize>,
    /// Strategies to evaluate.
    pub strategies: Vec<Strategy>,
    /// Parameter provenance for the models (see module docs on how the
    /// reading differs between `paper` and `sim`).
    pub params: ParamSource,
    /// Relative perturbation step `h` (`0.1` = ±10 %).
    pub step: f64,
    /// Constants to ablate (default: [`SimConstant::ALL`]).
    pub constants: Vec<SimConstant>,
}

impl Default for SensitivitySpec {
    fn default() -> Self {
        SensitivitySpec {
            archs: ArchSpec::paper_archs(),
            threads: RunConfig::MEASURED_THREADS.to_vec(),
            strategies: vec![Strategy::A, Strategy::B],
            params: ParamSource::Paper,
            step: 0.10,
            constants: SimConstant::ALL.to_vec(),
        }
    }
}

impl SensitivitySpec {
    /// Reject specs the ablation cannot run.
    pub fn validate(&self, base: &SimConfig) -> Result<()> {
        if !(self.step.is_finite() && self.step > 0.0 && self.step < 1.0) {
            return Err(Error::Config(format!(
                "sensitivity step must be in (0, 1), got {}",
                self.step
            )));
        }
        if self.constants.is_empty() {
            return Err(Error::Config("sensitivity spec ablates no constants".into()));
        }
        let mut keys: Vec<&str> = self.constants.iter().map(|c| c.key()).collect();
        keys.sort_unstable();
        if keys.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Config(
                "sensitivity constants must be unique (they key the report)".into(),
            ));
        }
        for &c in &self.constants {
            let plus = c.base_value(base) * (1.0 + self.step);
            if c == SimConstant::ExecFraction && plus > 1.0 {
                return Err(Error::Config(format!(
                    "step {} pushes exec_fraction to {plus} (> 1); lower --step \
                     or drop the constant",
                    self.step
                )));
            }
        }
        Ok(())
    }

    /// The one-at-a-time ablation grid: a `base` variant plus a
    /// (−h, +h) variant pair per constant, over the spec's measured
    /// evaluation domain.
    pub fn to_grid(&self, base: &SimConfig) -> Result<GridSpec> {
        self.validate(base)?;
        let mut sims = vec![SimVariant {
            name: BASE_VARIANT.into(),
            ..SimVariant::default()
        }];
        for &c in &self.constants {
            let c0 = c.base_value(base);
            for sign in [-1.0, 1.0] {
                let name = format!("{}{}", c.key(), pct_label(self.step, sign));
                sims.push(c.variant(name, c0 * (1.0 + sign * self.step)));
            }
        }
        Ok(GridSpec {
            archs: self.archs.clone(),
            threads: self.threads.clone(),
            strategies: self.strategies.clone(),
            params: self.params,
            sims,
            measure: true,
            ..GridSpec::default()
        })
    }
}

/// One (constant × architecture × strategy) cell of the report.
#[derive(Debug, Clone)]
pub struct SensitivityEntry {
    /// The ablated constant.
    pub constant: SimConstant,
    /// Architecture of the Δ group.
    pub arch: String,
    /// Strategy of the Δ group.
    pub strategy: Strategy,
    /// The constant's unperturbed value `c₀`.
    pub base_value: f64,
    /// Mean Δ of the group on the unperturbed simulator, percent.
    pub base_delta_pct: f64,
    /// Mean Δ at `c₀·(1−h)`, percent.
    pub minus_delta_pct: f64,
    /// Mean Δ at `c₀·(1+h)`, percent.
    pub plus_delta_pct: f64,
    /// Central-difference gradient: percentage points of mean Δ per
    /// +1 % change of the constant.
    pub gradient_pp_per_pct: f64,
}

/// One constant's overall rank across every (architecture × strategy)
/// group.
#[derive(Debug, Clone)]
pub struct RankedConstant {
    /// The ablated constant.
    pub constant: SimConstant,
    /// Mean |gradient| over the groups, pp per +1 %.
    pub mean_abs_gradient: f64,
    /// Worst-group |gradient|, pp per +1 %.
    pub max_abs_gradient: f64,
}

/// The full `repro sensitivity` outcome: per-group gradients (ranked
/// within each group) plus the overall constant ranking.
#[derive(Debug)]
pub struct SensitivityReport {
    /// Relative perturbation step `h` the gradients were measured at.
    pub step: f64,
    /// Parameter provenance the models ran under.
    pub params: ParamSource,
    /// Scenarios evaluated across the whole ablation grid.
    pub scenarios: usize,
    /// Sweep-cache telemetry (not serialized: hits/misses are exact —
    /// single-flight memos compute each distinct key once — but
    /// `coalesced` varies with scheduling; the numeric payload is
    /// bit-identical regardless).
    pub cache: CacheStats,
    /// Per-group entries, sorted by |gradient| within each
    /// (architecture, strategy) group, groups in axis order.
    pub entries: Vec<SensitivityEntry>,
    /// Overall ranking, most error-driving constant first.
    pub ranking: Vec<RankedConstant>,
}

/// Run the sensitivity analysis: one measured ablation sweep over the
/// spec's grid, folded into gradients and a ranking.
pub fn run(spec: &SensitivitySpec, runner: &SweepRunner) -> Result<SensitivityReport> {
    let base_sim = SimConfig::default();
    let grid = spec.to_grid(&base_sim)?;
    let results = runner.run(&grid)?;
    fold(spec, &base_sim, &results)
}

/// Pure fold from an already-evaluated ablation sweep (the grid must be
/// the spec's [`SensitivitySpec::to_grid`]).
pub fn fold(
    spec: &SensitivitySpec,
    base_sim: &SimConfig,
    results: &SweepResults,
) -> Result<SensitivityReport> {
    // Mean Δ per (variant, arch, strategy), keyed by variant name.
    let accuracy = results.accuracy();
    let mean_of = |sim: &str, arch: &str, strategy: Strategy| -> Result<f64> {
        accuracy
            .iter()
            .find(|a| {
                a.sim.as_deref() == Some(sim) && a.arch == arch && a.strategy == strategy
            })
            .map(|a| a.mean_delta_pct)
            .ok_or_else(|| {
                Error::Config(format!(
                    "sensitivity sweep produced no measured Δ group for \
                     {sim}/{arch}/{strategy} (was the grid altered?)"
                ))
            })
    };
    let mut entries = Vec::new();
    for arch in &spec.archs {
        for &strategy in &spec.strategies {
            let base_delta = mean_of(BASE_VARIANT, &arch.name, strategy)?;
            let mut group = Vec::with_capacity(spec.constants.len());
            for &c in &spec.constants {
                let minus_name = format!("{}{}", c.key(), pct_label(spec.step, -1.0));
                let plus_name = format!("{}{}", c.key(), pct_label(spec.step, 1.0));
                let minus = mean_of(&minus_name, &arch.name, strategy)?;
                let plus = mean_of(&plus_name, &arch.name, strategy)?;
                group.push(SensitivityEntry {
                    constant: c,
                    arch: arch.name.clone(),
                    strategy,
                    base_value: c.base_value(base_sim),
                    base_delta_pct: base_delta,
                    minus_delta_pct: minus,
                    plus_delta_pct: plus,
                    gradient_pp_per_pct: (plus - minus) / (2.0 * spec.step * 100.0),
                });
            }
            // Rank within the group, deterministic under f64 ties.
            group.sort_by(|x, y| {
                y.gradient_pp_per_pct
                    .abs()
                    .total_cmp(&x.gradient_pp_per_pct.abs())
                    .then_with(|| x.constant.key().cmp(y.constant.key()))
            });
            entries.extend(group);
        }
    }
    let mut ranking = Vec::with_capacity(spec.constants.len());
    for &c in &spec.constants {
        let grads: Vec<f64> = entries
            .iter()
            .filter(|e| e.constant == c)
            .map(|e| e.gradient_pp_per_pct.abs())
            .collect();
        ranking.push(RankedConstant {
            constant: c,
            mean_abs_gradient: grads.iter().sum::<f64>() / grads.len() as f64,
            max_abs_gradient: grads.iter().fold(0.0f64, |a, &b| a.max(b)),
        });
    }
    ranking.sort_by(|x, y| {
        y.mean_abs_gradient
            .total_cmp(&x.mean_abs_gradient)
            .then_with(|| x.constant.key().cmp(y.constant.key()))
    });
    Ok(SensitivityReport {
        step: spec.step,
        params: spec.params,
        scenarios: results.len(),
        cache: results.cache,
        entries,
        ranking,
    })
}

impl SensitivityReport {
    /// Serialize as the machine-readable payload (`--json FILE`). Wall
    /// time and cache counters are deliberately omitted so the document
    /// is bit-identical between serial and parallel runs.
    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("constant", Json::str(e.constant.key())),
                    ("arch", Json::str(e.arch.clone())),
                    ("strategy", Json::str(e.strategy.as_str())),
                    ("base_value", Json::num(e.base_value)),
                    ("base_delta_pct", Json::num(e.base_delta_pct)),
                    ("minus_delta_pct", Json::num(e.minus_delta_pct)),
                    ("plus_delta_pct", Json::num(e.plus_delta_pct)),
                    ("gradient_pp_per_pct", Json::num(e.gradient_pp_per_pct)),
                ])
            })
            .collect();
        let ranking = self
            .ranking
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("constant", Json::str(r.constant.key())),
                    ("mean_abs_gradient", Json::num(r.mean_abs_gradient)),
                    ("max_abs_gradient", Json::num(r.max_abs_gradient)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("kind", Json::str("micdl-sensitivity-report")),
            ("step", Json::num(self.step)),
            (
                "params",
                Json::str(match self.params {
                    ParamSource::Paper => "paper",
                    ParamSource::Simulator => "sim",
                }),
            ),
            ("scenarios", Json::num(self.scenarios as f64)),
            ("entries", Json::Arr(entries)),
            ("ranking", Json::Arr(ranking)),
        ])
    }

    /// Human-readable tables: overall ranking first, then the per-group
    /// gradients, plus a run footer.
    pub fn render(&self) -> String {
        let mut rank = Table::new(
            format!(
                "sensitivity ranking — ∂Δ/∂constant at ±{}% (pp per +1%)",
                // Same 2-decimal rounding as the variant names
                // (pct_label), so header and rows can never disagree.
                (self.step * 1e4).round() / 100.0
            ),
            &["rank", "constant", "mean |∂Δ|", "max |∂Δ|"],
        );
        for (i, r) in self.ranking.iter().enumerate() {
            rank.row(vec![
                (i + 1).to_string(),
                r.constant.key().into(),
                format!("{:.4}", r.mean_abs_gradient),
                format!("{:.4}", r.max_abs_gradient),
            ]);
        }
        let mut detail = Table::new(
            "per-group gradients (ranked within each arch × strategy)",
            &[
                "constant", "arch", "strat", "base value", "Δ@-h %", "Δ@base %",
                "Δ@+h %", "∂Δ [pp/+1%]",
            ],
        );
        for e in &self.entries {
            detail.row(vec![
                e.constant.key().into(),
                e.arch.clone(),
                e.strategy.as_str().into(),
                format!("{:.4}", e.base_value),
                format!("{:.3}", e.minus_delta_pct),
                format!("{:.3}", e.base_delta_pct),
                format!("{:.3}", e.plus_delta_pct),
                format!("{:+.4}", e.gradient_pp_per_pct),
            ]);
        }
        format!(
            "{}{}{} scenarios | cache: {} hits / {} misses ({:.0}% hit rate)\n",
            rank.render(),
            detail.render(),
            self.scenarios,
            self.cache.hits,
            self.cache.misses,
            self.cache.hit_rate() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SensitivitySpec {
        SensitivitySpec {
            archs: vec![ArchSpec::small()],
            threads: vec![15, 240],
            strategies: vec![Strategy::A],
            constants: vec![SimConstant::ClockGhz, SimConstant::FwdCyclesPerOp],
            ..SensitivitySpec::default()
        }
    }

    #[test]
    fn constant_inventory_round_trips() {
        for c in SimConstant::ALL {
            assert_eq!(SimConstant::parse(c.key()).unwrap(), c);
            // Each constant's variant overrides exactly one field: the
            // resolved config differs from base in fingerprint, and
            // applying the base value is the identity.
            let base = SimConfig::default();
            let v = c.variant("x".into(), c.base_value(&base) * 1.5);
            assert_ne!(v.apply(&base).fingerprint(), base.fingerprint(), "{c}");
            let noop = c.variant("x".into(), c.base_value(&base));
            assert_eq!(noop.apply(&base).fingerprint(), base.fingerprint(), "{c}");
        }
        assert!(SimConstant::parse("l2alpha").is_err());
    }

    #[test]
    fn grid_has_base_plus_two_variants_per_constant() {
        let spec = tiny_spec();
        let grid = spec.to_grid(&SimConfig::default()).unwrap();
        assert_eq!(grid.sims.len(), 1 + 2 * spec.constants.len());
        assert_eq!(grid.sims[0].name, BASE_VARIANT);
        assert_eq!(grid.sims[1].name, "clock_ghz-10%");
        assert_eq!(grid.sims[2].name, "clock_ghz+10%");
        assert!(grid.measure);
        assert!(grid.validate().is_ok());
        // 5 variants × 1 arch × 2 threads × 1 strategy.
        assert_eq!(grid.len(), 10);
    }

    #[test]
    fn spec_validation_rejects_bad_inputs() {
        let base = SimConfig::default();
        let mut spec = tiny_spec();
        spec.step = 0.0;
        assert!(spec.validate(&base).is_err());
        spec.step = 1.5;
        assert!(spec.validate(&base).is_err());
        let mut dup = tiny_spec();
        dup.constants = vec![SimConstant::ClockGhz, SimConstant::ClockGhz];
        assert!(dup.validate(&base).is_err());
        let mut empty = tiny_spec();
        empty.constants.clear();
        assert!(empty.validate(&base).is_err());
        // exec_fraction would leave (0, 1].
        let mut exec = tiny_spec();
        exec.constants = vec![SimConstant::ExecFraction];
        exec.step = 0.5;
        let err = exec.validate(&base).unwrap_err().to_string();
        assert!(err.contains("exec_fraction"), "{err}");
    }

    #[test]
    fn report_has_one_entry_per_constant_group_and_a_full_ranking() {
        let spec = tiny_spec();
        let report = run(&spec, &SweepRunner::serial()).unwrap();
        assert_eq!(report.scenarios, 10);
        assert_eq!(report.entries.len(), 2); // 2 constants × 1 arch × 1 strategy
        assert_eq!(report.ranking.len(), 2);
        // Entries within the group are |gradient|-descending.
        assert!(
            report.entries[0].gradient_pp_per_pct.abs()
                >= report.entries[1].gradient_pp_per_pct.abs()
        );
        // The clock swings the measured side hard: its gradient is
        // nonzero and the ranking is populated.
        assert!(report.ranking[0].mean_abs_gradient > 0.0);
        for e in &report.entries {
            assert!(e.minus_delta_pct.is_finite() && e.plus_delta_pct.is_finite());
        }
    }

    #[test]
    fn gradient_matches_hand_central_difference() {
        let spec = tiny_spec();
        let grid = spec.to_grid(&SimConfig::default()).unwrap();
        let results = SweepRunner::serial().run(&grid).unwrap();
        let report = fold(&spec, &SimConfig::default(), &results).unwrap();
        let acc = results.accuracy();
        let mean = |sim: &str| {
            acc.iter()
                .find(|a| a.sim.as_deref() == Some(sim))
                .unwrap()
                .mean_delta_pct
        };
        let e = report
            .entries
            .iter()
            .find(|e| e.constant == SimConstant::ClockGhz)
            .unwrap();
        let want = (mean("clock_ghz+10%") - mean("clock_ghz-10%")) / (2.0 * 0.10 * 100.0);
        assert_eq!(e.gradient_pp_per_pct.to_bits(), want.to_bits());
        assert_eq!(e.base_delta_pct.to_bits(), mean(BASE_VARIANT).to_bits());
    }

    #[test]
    fn json_payload_is_complete_and_parseable() {
        let report = run(&tiny_spec(), &SweepRunner::serial()).unwrap();
        let doc = Json::parse(&report.to_json().emit()).unwrap();
        assert_eq!(doc.get("kind").unwrap().as_str(), Some("micdl-sensitivity-report"));
        assert_eq!(doc.get("entries").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(doc.get("ranking").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(doc.get("params").unwrap().as_str(), Some("paper"));
        let text = report.render();
        assert!(text.contains("sensitivity ranking"), "{text}");
        assert!(text.contains("clock_ghz"), "{text}");
    }
}
