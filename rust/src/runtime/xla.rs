//! Minimal stand-in for the `xla` crate (PJRT bindings).
//!
//! The offline build has no libxla, so [`crate::runtime::client`] compiles
//! against this stub: [`Literal`] is a real host-side tensor (sufficient
//! for parameter initialization and its unit tests), while the PJRT
//! client/compile/execute surface returns an [`Error`] at the first call
//! that would need the native library. The e2e tests in
//! `tests/runtime_e2e.rs` skip themselves when `artifacts/meta.json` is
//! absent, so `cargo test` stays green on a stub build.
//!
//! Swapping the real `xla` crate back in is a one-line import change in
//! `runtime/client.rs` and `error.rs` plus a `[dependencies]` entry.

use std::fmt;

/// Error type mirroring `xla::Error` (a message string).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT execution is unavailable in this offline build \
     (bundled xla stub); link the real `xla` crate to run HLO artifacts";

/// Element buffer of a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Scalar types storable in a [`Literal`].
pub trait NativeType: Sized + Clone {
    fn wrap(values: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(values: Vec<Self>) -> Data {
        Data::F32(values)
    }

    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(values: Vec<Self>) -> Data {
        Data::I32(values)
    }

    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// Host-side tensor: typed element buffer + dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            data: T::wrap(values.to_vec()),
            dims: vec![values.len() as i64],
        }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Same elements, new dimensions (must conserve the element count).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if dims.iter().any(|&d| d < 0) || n as usize != self.element_count() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Tuple decomposition exists only on executable outputs, which the
    /// stub can never produce.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Parsed HLO module — the text is kept verbatim, never compiled here.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map(|text| HloModuleProto { text })
            .map_err(|e| Error(format!("read {path}: {e}")))
    }
}

/// Computation handle wrapping a parsed module.
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// PJRT CPU client stand-in: constructs, reports a platform, cannot compile.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stub)".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Compiled-executable stand-in (unreachable through the stub client, but
/// the full call surface must typecheck).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn reshape_rejects_bad_counts() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        // Negative dims whose product matches the element count are still
        // invalid (the real xla crate rejects them too).
        assert!(l.reshape(&[-1, -3]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("cpu"));
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
