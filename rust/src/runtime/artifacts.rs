//! Artifact registry: `artifacts/meta.json` + per-architecture HLO paths.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Parameter shapes of one trainable layer, as lowered.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamShapes {
    pub w: Vec<usize>,
    pub b: Vec<usize>,
}

/// One architecture's artifact entry.
#[derive(Debug, Clone)]
pub struct ArchArtifacts {
    pub name: String,
    pub params: Vec<ParamShapes>,
    pub train_hlo: PathBuf,
    pub infer_hlo: PathBuf,
    /// Expected executable arity: params·2 + x + y.
    pub train_inputs: usize,
    /// params·2 + loss.
    pub train_outputs: usize,
}

/// Parsed registry for an artifacts directory.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub batch: usize,
    pub lr: f64,
    pub input_hw: usize,
    pub num_classes: usize,
    pub archs: Vec<ArchArtifacts>,
}

impl ArtifactRegistry {
    /// Load and validate `dir/meta.json`.
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path).map_err(|e| {
            Error::Artifact(format!(
                "{}: {e} (run `make artifacts` first)",
                meta_path.display()
            ))
        })?;
        Self::parse(dir, &text)
    }

    /// Parse registry JSON (separated for testing).
    pub fn parse(dir: &Path, text: &str) -> Result<ArtifactRegistry> {
        let v = Json::parse(text)?;
        let usize_field = |key: &str| -> Result<usize> {
            v.expect(key)?
                .as_usize()
                .ok_or_else(|| Error::Artifact(format!("meta.json: bad {key}")))
        };
        let batch = usize_field("batch")?;
        let input_hw = usize_field("input_hw")?;
        let num_classes = usize_field("num_classes")?;
        let lr = v
            .expect("lr")?
            .as_f64()
            .ok_or_else(|| Error::Artifact("meta.json: bad lr".into()))?;

        let mut archs = Vec::new();
        for (name, entry) in v
            .expect("archs")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("meta.json: archs not object".into()))?
        {
            let mut params = Vec::new();
            for p in entry
                .expect("params")?
                .as_arr()
                .ok_or_else(|| Error::Artifact(format!("{name}: params not array")))?
            {
                let dims = |key: &str| -> Result<Vec<usize>> {
                    p.expect(key)?
                        .as_arr()
                        .ok_or_else(|| Error::Artifact(format!("{name}: bad {key}")))?
                        .iter()
                        .map(|d| {
                            d.as_usize().ok_or_else(|| {
                                Error::Artifact(format!("{name}: bad {key} dim"))
                            })
                        })
                        .collect()
                };
                params.push(ParamShapes { w: dims("w")?, b: dims("b")? });
            }
            let path_field = |key: &str| -> Result<PathBuf> {
                Ok(dir.join(entry.expect(key)?.as_str().ok_or_else(|| {
                    Error::Artifact(format!("{name}: bad {key}"))
                })?))
            };
            let n = params.len();
            let arch = ArchArtifacts {
                name: name.clone(),
                params,
                train_hlo: path_field("train_hlo")?,
                infer_hlo: path_field("infer_hlo")?,
                train_inputs: 2 * n + 2,
                train_outputs: 2 * n + 1,
            };
            // Cross-check against the meta's own counts when present.
            if let (Some(ti), Some(to)) = (
                entry.get("train_inputs").and_then(|j| j.as_usize()),
                entry.get("train_outputs").and_then(|j| j.as_usize()),
            ) {
                if ti != arch.train_inputs || to != arch.train_outputs {
                    return Err(Error::Artifact(format!(
                        "{name}: meta arity {ti}/{to} disagrees with params ({}/{})",
                        arch.train_inputs, arch.train_outputs
                    )));
                }
            }
            archs.push(arch);
        }
        archs.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), batch, lr, input_hw, num_classes, archs })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchArtifacts> {
        self.archs.iter().find(|a| a.name == name).ok_or_else(|| {
            Error::Artifact(format!(
                "no artifacts for arch {name:?} (have: {:?})",
                self.archs.iter().map(|a| a.name.as_str()).collect::<Vec<_>>()
            ))
        })
    }

    /// Verify the HLO files exist on disk.
    pub fn check_files(&self) -> Result<()> {
        for arch in &self.archs {
            for path in [&arch.train_hlo, &arch.infer_hlo] {
                if !path.exists() {
                    return Err(Error::Artifact(format!(
                        "missing artifact {} (run `make artifacts`)",
                        path.display()
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{
      "batch": 8, "lr": 0.05, "input_hw": 29, "num_classes": 10,
      "archs": {
        "small": {
          "params": [{"w":[5,1,4,4],"b":[5]},{"w":[845,10],"b":[10]}],
          "layers": [],
          "train_hlo": "train_small_b8.hlo.txt",
          "infer_hlo": "infer_small_b8.hlo.txt",
          "train_inputs": 6, "train_outputs": 5
        }
      }
    }"#;

    #[test]
    fn parses_meta() {
        let r = ArtifactRegistry::parse(Path::new("/tmp/a"), META).unwrap();
        assert_eq!(r.batch, 8);
        assert_eq!(r.input_hw, 29);
        let arch = r.arch("small").unwrap();
        assert_eq!(arch.params.len(), 2);
        assert_eq!(arch.params[0].w, vec![5, 1, 4, 4]);
        assert_eq!(arch.train_inputs, 6);
        assert!(arch.train_hlo.ends_with("train_small_b8.hlo.txt"));
    }

    #[test]
    fn rejects_arity_mismatch() {
        let bad = META.replace("\"train_inputs\": 6", "\"train_inputs\": 7");
        assert!(ArtifactRegistry::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn unknown_arch_lookup_fails() {
        let r = ArtifactRegistry::parse(Path::new("/tmp"), META).unwrap();
        assert!(r.arch("huge").is_err());
    }

    #[test]
    fn check_files_reports_missing() {
        let r = ArtifactRegistry::parse(Path::new("/definitely/not"), META).unwrap();
        assert!(r.check_files().is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // Integration smoke against the repo's own artifacts (skipped when
        // `make artifacts` has not run).
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("meta.json").exists() {
            let r = ArtifactRegistry::load(&dir).unwrap();
            assert!(r.arch("small").is_ok());
            r.check_files().unwrap();
        }
    }
}
