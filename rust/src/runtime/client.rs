//! PJRT client wrapper: compile HLO text once, execute many times.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. The lowered train step is
//! `(w0, b0, …, x, y) -> (w0', b0', …, loss)`, so a [`TrainHandle`] keeps
//! the parameter literals between steps and feeds the outputs of step `k`
//! straight back in as the inputs of step `k+1` — weights never leave the
//! runtime between steps.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::nn::init::XorShift64;
use crate::runtime::artifacts::{ArchArtifacts, ParamShapes};
use crate::runtime::xla;

/// Compiled-executable cache over one PJRT CPU client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtRuntime")
            .field("platform", &self.client.platform_name())
            .field("cached", &self.cache.len())
            .finish()
    }
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        Ok(PjrtRuntime { client: xla::PjRtClient::cpu()?, cache: HashMap::new() })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn compile_hlo(&mut self, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        let key = path.display().to_string();
        if !self.cache.contains_key(&key) {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }

    /// Build a training handle for one architecture with freshly
    /// initialized parameters (Uniform(−1/√fan_in, 1/√fan_in), zero bias —
    /// the same scheme as the JAX side).
    pub fn train_handle(
        &mut self,
        arch: &ArchArtifacts,
        batch: usize,
        input_hw: usize,
        seed: u64,
    ) -> Result<TrainHandle> {
        self.compile_hlo(&arch.train_hlo)?;
        let params = init_param_literals(&arch.params, seed)?;
        Ok(TrainHandle {
            key: arch.train_hlo.display().to_string(),
            infer_key: None,
            infer_path: arch.infer_hlo.clone(),
            params,
            batch,
            input_hw,
            n_outputs: arch.train_outputs,
            steps: 0,
        })
    }

    /// Run one training step, feeding updated parameters back into the
    /// handle. Returns the batch loss.
    pub fn train_step(
        &mut self,
        handle: &mut TrainHandle,
        images: &[f32],
        labels: &[i32],
    ) -> Result<f32> {
        let b = handle.batch;
        let hw = handle.input_hw as i64;
        if images.len() != b * (hw * hw) as usize {
            return Err(Error::Runtime(format!(
                "expected {}x{hw}x{hw} image batch, got {} floats",
                b,
                images.len()
            )));
        }
        if labels.len() != b {
            return Err(Error::Runtime(format!(
                "expected {b} labels, got {}",
                labels.len()
            )));
        }
        let x = xla::Literal::vec1(images).reshape(&[b as i64, 1, hw, hw])?;
        let y = xla::Literal::vec1(labels);

        let mut inputs: Vec<&xla::Literal> = handle.params.iter().collect();
        inputs.push(&x);
        inputs.push(&y);

        let exe = self
            .cache
            .get(&handle.key)
            .ok_or_else(|| Error::Runtime("train executable not compiled".into()))?;
        let result = exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let mut outputs = result.to_tuple()?;
        if outputs.len() != handle.n_outputs {
            return Err(Error::Runtime(format!(
                "train step returned {} outputs, expected {}",
                outputs.len(),
                handle.n_outputs
            )));
        }
        let loss = outputs.pop().unwrap().to_vec::<f32>()?[0];
        handle.params = outputs;
        handle.steps += 1;
        Ok(loss)
    }

    /// Run inference on a batch, returning per-sample argmax classes.
    pub fn infer(&mut self, handle: &mut TrainHandle, images: &[f32]) -> Result<Vec<usize>> {
        let b = handle.batch;
        let hw = handle.input_hw as i64;
        let x = xla::Literal::vec1(images).reshape(&[b as i64, 1, hw, hw])?;
        if handle.infer_key.is_none() {
            self.compile_hlo(&handle.infer_path)?;
            handle.infer_key = Some(handle.infer_path.display().to_string());
        }
        let exe = self
            .cache
            .get(handle.infer_key.as_ref().unwrap())
            .ok_or_else(|| Error::Runtime("infer executable not compiled".into()))?;
        let mut inputs: Vec<&xla::Literal> = handle.params.iter().collect();
        inputs.push(&x);
        let result = exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let logits = result.to_tuple1()?.to_vec::<f32>()?;
        let classes = logits
            .chunks(logits.len() / b)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect();
        Ok(classes)
    }
}

/// Per-run training state: the parameter literals and shapes.
pub struct TrainHandle {
    key: String,
    infer_key: Option<String>,
    infer_path: std::path::PathBuf,
    /// Current parameters, in lowering order (w0, b0, w1, b1, …).
    pub params: Vec<xla::Literal>,
    pub batch: usize,
    pub input_hw: usize,
    n_outputs: usize,
    /// Steps executed so far.
    pub steps: u64,
}

impl std::fmt::Debug for TrainHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TrainHandle")
            .field("params", &self.params.len())
            .field("batch", &self.batch)
            .field("steps", &self.steps)
            .finish()
    }
}

/// Initialize parameter literals matching the lowered shapes.
fn init_param_literals(shapes: &[ParamShapes], seed: u64) -> Result<Vec<xla::Literal>> {
    let mut rng = XorShift64::new(seed);
    let mut out = Vec::with_capacity(shapes.len() * 2);
    for p in shapes {
        let fan_in: usize = if p.w.len() == 4 {
            p.w[1] * p.w[2] * p.w[3]
        } else {
            p.w[0]
        };
        let n: usize = p.w.iter().product();
        let mut w = vec![0.0f32; n];
        crate::nn::init::init_weights(&mut rng, &mut w, fan_in);
        let dims: Vec<i64> = p.w.iter().map(|&d| d as i64).collect();
        out.push(xla::Literal::vec1(&w).reshape(&dims)?);
        let nb: usize = p.b.iter().product();
        out.push(xla::Literal::vec1(&vec![0.0f32; nb]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/runtime_e2e.rs (they need the
    // artifacts built); here only the pure parts.
    use super::*;

    #[test]
    fn init_param_literals_shapes() {
        let shapes = vec![
            ParamShapes { w: vec![5, 1, 4, 4], b: vec![5] },
            ParamShapes { w: vec![845, 10], b: vec![10] },
        ];
        let lits = init_param_literals(&shapes, 7).unwrap();
        assert_eq!(lits.len(), 4);
        assert_eq!(lits[0].element_count(), 80);
        assert_eq!(lits[1].element_count(), 5);
        assert_eq!(lits[2].element_count(), 8450);
        assert_eq!(lits[3].element_count(), 10);
    }

    #[test]
    fn init_is_seed_deterministic() {
        let shapes = vec![ParamShapes { w: vec![3, 4], b: vec![4] }];
        let a = init_param_literals(&shapes, 1).unwrap();
        let b = init_param_literals(&shapes, 1).unwrap();
        assert_eq!(
            a[0].to_vec::<f32>().unwrap(),
            b[0].to_vec::<f32>().unwrap()
        );
    }
}
