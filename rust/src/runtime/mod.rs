//! PJRT runtime: load the AOT HLO-text artifacts and execute them.
//!
//! The request path is Rust-only: `make artifacts` ran Python once to
//! lower the JAX/Pallas training step to HLO **text** (see
//! `python/compile/aot.py` — text, not serialized protos, because
//! xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids), and
//! this module loads, compiles, and runs those artifacts via the `xla`
//! crate's PJRT CPU client.
//!
//! * [`artifacts`] — the registry: parses `artifacts/meta.json`, resolves
//!   per-architecture HLO paths and parameter shapes.
//! * [`client`] — compiled-executable cache + typed train/infer wrappers.

pub mod artifacts;
pub mod client;
pub mod xla;

pub use artifacts::{ArchArtifacts, ArtifactRegistry};
pub use client::{PjrtRuntime, TrainHandle};
