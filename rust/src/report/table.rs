//! Aligned text tables + CSV emission for all experiment output.

use std::fmt::Write as _;

/// A simple column-aligned table with a title, header, and string cells.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; pads/truncates to the header width.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        let mut cells = cells;
        cells.resize(self.header.len(), String::new());
        self.rows.push(cells);
        self
    }

    /// Convenience: format heterogeneous cells via `ToString`.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        self.row(cells.iter().map(|c| c.to_string()).collect())
    }

    /// Render as an aligned text block.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (i, cell) in cells.iter().enumerate() {
                let _ = write!(s, "{:<width$}  ", cell, width = widths[i]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * ncols;
        let _ = writeln!(out, "{}", "-".repeat(total.min(120)));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Render as CSV (comma-separated, quoted only when needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }
}

/// Format seconds as `m.mm` minutes (the paper's table unit).
pub fn minutes(seconds: f64) -> String {
    format!("{:.1}", seconds / 60.0)
}

/// Format seconds in engineering notation like the paper's Table IV.
pub fn sci(seconds: f64) -> String {
    format!("{seconds:.2e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new("demo", &["a", "bbbb", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["10".into(), "20000".into(), "30".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("a   bbbb"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new("t", &["x", "y"]);
        t.row(vec!["only".into()]);
        assert_eq!(t.rows[0].len(), 2);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("t", &["x"]);
        t.row(vec!["a,b".into()]);
        assert!(t.to_csv().contains("\"a,b\""));
    }

    #[test]
    fn minutes_formatting() {
        assert_eq!(minutes(534.0), "8.9");
        assert_eq!(minutes(60.0), "1.0");
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(sci(1.40e-2), "1.40e-2");
    }
}
