//! Reporting: paper-style tables, figure series, and the embedded paper data.
//!
//! Every experiment renders through [`table::Table`] (aligned text output,
//! optional CSV) so the benches and the CLI print the same rows the paper
//! reports, side by side with the paper's own numbers from [`paper`].

pub mod paper;
pub mod series;
pub mod table;

pub use series::Series;
pub use table::Table;
