//! The paper's published numbers, embedded for side-by-side comparison.
//!
//! Sources (Viebke et al., HPCS 2019):
//! * Table II/III — model parameters (epochs, Prep, T_Fprop, T_Bprop, ...)
//! * Table IV — measured + predicted memory contention
//! * Tables VII/VIII — FProp/BProp operation counts
//! * Table IX — average prediction accuracy Δ
//! * Table X — predicted minutes for 480–3,840 threads
//! * Table XI — scaling epochs/images on the small CNN
//! * Fig. 1 — many-core processors vs TOP500 #1 peak performance
//!
//! A few Table IV entries are typographically damaged in the published PDF
//! (exponents truncated, e.g. "1.38 * 10-"); the values here restore them
//! from the table's exact linear structure, cross-checked against Table X:
//! plugging the restored contention into strategy (b) reproduces the
//! paper's predicted minutes to three significant figures (see
//! `perfmodel::tests::table10_strategy_b_matches_paper`).

use crate::nn::opcount::{ArchOpCounts, OpCounts};

/// Architecture index helper: 0=small, 1=medium, 2=large.
pub fn arch_index(name: &str) -> Option<usize> {
    match name {
        "small" => Some(0),
        "medium" => Some(1),
        "large" => Some(2),
        _ => None,
    }
}

pub const ARCH_NAMES: [&str; 3] = ["small", "medium", "large"];

// ---------------------------------------------------------------------------
// Tables VII / VIII — operation counts per image
// ---------------------------------------------------------------------------

/// Table VII: FProp operations per image (max-pool, fully-connected, conv).
pub const FPROP_OPS: [[u64; 3]; 3] = [
    [7_000, 5_000, 46_000],       // small  (total 58k)
    [29_000, 56_000, 474_000],    // medium (total 559k)
    [99_000, 137_000, 5_113_000], // large  (total 5,349k)
];

/// Table VIII: BProp operations per image.
pub const BPROP_OPS: [[u64; 3]; 3] = [
    [2_000, 10_000, 512_000],      // small  (total 524k)
    [4_000, 112_000, 6_003_000],   // medium (total 6,119k)
    [8_000, 274_000, 72_896_000],  // large  (total 73,178k)
];

/// Paper op counts for a named paper architecture.
pub fn op_counts(arch: &str) -> Option<ArchOpCounts> {
    let idx = arch_index(arch)?;
    let f = FPROP_OPS[idx];
    let b = BPROP_OPS[idx];
    Some(ArchOpCounts {
        fprop: OpCounts { max_pool: f[0], fully_connected: f[1], convolution: f[2] },
        bprop: OpCounts { max_pool: b[0], fully_connected: b[1], convolution: b[2] },
    })
}

// ---------------------------------------------------------------------------
// Table III — hardware-specific measured parameters
// ---------------------------------------------------------------------------

/// Clock speed `s` used by the models (GHz → Hz).
pub const CLOCK_HZ: f64 = 1.238e9;

/// Measured forward-propagation time per image, seconds (Table III, ms).
pub const T_FPROP_S: [f64; 3] = [1.45e-3, 12.55e-3, 148.88e-3];

/// Measured back-propagation time per image, seconds (Table III, ms).
pub const T_BPROP_S: [f64; 3] = [5.3e-3, 69.73e-3, 859.19e-3];

/// Measured preparation time, seconds (Table III).
pub const T_PREP_S: [f64; 3] = [12.56, 12.7, 13.5];

/// Prep operation counts for strategy (a) (Table II: 10^9 / 10^10 / 10^11).
pub const PREP_OPS: [f64; 3] = [1e9, 1e10, 1e11];

/// Prep operation counts that the paper's *published predictions*
/// (Table X) actually embed. The medium column of Table X is only
/// reproducible with Prep = 10^9 — Table II's 10^10 is inconsistent with
/// the paper's own predictions (with 10^10 every medium cell is ~8–11%
/// high; with 10^9 all twelve strategy-(a) cells land within ~1%, see
/// perfmodel::strategy_a::tests::table10_matches_paper). Strategy (a)
/// uses these; Table II is kept verbatim above for reference.
pub const MODEL_PREP_OPS: [f64; 3] = [1e9, 1e9, 1e11];

/// OperationFactor (Table III; "adjusted to closely match the measured
/// value for 15 threads ... at the same time account for vectorization").
pub const OPERATION_FACTOR: [f64; 3] = [15.0, 15.0, 15.0];

/// Epochs per architecture (Table II).
pub const EPOCHS: [usize; 3] = [70, 70, 15];

// ---------------------------------------------------------------------------
// Table IV — memory contention (seconds) per thread count and architecture
// ---------------------------------------------------------------------------

/// Thread counts of Table IV; entries at index >= 7 are model-predicted
/// (starred in the paper).
pub const CONTENTION_THREADS: [usize; 11] =
    [1, 15, 30, 60, 120, 180, 240, 480, 960, 1920, 3840];

/// Index of the first *predicted* (rather than measured) row.
pub const CONTENTION_PREDICTED_FROM: usize = 7;

/// MemoryContention(p) in seconds, per architecture column.
/// Damaged exponents restored (see module docs): large column is linear in
/// p at ≈5.7e-4·p, small at ≈5.8e-5·p, medium at ≈1.54e-4·p.
pub const CONTENTION_S: [[f64; 3]; 11] = [
    [7.10e-6, 1.56e-4, 8.83e-4],  // 1
    [6.40e-4, 2.00e-3, 8.75e-3],  // 15
    [1.36e-3, 3.97e-3, 1.67e-2],  // 30
    [3.07e-3, 8.03e-3, 3.22e-2],  // 60
    [6.76e-3, 1.65e-2, 6.74e-2],  // 120
    [9.95e-3, 2.50e-2, 1.00e-1],  // 180
    [1.40e-2, 3.83e-2, 1.38e-1],  // 240
    [2.78e-2, 7.31e-2, 2.73e-1],  // 480 *
    [5.60e-2, 1.47e-1, 5.46e-1],  // 960 *
    [1.12e-1, 2.95e-1, 1.09],     // 1920 *
    [2.25e-1, 5.91e-1, 2.19],     // 3840 *
];

/// Contention for (arch, p) from Table IV, linearly interpolated /
/// extrapolated between the tabulated thread counts (the table itself is
/// linear in p beyond 15 threads to within ~3%).
pub fn contention_s(arch: &str, p: usize) -> Option<f64> {
    let col = arch_index(arch)?;
    let ts = &CONTENTION_THREADS;
    if let Some(row) = ts.iter().position(|&t| t == p) {
        return Some(CONTENTION_S[row][col]);
    }
    // Linear interpolation on the two nearest rows (extrapolate the last
    // segment's slope above 3,840 — the table is linear there).
    let pf = p as f64;
    let (lo, hi) = match ts.iter().position(|&t| t > p) {
        Some(0) => (0, 1),
        Some(j) => (j - 1, j),
        None => (ts.len() - 2, ts.len() - 1),
    };
    let (t0, t1) = (ts[lo] as f64, ts[hi] as f64);
    let (c0, c1) = (CONTENTION_S[lo][col], CONTENTION_S[hi][col]);
    Some(c0 + (c1 - c0) * (pf - t0) / (t1 - t0))
}

// ---------------------------------------------------------------------------
// Table IX — average prediction accuracy Δ (percent)
// ---------------------------------------------------------------------------

/// Δ for strategies (a, b) per architecture (small, medium, large).
pub const ACCURACY_DELTA_PCT: [[f64; 2]; 3] = [
    [14.57, 16.35],
    [14.76, 7.48],
    [15.36, 10.22],
];

// ---------------------------------------------------------------------------
// Table X — predicted execution times (minutes) beyond the hardware
// ---------------------------------------------------------------------------

/// Rows: threads 480/960/1920/3840; cols: (small a, small b, medium a,
/// medium b, large a, large b).
pub const TABLE10_MINUTES: [[f64; 6]; 4] = [
    [6.6, 6.7, 36.8, 39.1, 92.9, 82.6],
    [5.4, 5.5, 23.9, 25.1, 60.8, 45.7],
    [4.9, 4.9, 17.4, 18.0, 44.8, 27.2],
    [4.6, 4.6, 14.2, 14.5, 36.8, 18.0],
];

pub const TABLE10_THREADS: [usize; 4] = [480, 960, 1920, 3840];

// ---------------------------------------------------------------------------
// Table XI — scaling images/epochs, small CNN, strategy (a), minutes
// ---------------------------------------------------------------------------

/// Rows: (i, it) = (60k,10k), (120k,20k), (240k,40k); cols: 240 threads
/// ep {70,140,280} then 480 threads ep {70,140,280}.
pub const TABLE11_MINUTES: [[f64; 6]; 3] = [
    [8.9, 17.6, 35.0, 6.6, 12.9, 25.6],
    [17.6, 35.0, 69.7, 12.9, 25.6, 51.1],
    [35.0, 69.7, 139.3, 25.6, 51.1, 101.9],
];

pub const TABLE11_IMAGES: [(usize, usize); 3] =
    [(60_000, 10_000), (120_000, 20_000), (240_000, 40_000)];
pub const TABLE11_EPOCHS: [usize; 3] = [70, 140, 280];
pub const TABLE11_THREADS: [usize; 2] = [240, 480];

// ---------------------------------------------------------------------------
// Fig. 1 — peak performance: many-core devices vs TOP500 #1 (TFLOP/s)
// ---------------------------------------------------------------------------

/// (label, year, peak double-precision TFLOP/s) — the devices the figure
/// plots against the TOP500 #1 timeline.
pub const FIG1_DEVICES: [(&str, u32, f64); 4] = [
    ("Intel Xeon Phi KNC 7120P", 2012, 1.2),
    ("NVIDIA Tesla K40", 2013, 1.4),
    ("Intel Xeon Phi KNL 7290", 2016, 3.5),
    ("NVIDIA Tesla V100", 2017, 7.8),
];

/// (system, year, peak TFLOP/s) — TOP500 #1 peak performance timeline
/// (values from the public TOP500 lists the figure cites).
pub const FIG1_TOP500: [(&str, u32, f64); 8] = [
    ("ASCI Red", 1997, 1.45),
    ("ASCI White", 2000, 12.3),
    ("Earth Simulator", 2002, 40.96),
    ("BlueGene/L", 2005, 367.0),
    ("Roadrunner", 2008, 1_456.7),
    ("K computer", 2011, 11_280.4),
    ("Tianhe-2", 2013, 54_902.4),
    ("Summit", 2018, 200_794.9),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_totals() {
        assert_eq!(op_counts("small").unwrap().fprop.total(), 58_000);
        assert_eq!(op_counts("medium").unwrap().fprop.total(), 559_000);
        assert_eq!(op_counts("large").unwrap().fprop.total(), 5_349_000);
    }

    #[test]
    fn table8_totals() {
        assert_eq!(op_counts("small").unwrap().bprop.total(), 524_000);
        assert_eq!(op_counts("medium").unwrap().bprop.total(), 6_119_000);
        assert_eq!(op_counts("large").unwrap().bprop.total(), 73_178_000);
    }

    #[test]
    fn table7_ratios_match_paper() {
        // Paper prints medium/small = 9.64, large/medium = 9.57.
        let s = op_counts("small").unwrap().fprop.total() as f64;
        let m = op_counts("medium").unwrap().fprop.total() as f64;
        let l = op_counts("large").unwrap().fprop.total() as f64;
        assert!((m / s - 9.64).abs() < 0.01);
        assert!((l / m - 9.57).abs() < 0.01);
    }

    #[test]
    fn table8_ratios_match_paper() {
        let s = op_counts("small").unwrap().bprop.total() as f64;
        let m = op_counts("medium").unwrap().bprop.total() as f64;
        let l = op_counts("large").unwrap().bprop.total() as f64;
        assert!((m / s - 11.68).abs() < 0.01);
        assert!((l / m - 11.96).abs() < 0.01);
    }

    #[test]
    fn contention_exact_rows() {
        assert_eq!(contention_s("small", 240), Some(1.40e-2));
        assert_eq!(contention_s("medium", 480), Some(7.31e-2));
        assert_eq!(contention_s("large", 3840), Some(2.19));
    }

    #[test]
    fn contention_interpolates_between_rows() {
        // Between 120 (6.76e-3) and 180 (9.95e-3) for small.
        let c = contention_s("small", 150).unwrap();
        assert!(c > 6.76e-3 && c < 9.95e-3);
        // Monotone in p.
        let mut prev = 0.0;
        for p in [1, 10, 100, 500, 2000, 5000] {
            let c = contention_s("large", p).unwrap();
            assert!(c > prev, "p={p}: {c} <= {prev}");
            prev = c;
        }
    }

    #[test]
    fn contention_restored_column_is_linear() {
        // The restored large column must double when p doubles (>=15).
        for (p0, p1) in [(240, 480), (480, 960), (960, 1920), (1920, 3840)] {
            let c0 = contention_s("large", p0).unwrap();
            let c1 = contention_s("large", p1).unwrap();
            let ratio = c1 / c0;
            assert!((ratio - 2.0).abs() < 0.05, "{p0}->{p1}: {ratio}");
        }
    }

    #[test]
    fn contention_unknown_arch_is_none() {
        assert!(contention_s("giant", 240).is_none());
    }

    #[test]
    fn fig1_knl_comparable_to_asci_red() {
        // The paper's Fig. 1 point: 2016 KNL ≈ the 1997/2000 #1 systems.
        let knl = FIG1_DEVICES[2].2;
        let asci_red = FIG1_TOP500[0].2;
        assert!(knl / asci_red > 1.0 && knl / asci_red < 5.0);
    }
}
