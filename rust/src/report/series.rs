//! (x, y) series with ASCII rendering — the figure analogue of [`super::Table`].
//!
//! Figures 5–7 plot predicted vs measured execution time over thread counts;
//! we render the same series as aligned columns plus a log-scale ASCII chart
//! so `repro exp fig5` output is directly comparable to the paper's figure.

use std::fmt::Write as _;

/// One named line of a figure.
#[derive(Debug, Clone, PartialEq)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) -> &mut Self {
        self.points.push((x, y));
        self
    }

    pub fn from_points(name: impl Into<String>, pts: &[(f64, f64)]) -> Self {
        Series { name: name.into(), points: pts.to_vec() }
    }
}

/// Render multiple series as a log-y ASCII chart (rows = x values).
pub fn render_chart(title: &str, series: &[Series], y_label: &str) -> String {
    const WIDTH: usize = 60;
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==  ({y_label}, log scale)");
    let ys: Vec<f64> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|&(_, y)| y))
        .filter(|y| *y > 0.0)
        .collect();
    if ys.is_empty() {
        return out;
    }
    let (ymin, ymax) = ys
        .iter()
        .fold((f64::INFINITY, 0.0f64), |(lo, hi), &y| (lo.min(y), hi.max(y)));
    let (lmin, lmax) = (ymin.ln(), ymax.ln().max(ymin.ln() + 1e-9));
    let marks = ['*', 'o', '+', 'x', '#'];

    let xs: Vec<f64> = series
        .first()
        .map(|s| s.points.iter().map(|&(x, _)| x).collect())
        .unwrap_or_default();
    for (row, &x) in xs.iter().enumerate() {
        let mut line = vec![' '; WIDTH + 1];
        for (si, s) in series.iter().enumerate() {
            if let Some(&(_, y)) = s.points.get(row) {
                if y > 0.0 {
                    let pos = ((y.ln() - lmin) / (lmax - lmin) * WIDTH as f64)
                        .round()
                        .clamp(0.0, WIDTH as f64) as usize;
                    line[pos] = marks[si % marks.len()];
                }
            }
        }
        let line: String = line.into_iter().collect();
        let _ = writeln!(out, "{x:>8} |{line}");
    }
    let _ = write!(out, "legend: ");
    for (si, s) in series.iter().enumerate() {
        let _ = write!(out, "{}={}  ", marks[si % marks.len()], s.name);
    }
    let _ = writeln!(out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_contains_all_series_marks() {
        let a = Series::from_points("pred", &[(1.0, 100.0), (2.0, 50.0)]);
        let b = Series::from_points("meas", &[(1.0, 110.0), (2.0, 55.0)]);
        let s = render_chart("fig", &[a, b], "seconds");
        assert!(s.contains('*') && s.contains('o'));
        assert!(s.contains("pred") && s.contains("meas"));
    }

    #[test]
    fn chart_handles_empty() {
        let s = render_chart("fig", &[Series::new("empty")], "s");
        assert!(s.contains("fig"));
    }

    #[test]
    fn push_builds_points() {
        let mut s = Series::new("x");
        s.push(1.0, 2.0).push(3.0, 4.0);
        assert_eq!(s.points, vec![(1.0, 2.0), (3.0, 4.0)]);
    }
}
