//! Back-propagation and SGD update (per paper Section II).
//!
//! Propagates δE/δy backward layer by layer: at the output the softmax
//! cross-entropy gradient is `p − onehot(label)`; dense and conv layers
//! push their deltas through the weights (δE/δy_i = Σ w_ij · δE/δx_j, the
//! expression in the paper) and accumulate weight gradients; pooling routes
//! deltas through the recorded argmax. Weights are updated in place —
//! plain SGD, matching the JAX train step.

use crate::config::arch::ResolvedLayer;
use crate::engine::forward::Activations;
use crate::engine::softmax;
use crate::error::{Error, Result};
use crate::nn::Network;

/// Back-propagate one image and apply the SGD update.
/// Returns the cross-entropy loss at the (pre-update) forward pass.
pub fn backward(
    net: &mut Network,
    acts: &Activations,
    image: &[f32],
    label: usize,
    lr: f32,
) -> Result<f32> {
    let shapes: Vec<_> = net.shapes().to_vec();
    if label >= acts.logits().len() {
        return Err(Error::Config(format!(
            "label {label} out of range for {} outputs",
            acts.logits().len()
        )));
    }

    // Output gradient: softmax CE.
    let probs = softmax(acts.logits());
    let loss = -probs[label].max(1e-12).ln();
    let mut delta: Vec<f32> = probs;
    delta[label] -= 1.0;

    // Walk layers backward. `param_idx` indexes trainable layers from the
    // end.
    let n_trainable = net.params.len();
    let mut param_idx = n_trainable;

    for li in (1..shapes.len()).rev() {
        let prev_out: &[f32] = if li == 1 { image } else { &acts.outs[li - 1] };
        match shapes[li].spec {
            ResolvedLayer::Dense { units, fan_in, last } => {
                param_idx -= 1;
                let p = &mut net.params[param_idx];
                // δ wrt pre-activation: through tanh' unless output layer.
                let mut dz = delta;
                if !last {
                    for (d, &y) in dz.iter_mut().zip(acts.outs[li].iter()) {
                        *d *= 1.0 - y * y;
                    }
                }
                // Delta for the previous layer before updating weights.
                let mut dprev = vec![0.0f32; fan_in];
                for f in 0..fan_in {
                    let wrow = f * units;
                    let mut acc = 0.0f32;
                    for u in 0..units {
                        acc += p.w[wrow + u] * dz[u];
                    }
                    dprev[f] = acc;
                }
                // SGD update.
                for f in 0..fan_in {
                    let x = prev_out[f];
                    if x != 0.0 {
                        let wrow = f * units;
                        for u in 0..units {
                            p.w[wrow + u] -= lr * x * dz[u];
                        }
                    }
                }
                for u in 0..units {
                    p.b[u] -= lr * dz[u];
                }
                delta = dprev;
            }
            ResolvedLayer::Pool { window, maps, in_hw, out_hw } => {
                let argmax = acts.pool_argmax[li]
                    .as_ref()
                    .ok_or_else(|| Error::Config("pool layer missing argmax".into()))?;
                let mut dprev = vec![0.0f32; maps * in_hw * in_hw];
                for (o, &src) in argmax.iter().enumerate() {
                    dprev[src] += delta[o];
                }
                let _ = (window, out_hw);
                delta = dprev;
            }
            ResolvedLayer::Conv { maps, kernel, in_maps, in_hw, out_hw } => {
                param_idx -= 1;
                let p = &mut net.params[param_idx];
                let ksq = kernel * kernel;
                let fan_in = in_maps * ksq;
                // Through tanh'.
                let mut dz = delta;
                for (d, &y) in dz.iter_mut().zip(acts.outs[li].iter()) {
                    *d *= 1.0 - y * y;
                }
                let mut dprev = vec![0.0f32; in_maps * in_hw * in_hw];
                // Accumulate weight gradients separately so every output
                // position sees the pre-update weights (true batch gradient
                // for this image, matching the JAX artifact's semantics).
                let mut dw = vec![0.0f32; maps * fan_in];
                if out_hw < 10 {
                    // Narrow maps: per-neuron scatter order (see
                    // forward.rs §Perf L3-3 for the adaptive rationale).
                    for m in 0..maps {
                        let wbase = m * fan_in;
                        let obase = m * out_hw * out_hw;
                        let mut db = 0.0f32;
                        for oy in 0..out_hw {
                            for ox in 0..out_hw {
                                let d = dz[obase + oy * out_hw + ox];
                                if d == 0.0 {
                                    continue;
                                }
                                db += d;
                                for im in 0..in_maps {
                                    let ibase = im * in_hw * in_hw;
                                    let wmap = wbase + im * ksq;
                                    for ky in 0..kernel {
                                        let irow = ibase + (oy + ky) * in_hw + ox;
                                        let wrow = wmap + ky * kernel;
                                        for kx in 0..kernel {
                                            dprev[irow + kx] += p.w[wrow + kx] * d;
                                            dw[wrow + kx] += d * prev_out[irow + kx];
                                        }
                                    }
                                }
                            }
                        }
                        p.b[m] -= lr * db;
                    }
                } else {
                    // Wide maps: weight-hoisted row order — the inner ox
                    // loops walk dz/dprev/prev rows contiguously and
                    // auto-vectorize (§Perf L3-4).
                    for m in 0..maps {
                        let wbase = m * fan_in;
                        let obase = m * out_hw * out_hw;
                        let db: f32 = dz[obase..obase + out_hw * out_hw].iter().sum();
                        for im in 0..in_maps {
                            let ibase = im * in_hw * in_hw;
                            let wmap = wbase + im * ksq;
                            for ky in 0..kernel {
                                for kx in 0..kernel {
                                    let w = p.w[wmap + ky * kernel + kx];
                                    let mut g = 0.0f32;
                                    for oy in 0..out_hw {
                                        let orow = obase + oy * out_hw;
                                        let irow = ibase + (oy + ky) * in_hw + kx;
                                        let dz_row = &dz[orow..orow + out_hw];
                                        let dp_row = &mut dprev[irow..irow + out_hw];
                                        let pv_row = &prev_out[irow..irow + out_hw];
                                        for ((dp, &d), &x) in
                                            dp_row.iter_mut().zip(dz_row).zip(pv_row)
                                        {
                                            *dp += w * d;
                                            g += d * x;
                                        }
                                    }
                                    dw[wmap + ky * kernel + kx] = g;
                                }
                            }
                        }
                        p.b[m] -= lr * db;
                    }
                }
                for (w, g) in p.w.iter_mut().zip(dw.iter()) {
                    *w -= lr * g;
                }
                delta = dprev;
            }
            ResolvedLayer::Input { .. } => break,
        }
    }

    Ok(loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;
    use crate::engine::forward::forward;
    use crate::engine::train_image;

    fn image(seed: u32) -> Vec<f32> {
        (0..841)
            .map(|i| {
                (((i as u32).wrapping_mul(2654435761).wrapping_add(seed) >> 16) & 0xff) as f32
                    / 255.0
            })
            .collect()
    }

    /// Finite-difference gradient check on a handful of parameters.
    fn fd_check(arch: ArchSpec, param_layer: usize, indices: &[usize]) {
        let img = image(17);
        let label = 4usize;
        let eps = 2e-3f32;

        for &wi in indices {
            let base = Network::new(arch.clone(), 31).unwrap();

            // Analytic gradient: run one SGD step with lr and recover
            // grad = (w_before - w_after) / lr.
            let lr = 1e-3f32;
            let mut net = base.clone();
            let _ = train_image(&mut net, &img, label, lr).unwrap();
            let analytic =
                (base.params[param_layer].w[wi] - net.params[param_layer].w[wi]) / lr;

            // Numeric gradient by central differences on the loss.
            let loss_at = |delta: f32| -> f32 {
                let mut n = base.clone();
                n.params[param_layer].w[wi] += delta;
                let acts = forward(&n, &img).unwrap();
                let probs = crate::engine::softmax(acts.logits());
                -probs[label].max(1e-12).ln()
            };
            let numeric = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps);

            let denom = numeric.abs().max(analytic.abs()).max(1e-4);
            let rel = (numeric - analytic).abs() / denom;
            assert!(
                rel < 0.08,
                "layer {param_layer} w[{wi}]: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn gradient_check_small_conv_weights() {
        fd_check(ArchSpec::small(), 0, &[0, 7, 33, 79]);
    }

    #[test]
    fn gradient_check_small_dense_weights() {
        fd_check(ArchSpec::small(), 1, &[0, 123, 4567, 8449]);
    }

    #[test]
    fn gradient_check_medium_second_conv() {
        fd_check(ArchSpec::medium(), 1, &[0, 1001, 19_999]);
    }

    #[test]
    fn loss_decreases_over_epoch_on_tiny_set() {
        let mut net = Network::new(ArchSpec::small(), 77).unwrap();
        // Learnable structured inputs (synthetic digit corpus).
        let (images, labels) = crate::dataset::synth::generate(20, 5);
        let epoch_loss = |net: &mut Network, lr: f32| -> f32 {
            let mut total = 0.0;
            for (img, &lab) in images.iter().zip(labels.iter()) {
                total += train_image(net, img, lab, lr).unwrap();
            }
            total / images.len() as f32
        };
        let first = epoch_loss(&mut net, 0.01);
        let mut last = first;
        for _ in 0..40 {
            last = epoch_loss(&mut net, 0.01);
        }
        assert!(last < first * 0.8, "{first} -> {last}");
    }

    #[test]
    fn rejects_out_of_range_label() {
        let mut net = Network::new(ArchSpec::small(), 1).unwrap();
        let img = image(5);
        let acts = forward(&net, &img).unwrap();
        assert!(backward(&mut net, &acts, &img, 99, 0.01).is_err());
    }

    #[test]
    fn zero_lr_keeps_weights() {
        let base = Network::new(ArchSpec::small(), 13).unwrap();
        let mut net = base.clone();
        let img = image(2);
        train_image(&mut net, &img, 1, 0.0).unwrap();
        assert_eq!(net.params, base.params);
    }
}
