//! Forward propagation through a [`Network`].

use crate::config::arch::ResolvedLayer;
use crate::error::{Error, Result};
use crate::nn::Network;

/// Per-layer forward state kept for the backward pass.
#[derive(Debug, Clone)]
pub struct Activations {
    /// Output values per layer, `outs[0]` is the input image.
    pub outs: Vec<Vec<f32>>,
    /// For each pool layer index (into `outs`), the argmax source index of
    /// every pooled output (into the layer's input vector).
    pub pool_argmax: Vec<Option<Vec<usize>>>,
}

impl Activations {
    /// The final layer's raw outputs (logits of the linear output layer).
    pub fn logits(&self) -> &[f32] {
        self.outs.last().map(|v| v.as_slice()).unwrap_or(&[])
    }
}

#[inline]
pub(crate) fn tanh_act(x: f32) -> f32 {
    x.tanh()
}

/// Forward-propagate one image (`29*29` values in row-major order).
pub fn forward(net: &Network, image: &[f32]) -> Result<Activations> {
    let shapes = net.shapes();
    let input_hw = match shapes[0].spec {
        ResolvedLayer::Input { hw } => hw,
        _ => return Err(Error::Config("first layer must be input".into())),
    };
    if image.len() != input_hw * input_hw {
        return Err(Error::Config(format!(
            "image has {} values, expected {}",
            image.len(),
            input_hw * input_hw
        )));
    }

    let mut outs: Vec<Vec<f32>> = Vec::with_capacity(shapes.len());
    let mut pool_argmax: Vec<Option<Vec<usize>>> = Vec::with_capacity(shapes.len());
    outs.push(image.to_vec());
    pool_argmax.push(None);

    let mut param_idx = 0usize;
    for shape in &shapes[1..] {
        let prev = outs.last().unwrap();
        match shape.spec {
            ResolvedLayer::Conv { maps, kernel, in_maps, in_hw, out_hw } => {
                let p = &net.params[param_idx];
                param_idx += 1;
                let mut out = vec![0.0f32; maps * out_hw * out_hw];
                let ksq = kernel * kernel;
                let fan_in = in_maps * ksq;
                // §Perf L3-3 — adaptive conv loop order. For wide output
                // maps (26x26, 13x13, 11x11) the (m, im, ky, kx) outer /
                // (oy, ox) inner order hoists the weight to a scalar and
                // walks `out`/`prev` rows contiguously, which LLVM
                // auto-vectorizes (-28% small fwd, -15% medium fwd). For
                // narrow maps (the large CNN's 6x6 C3) the row loop is too
                // short and per-iteration overhead dominates, so the
                // per-neuron dot-product order is kept (EXPERIMENTS.md
                // §Perf has the before/after table).
                if out_hw < 8 {
                    for m in 0..maps {
                        let wbase = m * fan_in;
                        let bias = p.b[m];
                        for oy in 0..out_hw {
                            for ox in 0..out_hw {
                                let mut acc = bias;
                                for im in 0..in_maps {
                                    let ibase = im * in_hw * in_hw;
                                    let wmap = wbase + im * ksq;
                                    for ky in 0..kernel {
                                        let irow = ibase + (oy + ky) * in_hw + ox;
                                        let wrow = wmap + ky * kernel;
                                        for kx in 0..kernel {
                                            acc += prev[irow + kx] * p.w[wrow + kx];
                                        }
                                    }
                                }
                                out[m * out_hw * out_hw + oy * out_hw + ox] =
                                    tanh_act(acc);
                            }
                        }
                    }
                    outs.push(out);
                    pool_argmax.push(None);
                    continue;
                }
                for m in 0..maps {
                    let obase = m * out_hw * out_hw;
                    let bias = p.b[m];
                    out[obase..obase + out_hw * out_hw].fill(bias);
                    let wbase = m * fan_in;
                    for im in 0..in_maps {
                        let ibase = im * in_hw * in_hw;
                        let wmap = wbase + im * ksq;
                        for ky in 0..kernel {
                            for kx in 0..kernel {
                                let w = p.w[wmap + ky * kernel + kx];
                                for oy in 0..out_hw {
                                    let orow = obase + oy * out_hw;
                                    let irow = ibase + (oy + ky) * in_hw + kx;
                                    let (orow_s, irow_s) = (
                                        &mut out[orow..orow + out_hw],
                                        &prev[irow..irow + out_hw],
                                    );
                                    for (o, &x) in orow_s.iter_mut().zip(irow_s) {
                                        *o += w * x;
                                    }
                                }
                            }
                        }
                    }
                    for o in out[obase..obase + out_hw * out_hw].iter_mut() {
                        *o = tanh_act(*o);
                    }
                }
                outs.push(out);
                pool_argmax.push(None);
            }
            ResolvedLayer::Pool { window, maps, in_hw, out_hw } => {
                let mut out = vec![0.0f32; maps * out_hw * out_hw];
                let mut argmax = vec![0usize; maps * out_hw * out_hw];
                for m in 0..maps {
                    let ibase = m * in_hw * in_hw;
                    for oy in 0..out_hw {
                        for ox in 0..out_hw {
                            let mut best = f32::NEG_INFINITY;
                            let mut best_idx = 0usize;
                            for wy in 0..window {
                                for wx in 0..window {
                                    let idx = ibase
                                        + (oy * window + wy) * in_hw
                                        + (ox * window + wx);
                                    if prev[idx] > best {
                                        best = prev[idx];
                                        best_idx = idx;
                                    }
                                }
                            }
                            let o = m * out_hw * out_hw + oy * out_hw + ox;
                            out[o] = best;
                            argmax[o] = best_idx;
                        }
                    }
                }
                outs.push(out);
                pool_argmax.push(Some(argmax));
            }
            ResolvedLayer::Dense { units, fan_in, last } => {
                let p = &net.params[param_idx];
                param_idx += 1;
                debug_assert_eq!(prev.len(), fan_in);
                let mut out = vec![0.0f32; units];
                for (f, &x) in prev.iter().enumerate() {
                    if x == 0.0 {
                        continue;
                    }
                    let wrow = f * units;
                    for u in 0..units {
                        out[u] += x * p.w[wrow + u];
                    }
                }
                for u in 0..units {
                    out[u] += p.b[u];
                    if !last {
                        out[u] = tanh_act(out[u]);
                    }
                }
                outs.push(out);
                pool_argmax.push(None);
            }
            ResolvedLayer::Input { .. } => {
                return Err(Error::Config("input layer repeated".into()))
            }
        }
    }

    Ok(Activations { outs, pool_argmax })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    fn image(seed: u32) -> Vec<f32> {
        (0..841)
            .map(|i| (((i as u32).wrapping_mul(2654435761).wrapping_add(seed) >> 16) & 0xff) as f32 / 255.0)
            .collect()
    }

    #[test]
    fn shapes_per_layer_small() {
        let net = Network::new(ArchSpec::small(), 1).unwrap();
        let acts = forward(&net, &image(0)).unwrap();
        let sizes: Vec<usize> = acts.outs.iter().map(|v| v.len()).collect();
        assert_eq!(sizes, vec![841, 5 * 26 * 26, 5 * 13 * 13, 10]);
    }

    #[test]
    fn shapes_per_layer_large() {
        let net = Network::new(ArchSpec::large(), 1).unwrap();
        let acts = forward(&net, &image(1)).unwrap();
        let sizes: Vec<usize> = acts.outs.iter().map(|v| v.len()).collect();
        assert_eq!(
            sizes,
            vec![841, 20 * 676, 20 * 169, 60 * 121, 100 * 36, 100 * 9, 150, 10]
        );
    }

    #[test]
    fn hidden_activations_bounded_by_tanh() {
        let net = Network::new(ArchSpec::medium(), 3).unwrap();
        let acts = forward(&net, &image(2)).unwrap();
        // All layers except input and final logits are tanh/max outputs of
        // tanh values, hence within [-1, 1].
        for layer in &acts.outs[1..acts.outs.len() - 1] {
            assert!(layer.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        }
    }

    #[test]
    fn pool_argmax_points_at_max() {
        let net = Network::new(ArchSpec::small(), 4).unwrap();
        let acts = forward(&net, &image(3)).unwrap();
        let conv_out = &acts.outs[1];
        let pool_out = &acts.outs[2];
        let argmax = acts.pool_argmax[2].as_ref().unwrap();
        for (o, &src) in argmax.iter().enumerate() {
            assert_eq!(conv_out[src], pool_out[o]);
        }
    }

    #[test]
    fn rejects_wrong_image_size() {
        let net = Network::new(ArchSpec::small(), 1).unwrap();
        assert!(forward(&net, &[0.0; 100]).is_err());
    }

    #[test]
    fn deterministic_forward() {
        let net = Network::new(ArchSpec::small(), 6).unwrap();
        let a = forward(&net, &image(9)).unwrap();
        let b = forward(&net, &image(9)).unwrap();
        assert_eq!(a.outs, b.outs);
    }

    #[test]
    fn zero_image_gives_bias_driven_logits() {
        // With zero input and zero biases, logits are exactly zero.
        let net = Network::new(ArchSpec::small(), 8).unwrap();
        let acts = forward(&net, &vec![0.0; 841]).unwrap();
        assert!(acts.logits().iter().all(|&z| z == 0.0));
    }
}
