//! Pure-Rust CNN forward/backward — the Cireşan-code substitute.
//!
//! The paper parallelizes Cireşan's C/C++ CNN training code [22]; we rebuild
//! that compute here so the system is self-contained: the engine is the
//! fallback training backend (no artifacts needed), the numerical oracle for
//! integration tests, and the reference the PJRT path is compared against.
//!
//! Semantics match `python/compile/model.py`: tanh hidden activations,
//! non-overlapping max pooling, softmax cross-entropy output, per-batch SGD.
//! The layer layouts are documented on [`crate::nn::Network`].

pub mod backward;
pub mod forward;

use crate::config::arch::ResolvedLayer;
use crate::error::Result;
use crate::nn::Network;

pub use backward::backward;
pub use forward::{forward, Activations};

/// Stable softmax over logits.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

/// Cross-entropy of a softmax distribution against an integer label.
pub fn cross_entropy(probs: &[f32], label: usize) -> f32 {
    -probs[label].max(1e-12).ln()
}

/// One SGD step on a single image. Returns the loss before the update.
pub fn train_image(net: &mut Network, image: &[f32], label: usize, lr: f32) -> Result<f32> {
    let acts = forward(net, image)?;
    backward(net, &acts, image, label, lr)
}

/// Forward-only classification: returns (predicted class, loss).
pub fn classify(net: &Network, image: &[f32], label: usize) -> Result<(usize, f32)> {
    let acts = forward(net, image)?;
    let probs = softmax(acts.logits());
    let pred = probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    Ok((pred, cross_entropy(&probs, label)))
}

/// Whether the final layer of the arch is the linear output (sanity helper).
pub fn output_units(net: &Network) -> usize {
    match net.shapes().last().map(|l| l.spec) {
        Some(ResolvedLayer::Dense { units, .. }) => units,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let p = softmax(&[1000.0, 1000.0, 1000.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p.iter().all(|&x| (x - 1.0 / 3.0).abs() < 1e-6));
    }

    #[test]
    fn cross_entropy_of_confident_correct_is_small() {
        assert!(cross_entropy(&[0.01, 0.99], 1) < 0.02);
        assert!(cross_entropy(&[0.99, 0.01], 1) > 4.0);
    }

    #[test]
    fn train_reduces_loss_on_one_image() {
        let mut net = Network::new(ArchSpec::small(), 11).unwrap();
        let image: Vec<f32> = (0..841).map(|i| ((i * 7919) % 97) as f32 / 97.0).collect();
        let first = train_image(&mut net, &image, 3, 0.1).unwrap();
        let mut last = first;
        for _ in 0..20 {
            last = train_image(&mut net, &image, 3, 0.1).unwrap();
        }
        assert!(last < first, "{last} !< {first}");
    }

    #[test]
    fn classify_returns_valid_class() {
        let net = Network::new(ArchSpec::small(), 2).unwrap();
        let image = vec![0.5; 841];
        let (pred, loss) = classify(&net, &image, 0).unwrap();
        assert!(pred < 10);
        assert!(loss.is_finite());
    }

    #[test]
    fn initial_loss_near_ln10() {
        // Untrained network ≈ uniform prediction over 10 classes.
        let net = Network::new(ArchSpec::small(), 5).unwrap();
        let image = vec![0.1; 841];
        let (_, loss) = classify(&net, &image, 7).unwrap();
        assert!((loss - 10f32.ln()).abs() < 0.7, "{loss}");
    }

    #[test]
    fn output_units_is_ten() {
        let net = Network::new(ArchSpec::medium(), 1).unwrap();
        assert_eq!(output_units(&net), 10);
    }
}
