//! The Fig. 4 workload on the simulated machine.
//!
//! Per run: serial preparation (instance creation is not parallelized),
//! then `ep` epochs of three barrier-separated phases — training
//! (fwd+bwd per image), validation (fwd over the training set), test
//! (fwd over the test set) — plus serial bookkeeping. Images are
//! partitioned contiguously: the first `i mod p` threads take ⌈i/p⌉
//! images, the rest ⌊i/p⌋ (the same split OpenMP static scheduling
//! produces).

use crate::config::arch::ArchSpec;
use crate::config::RunConfig;
use crate::error::Result;
use crate::simulator::cost::{CostModel, CostTable, PerImageCost};
use crate::simulator::event::Engine;
use crate::simulator::machine::PhiMachine;
use crate::simulator::stats::{PhaseTimes, SimResult};
use crate::simulator::SimConfig;

/// Simulation granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Fidelity {
    /// One event per image per phase on the DES engine — the reference
    /// semantics; O(i·ep) events.
    PerImage,
    /// Closed-form per (thread, phase) chunk — identical times, ~10³×
    /// faster (the §Perf optimization).
    #[default]
    Chunked,
}

impl Fidelity {
    /// Canonical token ("chunked" / "image") — the CLI and JSON-spec
    /// encoding, inverted by [`Fidelity::parse`].
    pub fn as_str(self) -> &'static str {
        match self {
            Fidelity::Chunked => "chunked",
            Fidelity::PerImage => "image",
        }
    }

    /// Parse a fidelity token (`chunked`, `image`, or the `per-image`
    /// alias).
    pub fn parse(text: &str) -> crate::error::Result<Fidelity> {
        match text {
            "chunked" => Ok(Fidelity::Chunked),
            "image" | "per-image" => Ok(Fidelity::PerImage),
            other => Err(crate::error::Error::Config(format!(
                "fidelity must be chunked|image, got {other:?}"
            ))),
        }
    }
}

/// Images assigned to thread `t` out of `total` split over `p` threads.
pub fn chunk_of(total: usize, p: usize, t: usize) -> usize {
    let base = total / p;
    let extra = total % p;
    if t < extra {
        base + 1
    } else {
        base
    }
}

/// Simulate one full training run.
pub fn simulate_training(
    arch: &ArchSpec,
    run: &RunConfig,
    cfg: &SimConfig,
) -> Result<SimResult> {
    let cost = CostModel::new(arch, cfg)?;
    simulate_training_with(&cost, run, cfg)
}

/// Simulate with a prebuilt [`CostModel`] — the sweep-cache path, which
/// resolves the per-layer op counts and cost calibration once per
/// (architecture, machine) instead of once per scenario.
pub fn simulate_training_with(
    cost: &CostModel,
    run: &RunConfig,
    cfg: &SimConfig,
) -> Result<SimResult> {
    simulate_with_cost(cost, run, cfg)
}

/// Simulate over a shared [`CostTable`] — the thread-ladder fast path.
/// Every per-image cost comes out of the table's per-occupancy-class
/// memo, so a ladder of runs over one (arch, fingerprint) computes each
/// class once across *all* its points (and all sweep workers), yet the
/// result is bit-identical to [`simulate_training_with`] on the wrapped
/// model (asserted in this module's tests).
pub fn simulate_training_shared(
    table: &CostTable,
    run: &RunConfig,
    cfg: &SimConfig,
) -> Result<SimResult> {
    simulate_with_cost(table, run, cfg)
}

fn simulate_with_cost<C: PerImageCost>(
    cost: &C,
    run: &RunConfig,
    cfg: &SimConfig,
) -> Result<SimResult> {
    run.validate()?;
    let machine = PhiMachine::new(cfg.machine.clone(), run.threads);
    match cfg.fidelity {
        Fidelity::Chunked => Ok(simulate_chunked(&machine, cost, run, cfg)),
        Fidelity::PerImage => Ok(simulate_per_image(&machine, cost, run, cfg)),
    }
}

/// Closed-form evaluation: per-phase time = max over threads of
/// (chunk × per-image cost); identical semantics to the DES.
fn simulate_chunked<C: PerImageCost>(
    machine: &PhiMachine,
    cost: &C,
    run: &RunConfig,
    cfg: &SimConfig,
) -> SimResult {
    let p = run.threads;
    let prep = cost.prep_s(cfg, p);
    let serial_epoch = cost.epoch_serial_s(cfg, run.train_images, run.test_images);

    let mut train_max = 0.0f64;
    let mut val_max = 0.0f64;
    let mut test_max = 0.0f64;
    let mut busy_min = f64::INFINITY;
    let mut busy_max = 0.0f64;

    // §Perf: O(cores) instead of O(p) (EXPERIMENTS.md §Perf L3-2).
    //
    // Per-image cost is non-decreasing in (chunk, occupancy, oversub),
    // and thread 0 maximizes all three simultaneously (largest chunk goes
    // to the lowest thread ids; core 0 carries the highest SMT occupancy
    // and oversubscription under scatter placement), so the slowest
    // worker is always t = 0. The fastest worker has the smallest chunk
    // and lowest occupancy; every core (hence every occupancy class)
    // appears exactly once among the last min(p, cores) threads, and when
    // small-chunk threads exist they extend to t = p−1, so the window
    // [p − min(p, cores), p) ∪ {0} always contains the global minimum.
    let window = p.min(cfg.machine.cores);
    let candidates = std::iter::once(0).chain((p - window)..p);
    for t in candidates {
        let train_chunk = chunk_of(run.train_images, p, t) as f64;
        let test_chunk = chunk_of(run.test_images, p, t) as f64;
        let fwd_s = cost.fwd_image_s(cfg, machine, t);
        let t_train = train_chunk * cost.train_image_s(cfg, machine, t);
        let t_val = train_chunk * fwd_s;
        let t_test = test_chunk * fwd_s;
        train_max = train_max.max(t_train);
        val_max = val_max.max(t_val);
        test_max = test_max.max(t_test);
        let busy = t_train + t_val + t_test;
        busy_min = busy_min.min(busy);
        busy_max = busy_max.max(busy);
    }

    let ep = run.epochs as f64;
    let phases = PhaseTimes {
        prep_s: prep,
        train_s: train_max * ep,
        validation_s: val_max * ep,
        test_s: test_max * ep,
        serial_s: serial_epoch * ep,
    };
    let total = phases.total();
    SimResult {
        total_s: total,
        execution_s: total - prep,
        phases,
        threads: p,
        events: 0,
        slowest_busy_s: busy_max * ep,
        fastest_busy_s: if busy_min.is_finite() { busy_min * ep } else { 0.0 },
    }
}

/// Per-image DES: each thread is an event chain processing its chunk one
/// image at a time; phases are separated by barriers.
fn simulate_per_image<C: PerImageCost>(
    machine: &PhiMachine,
    cost: &C,
    run: &RunConfig,
    cfg: &SimConfig,
) -> SimResult {
    #[derive(Debug, Clone, Copy)]
    struct Work {
        thread: usize,
        remaining: usize,
        phase: usize, // 0 = train, 1 = validation, 2 = test
    }

    let p = run.threads;
    let mut engine: Engine<Work> = Engine::new();
    let prep = cost.prep_s(cfg, p);
    let serial_epoch = cost.epoch_serial_s(cfg, run.train_images, run.test_images);

    let mut phases = PhaseTimes { prep_s: prep, ..Default::default() };
    let mut busy = vec![0.0f64; p];
    let mut clock = prep;

    for _epoch in 0..run.epochs {
        for phase in 0..3 {
            let phase_start = clock;
            let mut phase_end = phase_start;
            for t in 0..p {
                let chunk = match phase {
                    0 | 1 => chunk_of(run.train_images, p, t),
                    _ => chunk_of(run.test_images, p, t),
                };
                if chunk > 0 {
                    engine.schedule_at(phase_start, Work { thread: t, remaining: chunk, phase });
                }
            }
            while let Some((now, work)) = engine.pop() {
                let dt = match work.phase {
                    0 => cost.train_image_s(cfg, machine, work.thread),
                    _ => cost.fwd_image_s(cfg, machine, work.thread),
                };
                busy[work.thread] += dt;
                let done_at = now + dt;
                if work.remaining > 1 {
                    engine.schedule_at(
                        done_at,
                        Work { remaining: work.remaining - 1, ..work },
                    );
                } else {
                    phase_end = phase_end.max(done_at);
                }
            }
            let dur = phase_end - phase_start;
            match phase {
                0 => phases.train_s += dur,
                1 => phases.validation_s += dur,
                _ => phases.test_s += dur,
            }
            clock = phase_end;
        }
        phases.serial_s += serial_epoch;
        clock += serial_epoch;
    }

    let total = phases.total();
    SimResult {
        total_s: total,
        execution_s: total - prep,
        phases,
        threads: p,
        events: engine.processed(),
        slowest_busy_s: busy.iter().cloned().fold(0.0, f64::max),
        fastest_busy_s: busy.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_run(p: usize) -> (ArchSpec, RunConfig, SimConfig) {
        let arch = ArchSpec::small();
        // Scaled-down workload so per-image DES stays fast in tests.
        let run = RunConfig {
            train_images: 600,
            test_images: 100,
            epochs: 2,
            threads: p,
        };
        (arch, run, SimConfig::default())
    }

    #[test]
    fn chunk_partition_conserves_images() {
        for (total, p) in [(60_000, 240), (60_000, 7), (10, 16), (100, 1)] {
            let sum: usize = (0..p).map(|t| chunk_of(total, p, t)).sum();
            assert_eq!(sum, total, "total={total} p={p}");
            let max = (0..p).map(|t| chunk_of(total, p, t)).max().unwrap();
            let min = (0..p).map(|t| chunk_of(total, p, t)).min().unwrap();
            assert!(max - min <= 1);
            assert_eq!(max, total.div_ceil(p).min(total));
        }
    }

    #[test]
    fn chunked_equals_per_image() {
        for p in [1, 3, 16, 61, 100] {
            let (arch, run, mut cfg) = small_run(p);
            cfg.fidelity = Fidelity::Chunked;
            let a = simulate_training(&arch, &run, &cfg).unwrap();
            cfg.fidelity = Fidelity::PerImage;
            let b = simulate_training(&arch, &run, &cfg).unwrap();
            let rel = (a.total_s - b.total_s).abs() / b.total_s;
            assert!(rel < 1e-9, "p={p}: {} vs {}", a.total_s, b.total_s);
            assert!(b.events > 0 && a.events == 0);
        }
    }

    #[test]
    fn more_threads_is_faster_within_hardware() {
        let (arch, _, cfg) = small_run(1);
        let run = RunConfig::paper_default("small", 1).with_epochs(1);
        let t = |p: usize| {
            simulate_training(&arch, &run.with_threads(p), &cfg)
                .unwrap()
                .execution_s
        };
        let t1 = t(1);
        let t15 = t(15);
        let t60 = t(60);
        let t240 = t(240);
        assert!(t1 > t15 && t15 > t60 && t60 > t240, "{t1} {t15} {t60} {t240}");
    }

    #[test]
    fn speedup_sublinear_due_to_smt_and_contention() {
        let (arch, _, cfg) = small_run(1);
        let run = RunConfig::paper_default("small", 1).with_epochs(1);
        let t1 = simulate_training(&arch, &run.with_threads(1), &cfg).unwrap();
        let t240 = simulate_training(&arch, &run.with_threads(240), &cfg).unwrap();
        let speedup = t1.execution_s / t240.execution_s;
        assert!(speedup > 30.0 && speedup < 240.0, "speedup {speedup}");
    }

    #[test]
    fn doubling_epochs_doubles_execution_time() {
        let (arch, run, cfg) = small_run(16);
        let a = simulate_training(&arch, &run, &cfg).unwrap();
        let b = simulate_training(&arch, &run.with_epochs(4), &cfg).unwrap();
        let ratio = b.execution_s / a.execution_s;
        assert!((ratio - 2.0).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn execution_excludes_prep() {
        let (arch, run, cfg) = small_run(8);
        let r = simulate_training(&arch, &run, &cfg).unwrap();
        assert!((r.total_s - r.execution_s - r.phases.prep_s).abs() < 1e-9);
    }

    #[test]
    fn imbalance_appears_when_p_does_not_divide_i() {
        let arch = ArchSpec::small();
        let cfg = SimConfig::default();
        let run = RunConfig { train_images: 100, test_images: 10, epochs: 1, threads: 7 };
        let r = simulate_training(&arch, &run, &cfg).unwrap();
        assert!(r.imbalance() > 0.0);
    }

    #[test]
    fn zero_test_images_is_fine() {
        let arch = ArchSpec::small();
        let cfg = SimConfig::default();
        let run = RunConfig { train_images: 50, test_images: 0, epochs: 1, threads: 4 };
        let r = simulate_training(&arch, &run, &cfg).unwrap();
        assert_eq!(r.phases.test_s, 0.0);
        assert!(r.total_s > 0.0);
    }

    #[test]
    fn oversubscribed_run_simulates() {
        let arch = ArchSpec::small();
        let cfg = SimConfig::default();
        let run = RunConfig { train_images: 3840, test_images: 640, epochs: 1, threads: 3840 };
        let r = simulate_training(&arch, &run, &cfg).unwrap();
        assert!(r.total_s.is_finite() && r.total_s > 0.0);
    }

    #[test]
    fn shared_cost_table_is_bit_identical_across_a_thread_ladder() {
        // The ladder fast path: one CostTable shared across every point
        // of a threads ladder (including oversubscription) must produce
        // exactly the bits of a fresh CostModel evaluation per point —
        // chunked and per-image fidelity alike.
        let arch = ArchSpec::small();
        let mut cfg = SimConfig::default();
        let base_run =
            RunConfig { train_images: 600, test_images: 100, epochs: 2, threads: 1 };
        for fidelity in [Fidelity::Chunked, Fidelity::PerImage] {
            cfg.fidelity = fidelity;
            let model = std::sync::Arc::new(CostModel::new(&arch, &cfg).unwrap());
            let table = CostTable::new(std::sync::Arc::clone(&model));
            for p in [1, 3, 15, 61, 240, 488] {
                let run = RunConfig { threads: p, ..base_run };
                let fresh = simulate_training_with(&model, &run, &cfg).unwrap();
                let shared = simulate_training_shared(&table, &run, &cfg).unwrap();
                assert_eq!(
                    fresh.total_s.to_bits(),
                    shared.total_s.to_bits(),
                    "p={p} {fidelity:?}"
                );
                assert_eq!(fresh.execution_s.to_bits(), shared.execution_s.to_bits());
                assert_eq!(fresh.phases.train_s.to_bits(), shared.phases.train_s.to_bits());
                assert_eq!(fresh.phases.test_s.to_bits(), shared.phases.test_s.to_bits());
                assert_eq!(
                    fresh.slowest_busy_s.to_bits(),
                    shared.slowest_busy_s.to_bits()
                );
            }
        }
    }
}
