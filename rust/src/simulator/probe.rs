//! Measurement probes — the simulator-side analogue of the paper's
//! "small script" experiments.
//!
//! * [`contention_probe`] reproduces Table IV: per-image memory/sync wait
//!   when `p` threads compete, measured by running a short probe workload
//!   on the DES engine (weight-update traffic only).
//! * [`measure_image_times`] extracts strategy (b)'s measured parameters
//!   (T_Fprop, T_Bprop per image at one thread; T_prep) from the
//!   simulator — exactly how the authors measured them on the real Phi.

use crate::config::arch::ArchSpec;
use crate::config::RunConfig;
use crate::error::Result;
use crate::simulator::cost::CostModel;
use crate::simulator::machine::PhiMachine;
use crate::simulator::workload::{chunk_of, simulate_training};
use crate::simulator::{Fidelity, SimConfig};

/// Per-image memory contention at `p` threads (Table IV analogue).
///
/// Runs a micro-workload: each thread issues `iters` weight-update
/// rounds; the mean added wait per round is the contention. Because the
/// channel model is deterministic, the mean equals the closed-form
/// [`crate::simulator::memory::ContentionParams::contention_s`]; the probe
/// exists so the experiment exercises the same measurement path the paper
/// used (and stays meaningful if the memory model gains stochastic
/// queueing).
pub fn contention_probe(arch: &ArchSpec, p: usize, cfg: &SimConfig) -> Result<f64> {
    Ok(contention_probe_with(&CostModel::new(arch, cfg)?, p, cfg))
}

/// [`contention_probe`] against a prebuilt, calibrated [`CostModel`] —
/// the memoized path ([`crate::perfmodel::ContentionSource`] builds the
/// cost model once and probes every thread count against it).
pub fn contention_probe_with(cost: &CostModel, p: usize, cfg: &SimConfig) -> f64 {
    let iters = 16usize;
    let mut total = 0.0f64;
    for _round in 0..iters {
        total += cost.contention.contention_s(p, &cfg.machine);
    }
    total / iters as f64
}

/// Strategy (b) measured parameters, extracted from the simulator.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredParams {
    /// Forward time per image at one thread, seconds.
    pub t_fprop_s: f64,
    /// Backward time per image at one thread, seconds.
    pub t_bprop_s: f64,
    /// Preparation time, seconds (measured at the paper's reference
    /// instance count, 240).
    pub t_prep_s: f64,
}

/// Measure per-image forward/backward times at a single thread, and the
/// preparation time, from the simulator (the model (b) methodology).
pub fn measure_image_times(arch: &ArchSpec, cfg: &SimConfig) -> Result<MeasuredParams> {
    let cost = CostModel::new(arch, cfg)?;
    let machine = PhiMachine::new(cfg.machine.clone(), 1);
    let fwd = cost.fwd_image_s(cfg, &machine, 0);
    let train = cost.train_image_s(cfg, &machine, 0);
    // The single-thread contention floor is part of the measured
    // back-propagation time (the paper's measurement could not separate
    // them either).
    let bwd = train - fwd;
    Ok(MeasuredParams {
        t_fprop_s: fwd,
        t_bprop_s: bwd,
        t_prep_s: cost.prep_s(cfg, 240),
    })
}

/// Convenience: simulate the paper's standard workload for `arch` at `p`
/// threads and return the *execution* time (the figures' y-axis).
pub fn measured_execution_s(arch: &ArchSpec, p: usize, cfg: &SimConfig) -> Result<f64> {
    let run = RunConfig::paper_default(&arch.name, p);
    Ok(simulate_training(arch, &run, cfg)?.execution_s)
}

/// Micro-validation that the per-image DES and the chunked evaluator agree
/// on a down-scaled workload (used by integration tests and the CLI
/// self-check).
pub fn fidelity_crosscheck(arch: &ArchSpec, p: usize, cfg: &SimConfig) -> Result<f64> {
    let run = RunConfig {
        train_images: 4 * p.min(100),
        test_images: p.min(100),
        epochs: 1,
        threads: p,
    };
    let mut chunked_cfg = cfg.clone();
    chunked_cfg.fidelity = Fidelity::Chunked;
    let mut image_cfg = cfg.clone();
    image_cfg.fidelity = Fidelity::PerImage;
    let a = simulate_training(arch, &run, &chunked_cfg)?.total_s;
    let b = simulate_training(arch, &run, &image_cfg)?.total_s;
    let _ = chunk_of(run.train_images, p, 0);
    Ok((a - b).abs() / b.max(f64::MIN_POSITIVE))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_matches_table4_shape() {
        // Same thread counts as Table IV; assert monotone growth and the
        // calibrated anchors.
        let cfg = SimConfig::default();
        let arch = ArchSpec::medium();
        let mut prev = 0.0;
        for p in [1usize, 15, 30, 60, 120, 180, 240, 480, 960, 1920, 3840] {
            let c = contention_probe(&arch, p, &cfg).unwrap();
            assert!(c > prev, "p={p}");
            prev = c;
        }
        let at240 = contention_probe(&arch, 240, &cfg).unwrap();
        assert!((at240 - 3.83e-2).abs() / 3.83e-2 < 0.02, "{at240}");
    }

    #[test]
    fn measured_params_near_table3() {
        let cfg = SimConfig::default();
        for (name, f_ms, b_ms) in
            [("small", 1.45, 5.3), ("medium", 12.55, 69.73), ("large", 148.88, 859.19)]
        {
            let arch = ArchSpec::by_name(name).unwrap();
            let m = measure_image_times(&arch, &cfg).unwrap();
            assert!((m.t_fprop_s * 1e3 - f_ms).abs() / f_ms < 0.12, "{name} fwd");
            assert!((m.t_bprop_s * 1e3 - b_ms).abs() / b_ms < 0.12, "{name} bwd");
            assert!(m.t_prep_s > 12.0 && m.t_prep_s < 14.5, "{name} prep");
        }
    }

    #[test]
    fn fidelity_crosscheck_is_tight() {
        let cfg = SimConfig::default();
        for p in [1, 8, 61, 100] {
            let rel = fidelity_crosscheck(&ArchSpec::small(), p, &cfg).unwrap();
            assert!(rel < 1e-9, "p={p}: {rel}");
        }
    }

    #[test]
    fn measured_execution_scales_down_with_threads() {
        let cfg = SimConfig::default();
        let arch = ArchSpec::small();
        let t15 = measured_execution_s(&arch, 15, &cfg).unwrap();
        let t240 = measured_execution_s(&arch, 240, &cfg).unwrap();
        assert!(t240 < t15);
    }
}
