//! Generic discrete-event engine (time-ordered heap).
//!
//! Minimal but real: f64 simulation clock, stable FIFO ordering among
//! simultaneous events, O(log n) schedule/pop. The per-image fidelity mode
//! of [`crate::simulator::workload`] runs on this engine; the chunked mode
//! bypasses it (that bypass is the headline §Perf optimization — see
//! EXPERIMENTS.md).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event carrying a payload `T` at a simulation time.
#[derive(Debug, Clone)]
struct Scheduled<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (max-heap).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Discrete-event simulation engine.
#[derive(Debug)]
pub struct Engine<T> {
    heap: BinaryHeap<Scheduled<T>>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<T> Default for Engine<T> {
    fn default() -> Self {
        Engine { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }
}

impl<T> Engine<T> {
    /// An empty engine at simulation time 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending events.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `at` (>= now).
    pub fn schedule_at(&mut self, at: f64, payload: T) {
        debug_assert!(at >= self.now, "cannot schedule into the past");
        self.heap.push(Scheduled { time: at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` after `delay` seconds.
    pub fn schedule_in(&mut self, delay: f64, payload: T) {
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing the clock to it.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let ev = self.heap.pop()?;
        self.now = ev.time;
        self.processed += 1;
        Some((ev.time, ev.payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut e = Engine::new();
        e.schedule_at(3.0, "c");
        e.schedule_at(1.0, "a");
        e.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(e.now(), 3.0);
        assert_eq!(e.processed(), 3);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut e = Engine::new();
        for i in 0..10 {
            e.schedule_at(1.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut e = Engine::new();
        e.schedule_at(5.0, ());
        e.pop();
        e.schedule_in(2.5, ());
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 7.5);
    }

    #[test]
    fn chained_scheduling_like_a_thread_loop() {
        // A "thread" that processes 100 work items of 0.1 s each.
        let mut e = Engine::new();
        e.schedule_at(0.0, 100u32);
        let mut done_at = 0.0;
        while let Some((t, remaining)) = e.pop() {
            if remaining > 0 {
                e.schedule_in(0.1, remaining - 1);
            } else {
                done_at = t;
            }
        }
        assert!((done_at - 10.0).abs() < 1e-9);
        assert_eq!(e.processed(), 101);
    }

    #[test]
    fn empty_pop_is_none() {
        let mut e: Engine<()> = Engine::new();
        assert!(e.pop().is_none());
    }
}
