//! Memory-system model: L2 sharing, ring latency, channel contention.
//!
//! These are the effects the paper's analytic models do **not** capture
//! (they fold everything into the CPI ladder plus the measured
//! MemoryContention table); modelling them explicitly is what makes the
//! simulator's "measured" times deviate from the models for the same
//! structural reasons the authors' testbed did:
//!
//! * **L2 sharing** — each KNC core has 512 KB of L2; with `o` SMT threads
//!   resident, each sees ~512/o KB. When an architecture's per-thread
//!   working set exceeds that, the memory-bound portion of execution
//!   stretches: `pressure = 1 + α·min(ws/(L2/o), cap)`.
//! * **Ring / tag directory** — remote L2 and directory hops grow with the
//!   number of active cores: `ring = 1 + β·(active−1)/(cores−1)`.
//! * **GDDR channel contention** — the Table IV effect: concurrent
//!   threads serialize on the 16 memory channels. Calibrated per
//!   architecture as an *effective serialized traffic* per image
//!   (includes the coherence/synchronization amplification the paper's
//!   probe measured): `contention(p) = floor + traffic·(p−1)/BW`.

use crate::config::MachineConfig;
use crate::simulator::SimConfig;

/// L2 *sharing* pressure multiplier for a per-thread working set of
/// `ws_bytes` at SMT occupancy `occ`.
///
/// Only the sharing excess is modelled — the single-thread cache
/// behaviour is already inside the calibrated per-op cycle costs
/// ([`crate::simulator::cost`]), so by construction `occ == 1` gives 1.0:
/// `pressure = 1 + α · min(ws·(occ−1)/L2, cap)`.
pub fn l2_pressure(cfg: &SimConfig, ws_bytes: f64, occ: usize) -> f64 {
    let excess = ws_bytes * (occ.saturating_sub(1)) as f64
        / cfg.machine.l2_bytes as f64;
    1.0 + cfg.l2_alpha * excess.min(cfg.l2_ratio_cap)
}

/// Ring/tag-directory latency multiplier with `active` busy cores.
pub fn ring_factor(cfg: &SimConfig, active: usize) -> f64 {
    let denom = (cfg.machine.cores - 1).max(1) as f64;
    1.0 + cfg.ring_beta * ((active.saturating_sub(1)) as f64 / denom)
}

/// Calibrated channel-contention parameters for one architecture.
#[derive(Debug, Clone, Copy)]
pub struct ContentionParams {
    /// Single-thread floor, seconds per image.
    pub floor_s: f64,
    /// Effective serialized bytes per image per thread (includes
    /// coherence amplification — see module docs).
    pub traffic_bytes: f64,
}

impl ContentionParams {
    /// Calibration for the paper architectures, fit to Table IV at p=1
    /// (floor) and p=240 (slope). Custom architectures scale the medium
    /// calibration by parameter footprint.
    ///
    /// Traffic is calibrated against the *reference* 7120P bandwidth
    /// (352 GB/s), not the configured machine's — so ablations that widen
    /// the memory system genuinely reduce contention.
    pub fn for_arch(name: &str, param_bytes: f64, machine: &MachineConfig) -> Self {
        const REF_BW: f64 = 352.0e9;
        let _ = machine;
        let bw = REF_BW;
        match name {
            // traffic = contention(240)·BW/240  (slope through the origin)
            "small" => ContentionParams {
                floor_s: 7.10e-6,
                traffic_bytes: 1.40e-2 * bw / 240.0,
            },
            "medium" => ContentionParams {
                floor_s: 1.56e-4,
                traffic_bytes: 3.83e-2 * bw / 240.0,
            },
            "large" => ContentionParams {
                floor_s: 8.83e-4,
                traffic_bytes: 1.38e-1 * bw / 240.0,
            },
            _ => {
                // Scale from the medium CNN by parameter footprint (the
                // probe traffic is dominated by weight updates).
                let medium_bytes = 304.6e3;
                let scale = (param_bytes / medium_bytes).max(0.01);
                ContentionParams {
                    floor_s: 1.56e-4 * scale,
                    traffic_bytes: 3.83e-2 * bw / 240.0 * scale,
                }
            }
        }
    }

    /// Per-image contention wait at `p` concurrent threads.
    pub fn contention_s(&self, p: usize, machine: &MachineConfig) -> f64 {
        let queue = self.traffic_bytes * (p.saturating_sub(1)) as f64
            / machine.memory_bw_bytes;
        self.floor_s + queue
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    #[test]
    fn l2_pressure_grows_with_occupancy() {
        let c = cfg();
        let ws = 300.0e3; // medium-ish working set
        let p1 = l2_pressure(&c, ws, 1);
        let p2 = l2_pressure(&c, ws, 2);
        let p4 = l2_pressure(&c, ws, 4);
        assert!(p1 < p2 && p2 < p4, "{p1} {p2} {p4}");
    }

    #[test]
    fn l2_pressure_negligible_for_tiny_ws() {
        let c = cfg();
        let p = l2_pressure(&c, 34.0e3, 4); // small CNN
        assert!(p < 1.12, "{p}");
    }

    #[test]
    fn l2_pressure_is_one_at_single_occupancy() {
        // Single-thread cache behaviour lives in the calibrated base cost.
        let c = cfg();
        for ws in [10.0e3, 400.0e3, 2.0e6] {
            assert_eq!(l2_pressure(&c, ws, 1), 1.0);
        }
    }

    #[test]
    fn l2_pressure_capped() {
        let c = cfg();
        let p = l2_pressure(&c, 1.0e9, 4);
        assert!((p - (1.0 + c.l2_alpha * c.l2_ratio_cap)).abs() < 1e-12);
    }

    #[test]
    fn ring_factor_range() {
        let c = cfg();
        assert!((ring_factor(&c, 1) - 1.0).abs() < 1e-12);
        let full = ring_factor(&c, 61);
        assert!((full - (1.0 + c.ring_beta)).abs() < 1e-12);
        assert!(ring_factor(&c, 30) > 1.0 && ring_factor(&c, 30) < full);
    }

    #[test]
    fn contention_matches_table4_at_240() {
        let m = MachineConfig::xeon_phi_7120p();
        for (name, want) in [("small", 1.40e-2), ("medium", 3.83e-2), ("large", 1.38e-1)] {
            let p = ContentionParams::for_arch(name, 0.0, &m);
            let got = p.contention_s(240, &m);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.02, "{name}: {got} vs {want}");
        }
    }

    #[test]
    fn contention_floor_at_one_thread() {
        let m = MachineConfig::xeon_phi_7120p();
        let p = ContentionParams::for_arch("large", 0.0, &m);
        assert!((p.contention_s(1, &m) - 8.83e-4).abs() < 1e-9);
    }

    #[test]
    fn contention_roughly_linear_in_p() {
        let m = MachineConfig::xeon_phi_7120p();
        let p = ContentionParams::for_arch("medium", 0.0, &m);
        let c480 = p.contention_s(480, &m);
        let c960 = p.contention_s(960, &m);
        assert!((c960 / c480 - 2.0).abs() < 0.01);
    }

    #[test]
    fn custom_arch_scales_with_params() {
        let m = MachineConfig::xeon_phi_7120p();
        let small_fp = ContentionParams::for_arch("custom", 30.0e3, &m);
        let big_fp = ContentionParams::for_arch("custom", 3.0e6, &m);
        assert!(big_fp.traffic_bytes > small_fp.traffic_bytes * 50.0);
    }
}
