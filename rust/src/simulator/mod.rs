//! `micsim` — a discrete-event, cycle-approximate simulator of the Intel
//! Xeon Phi 7120P (Knights Corner).
//!
//! The paper's evaluation hardware does not exist in this environment, so
//! micsim stands in for it (DESIGN.md §1): every execution time this
//! reproduction reports as *measured* is a micsim output, and the paper's
//! analytic models predict micsim exactly the way they predicted the
//! authors' testbed.
//!
//! ## What is modelled
//!
//! * **Cores & SMT** — 61 in-order cores, 4 round-robin hardware threads
//!   each; the Table III CPI ladder (1/1/1.5/2) applies to the *execute*
//!   portion of each instruction stream ([`cost`]).
//! * **VPU** — the 512-bit SIMD unit appears as the calibrated
//!   cycles-per-operation constants (operations are Table VII/VIII
//!   abstract ops; the calibration against the paper's measured
//!   per-image times absorbs the achieved vector efficiency).
//! * **Memory system** — three effects the analytic models do not see
//!   ([`memory`]): per-core L2 sharing pressure when SMT occupancy rises,
//!   ring/tag-directory latency growth with active cores, and GDDR
//!   channel contention (the Table IV probe, [`probe`]).
//! * **Workload structure** — the Fig. 4 algorithm: serial prep, then per
//!   epoch: train (fwd+bwd per image), validation (fwd), test (fwd), with
//!   a barrier after each phase and ⌈i/p⌉/⌊i/p⌋ load imbalance
//!   ([`workload`]).
//! * **Oversubscription** — beyond 244 hardware threads, software threads
//!   multiplex round-robin with a context-switch overhead, letting the
//!   simulator (like the models) answer "what if p = 3,840?".
//!
//! ## Fidelity modes
//!
//! [`Fidelity::PerImage`] drives a discrete-event engine ([`event`]) with
//! one event per image per phase; [`Fidelity::Chunked`] evaluates the same
//! cost model in closed form per (thread, phase) chunk. They agree to
//! floating-point tolerance (asserted by tests) — chunked is the default
//! and ~10³× faster; per-image exists for traces and as the reference
//! semantics (EXPERIMENTS.md §Perf).

#![warn(missing_docs)]

pub mod cost;
pub mod event;
pub mod machine;
pub mod memory;
pub mod probe;
pub mod stats;
pub mod workload;

pub use cost::{CostModel, CostTable, PerImageCost};
pub use machine::PhiMachine;
pub use stats::{PhaseTimes, SimResult};
pub use workload::{
    simulate_training, simulate_training_shared, simulate_training_with, Fidelity,
};

use crate::config::MachineConfig;
use crate::nn::OpSource;

/// All tunable simulator constants (`repro sweep --sim-*` and the sweep
/// grid's sim axis ablate these — see `docs/SWEEP.md`).
///
/// ```
/// use micdl::simulator::SimConfig;
///
/// let mut cfg = SimConfig::default();
/// let base = cfg.fingerprint();
/// // Any field change is a different simulator — and a different
/// // memoization key, so sweep caches never serve stale measurements.
/// cfg.fwd_cycles_per_op *= 2.0;
/// assert_ne!(cfg.fingerprint(), base);
/// ```
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Machine description (defaults to the 7120P).
    pub machine: MachineConfig,
    /// Where per-image op counts come from (paper tables vs computed).
    pub op_source: OpSource,
    /// Calibrated cycles per abstract forward operation (see [`cost`]).
    pub fwd_cycles_per_op: f64,
    /// Calibrated cycles per abstract backward operation.
    pub bwd_cycles_per_op: f64,
    /// Fraction of per-image cycles that are issue-bound (subject to the
    /// SMT CPI ladder); the rest is memory-bound (subject to [`memory`]).
    pub exec_fraction: f64,
    /// L2-sharing pressure coefficient (α in memory.rs).
    pub l2_alpha: f64,
    /// Cap on the L2 working-set ratio used for pressure.
    pub l2_ratio_cap: f64,
    /// Ring/tag-directory latency growth coefficient (β in memory.rs).
    pub ring_beta: f64,
    /// Serial preparation: image/label I/O base seconds.
    pub prep_io_s: f64,
    /// Serial preparation: cycles per network weight per instance
    /// (instance creation is not parallelized — Fig. 4).
    pub prep_cycles_per_weight: f64,
    /// Per-epoch serial bookkeeping cycles per training image (the `4·i`
    /// term of Table V).
    pub serial_cycles_per_image: f64,
    /// Context-switch overhead fraction per software thread beyond the
    /// hardware thread count (oversubscription).
    pub oversub_overhead: f64,
    /// Simulation granularity.
    pub fidelity: Fidelity,
    /// Seed for the simulator's (deterministic) jitter streams.
    pub seed: u64,
}

/// Order-stable FNV-1a accumulator for [`SimConfig::fingerprint`].
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0100_0000_01b3);
        }
    }

    fn f64(&mut self, v: f64) {
        self.bytes(&v.to_bits().to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
}

impl SimConfig {
    /// Order-stable fingerprint over every field (machine included) — the
    /// simulator's memoization hook. The sweep cache
    /// ([`crate::sweep::SweepCache`]) keys micsim cost models and
    /// measurements by this, so *any* change to the simulator
    /// configuration invalidates memoized entries instead of silently
    /// reusing stale ones. The `seed` is folded in too: two configs that
    /// differ only in seed get distinct keys, which keeps the measured
    /// path seed-stable by construction (and the chunked path is
    /// seed-independent anyway — asserted in `tests/proptests.rs`).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        let m = &self.machine;
        h.str(&m.name);
        h.u64(m.cores as u64);
        h.u64(m.threads_per_core as u64);
        h.f64(m.clock_hz);
        h.u64(m.simd_lanes as u64);
        h.u64(m.memory_channels as u64);
        h.f64(m.memory_bw_bytes);
        h.u64(m.l1_bytes as u64);
        h.u64(m.l2_bytes as u64);
        h.u64(m.cpi_ladder.len() as u64);
        for &cpi in &m.cpi_ladder {
            h.f64(cpi);
        }
        h.u64(match self.op_source {
            OpSource::Paper => 0,
            OpSource::Computed => 1,
        });
        h.f64(self.fwd_cycles_per_op);
        h.f64(self.bwd_cycles_per_op);
        h.f64(self.exec_fraction);
        h.f64(self.l2_alpha);
        h.f64(self.l2_ratio_cap);
        h.f64(self.ring_beta);
        h.f64(self.prep_io_s);
        h.f64(self.prep_cycles_per_weight);
        h.f64(self.serial_cycles_per_image);
        h.f64(self.oversub_overhead);
        h.u64(match self.fidelity {
            Fidelity::PerImage => 0,
            Fidelity::Chunked => 1,
        });
        h.u64(self.seed);
        h.0
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            machine: MachineConfig::xeon_phi_7120p(),
            op_source: OpSource::Paper,
            // Calibrated against Table III measured per-image times over the
            // paper op counts: fwd ≈ 31 cycles/op (1.45 ms = 58k ops ×31 /
            // 1.238 GHz), bwd ≈ 13.7 (see cost.rs for the fit table).
            fwd_cycles_per_op: 31.0,
            bwd_cycles_per_op: 13.7,
            exec_fraction: 0.75,
            l2_alpha: 0.35,
            l2_ratio_cap: 3.0,
            ring_beta: 0.15,
            prep_io_s: 12.4,
            prep_cycles_per_weight: 15.5,
            serial_cycles_per_image: 4.0,
            oversub_overhead: 0.05,
            fidelity: Fidelity::Chunked,
            seed: 0x5EED,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_7120p_paper_source() {
        let cfg = SimConfig::default();
        assert_eq!(cfg.machine.cores, 61);
        assert_eq!(cfg.op_source, OpSource::Paper);
        assert!(cfg.exec_fraction > 0.0 && cfg.exec_fraction <= 1.0);
    }

    #[test]
    fn fingerprint_is_stable_for_equal_configs() {
        assert_eq!(
            SimConfig::default().fingerprint(),
            SimConfig::default().fingerprint()
        );
    }

    #[test]
    fn fingerprint_changes_with_every_field_class() {
        let base = SimConfig::default().fingerprint();
        let mut cost = SimConfig::default();
        cost.fwd_cycles_per_op += 1.0;
        assert_ne!(cost.fingerprint(), base);
        let mut machine = SimConfig::default();
        machine.machine.clock_hz *= 2.0;
        assert_ne!(machine.fingerprint(), base);
        let mut fidelity = SimConfig::default();
        fidelity.fidelity = Fidelity::PerImage;
        assert_ne!(fidelity.fingerprint(), base);
        let mut seed = SimConfig::default();
        seed.seed ^= 1;
        assert_ne!(seed.fingerprint(), base);
    }
}
