//! Per-image cycle cost model.
//!
//! ## Calibration
//!
//! One abstract operation (Table VII/VIII counting) costs a calibrated
//! number of core cycles at single-thread occupancy. The constants are
//! fit against the paper's measured per-image times (Table III) over the
//! paper's op counts:
//!
//! | arch   | T_Fprop | FProp ops | cycles/op | T_Bprop | BProp ops | cycles/op |
//! |--------|---------|-----------|-----------|---------|-----------|-----------|
//! | small  | 1.45 ms | 58k       | 30.9      | 5.30 ms | 524k      | 12.5      |
//! | medium | 12.55 ms| 559k      | 27.8      | 69.73 ms| 6,119k    | 14.1      |
//! | large  | 148.9 ms| 5,349k    | 34.5      | 859.2 ms| 73,178k   | 14.5      |
//!
//! The fit is tight (fwd 31±3, bwd 13.7±1): a single pair of constants
//! reproduces all six measurements within ~11%. The residual is the
//! simulator's honest disagreement with the paper's testbed and is what
//! keeps the models' prediction accuracy Δ in the paper's ballpark
//! instead of collapsing to zero (EXPERIMENTS.md §table9).
//!
//! ## Scaling with thread count
//!
//! Each per-image cost splits into an execute part (`exec_fraction`),
//! which scales with the SMT CPI ladder, and a memory part, which scales
//! with L2 pressure and ring occupancy ([`crate::simulator::memory`]).
//! Channel contention is added per image on top. Oversubscribed software
//! threads divide their hardware context round-robin and pay a switch
//! overhead.

use std::sync::Arc;

use crate::config::arch::ArchSpec;
use crate::error::Result;
use crate::nn::opcount;
use crate::simulator::machine::PhiMachine;
use crate::simulator::memory::{l2_pressure, ring_factor, ContentionParams};
use crate::simulator::SimConfig;
use crate::util::memo::Memo;

/// Per-image cost evaluation, abstracted over where the numbers come
/// from: a bare [`CostModel`] computes each call from scratch; a
/// [`CostTable`] serves the same values from a shared
/// per-occupancy-class memo (the thread-ladder fast path). Both produce
/// bit-identical seconds — the table runs the model's exact f64
/// operations, just once per class instead of once per call.
pub trait PerImageCost {
    /// Seconds for one forward pass on software thread `t`.
    fn fwd_image_s(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> f64;
    /// Seconds for one training image (forward + backward + contention).
    fn train_image_s(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> f64;
    /// Serial preparation seconds for `instances` network instances.
    fn prep_s(&self, cfg: &SimConfig, instances: usize) -> f64;
    /// Serial per-epoch bookkeeping seconds.
    fn epoch_serial_s(&self, cfg: &SimConfig, train_images: usize, test_images: usize) -> f64;
}

/// Resolved per-architecture cost inputs.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Forward cycles per image at occupancy 1 (before memory scaling).
    pub fwd_cycles: f64,
    /// Backward cycles per image at occupancy 1.
    pub bwd_cycles: f64,
    /// Per-thread working set in bytes (weights + largest activations).
    pub working_set_bytes: f64,
    /// Channel-contention calibration.
    pub contention: ContentionParams,
    /// Total trainable parameters in bytes.
    pub param_bytes: f64,
    /// Weights for the prep phase cost.
    pub total_weights: f64,
}

impl CostModel {
    /// Resolve and calibrate the per-image costs for one architecture.
    pub fn new(arch: &ArchSpec, cfg: &SimConfig) -> Result<CostModel> {
        // Paper op counts where available (the calibration anchors); fall
        // back to first-principles counts for custom architectures.
        let counts = opcount::resolve(arch, cfg.op_source)
            .or_else(|_| opcount::count(arch))?;
        let shapes = arch.shapes()?;
        let param_bytes: f64 = shapes.iter().map(|l| l.weights as f64 * 4.0).sum();
        // Working set: parameters + the two largest activation layers
        // (producer + consumer are live simultaneously).
        let mut neuron_bytes: Vec<f64> =
            shapes.iter().map(|l| l.neurons as f64 * 4.0).collect();
        neuron_bytes.sort_by(|a, b| b.total_cmp(a));
        let acts: f64 = neuron_bytes.iter().take(2).sum();
        let working_set_bytes = param_bytes + acts;

        Ok(CostModel {
            fwd_cycles: counts.fprop.total() as f64 * cfg.fwd_cycles_per_op,
            bwd_cycles: counts.bprop.total() as f64 * cfg.bwd_cycles_per_op,
            working_set_bytes,
            contention: ContentionParams::for_arch(&arch.name, param_bytes, &cfg.machine),
            param_bytes,
            total_weights: shapes.iter().map(|l| l.weights as f64).sum(),
        })
    }

    /// Seconds for one *forward* pass on software thread `t` of `machine`,
    /// including memory scaling and channel contention.
    pub fn fwd_image_s(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> f64 {
        self.image_s(cfg, machine, t, self.fwd_cycles, false)
    }

    /// Seconds for one *training* image (forward + backward).
    pub fn train_image_s(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> f64 {
        self.image_s(cfg, machine, t, self.fwd_cycles + self.bwd_cycles, true)
    }

    /// Shared per-image cost. `updates_weights` adds the contention term
    /// (the Table IV probe measures concurrent weight-update traffic; the
    /// forward-only phases read shared, cache-resident weights).
    fn image_s(
        &self,
        cfg: &SimConfig,
        machine: &PhiMachine,
        t: usize,
        cycles: f64,
        updates_weights: bool,
    ) -> f64 {
        let occ = machine.occupancy_of(t);
        let oversub = machine.oversub_of(t);
        let mut s = self.class_image_s(cfg, occ, oversub, machine.active_cores(), cycles);
        if updates_weights {
            s += self.contention.contention_s(machine.threads, &cfg.machine);
        }
        s
    }

    /// The occupancy-class core of [`CostModel::image_s`]: per-image
    /// seconds as a function of (SMT occupancy, oversubscription ratio,
    /// active cores) alone — the full scenario does not appear. This is
    /// what makes the thread-ladder fast path sound: every software
    /// thread of every ladder point with the same class gets the same
    /// value, so [`CostTable`] computes it once per class and the f64
    /// operation sequence (and hence the bits) is identical either way.
    fn class_image_s(
        &self,
        cfg: &SimConfig,
        occ: usize,
        oversub: f64,
        active_cores: usize,
        cycles: f64,
    ) -> f64 {
        let cpi = cfg.machine.cpi(occ);
        let exec = cycles * cfg.exec_fraction * cpi;
        let mem = cycles
            * (1.0 - cfg.exec_fraction)
            * l2_pressure(cfg, self.working_set_bytes, occ)
            * ring_factor(cfg, active_cores);
        let switch_penalty = 1.0 + cfg.oversub_overhead * (oversub - 1.0);
        (exec + mem) * oversub * switch_penalty / cfg.machine.clock_hz
    }

    /// Serial preparation seconds for `p` network instances (Fig. 4: not
    /// parallelized).
    pub fn prep_s(&self, cfg: &SimConfig, instances: usize) -> f64 {
        cfg.prep_io_s
            + instances as f64 * self.total_weights * cfg.prep_cycles_per_weight
                / cfg.machine.clock_hz
    }

    /// Serial per-epoch bookkeeping (shuffling indices, statistics).
    pub fn epoch_serial_s(&self, cfg: &SimConfig, train_images: usize, test_images: usize) -> f64 {
        (train_images as f64 * cfg.serial_cycles_per_image
            + test_images as f64 * 2.0
            + 10.0)
            / cfg.machine.clock_hz
    }
}

impl PerImageCost for CostModel {
    fn fwd_image_s(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> f64 {
        CostModel::fwd_image_s(self, cfg, machine, t)
    }

    fn train_image_s(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> f64 {
        CostModel::train_image_s(self, cfg, machine, t)
    }

    fn prep_s(&self, cfg: &SimConfig, instances: usize) -> f64 {
        CostModel::prep_s(self, cfg, instances)
    }

    fn epoch_serial_s(&self, cfg: &SimConfig, train_images: usize, test_images: usize) -> f64 {
        CostModel::epoch_serial_s(self, cfg, train_images, test_images)
    }
}

/// A [`CostModel`] fronted by shared per-occupancy-class memo tables —
/// the thread-ladder fast path.
///
/// `fwd_image_s`/`train_image_s` depend on the software thread only
/// through its *class* — (SMT occupancy, oversubscription ratio, active
/// cores) — and the contention term only through the machine's total
/// thread count `p`. A thread ladder over one (arch, fingerprint)
/// therefore touches a handful of classes (at most `threads_per_core ×`
/// distinct oversubscription ratios `× distinct core counts`, in
/// practice single digits) while evaluating thousands of per-image
/// calls; the table computes each class once, via
/// [`CostModel::class_image_s`]'s exact f64 sequence, and serves every
/// later call from the memo — bit-identical, single-flight under
/// concurrency (ladder points evaluated by different sweep workers
/// share the same table through the sweep cache).
///
/// One table is valid for **one** [`SimConfig`]: the class key does not
/// cover the config, because the sweep cache already keys tables by
/// [`SimConfig::fingerprint`]. Callers that change the config must use
/// a fresh table (as the cache does by construction).
#[derive(Debug)]
pub struct CostTable {
    model: Arc<CostModel>,
    /// (occupancy, oversub bits, active cores) → (fwd_s, fwd+bwd_s
    /// before contention).
    classes: Memo<(usize, u64, usize), (f64, f64)>,
    /// machine threads → contention seconds.
    contention: Memo<usize, f64>,
}

impl CostTable {
    /// Wrap a cost model in fresh (empty) class tables.
    pub fn new(model: Arc<CostModel>) -> CostTable {
        CostTable { model, classes: Memo::new(), contention: Memo::new() }
    }

    /// The wrapped cost model.
    pub fn model(&self) -> &Arc<CostModel> {
        &self.model
    }

    /// Both per-image class values for thread `t`, computed once per
    /// class.
    fn class(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> (f64, f64) {
        let occ = machine.occupancy_of(t);
        let oversub = machine.oversub_of(t);
        let active = machine.active_cores();
        self.classes.get_or_insert_with((occ, oversub.to_bits(), active), || {
            let fwd = self.model.class_image_s(cfg, occ, oversub, active, self.model.fwd_cycles);
            let train = self.model.class_image_s(
                cfg,
                occ,
                oversub,
                active,
                self.model.fwd_cycles + self.model.bwd_cycles,
            );
            (fwd, train)
        })
    }
}

impl PerImageCost for CostTable {
    fn fwd_image_s(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> f64 {
        self.class(cfg, machine, t).0
    }

    fn train_image_s(&self, cfg: &SimConfig, machine: &PhiMachine, t: usize) -> f64 {
        let base = self.class(cfg, machine, t).1;
        let contention = self.contention.get_or_insert_with(machine.threads, || {
            self.model.contention.contention_s(machine.threads, &cfg.machine)
        });
        base + contention
    }

    fn prep_s(&self, cfg: &SimConfig, instances: usize) -> f64 {
        self.model.prep_s(cfg, instances)
    }

    fn epoch_serial_s(&self, cfg: &SimConfig, train_images: usize, test_images: usize) -> f64 {
        self.model.epoch_serial_s(cfg, train_images, test_images)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn setup(arch: &str, p: usize) -> (SimConfig, PhiMachine, CostModel) {
        let cfg = SimConfig::default();
        let machine = PhiMachine::new(MachineConfig::xeon_phi_7120p(), p);
        let arch = ArchSpec::by_name(arch).unwrap();
        let cm = CostModel::new(&arch, &cfg).unwrap();
        (cfg, machine, cm)
    }

    #[test]
    fn single_thread_fwd_matches_table3_within_12pct() {
        // Table III: 1.45 / 12.55 / 148.88 ms per image.
        for (name, want_ms) in [("small", 1.45), ("medium", 12.55), ("large", 148.88)] {
            let (cfg, machine, cm) = setup(name, 1);
            let got_ms = cm.fwd_image_s(&cfg, &machine, 0) * 1e3;
            let rel = (got_ms - want_ms).abs() / want_ms;
            assert!(rel < 0.12, "{name}: {got_ms:.2} ms vs {want_ms} ms ({rel:.3})");
        }
    }

    #[test]
    fn single_thread_bwd_matches_table3_within_12pct() {
        for (name, want_ms) in [("small", 5.3), ("medium", 69.73), ("large", 859.19)] {
            let (cfg, machine, cm) = setup(name, 1);
            let fwd = cm.fwd_image_s(&cfg, &machine, 0);
            // train = fwd + bwd + contention floor; extract bwd.
            let train = cm.train_image_s(&cfg, &machine, 0);
            let bwd_ms = (train - fwd) * 1e3;
            let rel = (bwd_ms - want_ms).abs() / want_ms;
            assert!(rel < 0.12, "{name}: {bwd_ms:.2} ms vs {want_ms} ms");
        }
    }

    #[test]
    fn four_threads_per_core_slower_than_one_per_image() {
        let (cfg, m1, cm) = setup("medium", 1);
        let (_, m240, _) = setup("medium", 240);
        let t1 = cm.train_image_s(&cfg, &m1, 0);
        let t240 = cm.train_image_s(&cfg, &m240, 0);
        // Per image slower at occupancy 4 (CPI 2 + L2 sharing), but less
        // than the naive 2x because only the exec part doubles... plus
        // contention. Bound loosely.
        assert!(t240 > t1 * 1.3, "{t1} vs {t240}");
        assert!(t240 < t1 * 3.0, "{t1} vs {t240}");
    }

    #[test]
    fn oversubscription_divides_throughput() {
        let (cfg, m244, cm) = setup("small", 244);
        let (_, m488, _) = setup("small", 488);
        let t244 = cm.train_image_s(&cfg, &m244, 0);
        let t488 = cm.train_image_s(&cfg, &m488, 0);
        // 2x software threads per context: per-image latency roughly
        // doubles (plus switch overhead + contention growth).
        assert!(t488 > t244 * 1.8, "{t244} vs {t488}");
    }

    #[test]
    fn prep_scales_with_instances() {
        let (cfg, _, cm) = setup("large", 1);
        let p1 = cm.prep_s(&cfg, 1);
        let p240 = cm.prep_s(&cfg, 240);
        assert!(p240 > p1);
        // Table III: T_prep ≈ 12.56–13.5 s; check we are in that range
        // for 240 instances.
        assert!(p240 > 12.0 && p240 < 14.5, "{p240}");
    }

    #[test]
    fn prep_near_table3_for_all_archs() {
        for (name, want) in [("small", 12.56), ("medium", 12.7), ("large", 13.5)] {
            let (cfg, _, cm) = setup(name, 240);
            let got = cm.prep_s(&cfg, 240);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.08, "{name}: {got} vs {want}");
        }
    }

    #[test]
    fn working_set_ordering() {
        let (_, _, s) = setup("small", 1);
        let (_, _, m) = setup("medium", 1);
        let (_, _, l) = setup("large", 1);
        assert!(s.working_set_bytes < m.working_set_bytes);
        assert!(m.working_set_bytes < l.working_set_bytes);
    }

    #[test]
    fn validation_fwd_has_no_contention_term() {
        let (cfg, machine, cm) = setup("large", 240);
        let fwd = cm.fwd_image_s(&cfg, &machine, 0);
        let train = cm.train_image_s(&cfg, &machine, 0);
        let contention = cm.contention.contention_s(240, &cfg.machine);
        // train includes fwd+bwd cycles AND contention; fwd excludes it.
        assert!(train > fwd + contention);
    }
}
