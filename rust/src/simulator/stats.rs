//! Simulation results and phase breakdowns.

/// Wall time per algorithm phase, summed over epochs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    /// Serial preparation (image load + instance creation).
    pub prep_s: f64,
    /// Training phase (fwd+bwd over each thread's chunk), barrier-to-barrier.
    pub train_s: f64,
    /// Validation phase (fwd over the training set).
    pub validation_s: f64,
    /// Test phase (fwd over the test set).
    pub test_s: f64,
    /// Serial per-epoch bookkeeping.
    pub serial_s: f64,
}

impl PhaseTimes {
    /// Sum of every phase, seconds.
    pub fn total(&self) -> f64 {
        self.prep_s + self.train_s + self.validation_s + self.test_s + self.serial_s
    }
}

/// Full result of one simulated training run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total wall time, seconds.
    pub total_s: f64,
    /// The paper's reported "execution time" excludes initialization
    /// (Section V): total minus prep.
    pub execution_s: f64,
    /// Per-phase breakdown.
    pub phases: PhaseTimes,
    /// Threads simulated.
    pub threads: usize,
    /// Events processed (0 in chunked mode).
    pub events: u64,
    /// Busy seconds of the slowest worker (imbalance window, upper).
    pub slowest_busy_s: f64,
    /// Busy seconds of the fastest worker (imbalance window, lower).
    pub fastest_busy_s: f64,
}

impl SimResult {
    /// Load imbalance: (slowest - fastest) / slowest.
    pub fn imbalance(&self) -> f64 {
        if self.slowest_busy_s <= 0.0 {
            0.0
        } else {
            (self.slowest_busy_s - self.fastest_busy_s) / self.slowest_busy_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_total_sums() {
        let p = PhaseTimes {
            prep_s: 1.0,
            train_s: 2.0,
            validation_s: 3.0,
            test_s: 4.0,
            serial_s: 0.5,
        };
        assert!((p.total() - 10.5).abs() < 1e-12);
    }

    #[test]
    fn imbalance_zero_when_equal() {
        let r = SimResult {
            total_s: 1.0,
            execution_s: 1.0,
            phases: PhaseTimes::default(),
            threads: 2,
            events: 0,
            slowest_busy_s: 5.0,
            fastest_busy_s: 5.0,
        };
        assert_eq!(r.imbalance(), 0.0);
    }
}
