//! Thread placement on the simulated chip.
//!
//! Threads are placed round-robin across cores (scatter affinity, the
//! paper's configuration): thread `t` runs on core `t % cores`. Per-core
//! SMT occupancy therefore differs by at most one when `p` is not a
//! multiple of the core count — the simulator exploits this to model the
//! *heterogeneous* CPI across workers that the analytic models flatten
//! into a single ladder value.

use crate::config::MachineConfig;

/// Placement view of `p` software threads on the machine.
#[derive(Debug, Clone)]
pub struct PhiMachine {
    /// The machine being simulated.
    pub config: MachineConfig,
    /// Software threads in flight.
    pub threads: usize,
}

impl PhiMachine {
    /// Place `threads` software threads on `config` (scatter affinity).
    pub fn new(config: MachineConfig, threads: usize) -> Self {
        assert!(threads >= 1, "need at least one thread");
        PhiMachine { config, threads }
    }

    /// Core hosting software thread `t` (scatter affinity). Beyond the
    /// hardware thread count, software threads wrap around and multiplex.
    pub fn core_of(&self, t: usize) -> usize {
        t % self.config.cores
    }

    /// Hardware-thread occupancy of the core hosting thread `t` (how many
    /// *hardware* contexts on that core are busy), saturating at the SMT
    /// width.
    pub fn occupancy_of(&self, t: usize) -> usize {
        let core = self.core_of(t);
        // Threads on this core: t' ≡ core (mod cores), t' < p.
        let on_core = (self.threads + self.config.cores - 1 - core) / self.config.cores;
        on_core.min(self.config.threads_per_core)
    }

    /// Software threads multiplexed onto the core of thread `t`.
    pub fn sw_threads_on_core(&self, t: usize) -> usize {
        let core = self.core_of(t);
        (self.threads + self.config.cores - 1 - core) / self.config.cores
    }

    /// Oversubscription of thread `t`'s core: software threads per
    /// hardware context (1.0 when p ≤ 244 and balanced).
    pub fn oversub_of(&self, t: usize) -> f64 {
        let sw = self.sw_threads_on_core(t) as f64;
        let hw = self.occupancy_of(t) as f64;
        (sw / hw).max(1.0)
    }

    /// Number of cores with at least one thread.
    pub fn active_cores(&self) -> usize {
        self.threads.min(self.config.cores)
    }

    /// Worst-case (slowest) occupancy across all threads — what a barrier
    /// waits for.
    pub fn max_occupancy(&self) -> usize {
        self.config.occupancy(self.threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phi(p: usize) -> PhiMachine {
        PhiMachine::new(MachineConfig::xeon_phi_7120p(), p)
    }

    #[test]
    fn scatter_affinity_round_robin() {
        let m = phi(100);
        assert_eq!(m.core_of(0), 0);
        assert_eq!(m.core_of(60), 60);
        assert_eq!(m.core_of(61), 0);
        assert_eq!(m.core_of(122), 0);
    }

    #[test]
    fn occupancy_differs_by_at_most_one() {
        for p in [1, 15, 30, 61, 62, 100, 120, 180, 240] {
            let m = phi(p);
            let occs: Vec<usize> = (0..p).map(|t| m.occupancy_of(t)).collect();
            let min = *occs.iter().min().unwrap();
            let max = *occs.iter().max().unwrap();
            assert!(max - min <= 1, "p={p}: {min}..{max}");
            assert_eq!(max, m.max_occupancy(), "p={p}");
        }
    }

    #[test]
    fn occupancy_at_paper_thread_counts() {
        assert_eq!(phi(1).occupancy_of(0), 1);
        assert_eq!(phi(120).occupancy_of(0), 2);
        assert_eq!(phi(120).occupancy_of(119), 2);
        assert_eq!(phi(180).occupancy_of(0), 3);
        assert_eq!(phi(240).occupancy_of(0), 4);
    }

    #[test]
    fn hundred_threads_mixed_occupancy() {
        // 100 threads on 61 cores: cores 0..38 have 2 threads, 39..60 one.
        let m = phi(100);
        assert_eq!(m.occupancy_of(0), 2);
        assert_eq!(m.occupancy_of(99), 2); // core 38
        assert_eq!(m.occupancy_of(60), 1); // core 60
        assert_eq!(m.active_cores(), 61);
    }

    #[test]
    fn oversubscription_past_hw_threads() {
        let m = phi(488); // 2 sw threads per hw context
        assert_eq!(m.occupancy_of(0), 4);
        assert_eq!(m.sw_threads_on_core(0), 8);
        assert!((m.oversub_of(0) - 2.0).abs() < 1e-12);
        // Within hardware: no oversubscription.
        assert_eq!(phi(240).oversub_of(0), 1.0);
    }

    #[test]
    fn active_cores_saturates() {
        assert_eq!(phi(10).active_cores(), 10);
        assert_eq!(phi(3840).active_cores(), 61);
    }
}
