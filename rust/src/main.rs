//! `repro` — the micdl command-line launcher.
//!
//! Subcommands map onto the library's subsystems:
//!
//! ```text
//! repro exp <id|all> [--csv] [--params paper|sim]   reproduce a paper table/figure
//! repro arch [--name N | --json FILE]               architecture summary (Fig. 2)
//! repro simulate --arch A --threads P [...]         run micsim on a workload
//! repro predict --arch A --threads P [...]          run the performance models
//! repro predict --batch FILE.json [...]             batched what-if queries
//! repro serve [--addr HOST:PORT] [...]              embedded HTTP prediction server
//! repro sweep run [--spec FILE | axis flags]        evaluate a whole scenario grid
//! repro sweep baseline write|compare FILE           golden-baseline write / regression gate
//! repro conformance [--baseline FILE]               measured-mode Δ-band conformance
//! repro sensitivity [--arch LIST] [--json FILE]     ranked ∂Δ/∂constant report
//! repro lab list|gc|trace-params [--lab PATH]       inspect a persistent lab store
//! repro probe --arch A                              Table IV contention probe
//! repro train [...]                                 really train (engine or PJRT backend)
//! repro selfcheck                                   invariant + artifact checks
//! ```
//!
//! Argument parsing is hand-rolled (offline build — no clap); see
//! [`micdl::util`] for the rationale.
//!
//! `sweep`, `conformance` and `sensitivity` accept `--lab PATH`
//! (bare `--lab` means `./result`) to persist every computed cell,
//! model-parameter set and measurement through a [`micdl::lab`] store:
//! repeated runs become pure store hits and interrupted sweeps resume
//! (`--resume`) from the last persisted cell. `--no-store` bypasses an
//! otherwise-configured lab. The noun-verb spellings above are the
//! canonical surface; the old verbless flags (`sweep --write-baseline`,
//! `sweep --compare`) keep working as deprecated aliases.
//!
//! Exit codes are unified in [`ExitCode`] and documented in
//! docs/SWEEP.md: 0 on success; 1 on any configuration, parse, or
//! runtime error (the error is printed to stderr together with the
//! usage text); 2 when `sweep baseline compare` finds a golden-baseline
//! regression or `conformance --baseline` finds a Δ-band/claim
//! regression (the machine-readable report goes to stdout, the findings
//! to stderr).

use micdl::calibration::Calibration;
use micdl::config::{ArchSpec, MachineConfig, RunConfig};
use micdl::coordinator::leader::{LeaderConfig, PjrtTrainer};
use micdl::coordinator::pool::{DataParallelTrainer, PoolConfig};
use micdl::dataset;
use micdl::error::{Error, Result};
use micdl::experiments::{self, ExpOptions};
use micdl::lab::Lab;
use micdl::nn::opcount;
use micdl::perfmodel::{both_models, ParamSource, PerfModel};
use micdl::report::Table;
use micdl::serve::{predict_doc, PredictEngine, QueryBatch, ServeStats, Server};
use micdl::simulator::{probe, simulate_training, Fidelity, SimConfig};
use micdl::sweep::baseline::DEFAULT_TOLERANCE;
use micdl::sweep::{
    conformance, parse_axis, sensitivity, Baseline, CacheStats, ConformanceBaseline, GridSpec,
    SensitivitySpec, SimConstant, SimVariant, Strategy, SweepResults, SweepRunner,
};
use std::sync::Arc;

/// `format!` into the crate's config error.
macro_rules! err {
    ($($arg:tt)*) => { Error::Config(format!($($arg)*)) };
}

/// Early-return with a config error.
macro_rules! bail {
    ($($arg:tt)*) => { return Err(err!($($arg)*)) };
}

/// Process exit codes, unified across every subcommand (the table lives
/// in docs/SWEEP.md): `Ok` on success, `Error` on any configuration,
/// parse, or runtime failure, `Regression` when a baseline or Δ-band
/// check finds a regression.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ExitCode {
    /// Success.
    Ok = 0,
    /// Usage, configuration, or runtime error.
    Error = 1,
    /// A golden-baseline / conformance check found a regression.
    Regression = 2,
}

/// Minimal flag parser: positionals + `--key value` + boolean `--flag`.
#[derive(Debug, Default, Clone)]
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let value = argv.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if value.is_some() {
                    i += 1;
                }
                out.flags.push((name.to_string(), value));
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(k, _)| k == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == name)
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| err!("--{name} wants an integer, got {v:?}")),
        }
    }
}

const USAGE: &str = "\
repro — Performance Modelling of Deep Learning on Intel MIC Architectures (HPCS'19 reproduction)

USAGE:
  repro exp <fig1|table4|table7|table8|fig5|fig6|fig7|table9|table10|table11|all>
            [--csv] [--params paper|sim]
  repro arch [--name small|medium|large] [--json FILE]
  repro simulate --arch A [--threads P] [--epochs E] [--images I] [--test-images IT]
                 [--fidelity chunked|image]
  repro predict  --arch A [--threads P] [--epochs E] [--images I] [--test-images IT]
                 [--strategy a|b|c|both|all] [--params paper|sim]
  repro predict  --batch FILE.json [--params paper|sim] [--json OUT.json | --csv]
                 [--workers N | --serial] [--lab [PATH]] [--no-store]
                 (batched what-if queries: FILE is a JSON array of
                  {arch, strategy, threads | threads_range, train_images,
                  test_images, epochs, sim} objects, or {\"queries\": [...]}.
                  Result rows are bit-identical to the equivalent sweep
                  cells; parameter tables resolve at most once per
                  distinct (arch, sim) pair per batch; --lab serves
                  previously swept cells straight from the store. See
                  docs/SERVE.md.)
  repro serve    [--addr HOST:PORT] [--workers N | --serial] [--params paper|sim]
                 [--lab [PATH]] [--no-store]
                 (embedded HTTP prediction server over the same engine:
                  POST /predict evaluates a query batch, GET /healthz,
                  GET /stats, POST /shutdown. Default address is
                  127.0.0.1:8787; port 0 picks a free port — the resolved
                  address is printed on stdout. See docs/SERVE.md.)
  repro sweep [run] [--spec FILE.json] [--arch all|NAME[,NAME...]] [--threads LIST]
                 [--images IxIT[,IxIT...]] [--epochs LIST] [--strategy a|b|c|both|all]
                 [--params paper|sim] [--clock-ghz F[,F...]] [--measure]
                 [--sim-clock-ghz F[,F...]] [--sim-cores LIST] [--sim-threads LIST]
                 [--sim-fwd-cycles F[,F...]] [--sim-bwd-cycles F[,F...]]
                 [--sim-exec-fraction F[,F...]] [--sim-l2-alpha F[,F...]]
                 [--sim-l2-cap F[,F...]] [--sim-ring-beta F[,F...]]
                 [--sim-oversub F[,F...]] [--sim-fidelity chunked|image[,...]]
                 [--sim-seed LIST]
                 [--workers N | --serial] [--json OUT.json] [--csv] [--full]
                 [--lab [PATH]] [--resume] [--no-store] [--tolerance F]
                 [--shard K/N | --shards N [--continue-on-failure]]
                 (LIST = comma items and/or inclusive ranges: 1,15,30 or 1..244 or 8..64..8)
                 (The --sim-* flags build an ablation axis over simulator
                  constants — the cross product of every given list; sim
                  overrides win over --clock-ghz machine variants, with a
                  warning. --lab persists every computed cell to a disk
                  store (bare --lab means ./result); --resume reports the
                  prior run being resumed; --no-store bypasses the store.
                  --shard K/N evaluates only the scenarios with id % N ==
                  K-1 through the shared lab store; --shards N spawns one
                  child process per shard, retries failures with bounded
                  backoff (--continue-on-failure: exit 1 with a per-shard
                  report instead of aborting on the first permanently
                  failed shard), then merges to output byte-identical to
                  the unsharded run. Both require --lab.
                  See docs/SWEEP.md and docs/LAB.md.)
  repro sweep baseline write OUT.json      pin the swept grid as a golden baseline
  repro sweep baseline compare FILE.json   re-run and diff against a baseline
                 (compare alone re-runs the baseline's own grid; grid flags
                  override it. Exit 2 on baseline regression. The old
                  --write-baseline/--compare flag spellings keep working as
                  deprecated aliases.)
  repro conformance [--baseline FILE | --write-baseline FILE] [--report OUT.json]
                 [--closed-loop FILE | --write-closed-loop FILE]
                 [--closed-loop-report OUT.json]
                 [--residual FILE | --write-residual FILE]
                 [--residual-report OUT.json] [--workers N | --serial]
                 [--lab [PATH]] [--resume] [--no-store]
                 (measured-mode Δ-band conformance over the Tables IX-XI
                  grids. --baseline re-runs the file's grids and checks its
                  Δ bands and paper claims, exit 2 on regression; --write-
                  baseline pins the observed bands. --closed-loop does the
                  same for the closed-loop grid — Table IX under --params
                  sim, model parameters probed from the measuring
                  simulator — against baselines/closed_loop_smoke.json.
                  --residual checks the residual-regressor grids — Tables
                  IX-XI under strategies b and c, where every pinned (c)
                  band must also stay strictly below its (b) band —
                  against baselines/residual_smoke.json; any subset of the
                  checks may run in one invocation. With no check or
                  write flag the observed bands are printed, nothing
                  asserted. Check mode puts the report JSON on stdout,
                  findings on stderr; --report FILE additionally writes
                  the stdout payload — the combined document when several
                  checks run — to a path for CI artifacts.)
  repro sensitivity [--arch all|NAME[,NAME...]] [--threads LIST]
                 [--strategy a|b|c|both|all] [--params paper|sim] [--step F]
                 [--constants LIST] [--json OUT.json] [--workers N | --serial]
                 [--lab [PATH]] [--resume] [--no-store]
                 (one-at-a-time ablation over the simulator constants:
                  perturb each by ±step (default 0.1 = ±10%), re-measure
                  the Table IX Δ per architecture × strategy, and report
                  the ranked central-difference gradients ∂Δ/∂constant.
                  --constants picks from: clock_ghz fwd_cycles_per_op
                  bwd_cycles_per_op exec_fraction l2_alpha l2_ratio_cap
                  ring_beta oversub_overhead. --json writes the machine-
                  readable report, bit-identical parallel vs serial. See
                  docs/SWEEP.md.)
  repro lab list                            run manifests in a lab store
  repro lab gc [--dry-run]                  remove damaged/leftover store files
  repro lab trace-params --arch A [--params paper|sim]
                 (all lab verbs take --lab PATH, default ./result; `repro
                  list|gc|trace-params` are equivalent top-level aliases.
                  trace-params prints the persisted calibration entry with
                  its resolution provenance. See docs/LAB.md.)
  repro probe    [--arch A]
  repro train    [--backend engine|pjrt] [--arch A] [--epochs E] [--images N]
                 [--test-images N] [--workers W] [--lr F] [--artifacts DIR]
                 [--mnist DIR] [--seed S]
  repro selfcheck [--artifacts DIR]
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = dispatch(&argv).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        eprintln!("{USAGE}");
        ExitCode::Error
    });
    std::process::exit(code as i32);
}

fn parse_params(args: &Args) -> Result<ParamSource> {
    match args.get("params").unwrap_or("paper") {
        "paper" => Ok(ParamSource::Paper),
        "sim" | "simulator" => Ok(ParamSource::Simulator),
        other => bail!("--params must be paper|sim, got {other:?}"),
    }
}

fn parse_arch(args: &Args) -> Result<ArchSpec> {
    if let Some(path) = args.get("json") {
        let text = std::fs::read_to_string(path)?;
        return Ok(ArchSpec::from_json(&text)?);
    }
    ArchSpec::by_name(args.get("name").or(args.get("arch")).unwrap_or("small"))
}

fn parse_run(args: &Args, arch: &str) -> Result<RunConfig> {
    let default = RunConfig::paper_default(arch, 240);
    Ok(RunConfig {
        train_images: args.get_usize("images", default.train_images)?,
        test_images: args.get_usize("test-images", default.test_images)?,
        epochs: args.get_usize("epochs", default.epochs)?,
        threads: args.get_usize("threads", default.threads)?,
    })
}

fn dispatch(argv: &[String]) -> Result<ExitCode> {
    let Some(cmd) = argv.first().map(String::as_str) else {
        println!("{USAGE}");
        return Ok(ExitCode::Ok);
    };
    let args = Args::parse(&argv[1..]);
    match cmd {
        "exp" => cmd_exp(&args),
        "arch" => cmd_arch(&args),
        "simulate" => cmd_simulate(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "conformance" => cmd_conformance(&args),
        "sensitivity" => cmd_sensitivity(&args),
        "lab" => cmd_lab(&args, None),
        // Top-level aliases for the lab verbs (repx-style).
        "list" | "gc" | "trace-params" => cmd_lab(&args, Some(cmd)),
        "probe" => cmd_probe(&args),
        "train" => cmd_train(&args),
        "selfcheck" => cmd_selfcheck(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(ExitCode::Ok)
        }
        other => bail!("unknown command {other:?}"),
    }
}

fn cmd_exp(args: &Args) -> Result<ExitCode> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| err!("exp needs an id (or 'all')"))?;
    let opts = ExpOptions { csv: args.has("csv"), params: parse_params(args)? };
    print!("{}", experiments::run(id, &opts)?);
    Ok(ExitCode::Ok)
}

fn cmd_arch(args: &Args) -> Result<ExitCode> {
    let archs = if args.has("name") || args.has("json") {
        vec![parse_arch(args)?]
    } else {
        ArchSpec::paper_archs()
    };
    for arch in archs {
        let mut t = Table::new(
            format!("architecture {} (Fig. 2)", arch.name),
            &["layer", "maps/units", "map", "neurons", "weights"],
        );
        for shape in arch.shapes()? {
            use micdl::config::arch::ResolvedLayer::*;
            let (kind, m, hw) = match shape.spec {
                Input { hw } => ("input".to_string(), 1, format!("{hw}x{hw}")),
                Conv { maps, kernel, out_hw, .. } => {
                    (format!("conv {kernel}x{kernel}"), maps, format!("{out_hw}x{out_hw}"))
                }
                Pool { window, maps, out_hw, .. } => {
                    (format!("maxpool {window}x{window}"), maps, format!("{out_hw}x{out_hw}"))
                }
                Dense { units, .. } => ("dense".to_string(), units, "-".to_string()),
            };
            t.row(vec![
                kind,
                m.to_string(),
                hw,
                shape.neurons.to_string(),
                shape.weights.to_string(),
            ]);
        }
        print!("{}", t.render());
        let ops = opcount::count(&arch)?;
        println!(
            "computed ops/image: fprop {} bprop {}  |  total weights {}\n",
            ops.fprop.total(),
            ops.bprop.total(),
            arch.total_weights()?
        );
    }
    Ok(ExitCode::Ok)
}

fn cmd_simulate(args: &Args) -> Result<ExitCode> {
    let arch = parse_arch(args)?;
    let run = parse_run(args, &arch.name)?;
    let mut cfg = SimConfig::default();
    cfg.fidelity = Fidelity::parse(args.get("fidelity").unwrap_or("chunked"))?;
    let r = simulate_training(&arch, &run, &cfg)?;
    println!(
        "micsim: arch={} threads={} epochs={} i={} it={}",
        arch.name, run.threads, run.epochs, run.train_images, run.test_images
    );
    println!(
        "  execution {:.1}s ({:.1} min) | total {:.1}s | prep {:.1}s",
        r.execution_s,
        r.execution_s / 60.0,
        r.total_s,
        r.phases.prep_s
    );
    println!(
        "  phases: train {:.1}s  validation {:.1}s  test {:.1}s  serial {:.2}s",
        r.phases.train_s, r.phases.validation_s, r.phases.test_s, r.phases.serial_s
    );
    println!("  imbalance {:.4} | events {}", r.imbalance(), r.events);
    Ok(ExitCode::Ok)
}

fn cmd_predict(args: &Args) -> Result<ExitCode> {
    if args.has("batch") {
        return cmd_predict_batch(args);
    }
    let arch = parse_arch(args)?;
    let run = parse_run(args, &arch.name)?;
    // The Calibration facade resolves (a)/(b) parameters once and fits
    // the (c) residual model on demand, so `--strategy c` works here
    // exactly as it does in sweeps and serve batches.
    let cal = Calibration::new(parse_params(args)?);
    let sim = SimConfig::default();
    let strategies = Strategy::parse_list(args.get("strategy").unwrap_or("both"))?;
    let mut t = Table::new(
        format!(
            "prediction: arch={} threads={} epochs={}",
            arch.name, run.threads, run.epochs
        ),
        &["strategy", "prep s", "train+val s", "test s", "T_mem s", "total s", "minutes"],
    );
    for &s in &strategies {
        let model = cal.strategy(&arch, s, &sim)?;
        let p = model.predict(&run)?;
        t.row(vec![
            model.name().into(),
            format!("{:.2}", p.prep_s),
            format!("{:.1}", p.train_s),
            format!("{:.1}", p.test_s),
            format!("{:.1}", p.mem_s),
            format!("{:.1}", p.total_s),
            format!("{:.1}", p.total_s / 60.0),
        ]);
    }
    print!("{}", t.render());
    Ok(ExitCode::Ok)
}

/// The `predict --batch` flag inventory: (name, takes a value) — one
/// table drives both validation passes, like [`SWEEP_FLAGS`]. The
/// single-point `repro predict` keeps its original free-form flags.
const PREDICT_BATCH_FLAGS: [(&str, bool); 8] = [
    ("batch", true),
    ("params", true),
    ("json", true),
    ("csv", false),
    ("workers", true),
    ("serial", false),
    ("lab", false),
    ("no-store", false),
];

/// The `serve` flag inventory, same contract as [`PREDICT_BATCH_FLAGS`].
const SERVE_FLAGS: [(&str, bool); 6] = [
    ("addr", true),
    ("workers", true),
    ("serial", false),
    ("params", true),
    ("lab", false),
    ("no-store", false),
];

/// Build the prediction engine shared by `repro predict --batch` and
/// `repro serve`: parameter source, worker count, and the `--lab` store
/// when one is configured (warm cells then serve straight from disk).
fn build_engine(args: &Args) -> Result<PredictEngine> {
    let workers = if args.has("serial") {
        1
    } else {
        args.get_usize("workers", 0)?
    };
    let engine = PredictEngine::new(parse_params(args)?, workers);
    Ok(match parse_lab(args)? {
        Some(lab) => engine.with_store(Arc::clone(lab.store())),
        None => engine,
    })
}

/// One human-readable line of engine telemetry (the predict footer).
fn serve_stats_line(stats: &ServeStats) -> String {
    let mut line = format!(
        "{} queries in {} batches, {} cells | calibration resolutions: {}",
        stats.queries, stats.batches, stats.cells, stats.calibration_resolutions
    );
    if let Some(s) = &stats.store {
        line.push_str(&format!(" | store: {} hits / {} misses", s.hits, s.misses));
    }
    line
}

/// Render one evaluated query with the sweep's own per-cell table so
/// the human-readable predict output matches `repro sweep run --full`
/// row for row (the footer telemetry is the engine's, printed once by
/// the caller, so only the table is borrowed here).
fn query_table(q: &micdl::serve::QueryResult) -> Table {
    SweepResults {
        grid: q.grid.clone(),
        results: q.results.clone(),
        cache: CacheStats::default(),
        store: None,
        wall_s: 0.0,
        workers: 1,
    }
    .table(true)
}

/// `repro predict --batch FILE`: evaluate a query batch through the
/// [`micdl::serve`] engine. `--json` writes the predict document (rows
/// bit-identical to the equivalent sweep cells), `--csv` streams the
/// cells as one CSV table, default prints per-query tables plus the
/// engine-stats footer.
fn cmd_predict_batch(args: &Args) -> Result<ExitCode> {
    check_flags(args, &PREDICT_BATCH_FLAGS, "predict")?;
    if args.has("json") && args.has("csv") {
        bail!("--json and --csv are mutually exclusive");
    }
    let path = args
        .get("batch")
        .ok_or_else(|| err!("--batch needs a file path"))?;
    let batch = QueryBatch::from_json(&std::fs::read_to_string(path)?)?;
    let engine = build_engine(args)?;
    let results = engine.eval_batch(&batch)?;
    let stats = engine.stats();
    if let Some(out) = args.get("json") {
        std::fs::write(out, predict_doc(&results, &stats).emit())?;
        eprintln!(
            "wrote {} result rows ({} queries) to {out}",
            stats.cells, stats.queries
        );
        eprintln!("{}", serve_stats_line(&stats));
        return Ok(ExitCode::Ok);
    }
    if args.has("csv") {
        // One CSV stream. The column set depends on the query — a sim
        // variant adds a leading `sim` column — so the header line is
        // re-emitted whenever it changes (and skipped while it repeats):
        // every data row always aligns with the nearest header above it.
        let mut last_header: Option<String> = None;
        for q in &results {
            let csv = query_table(q).to_csv();
            let mut lines = csv.lines();
            let Some(header) = lines.next() else { continue };
            if last_header.as_deref() != Some(header) {
                println!("{header}");
                last_header = Some(header.to_string());
            }
            for line in lines {
                println!("{line}");
            }
        }
        return Ok(ExitCode::Ok);
    }
    for q in &results {
        print!("{}", query_table(q).render());
    }
    println!("{}", serve_stats_line(&stats));
    Ok(ExitCode::Ok)
}

/// `repro serve`: bind the embedded HTTP prediction server and block
/// until a `POST /shutdown` arrives. The resolved address goes to
/// stdout (port 0 picks a free port), so scripts can `--addr 127.0.0.1:0`
/// and read the line back.
fn cmd_serve(args: &Args) -> Result<ExitCode> {
    check_flags(args, &SERVE_FLAGS, "serve")?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:8787");
    let workers = if args.has("serial") {
        1
    } else {
        args.get_usize("workers", 0)?
    };
    let engine = Arc::new(build_engine(args)?);
    let server = Server::bind(engine, addr, workers)?;
    println!("listening on {}", server.local_addr()?);
    use std::io::Write;
    std::io::stdout().flush()?;
    server.run()?;
    eprintln!("serve: shut down cleanly");
    Ok(ExitCode::Ok)
}

/// Parse the `--images` axis: `IxIT` pairs, comma-separated
/// (`60000x10000,30000x5000`).
fn parse_images(text: &str) -> Result<Vec<(usize, usize)>> {
    let mut out = Vec::new();
    for item in text.split(',') {
        let (i, it) = item
            .trim()
            .split_once(['x', 'X'])
            .ok_or_else(|| err!("--images wants IxIT pairs, got {item:?}"))?;
        let parse = |s: &str| -> Result<usize> {
            s.trim()
                .parse()
                .map_err(|_| err!("--images wants integers, got {s:?}"))
        };
        out.push((parse(i)?, parse(it)?));
    }
    Ok(out)
}

/// The sweep flag inventory: (name, takes a value, shapes the grid).
/// One table drives both the missing-value check and the "did the user
/// give an explicit grid" test, so the per-flag handlers in [`cmd_sweep`]
/// cannot drift out of sync with either.
const SWEEP_FLAGS: [(&str, bool, bool); 35] = [
    ("spec", true, true),
    ("arch", true, true),
    ("threads", true, true),
    ("epochs", true, true),
    ("images", true, true),
    ("strategy", true, true),
    ("params", true, true),
    ("clock-ghz", true, true),
    ("measure", false, true),
    ("sim-clock-ghz", true, true),
    ("sim-cores", true, true),
    ("sim-threads", true, true),
    ("sim-fwd-cycles", true, true),
    ("sim-bwd-cycles", true, true),
    ("sim-exec-fraction", true, true),
    ("sim-l2-alpha", true, true),
    ("sim-l2-cap", true, true),
    ("sim-ring-beta", true, true),
    ("sim-oversub", true, true),
    ("sim-fidelity", true, true),
    ("sim-seed", true, true),
    ("workers", true, false),
    ("serial", false, false),
    ("json", true, false),
    ("csv", false, false),
    ("full", false, false),
    ("compare", true, false),
    ("write-baseline", true, false),
    ("tolerance", true, false),
    // `--lab` is registered valueless so the bare spelling (meaning
    // ./result) passes validation; a given value still parses.
    ("lab", false, false),
    ("resume", false, false),
    ("no-store", false, false),
    ("shard", true, false),
    ("shards", true, false),
    ("continue-on-failure", false, false),
];

/// Open the lab named by `--lab` (bare `--lab` means `./result`).
/// `None` when the flag is absent — persistence is strictly opt-in — or
/// when `--no-store` bypasses an otherwise-configured lab. `--resume`
/// and `--no-store` are meaningless without `--lab`, so both error.
fn parse_lab(args: &Args) -> Result<Option<Lab>> {
    if !args.has("lab") {
        if args.has("resume") {
            bail!("--resume requires --lab (there is no store to resume from)");
        }
        if args.has("no-store") {
            bail!("--no-store requires --lab (there is no store to bypass)");
        }
        return Ok(None);
    }
    if args.has("resume") && args.has("no-store") {
        bail!("--resume and --no-store are mutually exclusive");
    }
    if args.has("no-store") {
        return Ok(None);
    }
    Ok(Some(Lab::open(args.get("lab").unwrap_or("./result"))?))
}

/// The runner for a subcommand: wired to the lab's store when one is
/// configured, plain otherwise.
fn runner_for(workers: usize, lab: &Option<Lab>) -> SweepRunner {
    match lab {
        Some(lab) => lab.runner(workers),
        None => SweepRunner::new(workers),
    }
}

/// Reject unknown flags and valued flags given without a value — a
/// typo'd or valueless flag must error, not silently no-op (a dropped
/// `--compare` would make a CI gate vacuous, a dropped `--json` starves
/// the script capturing the dump). One helper shared by every
/// flag-table-driven subcommand so the two validation passes cannot
/// drift between them.
fn check_flags(args: &Args, flags: &[(&str, bool)], cmd: &str) -> Result<()> {
    for (flag, _) in &args.flags {
        if !flags.iter().any(|&(f, _)| f == flag.as_str()) {
            bail!("unknown {cmd} flag --{flag}");
        }
    }
    for &(flag, valued) in flags {
        if valued && args.has(flag) && args.get(flag).is_none() {
            bail!("--{flag} needs a value");
        }
    }
    Ok(())
}

/// Parse a comma-separated float list (`--sim-clock-ghz 1.0,1.238,1.5`).
fn parse_float_list(text: &str, flag: &str) -> Result<Vec<f64>> {
    text.split(',')
        .map(|v| {
            v.trim()
                .parse()
                .map_err(|_| err!("--{flag} wants floats, got {v:?}"))
        })
        .collect()
}

/// Build the sim-ablation axis from the `--sim-*` flags: the cross
/// product of every given list (each unset field inherits the base
/// simulator). `None` when no `--sim-*` flag was given, so a `--spec`
/// file's `sim` axis survives.
fn parse_sim_axis(args: &Args) -> Result<Option<Vec<SimVariant>>> {
    fn cross<T: Copy>(
        variants: Vec<SimVariant>,
        values: &[T],
        set: impl Fn(&mut SimVariant, T),
    ) -> Vec<SimVariant> {
        let mut out = Vec::with_capacity(variants.len() * values.len());
        for v in &variants {
            for &value in values {
                let mut next = v.clone();
                set(&mut next, value);
                out.push(next);
            }
        }
        out
    }
    let mut variants = vec![SimVariant::default()];
    let mut any = false;
    macro_rules! axis_f64 {
        ($flag:literal, $field:ident) => {
            if let Some(text) = args.get($flag) {
                any = true;
                let values = parse_float_list(text, $flag)?;
                variants = cross(variants, &values, |v, x| v.$field = Some(x));
            }
        };
    }
    axis_f64!("sim-clock-ghz", clock_ghz);
    axis_f64!("sim-fwd-cycles", fwd_cycles_per_op);
    axis_f64!("sim-bwd-cycles", bwd_cycles_per_op);
    axis_f64!("sim-exec-fraction", exec_fraction);
    axis_f64!("sim-l2-alpha", l2_alpha);
    axis_f64!("sim-l2-cap", l2_ratio_cap);
    axis_f64!("sim-ring-beta", ring_beta);
    axis_f64!("sim-oversub", oversub_overhead);
    if let Some(text) = args.get("sim-cores") {
        any = true;
        let values = parse_axis(text)?;
        variants = cross(variants, &values, |v, x| v.cores = Some(x));
    }
    if let Some(text) = args.get("sim-threads") {
        any = true;
        let values = parse_axis(text)?;
        variants = cross(variants, &values, |v, x| v.threads_per_core = Some(x));
    }
    if let Some(text) = args.get("sim-seed") {
        any = true;
        let values = parse_axis(text)?;
        variants = cross(variants, &values, |v, x| v.seed = Some(x as u64));
    }
    if let Some(text) = args.get("sim-fidelity") {
        any = true;
        let values = text
            .split(',')
            .map(|f| Fidelity::parse(f.trim()))
            .collect::<Result<Vec<_>>>()?;
        variants = cross(variants, &values, |v, x| v.fidelity = Some(x));
    }
    if !any {
        return Ok(None);
    }
    for v in &mut variants {
        v.name = v.auto_name();
    }
    Ok(Some(variants))
}

/// Map the noun-verb spellings (`sweep run`, `sweep baseline
/// write|compare PATH`) onto the flag surface. The verbless legacy
/// spelling keeps working but earns one deprecation note on stderr.
fn normalize_sweep_verbs(args: &mut Args) -> Result<()> {
    let verbs: Vec<&str> = args.positional.iter().map(String::as_str).collect();
    match verbs.as_slice() {
        [] => eprintln!(
            "deprecated: verbless `repro sweep` — use `repro sweep run` \
             (or `repro sweep baseline write|compare PATH`)"
        ),
        ["run"] => {}
        ["baseline", "write", path] => {
            let path = path.to_string();
            args.flags.push(("write-baseline".into(), Some(path)));
        }
        ["baseline", "compare", path] => {
            let path = path.to_string();
            args.flags.push(("compare".into(), Some(path)));
        }
        other => {
            bail!(
                "unknown sweep verb {:?} (expected `run` or `baseline write|compare PATH`)",
                other.join(" ")
            )
        }
    }
    args.positional.clear();
    Ok(())
}

/// Parse `--shard K/N` (1-based on the CLI: `1/3` .. `3/3`) into the
/// 0-based `(k, n)` the library uses ([`GridSpec::shard`]).
fn parse_shard(args: &Args) -> Result<Option<(usize, usize)>> {
    let Some(text) = args.get("shard") else {
        return Ok(None);
    };
    let (k, n) = text
        .split_once('/')
        .ok_or_else(|| err!("--shard wants K/N (e.g. 1/3), got {text:?}"))?;
    let parse = |s: &str| -> Result<usize> {
        s.trim()
            .parse()
            .map_err(|_| err!("--shard wants integers in K/N, got {text:?}"))
    };
    let (k, n) = (parse(k)?, parse(n)?);
    if n == 0 {
        bail!("--shard N must be >= 1, got {text:?}");
    }
    if k == 0 || k > n {
        bail!("--shard K is 1-based (1 <= K <= N), got {text:?}");
    }
    Ok(Some((k - 1, n)))
}

/// The failed shard child's `error:` stderr line, when the run errored
/// (the usage text that follows is noise here).
fn shard_error_line(out: &std::process::Output) -> Option<String> {
    String::from_utf8_lossy(&out.stderr)
        .lines()
        .find(|l| l.starts_with("error: "))
        .map(str::to_string)
}

/// The last interesting line of a failed shard child: its `error:` line
/// when the run errored, or the last non-empty stderr line otherwise
/// (e.g. nothing on a kill).
fn shard_failure_detail(out: &std::process::Output) -> String {
    let text = String::from_utf8_lossy(&out.stderr);
    let detail = text
        .lines()
        .find(|l| l.starts_with("error: "))
        .or_else(|| text.lines().rev().find(|l| !l.trim().is_empty()))
        .unwrap_or("(no stderr)");
    format!("{} — {detail}", out.status)
}

/// True when a failed shard child's `error:` line ([`shard_error_line`])
/// is deterministic — a configuration or spec-parse error that every
/// retry would reproduce byte for byte. The driver fails such shards
/// immediately instead of burning the full retry budget (retries are
/// for transient failures: I/O contention on the shared store, kills,
/// flaky environments). The match is anchored to the start of the line,
/// where the [`Error`] display prefix lands (`config error:` / `json
/// error:`) — a transient failure that merely *quotes* a config-error
/// string deeper in its message keeps its retry budget.
fn shard_error_is_config(error_line: Option<&str>) -> bool {
    error_line.is_some_and(|l| {
        l.starts_with("error: config error:") || l.starts_with("error: json error:")
    })
}

/// The `--shards N` driver: spawn one `repro sweep run --shard k/N`
/// child process per shard, all against the shared lab store, retrying
/// failed shards in up to 3 waves with linear backoff. Once every shard
/// has persisted its cells, reassemble by running the full grid
/// in-process — a pure-store-hit pass whose output is byte-identical to
/// an unsharded run (docs/SWEEP.md, "Sharded execution").
///
/// Without `--continue-on-failure` the first shard to exhaust its
/// retries aborts the grid (exit 1); with it, every shard gets its full
/// retry budget and the driver exits 1 with a per-shard failure report
/// on stderr.
fn run_shard_driver(
    lab: &Lab,
    grid: &GridSpec,
    n: usize,
    workers: usize,
    args: &Args,
) -> Result<micdl::sweep::SweepResults> {
    const ATTEMPTS: usize = 3;
    /// Flags the driver owns: fan-out control plus every output/report
    /// flag (the driver renders the merged results; children stay mute
    /// on stdout).
    const DRIVER_ONLY: [&str; 8] = [
        "shards",
        "continue-on-failure",
        "json",
        "csv",
        "full",
        "compare",
        "write-baseline",
        "tolerance",
    ];
    let exe = std::env::current_exe()?;
    let mut base: Vec<String> = vec!["sweep".into(), "run".into()];
    for (name, value) in &args.flags {
        if DRIVER_ONLY.contains(&name.as_str()) {
            continue;
        }
        base.push(format!("--{name}"));
        if let Some(v) = value {
            base.push(v.clone());
        }
    }
    let mut pending: Vec<usize> = (0..n).collect();
    // Permanently failed shards: (shard, detail, retries exhausted?).
    // Deterministic config/validation failures land here on first sight
    // (retryable = false) — re-running them burns the budget to
    // reproduce the same error; only transient failures get the
    // remaining attempts.
    let mut failures: Vec<(usize, String, bool)> = Vec::new();
    for attempt in 1..=ATTEMPTS {
        let mut children = Vec::new();
        for &k in &pending {
            let mut argv = base.clone();
            argv.push("--shard".into());
            argv.push(format!("{}/{n}", k + 1));
            let child = std::process::Command::new(&exe)
                .args(&argv)
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::piped())
                .spawn()?;
            children.push((k, child));
        }
        let mut still: Vec<usize> = Vec::new();
        for (k, child) in children {
            let out = child.wait_with_output()?;
            if out.status.success() {
                eprintln!("note: shard {}/{n} complete", k + 1);
                continue;
            }
            let detail = shard_failure_detail(&out);
            if shard_error_is_config(shard_error_line(&out).as_deref()) {
                eprintln!(
                    "warning: shard {}/{n} failed (non-retryable, attempt \
                     {attempt}/{ATTEMPTS} is final): {detail}",
                    k + 1
                );
                failures.push((k, detail, false));
            } else if attempt == ATTEMPTS {
                eprintln!(
                    "warning: shard {}/{n} failed (attempt {attempt}/{ATTEMPTS}): {detail}",
                    k + 1
                );
                failures.push((k, detail, true));
            } else {
                eprintln!(
                    "warning: shard {}/{n} failed (attempt {attempt}/{ATTEMPTS}): {detail}",
                    k + 1
                );
                still.push(k);
            }
        }
        // Fail-fast mode stops at the first permanent failure; with
        // --continue-on-failure the transient shards keep their budget
        // and every permanent failure is reported at the end.
        if !failures.is_empty() && !args.has("continue-on-failure") {
            break;
        }
        if still.is_empty() {
            break;
        }
        pending = still;
        std::thread::sleep(std::time::Duration::from_millis(250 * attempt as u64));
    }
    if !failures.is_empty() {
        failures.sort_by_key(|&(k, _, _)| k);
        if args.has("continue-on-failure") {
            eprintln!(
                "shard failure report: {} of {n} shards failed permanently",
                failures.len()
            );
            for (k, detail, retryable) in &failures {
                let how = if *retryable {
                    format!("after {ATTEMPTS} attempts")
                } else {
                    "non-retryable".to_string()
                };
                eprintln!("  shard {}/{n} ({how}): {detail}", k + 1);
            }
            bail!("{} of {n} shards failed (report above)", failures.len());
        }
        let (k, detail, retryable) = &failures[0];
        if *retryable {
            bail!("shard {}/{n} failed after {ATTEMPTS} attempts: {detail}", k + 1);
        }
        bail!("shard {}/{n} failed with a non-retryable error: {detail}", k + 1);
    }
    // Every shard persisted its cells under the keys an unsharded run
    // uses, so this full pass is pure store hits and its payload is the
    // canonical unsharded one (it also flips the parent manifest to
    // `complete`).
    lab.run(grid, workers)
}

fn cmd_sweep(args: &Args) -> Result<ExitCode> {
    let mut args = args.clone();
    normalize_sweep_verbs(&mut args)?;
    let args = &args;
    check_flags(args, &SWEEP_FLAGS.map(|(f, v, _)| (f, v)), "sweep")?;
    let lab = parse_lab(args)?;
    let shard = parse_shard(args)?;
    let shard_count = match args.get("shards") {
        None => None,
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| err!("--shards wants an integer, got {v:?}"))?;
            if n == 0 {
                bail!("--shards must be >= 1");
            }
            Some(n)
        }
    };
    if shard.is_some() && shard_count.is_some() {
        bail!("--shard and --shards are mutually exclusive (the driver assigns shards)");
    }
    if (shard.is_some() || shard_count.is_some()) && lab.is_none() {
        bail!(
            "--shard/--shards require --lab without --no-store \
             (shards compose through a shared store)"
        );
    }
    if shard.is_some() && (args.has("compare") || args.has("write-baseline")) {
        bail!(
            "--shard evaluates a partial grid; baseline write/compare need the \
             full grid (run them on the driver via --shards, or unsharded)"
        );
    }
    if args.has("continue-on-failure") && shard_count.is_none() {
        bail!("--continue-on-failure only applies to the --shards driver");
    }
    let baseline = args
        .get("compare")
        .map(|path| Baseline::load(std::path::Path::new(path)))
        .transpose()?;
    // Validate up front — a malformed tolerance must not cost a full
    // sweep before erroring.
    let tolerance = match args.get("tolerance") {
        None => DEFAULT_TOLERANCE,
        Some(v) => {
            let t: f64 = v
                .parse()
                .map_err(|_| err!("--tolerance wants a float, got {v:?}"))?;
            if !(t.is_finite() && t >= 0.0) {
                bail!("--tolerance must be finite and >= 0, got {t}");
            }
            t
        }
    };
    // `--compare` with no grid-shaping flags re-runs the baseline's own
    // grid; any explicit flag (or `--spec`) overrides it.
    let grid_shaped = SWEEP_FLAGS
        .iter()
        .any(|&(f, _, shapes_grid)| shapes_grid && args.has(f));
    let mut grid = match (args.get("spec"), &baseline) {
        (Some(path), _) => GridSpec::from_json(&std::fs::read_to_string(path)?)?,
        (None, Some(base)) if !grid_shaped => base.grid()?,
        _ => GridSpec::default(),
    };
    if let Some(v) = args.get("arch") {
        grid.archs = if v == "all" {
            ArchSpec::paper_archs()
        } else {
            v.split(',')
                .map(|name| ArchSpec::by_name(name.trim()))
                .collect::<Result<Vec<_>>>()?
        };
    }
    if let Some(v) = args.get("threads") {
        grid.threads = parse_axis(v)?;
    }
    if let Some(v) = args.get("epochs") {
        grid.epochs = parse_axis(v)?;
    }
    if let Some(v) = args.get("images") {
        grid.images = parse_images(v)?;
    }
    if let Some(v) = args.get("strategy") {
        grid.strategies = Strategy::parse_list(v)?;
    }
    if args.has("params") {
        grid.params = parse_params(args)?;
    }
    if args.has("measure") {
        grid.measure = true;
    }
    if let Some(v) = args.get("clock-ghz") {
        grid.machines = v
            .split(',')
            .map(|c| -> Result<MachineConfig> {
                let ghz: f64 = c
                    .trim()
                    .parse()
                    .map_err(|_| err!("--clock-ghz wants floats, got {c:?}"))?;
                Ok(MachineConfig::xeon_phi_7120p_at_ghz(ghz))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(sims) = parse_sim_axis(args)? {
        grid.sims = sims;
    }
    grid.normalize();
    // The machine/sim composition is explicit: sim overrides win, and a
    // collision with the machine axis warns instead of silently dropping
    // one side (the old behaviour under --measure).
    for warning in grid.sim_machine_conflicts() {
        eprintln!("warning: {warning}");
    }
    let workers = if args.has("serial") {
        1
    } else {
        args.get_usize("workers", 0)?
    };
    let results = match (&lab, shard, shard_count) {
        (Some(lab), Some((k, n)), _) => {
            if args.has("resume") {
                match lab.find_shard_run(&grid, k, n)? {
                    Some(m) => eprintln!(
                        "note: resuming shard run {} (was {}) — persisted cells serve \
                         from the store",
                        m.get("id").and_then(|j| j.as_str()).unwrap_or("?"),
                        m.get("status").and_then(|j| j.as_str()).unwrap_or("?"),
                    ),
                    None => eprintln!(
                        "note: no prior run of this shard in the lab — starting fresh"
                    ),
                }
            }
            lab.run_shard(&grid, k, n, workers)?
        }
        (Some(lab), None, Some(n)) => run_shard_driver(lab, &grid, n, workers, args)?,
        (Some(lab), None, None) => {
            if args.has("resume") {
                match lab.find_run(&grid)? {
                    Some(m) => eprintln!(
                        "note: resuming run {} (was {}) — persisted cells serve from the store",
                        m.get("id").and_then(|j| j.as_str()).unwrap_or("?"),
                        m.get("status").and_then(|j| j.as_str()).unwrap_or("?"),
                    ),
                    None => eprintln!(
                        "note: no prior run of this grid in the lab — starting fresh"
                    ),
                }
            }
            lab.run(&grid, workers)?
        }
        (None, _, _) => SweepRunner::new(workers).run(&grid)?,
    };
    if let Some(path) = args.get("json") {
        std::fs::write(path, results.to_json().emit())?;
        eprintln!("wrote {} scenario results to {path}", results.len());
    }
    if let Some(path) = args.get("write-baseline") {
        let base = Baseline::from_results(&results)?;
        std::fs::write(path, base.to_json().emit())?;
        eprintln!("wrote baseline ({} cells) to {path}", base.cells.len());
    }
    if let Some(base) = baseline {
        // Compare mode: stdout carries the machine-readable diff report,
        // stderr the human-readable findings. Exit 2 on regression.
        let report = base.compare(&results, tolerance)?;
        println!("{}", report.to_json().emit());
        eprint!("{}", report.render());
        return Ok(if report.is_clean() {
            ExitCode::Ok
        } else {
            ExitCode::Regression
        });
    }
    if args.has("csv") {
        print!("{}", results.table(true).to_csv());
    } else {
        print!("{}", results.render(args.has("full")));
    }
    Ok(ExitCode::Ok)
}

/// The conformance flag inventory: (name, takes a value). One table
/// drives both validation passes, like [`SWEEP_FLAGS`].
const CONFORMANCE_FLAGS: [(&str, bool); 14] = [
    ("baseline", true),
    ("write-baseline", true),
    ("report", true),
    ("closed-loop", true),
    ("write-closed-loop", true),
    ("closed-loop-report", true),
    ("residual", true),
    ("write-residual", true),
    ("residual-report", true),
    ("workers", true),
    ("serial", false),
    ("lab", false),
    ("resume", false),
    ("no-store", false),
];

fn cmd_conformance(args: &Args) -> Result<ExitCode> {
    check_flags(args, &CONFORMANCE_FLAGS, "conformance")?;
    let lab = parse_lab(args)?;
    if args.has("baseline") && args.has("write-baseline") {
        bail!("--baseline and --write-baseline are mutually exclusive");
    }
    if args.has("closed-loop") && args.has("write-closed-loop") {
        bail!("--closed-loop and --write-closed-loop are mutually exclusive");
    }
    if args.has("residual") && args.has("write-residual") {
        bail!("--residual and --write-residual are mutually exclusive");
    }
    let writes = args.has("write-baseline")
        || args.has("write-closed-loop")
        || args.has("write-residual");
    let checks = args.has("baseline") || args.has("closed-loop") || args.has("residual");
    if writes && checks {
        bail!("write and check modes are mutually exclusive in one invocation");
    }
    // Only check mode produces a report — accepting --report elsewhere
    // would silently no-op and leave a script reading a stale file.
    if args.has("report") && !checks {
        bail!(
            "--report requires a check flag (--baseline, --closed-loop or \
             --residual; only check mode writes a report)"
        );
    }
    if args.has("closed-loop-report") && !args.has("closed-loop") {
        bail!("--closed-loop-report requires --closed-loop");
    }
    if args.has("residual-report") && !args.has("residual") {
        bail!("--residual-report requires --residual");
    }
    let workers = if args.has("serial") {
        1
    } else {
        args.get_usize("workers", 0)?
    };
    // With a lab attached, every conformance grid cell persists and
    // `--resume` after an interruption serves the persisted cells (the
    // store is content-addressed, so reuse needs no manifest here).
    let runner = runner_for(workers, &lab);
    if writes {
        if let Some(path) = args.get("write-baseline") {
            let base = ConformanceBaseline::capture(&runner)?;
            std::fs::write(path, base.to_json().emit())?;
            eprintln!(
                "wrote conformance baseline ({} grids, {} bands, {} claims) to {path}",
                base.grids.len(),
                base.grids.iter().map(|g| g.bands.len()).sum::<usize>(),
                base.claims.len()
            );
        }
        if let Some(path) = args.get("write-closed-loop") {
            let base = ConformanceBaseline::capture_closed_loop(&runner)?;
            std::fs::write(path, base.to_json().emit())?;
            eprintln!(
                "wrote closed-loop baseline ({} grids, {} bands, {} claims) to {path}",
                base.grids.len(),
                base.grids.iter().map(|g| g.bands.len()).sum::<usize>(),
                base.claims.len()
            );
        }
        if let Some(path) = args.get("write-residual") {
            let base = ConformanceBaseline::capture_residual(&runner)?;
            std::fs::write(path, base.to_json().emit())?;
            eprintln!(
                "wrote residual baseline ({} grids, {} bands, {} claims) to {path}",
                base.grids.len(),
                base.grids.iter().map(|g| g.bands.len()).sum::<usize>(),
                base.claims.len()
            );
        }
        return Ok(ExitCode::Ok);
    }
    if !checks {
        // Observational mode: run the Tables IX-XI grids plus the
        // closed-loop and residual grids and print the observed Δ bands
        // without asserting anything.
        let mut runs = conformance::run_paper_grids(&runner)?;
        runs.extend(conformance::run_closed_loop_grids(&runner)?);
        runs.extend(conformance::run_residual_grids(&runner)?);
        let mut t = Table::new(
            "measured-mode Δ bands (observed; nothing asserted)",
            &["grid", "arch", "strat", "points", "mean Δ %", "max Δ %", "at p"],
        );
        for (id, res) in &runs {
            for a in res.accuracy() {
                t.row(vec![
                    id.clone(),
                    a.arch.clone(),
                    a.strategy.as_str().into(),
                    a.points.to_string(),
                    format!("{:.3}", a.mean_delta_pct),
                    format!("{:.3}", a.max_delta_pct),
                    a.max_at_threads.to_string(),
                ]);
            }
            for &s in &res.grid.strategies {
                if let Some(overall) = res.accuracy_overall(s) {
                    t.row(vec![
                        id.clone(),
                        "all".into(),
                        s.as_str().into(),
                        overall.points.to_string(),
                        format!("{:.3}", overall.mean_delta_pct),
                        format!("{:.3}", overall.max_delta_pct),
                        overall.max_at_threads.to_string(),
                    ]);
                }
            }
        }
        print!("{}", t.render());
        return Ok(ExitCode::Ok);
    }
    // Check mode: stdout carries the machine-readable report (one report
    // object, or a combined document when both baselines are checked),
    // stderr the human-readable findings. Exit 2 on any regression.
    let mut clean = true;
    let mut payloads: Vec<(&str, String)> = Vec::new();
    if let Some(path) = args.get("baseline") {
        let base = ConformanceBaseline::load(std::path::Path::new(path))?;
        let report = base.check(&runner)?;
        eprint!("{}", report.render());
        clean &= report.is_clean();
        payloads.push(("measured", report.to_json().emit()));
    }
    if let Some(path) = args.get("closed-loop") {
        let base = ConformanceBaseline::load(std::path::Path::new(path))?;
        let report = base.check(&runner)?;
        let json = report.to_json().emit();
        if let Some(out) = args.get("closed-loop-report") {
            std::fs::write(out, &json)?;
        }
        eprint!("{}", report.render());
        clean &= report.is_clean();
        payloads.push(("closed_loop", json));
    }
    if let Some(path) = args.get("residual") {
        let base = ConformanceBaseline::load(std::path::Path::new(path))?;
        let report = base.check(&runner)?;
        let json = report.to_json().emit();
        if let Some(out) = args.get("residual-report") {
            std::fs::write(out, &json)?;
        }
        eprint!("{}", report.render());
        clean &= report.is_clean();
        payloads.push(("residual", json));
    }
    // The stdout payload: one report object, or the combined document
    // when several baselines were checked. `--report` mirrors exactly
    // this payload to a file (the CI artifact path), whatever the mode.
    let payload = match payloads.as_slice() {
        [(_, json)] => json.clone(),
        _ => {
            let parts: Vec<String> = payloads
                .iter()
                .map(|(key, json)| format!("\"{key}\":{json}"))
                .collect();
            format!(
                "{{\"kind\":\"micdl-conformance-run\",\"clean\":{clean},{}}}",
                parts.join(",")
            )
        }
    };
    if let Some(out) = args.get("report") {
        std::fs::write(out, &payload)?;
    }
    println!("{payload}");
    Ok(if clean { ExitCode::Ok } else { ExitCode::Regression })
}

/// The sensitivity flag inventory: (name, takes a value) — one table
/// drives both validation passes, like [`SWEEP_FLAGS`].
const SENSITIVITY_FLAGS: [(&str, bool); 12] = [
    ("arch", true),
    ("threads", true),
    ("strategy", true),
    ("params", true),
    ("step", true),
    ("constants", true),
    ("json", true),
    ("workers", true),
    ("serial", false),
    ("lab", false),
    ("resume", false),
    ("no-store", false),
];

fn cmd_sensitivity(args: &Args) -> Result<ExitCode> {
    check_flags(args, &SENSITIVITY_FLAGS, "sensitivity")?;
    let lab = parse_lab(args)?;
    let mut spec = SensitivitySpec::default();
    if let Some(v) = args.get("arch") {
        spec.archs = if v == "all" {
            ArchSpec::paper_archs()
        } else {
            v.split(',')
                .map(|name| ArchSpec::by_name(name.trim()))
                .collect::<Result<Vec<_>>>()?
        };
    }
    if let Some(v) = args.get("threads") {
        spec.threads = parse_axis(v)?;
    }
    if let Some(v) = args.get("strategy") {
        spec.strategies = Strategy::parse_list(v)?;
    }
    if args.has("params") {
        spec.params = parse_params(args)?;
    }
    if let Some(v) = args.get("step") {
        spec.step = v
            .parse()
            .map_err(|_| err!("--step wants a float, got {v:?}"))?;
    }
    if let Some(v) = args.get("constants") {
        spec.constants = v
            .split(',')
            .map(|c| SimConstant::parse(c.trim()))
            .collect::<Result<Vec<_>>>()?;
    }
    let workers = if args.has("serial") {
        1
    } else {
        args.get_usize("workers", 0)?
    };
    let report = sensitivity::run(&spec, &runner_for(workers, &lab))?;
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().emit())?;
        eprintln!(
            "wrote sensitivity report ({} entries, {} ranked constants) to {path}",
            report.entries.len(),
            report.ranking.len()
        );
    }
    print!("{}", report.render());
    Ok(ExitCode::Ok)
}

/// `repro lab list|gc|trace-params` (and the equivalent top-level
/// aliases, which pass `verb` explicitly). All verbs address the lab at
/// `--lab PATH`, default `./result`.
fn cmd_lab(args: &Args, verb: Option<&str>) -> Result<ExitCode> {
    const LAB_FLAGS: [(&str, bool); 4] =
        [("lab", false), ("dry-run", false), ("arch", true), ("params", true)];
    check_flags(args, &LAB_FLAGS, "lab")?;
    let verb = match verb {
        Some(v) => {
            if !args.positional.is_empty() {
                bail!("unexpected argument {:?}", args.positional[0]);
            }
            v
        }
        None => match args.positional.as_slice() {
            [v] => v.as_str(),
            [] => bail!("lab needs a verb: list | gc | trace-params"),
            more => bail!("unexpected argument {:?}", more[1]),
        },
    };
    let lab = Lab::open(args.get("lab").unwrap_or("./result"))?;
    match verb {
        "list" => {
            let runs = lab.list_runs()?;
            // Shard manifests (`{parent}.{k}of{n}`) sort directly under
            // their parent run id — the `.` separator orders before
            // every hex digit — so indenting them is all the grouping
            // the id-sorted listing needs.
            let mut t = Table::new(
                format!("lab runs — {}", runs.len()),
                &["id", "status", "scenarios"],
            );
            for m in &runs {
                let id = m.get("id").and_then(|j| j.as_str()).unwrap_or("?");
                let id = if m.get("shard").is_some() {
                    format!("  └ {id}")
                } else {
                    id.to_string()
                };
                t.row(vec![
                    id,
                    m.get("status").and_then(|j| j.as_str()).unwrap_or("?").to_string(),
                    m.get("scenarios")
                        .and_then(|j| j.as_usize())
                        .map(|n| n.to_string())
                        .unwrap_or_else(|| "?".into()),
                ]);
            }
            print!("{}", t.render());
        }
        "gc" => {
            let report = lab.gc(args.has("dry-run"))?;
            println!(
                "gc{}: scanned {} store files, removed {}, kept {}",
                if report.dry_run { " (dry run)" } else { "" },
                report.scanned,
                report.removed,
                report.kept
            );
        }
        "trace-params" => {
            let arch = args
                .get("arch")
                .ok_or_else(|| err!("trace-params needs --arch"))?;
            let source = parse_params(args)?;
            match lab.trace_params(arch, source, &SimConfig::default()) {
                Some(doc) => println!("{}", doc.emit()),
                None => {
                    eprintln!(
                        "no persisted calibration for ({arch}, {}) in this lab",
                        micdl::lab::source_tag(source)
                    );
                    return Ok(ExitCode::Error);
                }
            }
        }
        other => bail!("unknown lab verb {other:?} (expected list | gc | trace-params)"),
    }
    Ok(ExitCode::Ok)
}

fn cmd_probe(args: &Args) -> Result<ExitCode> {
    let arch = parse_arch(args)?;
    let cfg = SimConfig::default();
    let mut t = Table::new(
        format!("contention probe — {} (Table IV analogue)", arch.name),
        &["threads", "contention s/image"],
    );
    for p in [1usize, 15, 30, 60, 120, 180, 240, 480, 960, 1920, 3840] {
        t.row(vec![
            p.to_string(),
            format!("{:.3e}", probe::contention_probe(&arch, p, &cfg)?),
        ]);
    }
    print!("{}", t.render());
    Ok(ExitCode::Ok)
}

fn cmd_train(args: &Args) -> Result<ExitCode> {
    let backend = args.get("backend").unwrap_or("engine");
    let epochs = args.get_usize("epochs", 3)?;
    let n_train = args.get_usize("images", 2000)?;
    let n_test = args.get_usize("test-images", 400)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let mnist_dir = args.get("mnist").map(std::path::PathBuf::from);
    let (train, test) = dataset::load_or_synth(mnist_dir.as_deref(), n_train, n_test, seed);
    println!(
        "dataset: {} train / {} test images ({})",
        train.len(),
        test.len(),
        train.source
    );
    match backend {
        "engine" => {
            let arch = parse_arch(args)?;
            let cfg = PoolConfig {
                workers: args.get_usize("workers", 8)?,
                epochs,
                lr: args
                    .get("lr")
                    .map(|v| v.parse())
                    .transpose()
                    .map_err(|_| err!("--lr wants a float"))?
                    .unwrap_or(0.02),
                eval_cap: 1024,
                seed,
                verbose: true,
            };
            let mut trainer = DataParallelTrainer::new(arch, cfg)?;
            let report = trainer.train(&train, &test)?;
            println!(
                "done: {:.1} img/s, final test accuracy {:.3}, converging={}",
                report.train_throughput,
                report.final_test_accuracy(),
                report.converging()
            );
            println!("metrics: {}", trainer.metrics.report());
        }
        "pjrt" => {
            let dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
            let cfg = LeaderConfig {
                arch: args.get("arch").unwrap_or("small").to_string(),
                epochs,
                eval_cap_batches: 8,
                seed,
                verbose: true,
            };
            let mut trainer = PjrtTrainer::new(&dir, cfg)?;
            let report = trainer.train(&train, &test)?;
            println!(
                "done: {:.1} img/s through PJRT, {} steps, final test accuracy {:.3}",
                report.train_throughput,
                trainer.steps(),
                report.final_test_accuracy()
            );
        }
        other => bail!("--backend must be engine|pjrt, got {other:?}"),
    }
    Ok(ExitCode::Ok)
}

fn cmd_selfcheck(args: &Args) -> Result<ExitCode> {
    // 1. Simulator fidelity crosscheck.
    let cfg = SimConfig::default();
    for arch in ArchSpec::paper_archs() {
        let rel = probe::fidelity_crosscheck(&arch, 61, &cfg)?;
        println!(
            "fidelity crosscheck {}: per-image vs chunked rel err {rel:.2e}",
            arch.name
        );
        if rel > 1e-6 {
            bail!("fidelity mismatch for {}", arch.name);
        }
    }
    // 2. Model sanity: Table X anchor.
    let (a, b) = both_models(&ArchSpec::small(), ParamSource::Paper)?;
    let run = RunConfig::paper_default("small", 480);
    let ta = a.predict(&run)?.total_s / 60.0;
    let tb = b.predict(&run)?.total_s / 60.0;
    println!("model anchor small@480: a={ta:.1} min (paper 6.6), b={tb:.1} min (paper 6.7)");
    if (ta - 6.6).abs() > 0.3 || (tb - 6.7).abs() > 0.3 {
        bail!("model anchor drifted");
    }
    // 3. Artifacts (optional).
    let dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    match micdl::runtime::ArtifactRegistry::load(&dir) {
        Ok(reg) => {
            reg.check_files()?;
            println!(
                "artifacts: {} archs at batch {} ({})",
                reg.archs.len(),
                reg.batch,
                dir.display()
            );
        }
        Err(e) => println!("artifacts: not available ({e}) — run `make artifacts`"),
    }
    println!("selfcheck OK");
    Ok(ExitCode::Ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_failure_classification_is_on_the_error_prefix() {
        // Deterministic child failures — retrying reproduces them.
        assert!(shard_error_is_config(Some(
            "error: config error: thread counts must be >= 1"
        )));
        assert!(shard_error_is_config(Some(
            "error: json error: expected ':' after object key"
        )));
        // Transient or unclassifiable failures keep the retry budget.
        assert!(!shard_error_is_config(Some(
            "error: io error: permission denied"
        )));
        assert!(!shard_error_is_config(None)); // e.g. a kill: no stderr
        // Anchored at the start of the line: a transient failure that
        // merely quotes a config-error string stays retryable.
        assert!(!shard_error_is_config(Some(
            "error: io error: cannot persist \"error: config error: x\": disk full"
        )));
    }

    #[cfg(unix)]
    #[test]
    fn shard_error_line_extraction() {
        use std::os::unix::process::ExitStatusExt;
        let out = |stderr: &str| std::process::Output {
            status: std::process::ExitStatus::from_raw(1 << 8),
            stdout: Vec::new(),
            stderr: stderr.as_bytes().to_vec(),
        };
        let failed = out("note: probing\nerror: config error: bad axis\nusage: repro ...");
        assert_eq!(
            shard_error_line(&failed).as_deref(),
            Some("error: config error: bad axis")
        );
        assert!(shard_error_is_config(shard_error_line(&failed).as_deref()));
        assert_eq!(shard_error_line(&out("")), None);
    }
}
