//! Configuration system: CNN architectures, the machine model, and runs.
//!
//! Three orthogonal configs compose a complete experiment:
//!
//! * [`ArchSpec`] — which network (the paper's small/medium/large, or a
//!   custom layer stack loaded from JSON),
//! * [`MachineConfig`] — which Xeon Phi (core count, clock, SMT/CPI ladder,
//!   memory channels; defaults to the paper's 7120P),
//! * [`RunConfig`] — the workload: `i` training images, `it` test images,
//!   `ep` epochs, `p` processing units (the performance-model inputs of
//!   Table I).

pub mod arch;
pub mod machine;
pub mod run;

pub use arch::{ArchSpec, LayerSpec};
pub use machine::MachineConfig;
pub use run::RunConfig;
