//! Run configuration: the performance-model input variables of Table I.

use crate::error::{Error, Result};

/// The workload parameters `T(i, it, ep, p, s)` ranges over (Table I/II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunConfig {
    /// Number of training/validation images (`i`, paper default 60,000).
    pub train_images: usize,
    /// Number of test images (`it`, paper default 10,000).
    pub test_images: usize,
    /// Number of epochs (`ep`: 70 for small/medium, 15 for large).
    pub epochs: usize,
    /// Number of processing units / threads (`p`, 1–3,840).
    pub threads: usize,
}

impl RunConfig {
    /// Paper defaults for a given architecture name (Table II).
    pub fn paper_default(arch: &str, threads: usize) -> Self {
        RunConfig {
            train_images: 60_000,
            test_images: 10_000,
            epochs: if arch == "large" { 15 } else { 70 },
            threads,
        }
    }

    /// The measured thread counts of the evaluation (Section V).
    pub const MEASURED_THREADS: [usize; 7] = [1, 15, 30, 60, 120, 180, 240];

    /// The model-extrapolated thread counts (Table X).
    pub const PREDICTED_THREADS: [usize; 4] = [480, 960, 1920, 3840];

    pub fn validate(&self) -> Result<()> {
        if self.threads == 0 {
            return Err(Error::Config("threads must be >= 1".into()));
        }
        if self.train_images == 0 {
            return Err(Error::Config("train_images must be >= 1".into()));
        }
        if self.epochs == 0 {
            return Err(Error::Config("epochs must be >= 1".into()));
        }
        Ok(())
    }

    /// Per-thread training chunk (the slowest worker's share): ⌈i/p⌉.
    pub fn train_chunk(&self) -> usize {
        self.train_images.div_ceil(self.threads)
    }

    /// Per-thread test chunk: ⌈it/p⌉.
    pub fn test_chunk(&self) -> usize {
        self.test_images.div_ceil(self.threads)
    }

    pub fn with_threads(mut self, p: usize) -> Self {
        self.threads = p;
        self
    }

    pub fn with_epochs(mut self, ep: usize) -> Self {
        self.epochs = ep;
        self
    }

    pub fn with_images(mut self, i: usize, it: usize) -> Self {
        self.train_images = i;
        self.test_images = it;
        self
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig::paper_default("small", 240)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_table2() {
        let small = RunConfig::paper_default("small", 240);
        assert_eq!(small.train_images, 60_000);
        assert_eq!(small.test_images, 10_000);
        assert_eq!(small.epochs, 70);
        assert_eq!(RunConfig::paper_default("medium", 1).epochs, 70);
        assert_eq!(RunConfig::paper_default("large", 1).epochs, 15);
    }

    #[test]
    fn chunk_is_ceiling_division() {
        let rc = RunConfig::paper_default("small", 480);
        assert_eq!(rc.train_chunk(), 125);
        assert_eq!(rc.test_chunk(), 21); // ceil(10000/480)
        let rc1 = rc.with_threads(7);
        assert_eq!(rc1.train_chunk(), 8572); // ceil(60000/7)
    }

    #[test]
    fn validation_rejects_zeroes() {
        assert!(RunConfig { threads: 0, ..Default::default() }.validate().is_err());
        assert!(RunConfig { train_images: 0, ..Default::default() }.validate().is_err());
        assert!(RunConfig { epochs: 0, ..Default::default() }.validate().is_err());
        assert!(RunConfig::default().validate().is_ok());
    }
}
