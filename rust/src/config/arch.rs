//! CNN architecture specifications (paper Fig. 2 + custom JSON stacks).
//!
//! The three built-in architectures are reconstructed so that every quantity
//! quoted in the Fig. 2 captions holds exactly (verified by unit tests here
//! and mirrored by `python/tests/test_model.py` on the JAX side):
//!
//! * **small**  — I(29²) → C(5 maps, 4×4) → M(2) → O(10)
//! * **medium** — I(29²) → C(20, 4×4) → M(2) → C(40, 5×5) → M(3) → F(150) → O(10)
//! * **large**  — I(29²) → C(20, 4×4) → M(2) → C(60, 3×3) → C(100, 6×6) → M(2) → F(150) → O(10)

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Input image side (MNIST 28×28 padded to 29×29, as in Cireşan's code).
pub const INPUT_HW: usize = 29;
/// Output classes (MNIST digits).
pub const NUM_CLASSES: usize = 10;

/// One layer of a CNN stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerSpec {
    /// Convolutional layer: `maps` feature maps, `kernel`×`kernel` receptive
    /// field, valid padding, stride 1, tanh activation.
    Conv { maps: usize, kernel: usize },
    /// Non-overlapping max pooling with window `window`×`window`.
    Pool { window: usize },
    /// Fully connected layer with `units` neurons (tanh unless `last`).
    Dense { units: usize },
}

/// A complete architecture: name + layer stack over the 29×29 input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArchSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
}

/// Resolved static shape of one layer after the shape walk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerShape {
    pub spec: ResolvedLayer,
    /// Neurons in this layer (maps × hw² for spatial layers).
    pub neurons: usize,
    /// Trainable weights incl. biases (0 for pool).
    pub weights: usize,
}

/// A layer with its input/output geometry resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolvedLayer {
    Input { hw: usize },
    Conv { maps: usize, kernel: usize, in_maps: usize, in_hw: usize, out_hw: usize },
    Pool { window: usize, maps: usize, in_hw: usize, out_hw: usize },
    Dense { units: usize, fan_in: usize, last: bool },
}

impl ArchSpec {
    /// The paper's small CNN (Fig. 2a).
    pub fn small() -> Self {
        ArchSpec {
            name: "small".into(),
            layers: vec![
                LayerSpec::Conv { maps: 5, kernel: 4 },
                LayerSpec::Pool { window: 2 },
                LayerSpec::Dense { units: NUM_CLASSES },
            ],
        }
    }

    /// The paper's medium CNN (Fig. 2b).
    pub fn medium() -> Self {
        ArchSpec {
            name: "medium".into(),
            layers: vec![
                LayerSpec::Conv { maps: 20, kernel: 4 },
                LayerSpec::Pool { window: 2 },
                LayerSpec::Conv { maps: 40, kernel: 5 },
                LayerSpec::Pool { window: 3 },
                LayerSpec::Dense { units: 150 },
                LayerSpec::Dense { units: NUM_CLASSES },
            ],
        }
    }

    /// The paper's large CNN (Fig. 2c).
    pub fn large() -> Self {
        ArchSpec {
            name: "large".into(),
            layers: vec![
                LayerSpec::Conv { maps: 20, kernel: 4 },
                LayerSpec::Pool { window: 2 },
                LayerSpec::Conv { maps: 60, kernel: 3 },
                LayerSpec::Conv { maps: 100, kernel: 6 },
                LayerSpec::Pool { window: 2 },
                LayerSpec::Dense { units: 150 },
                LayerSpec::Dense { units: NUM_CLASSES },
            ],
        }
    }

    /// All three paper architectures, in size order.
    pub fn paper_archs() -> Vec<ArchSpec> {
        vec![Self::small(), Self::medium(), Self::large()]
    }

    /// Look up a paper architecture by name.
    pub fn by_name(name: &str) -> Result<ArchSpec> {
        match name {
            "small" => Ok(Self::small()),
            "medium" => Ok(Self::medium()),
            "large" => Ok(Self::large()),
            other => Err(Error::Config(format!(
                "unknown architecture {other:?} (expected small|medium|large, \
                 or load a custom stack with ArchSpec::from_json)"
            ))),
        }
    }

    /// Load a custom architecture from JSON, e.g.
    /// `{"name":"tiny","layers":[{"type":"conv","maps":3,"kernel":4}, ...]}`.
    pub fn from_json(json: &str) -> Result<ArchSpec> {
        let v = Json::parse(json)?;
        let name = v
            .expect("name")?
            .as_str()
            .ok_or_else(|| Error::Json("name must be a string".into()))?
            .to_string();
        let mut layers = Vec::new();
        let layer_list = v
            .expect("layers")?
            .as_arr()
            .ok_or_else(|| Error::Json("layers must be an array".into()))?;
        for (i, l) in layer_list.iter().enumerate() {
            let ty = l
                .expect("type")?
                .as_str()
                .ok_or_else(|| Error::Json(format!("layer {i}: type must be a string")))?;
            let field = |key: &str| -> Result<usize> {
                l.expect(key)?.as_usize().ok_or_else(|| {
                    Error::Json(format!("layer {i}: {key} must be a non-negative integer"))
                })
            };
            layers.push(match ty {
                "conv" => LayerSpec::Conv { maps: field("maps")?, kernel: field("kernel")? },
                "pool" => LayerSpec::Pool { window: field("window")? },
                "dense" => LayerSpec::Dense { units: field("units")? },
                other => {
                    return Err(Error::Json(format!(
                        "layer {i}: unknown type {other:?} (conv|pool|dense)"
                    )))
                }
            });
        }
        let spec = ArchSpec { name, layers };
        spec.validate()?;
        Ok(spec)
    }

    /// Serialize to the JSON schema accepted by [`ArchSpec::from_json`].
    pub fn to_json(&self) -> String {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| match *l {
                LayerSpec::Conv { maps, kernel } => Json::obj(vec![
                    ("type", Json::str("conv")),
                    ("maps", Json::num(maps as f64)),
                    ("kernel", Json::num(kernel as f64)),
                ]),
                LayerSpec::Pool { window } => Json::obj(vec![
                    ("type", Json::str("pool")),
                    ("window", Json::num(window as f64)),
                ]),
                LayerSpec::Dense { units } => Json::obj(vec![
                    ("type", Json::str("dense")),
                    ("units", Json::num(units as f64)),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("layers", Json::Arr(layers)),
        ])
        .emit()
    }

    /// Static shape walk: resolve every layer's geometry over the 29×29
    /// input. Fails if a layer does not fit (kernel larger than input,
    /// pooling window not dividing the map, dense before spatial collapse
    /// is fine — it flattens).
    pub fn shapes(&self) -> Result<Vec<LayerShape>> {
        let mut out = vec![LayerShape {
            spec: ResolvedLayer::Input { hw: INPUT_HW },
            neurons: INPUT_HW * INPUT_HW,
            weights: 0,
        }];
        let mut maps = 1usize;
        let mut hw = INPUT_HW;
        let mut flat: Option<usize> = None;

        for (idx, layer) in self.layers.iter().enumerate() {
            let last = idx + 1 == self.layers.len();
            match *layer {
                LayerSpec::Conv { maps: m, kernel: k } => {
                    if flat.is_some() {
                        return Err(Error::Config(format!(
                            "{}: conv layer {idx} after dense layer", self.name
                        )));
                    }
                    if k == 0 || k > hw {
                        return Err(Error::Config(format!(
                            "{}: conv layer {idx} kernel {k} does not fit {hw}×{hw}",
                            self.name
                        )));
                    }
                    if m == 0 {
                        return Err(Error::Config(format!(
                            "{}: conv layer {idx} has zero maps", self.name
                        )));
                    }
                    let out_hw = hw - k + 1;
                    out.push(LayerShape {
                        spec: ResolvedLayer::Conv {
                            maps: m, kernel: k, in_maps: maps, in_hw: hw, out_hw,
                        },
                        neurons: m * out_hw * out_hw,
                        weights: m * (maps * k * k + 1),
                    });
                    maps = m;
                    hw = out_hw;
                }
                LayerSpec::Pool { window: w } => {
                    if flat.is_some() {
                        return Err(Error::Config(format!(
                            "{}: pool layer {idx} after dense layer", self.name
                        )));
                    }
                    if w == 0 || hw % w != 0 {
                        return Err(Error::Config(format!(
                            "{}: pool layer {idx} window {w} does not divide {hw}",
                            self.name
                        )));
                    }
                    let out_hw = hw / w;
                    out.push(LayerShape {
                        spec: ResolvedLayer::Pool { window: w, maps, in_hw: hw, out_hw },
                        neurons: maps * out_hw * out_hw,
                        weights: 0,
                    });
                    hw = out_hw;
                }
                LayerSpec::Dense { units } => {
                    if units == 0 {
                        return Err(Error::Config(format!(
                            "{}: dense layer {idx} has zero units", self.name
                        )));
                    }
                    let fan_in = flat.unwrap_or(maps * hw * hw);
                    out.push(LayerShape {
                        spec: ResolvedLayer::Dense { units, fan_in, last },
                        neurons: units,
                        weights: fan_in * units + units,
                    });
                    flat = Some(units);
                }
            }
        }

        match out.last().map(|l| l.spec) {
            Some(ResolvedLayer::Dense { units, .. }) if units == NUM_CLASSES => Ok(out),
            _ => Err(Error::Config(format!(
                "{}: network must end in a dense layer with {NUM_CLASSES} units",
                self.name
            ))),
        }
    }

    /// Validate without keeping the shapes.
    pub fn validate(&self) -> Result<()> {
        self.shapes().map(|_| ())
    }

    /// Total trainable weights (incl. biases) across all layers.
    pub fn total_weights(&self) -> Result<usize> {
        Ok(self.shapes()?.iter().map(|l| l.weights).sum())
    }

    /// Total neurons across all layers (incl. input).
    pub fn total_neurons(&self) -> Result<usize> {
        Ok(self.shapes()?.iter().map(|l| l.neurons).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_matches_fig2a_caption() {
        let shapes = ArchSpec::small().shapes().unwrap();
        // "the first convolutional layer has 5 maps, 3380 neurons, uses a
        //  kernel size of 4x4, a map size of 26x26 and 85 weights"
        let conv = &shapes[1];
        assert_eq!(conv.neurons, 3380);
        assert_eq!(conv.weights, 85);
        match conv.spec {
            ResolvedLayer::Conv { maps, kernel, out_hw, .. } => {
                assert_eq!(maps, 5);
                assert_eq!(kernel, 4);
                assert_eq!(out_hw, 26);
            }
            _ => panic!("expected conv"),
        }
    }

    #[test]
    fn medium_matches_fig2b_caption() {
        let shapes = ArchSpec::medium().shapes().unwrap();
        let conv = &shapes[1];
        assert_eq!(conv.neurons, 13520);
        assert_eq!(conv.weights, 340);
    }

    #[test]
    fn large_matches_fig2c_caption() {
        let shapes = ArchSpec::large().shapes().unwrap();
        // "the last convolutional layer has 100 maps, 3,600 neurons, a 6x6
        //  kernel, a map size of 6x6 and 216,100 weights"
        let last_conv = shapes
            .iter()
            .filter(|l| matches!(l.spec, ResolvedLayer::Conv { .. }))
            .next_back()
            .unwrap();
        assert_eq!(last_conv.neurons, 3600);
        assert_eq!(last_conv.weights, 216_100);
        match last_conv.spec {
            ResolvedLayer::Conv { maps, kernel, out_hw, .. } => {
                assert_eq!(maps, 100);
                assert_eq!(kernel, 6);
                assert_eq!(out_hw, 6);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn input_layer_841_neurons() {
        for arch in ArchSpec::paper_archs() {
            assert_eq!(arch.shapes().unwrap()[0].neurons, 841, "{}", arch.name);
        }
    }

    #[test]
    fn output_layer_10_neurons() {
        for arch in ArchSpec::paper_archs() {
            assert_eq!(arch.shapes().unwrap().last().unwrap().neurons, 10);
        }
    }

    #[test]
    fn sizes_strictly_ordered() {
        let w: Vec<usize> = ArchSpec::paper_archs()
            .iter()
            .map(|a| a.total_weights().unwrap())
            .collect();
        assert!(w[0] < w[1] && w[1] < w[2], "{w:?}");
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ["small", "medium", "large"] {
            assert_eq!(ArchSpec::by_name(name).unwrap().name, name);
        }
        assert!(ArchSpec::by_name("huge").is_err());
    }

    #[test]
    fn custom_json_arch() {
        let json = r#"{"name":"tiny","layers":[
            {"type":"conv","maps":3,"kernel":4},
            {"type":"pool","window":2},
            {"type":"dense","units":10}]}"#;
        let spec = ArchSpec::from_json(json).unwrap();
        assert_eq!(spec.name, "tiny");
        assert_eq!(spec.shapes().unwrap().len(), 4);
    }

    #[test]
    fn rejects_kernel_too_large() {
        let spec = ArchSpec {
            name: "bad".into(),
            layers: vec![
                LayerSpec::Conv { maps: 2, kernel: 40 },
                LayerSpec::Dense { units: 10 },
            ],
        };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn rejects_nondividing_pool() {
        let spec = ArchSpec {
            name: "bad".into(),
            layers: vec![
                LayerSpec::Conv { maps: 2, kernel: 4 }, // 26×26
                LayerSpec::Pool { window: 4 },          // 26 % 4 != 0
                LayerSpec::Dense { units: 10 },
            ],
        };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn rejects_conv_after_dense() {
        let spec = ArchSpec {
            name: "bad".into(),
            layers: vec![
                LayerSpec::Dense { units: 30 },
                LayerSpec::Conv { maps: 2, kernel: 3 },
                LayerSpec::Dense { units: 10 },
            ],
        };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn rejects_wrong_output_width() {
        let spec = ArchSpec {
            name: "bad".into(),
            layers: vec![LayerSpec::Dense { units: 7 }],
        };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn rejects_zero_maps_units_window() {
        for layers in [
            vec![LayerSpec::Conv { maps: 0, kernel: 3 }, LayerSpec::Dense { units: 10 }],
            vec![LayerSpec::Pool { window: 0 }, LayerSpec::Dense { units: 10 }],
            vec![LayerSpec::Dense { units: 0 }, LayerSpec::Dense { units: 10 }],
        ] {
            let spec = ArchSpec { name: "bad".into(), layers };
            assert!(spec.validate().is_err());
        }
    }

    #[test]
    fn serde_roundtrip() {
        let arch = ArchSpec::medium();
        let json = arch.to_json();
        assert_eq!(ArchSpec::from_json(&json).unwrap(), arch);
    }
}
