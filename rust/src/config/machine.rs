//! Machine model configuration (paper Section III + Table III).
//!
//! Defaults describe the Intel Xeon Phi 7120P used in the paper: 61 cores at
//! 1.238 GHz, 4 hardware threads per core scheduled round-robin, 512-bit
//! SIMD (16 f32 lanes), 16 GDDR memory channels (352 GB/s aggregate peak),
//! per-core 32 KB L1 / 512 KB L2 kept coherent over a bidirectional ring.

/// Static description of one MIC processor.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    /// Marketing / model name (reporting only).
    pub name: String,
    /// Physical cores.
    pub cores: usize,
    /// Hardware threads per core (round-robin issue).
    pub threads_per_core: usize,
    /// Core clock in Hz (paper uses 1.238 GHz in the model, Table III).
    pub clock_hz: f64,
    /// SIMD lanes for f32 (512-bit / 32-bit).
    pub simd_lanes: usize,
    /// GDDR memory channels.
    pub memory_channels: usize,
    /// Aggregate peak memory bandwidth, bytes/s.
    pub memory_bw_bytes: f64,
    /// L1 data cache per core, bytes.
    pub l1_bytes: usize,
    /// L2 cache per core, bytes.
    pub l2_bytes: usize,
    /// CPI ladder indexed by threads-resident-per-core (1-based: `cpi[0]`
    /// is 1 thread/core). Paper Table III: 1–2 threads CPI 1, 3 → 1.5,
    /// 4 → 2 ("each thread gets to execute two instructions every fourth
    /// cycle").
    pub cpi_ladder: Vec<f64>,
}

impl MachineConfig {
    /// The paper's evaluation platform: Intel Xeon Phi 7120P (KNC).
    pub fn xeon_phi_7120p() -> Self {
        MachineConfig {
            name: "Intel Xeon Phi 7120P (KNC)".into(),
            cores: 61,
            threads_per_core: 4,
            clock_hz: 1.238e9,
            simd_lanes: 16,
            memory_channels: 16,
            memory_bw_bytes: 352.0e9,
            l1_bytes: 32 * 1024,
            l2_bytes: 512 * 1024,
            cpi_ladder: vec![1.0, 1.0, 1.5, 2.0],
        }
    }

    /// A 7120P variant at a different core clock (the sweep machine
    /// axis: `repro sweep --clock-ghz` and the `clock_ghz` spec key).
    pub fn xeon_phi_7120p_at_ghz(ghz: f64) -> Self {
        let mut m = Self::xeon_phi_7120p();
        m.clock_hz = ghz * 1e9;
        m.name = format!("7120P@{ghz}GHz");
        m
    }

    /// Maximum hardware threads (244 on the 7120P).
    pub fn max_hw_threads(&self) -> usize {
        self.cores * self.threads_per_core
    }

    /// CPI for a core with `occupancy` resident threads. Occupancies above
    /// the ladder saturate at the last entry (the paper's model does the
    /// same when predicting beyond 244 threads: CPI stays at 2).
    pub fn cpi(&self, occupancy: usize) -> f64 {
        if occupancy == 0 {
            return self.cpi_ladder[0];
        }
        let idx = occupancy.min(self.cpi_ladder.len());
        self.cpi_ladder[idx - 1]
    }

    /// Threads resident per core when `p` threads are spread round-robin
    /// over the cores (the paper's affinity: balanced/scatter). For
    /// `p > max_hw_threads`, hardware occupancy saturates at
    /// `threads_per_core` and software threads oversubscribe.
    pub fn occupancy(&self, p: usize) -> usize {
        if p == 0 {
            return 0;
        }
        p.div_ceil(self.cores).min(self.threads_per_core)
    }

    /// Software oversubscription factor: how many software threads share
    /// each hardware thread (1.0 up to 244, then p/244).
    pub fn oversubscription(&self, p: usize) -> f64 {
        let max = self.max_hw_threads();
        if p <= max {
            1.0
        } else {
            p as f64 / max as f64
        }
    }

    /// Single-thread peak f32 FLOP/s (fma counted as 2): lanes × 2 × clock.
    pub fn peak_flops_thread(&self) -> f64 {
        self.simd_lanes as f64 * 2.0 * self.clock_hz
    }
}

impl Default for MachineConfig {
    fn default() -> Self {
        Self::xeon_phi_7120p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phi_7120p_has_244_hw_threads() {
        assert_eq!(MachineConfig::xeon_phi_7120p().max_hw_threads(), 244);
    }

    #[test]
    fn cpi_ladder_matches_table3() {
        let m = MachineConfig::xeon_phi_7120p();
        assert_eq!(m.cpi(1), 1.0);
        assert_eq!(m.cpi(2), 1.0);
        assert_eq!(m.cpi(3), 1.5);
        assert_eq!(m.cpi(4), 2.0);
        // Saturates beyond the ladder.
        assert_eq!(m.cpi(7), 2.0);
    }

    #[test]
    fn occupancy_round_robin() {
        let m = MachineConfig::xeon_phi_7120p();
        assert_eq!(m.occupancy(1), 1);
        assert_eq!(m.occupancy(61), 1);
        assert_eq!(m.occupancy(62), 2);
        assert_eq!(m.occupancy(120), 2);
        assert_eq!(m.occupancy(122), 2);
        assert_eq!(m.occupancy(180), 3);
        assert_eq!(m.occupancy(240), 4);
        // Beyond hardware: occupancy saturates.
        assert_eq!(m.occupancy(3840), 4);
    }

    #[test]
    fn oversubscription_kicks_in_past_244() {
        let m = MachineConfig::xeon_phi_7120p();
        assert_eq!(m.oversubscription(240), 1.0);
        assert_eq!(m.oversubscription(244), 1.0);
        assert!((m.oversubscription(488) - 2.0).abs() < 1e-12);
        assert!((m.oversubscription(3840) - 3840.0 / 244.0).abs() < 1e-12);
    }

    #[test]
    fn peak_flops_about_2_tflops_chipwide() {
        // 61 cores × 2 ops × 16 lanes × 1.238 GHz ≈ 2.4 TFLOP/s single
        // precision (the paper quotes "two teraFLOP/s of single precision").
        let m = MachineConfig::xeon_phi_7120p();
        let chip = m.peak_flops_thread() * m.cores as f64;
        assert!(chip > 2.0e12 && chip < 2.6e12, "{chip}");
    }
}
