//! `micdl::calibration` — the parameter-estimation subsystem.
//!
//! The paper's two models differ only in *how their parameter values are
//! estimated*: minimal measurement for model (a) (op counts + one
//! calibrated OperationFactor), measurement-heavy for model (b)
//! (per-image times measured directly). This module owns that entire
//! estimation step behind one API:
//!
//! ```text
//! Calibration::new(ParamSource)            which discipline
//!     .resolve(arch, sim) -> ModelParams   every resolved constant
//! ```
//!
//! A [`Calibrator`] turns an architecture plus a simulator configuration
//! (the stand-in for the paper's testbed) into a full [`ModelParams`]:
//! the Table V operands for strategy (a) ([`StrategyAParams`]), the
//! Table VI measured times for strategy (b) ([`StrategyBParams`]), and a
//! shared memoized [`ContentionSource`] for the `T_mem` term. Three
//! implementations cover the estimation disciplines ([`source`]):
//!
//! * [`PaperSource`] — the published Tables II–IV/VII/VIII constants
//!   (exact reproduction; what [`crate::perfmodel::ParamSource::Paper`]
//!   maps to);
//! * [`ProbeSource`] — times measured from micsim probes, the way the
//!   authors measured model (b) on hardware;
//! * [`ComputedSource`] — computed op counts with the op-count→cycles
//!   mapping *fitted* to the probes: the closed-loop parameterization of
//!   strategy (a) (what [`crate::perfmodel::ParamSource::Simulator`]
//!   maps to).
//!
//! The [`Calibration`] facade memoizes resolutions per (architecture,
//! [`SimConfig::fingerprint`]) — the sweep cache resolves once per
//! (arch, resolved simulator) and both strategies' models are built from
//! the same [`ModelParams`], sharing one contention-probe calibration.
//!
//! ```
//! use micdl::calibration::Calibration;
//! use micdl::config::ArchSpec;
//! use micdl::perfmodel::ParamSource;
//! use micdl::simulator::SimConfig;
//!
//! let cal = Calibration::new(ParamSource::Paper);
//! let params = cal.resolve(&ArchSpec::small(), &SimConfig::default()).unwrap();
//! assert_eq!(params.strategy_b().unwrap().t_fprop_s, 1.45e-3); // Table III
//! ```

#![warn(missing_docs)]

pub mod contention;
pub mod source;

pub use contention::ContentionSource;
pub use source::{ComputedSource, PaperSource, ProbeSource};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::{ArchSpec, MachineConfig};
use crate::error::{Error, Result};
use crate::perfmodel::ParamSource;
use crate::simulator::SimConfig;

/// Strategy (a)'s resolved operands — the Table V terms
/// (see [`crate::perfmodel::StrategyA`] for the formula they feed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyAParams {
    /// `FProp` operations per image (Table VII totals, or computed).
    pub fprop_ops: f64,
    /// `BProp` operations per image (Table VIII totals, or computed).
    pub bprop_ops: f64,
    /// `Prep` operation estimate (Table II, or back-derived from the
    /// probed preparation time).
    pub prep_ops: f64,
    /// The OperationFactor `OF` scaling every compute term (Table III's
    /// published value, or fitted against the measuring simulator).
    pub operation_factor: f64,
}

/// Strategy (b)'s resolved operands — the Table VI measured times.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyBParams {
    /// Measured forward time per image at one thread, seconds.
    pub t_fprop_s: f64,
    /// Measured backward time per image at one thread, seconds.
    pub t_bprop_s: f64,
    /// Measured preparation time, seconds.
    pub t_prep_s: f64,
}

/// Every model parameter one calibrator resolved for one (architecture,
/// simulator configuration) pair — what both strategies construct from.
#[derive(Debug, Clone)]
pub struct ModelParams {
    /// Architecture the parameters were resolved for.
    pub arch: String,
    /// Name of the calibrator that produced them
    /// ([`Calibrator::name`]).
    pub calibrator: &'static str,
    /// Machine the CPI/clock terms evaluate against (the resolved
    /// simulator's machine).
    pub machine: MachineConfig,
    /// Strategy (a) operands — `None` when the calibrator cannot
    /// estimate them (e.g. [`PaperSource`] on a custom architecture with
    /// no published op counts).
    pub a: Option<StrategyAParams>,
    /// Strategy (b) operands — `None` only for calibrators that resolve
    /// no measured times (none of the shipped ones).
    pub b: Option<StrategyBParams>,
    /// Shared MemoryContention(p) resolver; clones share one memoized
    /// probe calibration.
    pub contention: ContentionSource,
}

impl ModelParams {
    /// The strategy-(a) operands, or a configuration error naming the
    /// calibrator that could not estimate them.
    pub fn strategy_a(&self) -> Result<StrategyAParams> {
        self.a.ok_or_else(|| {
            Error::Config(format!(
                "calibrator {:?} resolves no strategy-(a) parameters for \
                 arch {:?} (no published op counts; use --params sim)",
                self.calibrator, self.arch
            ))
        })
    }

    /// The strategy-(b) operands, or a configuration error.
    pub fn strategy_b(&self) -> Result<StrategyBParams> {
        self.b.ok_or_else(|| {
            Error::Config(format!(
                "calibrator {:?} resolves no strategy-(b) parameters for arch {:?}",
                self.calibrator, self.arch
            ))
        })
    }
}

/// One parameter-estimation discipline: resolve every model constant for
/// an architecture against a simulator configuration.
pub trait Calibrator: Send + Sync {
    /// Short identifier for reports and error messages.
    fn name(&self) -> &'static str;
    /// Resolve the full parameter set. Deterministic: equal inputs
    /// (architecture, [`SimConfig::fingerprint`]) give bit-identical
    /// parameters.
    fn resolve(&self, arch: &ArchSpec, sim: &SimConfig) -> Result<ModelParams>;
}

/// The calibration facade: maps a [`ParamSource`] to its calibrator and
/// memoizes resolutions per (architecture, simulator fingerprint).
///
/// [`ParamSource::Paper`] resolves through [`PaperSource`];
/// [`ParamSource::Simulator`] through [`ComputedSource`] (which probes
/// via [`ProbeSource`] internally) — the single place the mapping
/// lives, so the model constructors and the sweep cache cannot drift.
pub struct Calibration {
    source: ParamSource,
    calibrator: Box<dyn Calibrator>,
    memo: Mutex<HashMap<(String, u64), Arc<ModelParams>>>,
    resolutions: AtomicU64,
}

impl std::fmt::Debug for Calibration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Calibration")
            .field("source", &self.source)
            .field("calibrator", &self.calibrator.name())
            .field("resolutions", &self.resolutions())
            .finish()
    }
}

impl Calibration {
    /// The calibration for one parameter source.
    pub fn new(source: ParamSource) -> Calibration {
        let calibrator: Box<dyn Calibrator> = match source {
            ParamSource::Paper => Box::new(PaperSource),
            ParamSource::Simulator => Box::new(ComputedSource),
        };
        Calibration {
            source,
            calibrator,
            memo: Mutex::new(HashMap::new()),
            resolutions: AtomicU64::new(0),
        }
    }

    /// The parameter source this calibration maps.
    pub fn source(&self) -> ParamSource {
        self.source
    }

    /// The underlying calibrator's name ("paper" / "computed").
    pub fn calibrator_name(&self) -> &'static str {
        self.calibrator.name()
    }

    /// Resolve (memoized) the parameters for one architecture against
    /// one simulator configuration. Entries are keyed by (architecture
    /// name, [`SimConfig::fingerprint`]), so any simulator change is a
    /// fresh resolution and equal configurations share one — including
    /// between the (a) and (b) models of a sweep cell.
    ///
    /// Lookups are lock-drop-compute-insert (the sweep-cache policy):
    /// two workers missing the same key concurrently may both run the
    /// calibrator — every resolution is deterministic and the first
    /// insert wins, so results stay bit-identical;
    /// [`Calibration::resolutions`] counts actual runs, which is
    /// exactly one per key only without concurrent cold misses.
    pub fn resolve(&self, arch: &ArchSpec, sim: &SimConfig) -> Result<Arc<ModelParams>> {
        let key = (arch.name.clone(), sim.fingerprint());
        if let Some(params) = self.memo.lock().unwrap().get(&key) {
            return Ok(Arc::clone(params));
        }
        let built = Arc::new(self.calibrator.resolve(arch, sim)?);
        self.resolutions.fetch_add(1, Ordering::Relaxed);
        Ok(Arc::clone(
            self.memo.lock().unwrap().entry(key).or_insert(built),
        ))
    }

    /// How many resolutions actually ran (memo misses) — the
    /// probe-memoization observability hook `bench_sweep` and the tests
    /// pin.
    pub fn resolutions(&self) -> u64 {
        self.resolutions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_source_maps_to_the_documented_calibrators() {
        assert_eq!(Calibration::new(ParamSource::Paper).calibrator_name(), "paper");
        assert_eq!(
            Calibration::new(ParamSource::Simulator).calibrator_name(),
            "computed"
        );
    }

    #[test]
    fn resolve_is_memoized_per_arch_and_fingerprint() {
        let cal = Calibration::new(ParamSource::Simulator);
        let arch = ArchSpec::small();
        let sim = SimConfig::default();
        assert_eq!(cal.resolutions(), 0, "resolution must be lazy");
        let first = cal.resolve(&arch, &sim).unwrap();
        let second = cal.resolve(&arch, &sim).unwrap();
        assert!(Arc::ptr_eq(&first, &second), "equal inputs share one entry");
        assert_eq!(cal.resolutions(), 1);
        // A different simulator is a fresh resolution...
        let mut slower = SimConfig::default();
        slower.fwd_cycles_per_op *= 2.0;
        let slow = cal.resolve(&arch, &slower).unwrap();
        assert!(!Arc::ptr_eq(&first, &slow));
        assert_eq!(cal.resolutions(), 2);
        // ...and so is a different architecture.
        cal.resolve(&ArchSpec::medium(), &sim).unwrap();
        assert_eq!(cal.resolutions(), 3);
    }

    #[test]
    fn memoized_params_bit_identical_to_fresh_resolution() {
        let cal = Calibration::new(ParamSource::Simulator);
        let arch = ArchSpec::large();
        let sim = SimConfig::default();
        let memoized = cal.resolve(&arch, &sim).unwrap();
        let fresh = ComputedSource.resolve(&arch, &sim).unwrap();
        let (ma, fa) = (
            memoized.strategy_a().unwrap(),
            fresh.strategy_a().unwrap(),
        );
        assert_eq!(ma.operation_factor.to_bits(), fa.operation_factor.to_bits());
        assert_eq!(ma.prep_ops.to_bits(), fa.prep_ops.to_bits());
        let (mb, fb) = (
            memoized.strategy_b().unwrap(),
            fresh.strategy_b().unwrap(),
        );
        assert_eq!(mb.t_fprop_s.to_bits(), fb.t_fprop_s.to_bits());
    }

    #[test]
    fn missing_params_error_names_the_calibrator() {
        let mut arch = ArchSpec::small();
        arch.name = "custom".into();
        let cal = Calibration::new(ParamSource::Paper);
        let params = cal.resolve(&arch, &SimConfig::default()).unwrap();
        let err = params.strategy_a().unwrap_err().to_string();
        assert!(err.contains("paper") && err.contains("custom"), "{err}");
    }
}
