//! `micdl::calibration` — the parameter-estimation subsystem.
//!
//! The paper's two models differ only in *how their parameter values are
//! estimated*: minimal measurement for model (a) (op counts + one
//! calibrated OperationFactor), measurement-heavy for model (b)
//! (per-image times measured directly). This module owns that entire
//! estimation step behind one API:
//!
//! ```text
//! Calibration::new(ParamSource)            which discipline
//!     .resolve(arch, sim) -> ModelParams   every resolved constant
//! ```
//!
//! A [`Calibrator`] turns an architecture plus a simulator configuration
//! (the stand-in for the paper's testbed) into a full [`ModelParams`]:
//! the Table V operands for strategy (a) ([`StrategyAParams`]), the
//! Table VI measured times for strategy (b) ([`StrategyBParams`]), and a
//! shared memoized [`ContentionSource`] for the `T_mem` term. Three
//! implementations cover the estimation disciplines ([`source`]):
//!
//! * [`PaperSource`] — the published Tables II–IV/VII/VIII constants
//!   (exact reproduction; what [`crate::perfmodel::ParamSource::Paper`]
//!   maps to);
//! * [`ProbeSource`] — times measured from micsim probes, the way the
//!   authors measured model (b) on hardware;
//! * [`ComputedSource`] — computed op counts with the op-count→cycles
//!   mapping *fitted* to the probes: the closed-loop parameterization of
//!   strategy (a) (what [`crate::perfmodel::ParamSource::Simulator`]
//!   maps to).
//!
//! The [`Calibration`] facade memoizes resolutions per (architecture,
//! [`SimConfig::fingerprint`]) — the sweep cache resolves once per
//! (arch, resolved simulator) and both strategies' models are built from
//! the same [`ModelParams`], sharing one contention-probe calibration.
//!
//! ```
//! use micdl::calibration::Calibration;
//! use micdl::config::ArchSpec;
//! use micdl::perfmodel::ParamSource;
//! use micdl::simulator::SimConfig;
//!
//! let cal = Calibration::new(ParamSource::Paper);
//! let params = cal.resolve(&ArchSpec::small(), &SimConfig::default()).unwrap();
//! assert_eq!(params.strategy_b().unwrap().t_fprop_s, 1.45e-3); // Table III
//! ```

#![warn(missing_docs)]

pub mod contention;
pub mod residual;
pub mod source;

pub use contention::ContentionSource;
pub use residual::{ResidualModel, ResidualSource};
pub use source::{ComputedSource, PaperSource, ProbeSource};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::{ArchSpec, MachineConfig};
use crate::error::{Error, Result};
use crate::lab::{self, Store};
use crate::perfmodel::{ParamSource, PerfModel, StrategyA, StrategyB, StrategyC};
use crate::simulator::SimConfig;
use crate::sweep::Strategy;
use crate::util::json::Json;
use crate::util::memo::Memo;

/// Strategy (a)'s resolved operands — the Table V terms
/// (see [`crate::perfmodel::StrategyA`] for the formula they feed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyAParams {
    /// `FProp` operations per image (Table VII totals, or computed).
    pub fprop_ops: f64,
    /// `BProp` operations per image (Table VIII totals, or computed).
    pub bprop_ops: f64,
    /// `Prep` operation estimate (Table II, or back-derived from the
    /// probed preparation time).
    pub prep_ops: f64,
    /// The OperationFactor `OF` scaling every compute term (Table III's
    /// published value, or fitted against the measuring simulator).
    pub operation_factor: f64,
}

/// Strategy (b)'s resolved operands — the Table VI measured times.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyBParams {
    /// Measured forward time per image at one thread, seconds.
    pub t_fprop_s: f64,
    /// Measured backward time per image at one thread, seconds.
    pub t_bprop_s: f64,
    /// Measured preparation time, seconds.
    pub t_prep_s: f64,
}

/// Every model parameter one calibrator resolved for one (architecture,
/// simulator configuration) pair — what both strategies construct from.
#[derive(Debug, Clone)]
pub struct ModelParams {
    /// Architecture the parameters were resolved for.
    pub arch: String,
    /// Name of the calibrator that produced them
    /// ([`Calibrator::name`]).
    pub calibrator: &'static str,
    /// Machine the CPI/clock terms evaluate against (the resolved
    /// simulator's machine).
    pub machine: MachineConfig,
    /// Strategy (a) operands — `None` when the calibrator cannot
    /// estimate them (e.g. [`PaperSource`] on a custom architecture with
    /// no published op counts).
    pub a: Option<StrategyAParams>,
    /// Strategy (b) operands — `None` only for calibrators that resolve
    /// no measured times (none of the shipped ones).
    pub b: Option<StrategyBParams>,
    /// Shared MemoryContention(p) resolver; clones share one memoized
    /// probe calibration.
    pub contention: ContentionSource,
}

impl ModelParams {
    /// The strategy-(a) operands, or a configuration error naming the
    /// calibrator that could not estimate them.
    pub fn strategy_a(&self) -> Result<StrategyAParams> {
        self.a.ok_or_else(|| {
            Error::Config(format!(
                "calibrator {:?} resolves no strategy-(a) parameters for \
                 arch {:?} (no published op counts; use --params sim)",
                self.calibrator, self.arch
            ))
        })
    }

    /// The strategy-(b) operands, or a configuration error.
    pub fn strategy_b(&self) -> Result<StrategyBParams> {
        self.b.ok_or_else(|| {
            Error::Config(format!(
                "calibrator {:?} resolves no strategy-(b) parameters for arch {:?}",
                self.calibrator, self.arch
            ))
        })
    }
}

/// One parameter-estimation discipline: resolve every model constant for
/// an architecture against a simulator configuration.
pub trait Calibrator: Send + Sync {
    /// Short identifier for reports and error messages.
    fn name(&self) -> &'static str;
    /// Resolve the full parameter set. Deterministic: equal inputs
    /// (architecture, [`SimConfig::fingerprint`]) give bit-identical
    /// parameters.
    fn resolve(&self, arch: &ArchSpec, sim: &SimConfig) -> Result<ModelParams>;
}

/// The calibration facade: maps a [`ParamSource`] to its calibrator and
/// memoizes resolutions per (architecture, simulator fingerprint).
///
/// [`ParamSource::Paper`] resolves through [`PaperSource`];
/// [`ParamSource::Simulator`] through [`ComputedSource`] (which probes
/// via [`ProbeSource`] internally) — the single place the mapping
/// lives, so the model constructors and the sweep cache cannot drift.
pub struct Calibration {
    source: ParamSource,
    calibrator: Box<dyn Calibrator>,
    memo: Memo<(String, u64), Arc<ModelParams>>,
    resolutions: AtomicU64,
    residual: ResidualSource,
    store: Option<Arc<Store>>,
}

impl std::fmt::Debug for Calibration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Calibration")
            .field("source", &self.source)
            .field("calibrator", &self.calibrator.name())
            .field("resolutions", &self.resolutions())
            .finish()
    }
}

impl Calibration {
    /// The calibration for one parameter source.
    pub fn new(source: ParamSource) -> Calibration {
        let calibrator: Box<dyn Calibrator> = match source {
            ParamSource::Paper => Box::new(PaperSource),
            ParamSource::Simulator => Box::new(ComputedSource),
        };
        Calibration {
            source,
            calibrator,
            memo: Memo::new(),
            resolutions: AtomicU64::new(0),
            residual: ResidualSource::new(source),
            store: None,
        }
    }

    /// Attach a lab store: resolutions (and residual fits) are served
    /// from disk when persisted (without counting as calibrator runs /
    /// fits) and written through — with their provenance — when
    /// computed.
    pub fn with_store(mut self, store: Arc<Store>) -> Calibration {
        self.residual.set_store(Arc::clone(&store));
        self.store = Some(store);
        self
    }

    /// The parameter source this calibration maps.
    pub fn source(&self) -> ParamSource {
        self.source
    }

    /// The underlying calibrator's name ("paper" / "computed").
    pub fn calibrator_name(&self) -> &'static str {
        self.calibrator.name()
    }

    /// Resolve (memoized) the parameters for one architecture against
    /// one simulator configuration. Entries are keyed by (architecture
    /// name, [`SimConfig::fingerprint`]), so any simulator change is a
    /// fresh resolution and equal configurations share one — including
    /// between the (a) and (b) models of a sweep cell.
    ///
    /// The memo is single-flight ([`crate::util::memo::Memo`]): a
    /// concurrent cold miss runs the calibrator **exactly once** — the
    /// other workers block on the in-flight resolution and share its
    /// result — so [`Calibration::resolutions`] counts exactly one run
    /// per distinct key on any error-free run, whatever the concurrency.
    /// The store probe and write-through sit inside the same slot:
    /// persisted resolutions rebuild bit-identically without counting as
    /// calibrator runs, and each key is written at most once.
    pub fn resolve(&self, arch: &ArchSpec, sim: &SimConfig) -> Result<Arc<ModelParams>> {
        let key = (arch.name.clone(), sim.fingerprint());
        self.memo.get_or_try_insert_with(key, || {
            // Disk first: a persisted resolution rebuilds bit-identically
            // (parameters are plain f64s that round-trip exactly; machine
            // and contention are derived from the same `sim`) and does
            // not count as a calibrator run.
            if let Some(store) = &self.store {
                let skey = lab::params_key(&arch.name, self.source, sim.fingerprint());
                if let Some(rebuilt) = store
                    .get(lab::Kind::Params, &skey)
                    .and_then(|payload| self.params_from_payload(&payload, arch, sim))
                {
                    return Ok(Arc::new(rebuilt));
                }
            }
            let built = Arc::new(self.calibrator.resolve(arch, sim)?);
            self.resolutions.fetch_add(1, Ordering::Relaxed);
            if let Some(store) = &self.store {
                let skey = lab::params_key(&arch.name, self.source, sim.fingerprint());
                store.put(lab::Kind::Params, &skey, self.params_payload(&built))?;
            }
            Ok(built)
        })
    }

    /// Build a strategy model from this calibration's resolved (and,
    /// with a store attached, persisted) parameters — the single entry
    /// point replacing the `StrategyA/B::{new, with_sim}` constructor
    /// zoo. The (a)/(b) pair for one cell shares one resolution.
    pub fn strategy(
        &self,
        arch: &ArchSpec,
        kind: Strategy,
        sim: &SimConfig,
    ) -> Result<Box<dyn PerfModel + Send + Sync>> {
        let params = self.resolve(arch, sim)?;
        Ok(match kind {
            Strategy::A => Box::new(StrategyA::from_params(&params)?),
            Strategy::B => Box::new(StrategyB::from_params(&params)?),
            Strategy::C => {
                let b = StrategyB::from_params(&params)?;
                let model = self.residual.resolve(arch, sim, &b)?;
                Box::new(StrategyC::new(b, model))
            }
        })
    }

    /// The store payload for a resolution: operands plus provenance
    /// (which calibrator produced them, from which parameter source).
    fn params_payload(&self, params: &ModelParams) -> Json {
        let mut pairs = vec![
            ("arch", Json::str(params.arch.clone())),
            ("calibrator", Json::str(params.calibrator)),
            ("source", Json::str(lab::source_tag(self.source))),
        ];
        if let Some(a) = params.a {
            pairs.push((
                "a",
                Json::obj(vec![
                    ("fprop_ops", Json::num(a.fprop_ops)),
                    ("bprop_ops", Json::num(a.bprop_ops)),
                    ("prep_ops", Json::num(a.prep_ops)),
                    ("operation_factor", Json::num(a.operation_factor)),
                ]),
            ));
        }
        if let Some(b) = params.b {
            pairs.push((
                "b",
                Json::obj(vec![
                    ("t_fprop_s", Json::num(b.t_fprop_s)),
                    ("t_bprop_s", Json::num(b.t_bprop_s)),
                    ("t_prep_s", Json::num(b.t_prep_s)),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    /// Rebuild [`ModelParams`] from a store payload. `None` (forcing a
    /// fresh calibrator run) on any mismatch: wrong arch, unknown
    /// calibrator name, or missing operand fields. The machine and the
    /// contention source are reconstructed from `sim`, which is what the
    /// shipped calibrators derive them from.
    fn params_from_payload(
        &self,
        payload: &Json,
        arch: &ArchSpec,
        sim: &SimConfig,
    ) -> Option<ModelParams> {
        if payload.get("arch")?.as_str()? != arch.name {
            return None;
        }
        if payload.get("source")?.as_str()? != lab::source_tag(self.source) {
            return None;
        }
        let calibrator: &'static str = match payload.get("calibrator")?.as_str()? {
            "paper" => "paper",
            "probe" => "probe",
            "computed" => "computed",
            _ => return None,
        };
        let a = match payload.get("a") {
            Some(o) => Some(StrategyAParams {
                fprop_ops: o.get("fprop_ops")?.as_f64()?,
                bprop_ops: o.get("bprop_ops")?.as_f64()?,
                prep_ops: o.get("prep_ops")?.as_f64()?,
                operation_factor: o.get("operation_factor")?.as_f64()?,
            }),
            None => None,
        };
        let b = match payload.get("b") {
            Some(o) => Some(StrategyBParams {
                t_fprop_s: o.get("t_fprop_s")?.as_f64()?,
                t_bprop_s: o.get("t_bprop_s")?.as_f64()?,
                t_prep_s: o.get("t_prep_s")?.as_f64()?,
            }),
            None => None,
        };
        Some(ModelParams {
            arch: arch.name.clone(),
            calibrator,
            machine: sim.machine.clone(),
            a,
            b,
            contention: ContentionSource::new(arch, self.source).with_sim_config(sim.clone()),
        })
    }

    /// How many resolutions actually ran (memo misses) — the
    /// probe-memoization observability hook `bench_sweep` and the tests
    /// pin.
    pub fn resolutions(&self) -> u64 {
        self.resolutions.load(Ordering::Relaxed)
    }

    /// How many strategy-(c) residual fits actually ran — a separate
    /// counter from [`Calibration::resolutions`], so the existing
    /// resolution pins are untouched by (c) traffic and warm-store
    /// reruns can assert zero refits.
    pub fn residual_fits(&self) -> u64 {
        self.residual.fits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_source_maps_to_the_documented_calibrators() {
        assert_eq!(Calibration::new(ParamSource::Paper).calibrator_name(), "paper");
        assert_eq!(
            Calibration::new(ParamSource::Simulator).calibrator_name(),
            "computed"
        );
    }

    #[test]
    fn resolve_is_memoized_per_arch_and_fingerprint() {
        let cal = Calibration::new(ParamSource::Simulator);
        let arch = ArchSpec::small();
        let sim = SimConfig::default();
        assert_eq!(cal.resolutions(), 0, "resolution must be lazy");
        let first = cal.resolve(&arch, &sim).unwrap();
        let second = cal.resolve(&arch, &sim).unwrap();
        assert!(Arc::ptr_eq(&first, &second), "equal inputs share one entry");
        assert_eq!(cal.resolutions(), 1);
        // A different simulator is a fresh resolution...
        let mut slower = SimConfig::default();
        slower.fwd_cycles_per_op *= 2.0;
        let slow = cal.resolve(&arch, &slower).unwrap();
        assert!(!Arc::ptr_eq(&first, &slow));
        assert_eq!(cal.resolutions(), 2);
        // ...and so is a different architecture.
        cal.resolve(&ArchSpec::medium(), &sim).unwrap();
        assert_eq!(cal.resolutions(), 3);
    }

    #[test]
    fn memoized_params_bit_identical_to_fresh_resolution() {
        let cal = Calibration::new(ParamSource::Simulator);
        let arch = ArchSpec::large();
        let sim = SimConfig::default();
        let memoized = cal.resolve(&arch, &sim).unwrap();
        let fresh = ComputedSource.resolve(&arch, &sim).unwrap();
        let (ma, fa) = (
            memoized.strategy_a().unwrap(),
            fresh.strategy_a().unwrap(),
        );
        assert_eq!(ma.operation_factor.to_bits(), fa.operation_factor.to_bits());
        assert_eq!(ma.prep_ops.to_bits(), fa.prep_ops.to_bits());
        let (mb, fb) = (
            memoized.strategy_b().unwrap(),
            fresh.strategy_b().unwrap(),
        );
        assert_eq!(mb.t_fprop_s.to_bits(), fb.t_fprop_s.to_bits());
    }

    #[test]
    fn store_backed_resolution_bit_identical_and_uncounted() {
        let dir = crate::util::tmp::TempDir::new("cal-store").unwrap();
        let store = Arc::new(Store::open(dir.path()).unwrap());
        let arch = ArchSpec::small();
        let sim = SimConfig::default();
        let writer = Calibration::new(ParamSource::Simulator).with_store(Arc::clone(&store));
        let fresh = writer.resolve(&arch, &sim).unwrap();
        assert_eq!(writer.resolutions(), 1);
        // A new facade over the same store serves the persisted entry
        // without running the calibrator, bit-for-bit.
        let reader = Calibration::new(ParamSource::Simulator).with_store(Arc::clone(&store));
        let served = reader.resolve(&arch, &sim).unwrap();
        assert_eq!(reader.resolutions(), 0, "store hits are not calibrator runs");
        let (fa, sa) = (fresh.strategy_a().unwrap(), served.strategy_a().unwrap());
        assert_eq!(fa.operation_factor.to_bits(), sa.operation_factor.to_bits());
        assert_eq!(fa.prep_ops.to_bits(), sa.prep_ops.to_bits());
        assert_eq!(fa.fprop_ops.to_bits(), sa.fprop_ops.to_bits());
        assert_eq!(fa.bprop_ops.to_bits(), sa.bprop_ops.to_bits());
        let (fb, sb) = (fresh.strategy_b().unwrap(), served.strategy_b().unwrap());
        assert_eq!(fb.t_fprop_s.to_bits(), sb.t_fprop_s.to_bits());
        assert_eq!(fb.t_bprop_s.to_bits(), sb.t_bprop_s.to_bits());
        assert_eq!(fb.t_prep_s.to_bits(), sb.t_prep_s.to_bits());
        assert_eq!(served.calibrator, "computed", "provenance survives the disk trip");
        // A different source never reads another source's entry.
        let paper = Calibration::new(ParamSource::Paper).with_store(Arc::clone(&store));
        paper.resolve(&arch, &sim).unwrap();
        assert_eq!(paper.resolutions(), 1, "source is part of the key");
    }

    #[test]
    fn strategy_facade_matches_from_params() {
        use crate::config::RunConfig;
        let cal = Calibration::new(ParamSource::Simulator);
        let arch = ArchSpec::small();
        let sim = SimConfig::default();
        let a = cal.strategy(&arch, Strategy::A, &sim).unwrap();
        let b = cal.strategy(&arch, Strategy::B, &sim).unwrap();
        assert_eq!(cal.resolutions(), 1, "the (a)/(b) pair shares one resolution");
        assert_eq!(a.name(), "a");
        assert_eq!(b.name(), "b");
        let params = cal.resolve(&arch, &sim).unwrap();
        let run = RunConfig::paper_default("small", 240);
        assert_eq!(
            a.predict(&run).unwrap().total_s.to_bits(),
            StrategyA::from_params(&params)
                .unwrap()
                .predict(&run)
                .unwrap()
                .total_s
                .to_bits()
        );
        assert_eq!(
            b.predict(&run).unwrap().total_s.to_bits(),
            StrategyB::from_params(&params)
                .unwrap()
                .predict(&run)
                .unwrap()
                .total_s
                .to_bits()
        );
    }

    #[test]
    fn missing_params_error_names_the_calibrator() {
        let mut arch = ArchSpec::small();
        arch.name = "custom".into();
        let cal = Calibration::new(ParamSource::Paper);
        let params = cal.resolve(&arch, &SimConfig::default()).unwrap();
        let err = params.strategy_a().unwrap_err().to_string();
        assert!(err.contains("paper") && err.contains("custom"), "{err}");
    }
}
