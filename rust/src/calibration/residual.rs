//! The strategy-(c) residual regressor: a sweep-trained correction on
//! top of strategy (b).
//!
//! The paper's measurement-based model (b) still carries a systematic
//! residual against the measuring simulator — fractional-vs-ceiling
//! chunking, the L2/ring memory effects the closed form lacks, and the
//! oversubscription regime beyond 244 threads. Following the ResPerfNet
//! observation (learn the *residual* of an analytic predictor rather
//! than the time itself), this module fits a small ridge regressor on
//! the log-residual
//!
//! ```text
//! z = ln( measured_execution_s / predicted_b_total_s )
//! ```
//!
//! over a **seeded training grid** ([`training_runs`]): four workload
//! variants (the paper workload, its 2×/4× Table XI scalings, and one
//! [`XorShift64`]-jittered variant) crossed with the Table IV thread
//! ladder. Strategy (c) then predicts `(b)'s total × exp(w · x)`
//! ([`crate::perfmodel::StrategyC`]).
//!
//! Everything is deterministic from `(arch, SimConfig::fingerprint())`:
//! the grid derives from `SimConfig::seed ^ fnv1a(arch) ^` a fixed
//! salt, the normal equations accumulate strictly in training-grid
//! order, and the solver is plain Gaussian elimination with partial
//! pivoting — so serial, parallel, and store-round-tripped fits are
//! bit-identical (pinned by `tests/proptests.rs`).
//!
//! [`ResidualSource`] is the facade mirror of [`super::Calibration`]:
//! memoized per (arch, fingerprint), lab-store persisted under
//! `residual:v1:...` keys with full provenance (training-grid hash,
//! feature list, seed), counting only real fits ([`ResidualSource::fits`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::{ArchSpec, MachineConfig, RunConfig};
use crate::error::{Error, Result};
use crate::lab::{self, Store};
use crate::nn::init::XorShift64;
use crate::perfmodel::{ParamSource, PerfModel, StrategyB};
use crate::report::paper;
use crate::simulator::{simulate_training_shared, CostModel, CostTable, SimConfig};
use crate::util::json::Json;
use crate::util::memo::Memo;

/// Salt folded into the training-grid RNG seed ("code fit"), so the
/// residual grid never aliases another consumer of `SimConfig::seed`.
pub const RESIDUAL_SALT: u64 = 0xC0DE_F17;

/// Ridge regularizer λ on the normal-equation diagonal.
pub const LAMBDA: f64 = 1e-3;

/// The feature vector, in fit order. The last three entries are per-fit
/// constants — the sensitivity report's top-ranked simulator knobs —
/// folded in so the persisted provenance names everything the fit saw.
pub const FEATURE_NAMES: [&str; 14] = [
    "intercept",
    "ln_threads",
    "ln_threads_sq",
    "occupancy",
    "cpi",
    "oversub_flag",
    "ln_oversub",
    "ln_train_images",
    "ln_test_images_p1",
    "ln_epochs",
    "ln_total_weights",
    "fwd_cycles_per_op",
    "exec_fraction",
    "oversub_overhead",
];

/// The seeded training grid: workload variants × the Table IV thread
/// ladder, in fit order (workload-outer, threads-inner, so index-mod-k
/// folds mix both axes). Variants: the paper workload, its 2× and 4×
/// Table XI scalings, and one jittered draw from the seeded stream.
pub fn training_runs(arch: &ArchSpec, seed: u64) -> Vec<RunConfig> {
    let base = RunConfig::paper_default(&arch.name, 1);
    let ep = base.epochs;
    let mut rng =
        XorShift64::new((seed ^ lab::fnv1a(arch.name.as_bytes())) ^ RESIDUAL_SALT);
    let jitter = (
        15_000 + rng.next_below(45_001),
        2_500 + rng.next_below(7_501),
        5 + rng.next_below(ep),
    );
    let workloads = [
        (base.train_images, base.test_images, ep),
        (2 * base.train_images, 2 * base.test_images, 2 * ep),
        (4 * base.train_images, 4 * base.test_images, 4 * ep),
        jitter,
    ];
    let mut runs = Vec::with_capacity(workloads.len() * paper::CONTENTION_THREADS.len());
    for (i, it, e) in workloads {
        for &p in paper::CONTENTION_THREADS.iter() {
            runs.push(RunConfig {
                train_images: i,
                test_images: it,
                epochs: e,
                threads: p,
            });
        }
    }
    runs
}

/// The feature vector for one run (order: [`FEATURE_NAMES`]).
pub fn feature_vector(
    machine: &MachineConfig,
    total_weights: f64,
    fwd_cycles_per_op: f64,
    exec_fraction: f64,
    oversub_overhead: f64,
    run: &RunConfig,
) -> Vec<f64> {
    let p = run.threads;
    let lp = (p as f64).ln();
    let occ = machine.occupancy(p);
    let cpi = machine.cpi(occ);
    let hw = machine.max_hw_threads();
    let oversub = p > hw;
    let ln_oversub = if oversub {
        (p as f64 / hw as f64).ln().max(0.0)
    } else {
        0.0
    };
    vec![
        1.0,
        lp,
        lp * lp,
        occ as f64,
        cpi,
        if oversub { 1.0 } else { 0.0 },
        ln_oversub,
        (run.train_images as f64).ln(),
        (run.test_images as f64 + 1.0).ln(),
        (run.epochs as f64).ln(),
        total_weights.ln(),
        fwd_cycles_per_op,
        exec_fraction,
        oversub_overhead,
    ]
}

/// One training point: the run, both sides of the residual, and the
/// assembled feature vector.
#[derive(Debug, Clone)]
pub struct TrainSample {
    /// The training-grid run.
    pub run: RunConfig,
    /// micsim's measured execution time, seconds.
    pub measured_s: f64,
    /// Feature vector in [`FEATURE_NAMES`] order.
    pub features: Vec<f64>,
    /// The fit target `ln(measured / predicted_b)`.
    pub z: f64,
}

/// Evaluate the training grid: one measured/predicted pair per run, in
/// grid order, sharing one [`CostTable`] over one [`CostModel`] — the
/// thread-ladder fast path: the grid is 4 workload variants × the full
/// thread ladder, so the per-occupancy-class cost terms are computed
/// once and reused across all 44 points, bit-identically.
pub fn training_samples(
    arch: &ArchSpec,
    b: &StrategyB,
    sim: &SimConfig,
) -> Result<Vec<TrainSample>> {
    let cost = CostTable::new(Arc::new(CostModel::new(arch, sim)?));
    let total_weights = arch.total_weights()? as f64;
    let runs = training_runs(arch, sim.seed);
    let mut out = Vec::with_capacity(runs.len());
    for run in runs {
        let measured_s = simulate_training_shared(&cost, &run, sim)?.execution_s;
        let predicted_s = b.predict(&run)?.total_s;
        if !(measured_s > 0.0 && measured_s.is_finite())
            || !(predicted_s > 0.0 && predicted_s.is_finite())
        {
            return Err(Error::Config(format!(
                "residual training point {}@p={} is degenerate \
                 (measured {measured_s}, predicted {predicted_s})",
                arch.name, run.threads
            )));
        }
        let features = feature_vector(
            &sim.machine,
            total_weights,
            sim.fwd_cycles_per_op,
            sim.exec_fraction,
            sim.oversub_overhead,
            &run,
        );
        out.push(TrainSample {
            run,
            measured_s,
            features,
            z: (measured_s / predicted_s).ln(),
        });
    }
    Ok(out)
}

/// Solve the ridge normal equations `(XᵀX + λI) w = Xᵀz` by Gaussian
/// elimination with partial pivoting. Accumulation runs strictly in
/// point order — the determinism contract the property tests pin — so
/// callers must not reorder `points`. Public so the k-fold test can fit
/// training subsets without building a full [`ResidualModel`].
pub fn solve(points: &[(Vec<f64>, f64)], lambda: f64) -> Result<Vec<f64>> {
    let Some(first) = points.first() else {
        return Err(Error::Config(
            "residual fit needs at least one training point".into(),
        ));
    };
    let d = first.0.len();
    let mut xtx = vec![vec![0.0f64; d]; d];
    let mut xtz = vec![0.0f64; d];
    for (x, z) in points {
        if x.len() != d {
            return Err(Error::Config(format!(
                "residual fit: ragged feature vector ({} vs {d})",
                x.len()
            )));
        }
        for r in 0..d {
            let xr = x[r];
            let row = &mut xtx[r];
            for c in 0..d {
                row[c] += xr * x[c];
            }
            xtz[r] += xr * z;
        }
    }
    for r in 0..d {
        xtx[r][r] += lambda;
    }
    // Augmented system [XᵀX + λI | Xᵀz].
    let mut a: Vec<Vec<f64>> = (0..d)
        .map(|r| {
            let mut row = xtx[r].clone();
            row.push(xtz[r]);
            row
        })
        .collect();
    for col in 0..d {
        let mut piv = col;
        for r in col + 1..d {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        let pivval = a[col][col];
        if pivval == 0.0 || !pivval.is_finite() {
            return Err(Error::Config(
                "residual fit: singular normal equations (λ should prevent this)"
                    .into(),
            ));
        }
        let pivrow = a[col].clone();
        for r in col + 1..d {
            let f = a[r][col] / pivval;
            if f == 0.0 {
                continue;
            }
            let row = &mut a[r];
            for c in col..=d {
                row[c] -= f * pivrow[c];
            }
        }
    }
    let mut w = vec![0.0f64; d];
    for r in (0..d).rev() {
        let mut acc = a[r][d];
        for c in r + 1..d {
            acc -= a[r][c] * w[c];
        }
        w[r] = acc / a[r][r];
    }
    Ok(w)
}

/// The canonical training-set fingerprint: FNV-1a over a string naming
/// the architecture, parameter source, simulator fingerprint, seed,
/// regularizer bits, feature list, and every training run in order —
/// recomputable at load time without re-running the simulator, which is
/// how store-loaded models are verified against the grid they claim.
pub fn train_hash(
    arch: &ArchSpec,
    source: ParamSource,
    sim: &SimConfig,
    runs: &[RunConfig],
) -> u64 {
    let mut text = format!(
        "residual:v1:{}:{}:{:016x}:seed={}:lambda={:016x}:features=",
        arch.name,
        lab::source_tag(source),
        sim.fingerprint(),
        sim.seed,
        LAMBDA.to_bits(),
    );
    for name in FEATURE_NAMES {
        text.push_str(name);
        text.push(',');
    }
    for run in runs {
        text.push_str(&format!(
            ":{}/{}/{}/{}",
            run.train_images, run.test_images, run.epochs, run.threads
        ));
    }
    lab::fnv1a(text.as_bytes())
}

/// A fitted residual model: the ridge weights plus everything needed to
/// rebuild the feature vector at prediction time and to verify
/// provenance at load time.
#[derive(Debug, Clone)]
pub struct ResidualModel {
    /// Architecture the model corrects.
    pub arch: String,
    /// Machine the occupancy/CPI features evaluate against.
    pub machine: MachineConfig,
    /// `ArchSpec::total_weights()` as f64 (the `ln_total_weights` base).
    pub total_weights: f64,
    /// Per-fit constant feature (the resolved simulator's value).
    pub fwd_cycles_per_op: f64,
    /// Per-fit constant feature.
    pub exec_fraction: f64,
    /// Per-fit constant feature.
    pub oversub_overhead: f64,
    /// `SimConfig::seed` the training grid derived from.
    pub seed: u64,
    /// Ridge regularizer the fit used.
    pub lambda: f64,
    /// Fitted weights, one per [`FEATURE_NAMES`] entry.
    pub weights: Vec<f64>,
    /// Training points the fit consumed.
    pub train_points: usize,
    /// Canonical training-set fingerprint ([`train_hash`]).
    pub train_hash: u64,
}

impl ResidualModel {
    /// Fit the residual of `b` against micsim over the seeded training
    /// grid — deterministic from `(arch, sim.fingerprint())`.
    pub fn fit(
        arch: &ArchSpec,
        b: &StrategyB,
        sim: &SimConfig,
        source: ParamSource,
    ) -> Result<ResidualModel> {
        let samples = training_samples(arch, b, sim)?;
        let points: Vec<(Vec<f64>, f64)> =
            samples.iter().map(|s| (s.features.clone(), s.z)).collect();
        let weights = solve(&points, LAMBDA)?;
        let runs: Vec<RunConfig> = samples.iter().map(|s| s.run).collect();
        Ok(ResidualModel {
            arch: arch.name.clone(),
            machine: sim.machine.clone(),
            total_weights: arch.total_weights()? as f64,
            fwd_cycles_per_op: sim.fwd_cycles_per_op,
            exec_fraction: sim.exec_fraction,
            oversub_overhead: sim.oversub_overhead,
            seed: sim.seed,
            lambda: LAMBDA,
            weights,
            train_points: runs.len(),
            train_hash: train_hash(arch, source, sim, &runs),
        })
    }

    /// The feature vector this model evaluates for one run.
    pub fn features(&self, run: &RunConfig) -> Vec<f64> {
        feature_vector(
            &self.machine,
            self.total_weights,
            self.fwd_cycles_per_op,
            self.exec_fraction,
            self.oversub_overhead,
            run,
        )
    }

    /// The multiplicative correction `exp(w · x)` strategy (c) applies
    /// to strategy (b)'s prediction.
    pub fn ratio(&self, run: &RunConfig) -> f64 {
        let x = self.features(run);
        self.weights
            .iter()
            .zip(&x)
            .map(|(w, xi)| w * xi)
            .sum::<f64>()
            .exp()
    }
}

/// The residual-model facade: memoized per (architecture, simulator
/// fingerprint), optionally lab-store backed — the [`super::Calibration`]
/// policy, with its own fit counter so calibrator-resolution pins stay
/// untouched.
pub struct ResidualSource {
    source: ParamSource,
    memo: Memo<(String, u64), Arc<ResidualModel>>,
    fits: AtomicU64,
    store: Option<Arc<Store>>,
}

impl std::fmt::Debug for ResidualSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResidualSource")
            .field("source", &self.source)
            .field("fits", &self.fits())
            .finish()
    }
}

impl ResidualSource {
    /// The residual source for one parameter source.
    pub fn new(source: ParamSource) -> ResidualSource {
        ResidualSource {
            source,
            memo: Memo::new(),
            fits: AtomicU64::new(0),
            store: None,
        }
    }

    /// Attach a lab store: fits are served from disk when persisted
    /// (without counting) and written through — with provenance — when
    /// computed. Called by [`super::Calibration::with_store`].
    pub fn set_store(&mut self, store: Arc<Store>) {
        self.store = Some(store);
    }

    /// Resolve (memoized) the fitted model for one architecture against
    /// one simulator configuration. Same single-flight policy as
    /// [`super::Calibration::resolve`]: a concurrent cold miss runs the
    /// (expensive, 44-point) fit exactly once — latecomers block on the
    /// in-flight fit and share its model — so [`ResidualSource::fits`]
    /// counts exactly one fit per distinct (arch, fingerprint) key on
    /// any error-free run. Store probe and write-through sit inside the
    /// same slot.
    pub fn resolve(
        &self,
        arch: &ArchSpec,
        sim: &SimConfig,
        b: &StrategyB,
    ) -> Result<Arc<ResidualModel>> {
        let key = (arch.name.clone(), sim.fingerprint());
        self.memo.get_or_try_insert_with(key, || {
            if let Some(store) = &self.store {
                let skey = lab::residual_key(&arch.name, self.source, sim.fingerprint());
                if let Some(model) = store
                    .get(lab::Kind::Residual, &skey)
                    .and_then(|payload| self.model_from_payload(&payload, arch, sim))
                {
                    return Ok(Arc::new(model));
                }
            }
            let built = Arc::new(ResidualModel::fit(arch, b, sim, self.source)?);
            self.fits.fetch_add(1, Ordering::Relaxed);
            if let Some(store) = &self.store {
                let skey = lab::residual_key(&arch.name, self.source, sim.fingerprint());
                store.put(lab::Kind::Residual, &skey, self.model_payload(&built))?;
            }
            Ok(built)
        })
    }

    /// How many fits actually ran (memo+store misses) — the warm-rerun
    /// observability hook `tests/lab.rs` pins to zero.
    pub fn fits(&self) -> u64 {
        self.fits.load(Ordering::Relaxed)
    }

    /// The store payload: weights plus full provenance (training-grid
    /// hash, feature list, seed, per-fit constants).
    fn model_payload(&self, m: &ResidualModel) -> Json {
        Json::obj(vec![
            ("arch", Json::str(m.arch.clone())),
            ("source", Json::str(lab::source_tag(self.source))),
            ("seed", Json::str(format!("{:016x}", m.seed))),
            ("lambda", Json::num(m.lambda)),
            ("train_hash", Json::str(format!("{:016x}", m.train_hash))),
            ("train_points", Json::num(m.train_points as f64)),
            (
                "features",
                Json::Arr(FEATURE_NAMES.iter().map(|n| Json::str(*n)).collect()),
            ),
            (
                "weights",
                Json::Arr(m.weights.iter().map(|w| Json::num(*w)).collect()),
            ),
            (
                "consts",
                Json::obj(vec![
                    ("fwd_cycles_per_op", Json::num(m.fwd_cycles_per_op)),
                    ("exec_fraction", Json::num(m.exec_fraction)),
                    ("oversub_overhead", Json::num(m.oversub_overhead)),
                ]),
            ),
        ])
    }

    /// Rebuild a [`ResidualModel`] from a store payload. `None` (forcing
    /// a fresh fit) on any mismatch: wrong arch/source/seed/λ, a
    /// training-grid hash that no longer matches the grid this
    /// (arch, sim) would generate, or a malformed weight vector. The
    /// hash recomputation needs only [`training_runs`] — no simulation —
    /// so warm loads stay cheap.
    fn model_from_payload(
        &self,
        payload: &Json,
        arch: &ArchSpec,
        sim: &SimConfig,
    ) -> Option<ResidualModel> {
        if payload.get("arch")?.as_str()? != arch.name {
            return None;
        }
        if payload.get("source")?.as_str()? != lab::source_tag(self.source) {
            return None;
        }
        let seed = u64::from_str_radix(payload.get("seed")?.as_str()?, 16).ok()?;
        if seed != sim.seed {
            return None;
        }
        let lambda = payload.get("lambda")?.as_f64()?;
        if lambda.to_bits() != LAMBDA.to_bits() {
            return None;
        }
        let runs = training_runs(arch, sim.seed);
        let expect = train_hash(arch, self.source, sim, &runs);
        if payload.get("train_hash")?.as_str()? != format!("{expect:016x}") {
            return None;
        }
        if payload.get("train_points")?.as_usize()? != runs.len() {
            return None;
        }
        let weights: Vec<f64> = payload
            .get("weights")?
            .as_arr()?
            .iter()
            .map(Json::as_f64)
            .collect::<Option<Vec<_>>>()?;
        if weights.len() != FEATURE_NAMES.len() {
            return None;
        }
        Some(ResidualModel {
            arch: arch.name.clone(),
            machine: sim.machine.clone(),
            total_weights: arch.total_weights().ok()? as f64,
            fwd_cycles_per_op: sim.fwd_cycles_per_op,
            exec_fraction: sim.exec_fraction,
            oversub_overhead: sim.oversub_overhead,
            seed,
            lambda,
            weights,
            train_points: runs.len(),
            train_hash: expect,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration::Calibration;

    fn fitted(arch: &ArchSpec) -> ResidualModel {
        let sim = SimConfig::default();
        let params = Calibration::new(ParamSource::Paper)
            .resolve(arch, &sim)
            .unwrap();
        let b = StrategyB::from_params(&params).unwrap();
        ResidualModel::fit(arch, &b, &sim, ParamSource::Paper).unwrap()
    }

    #[test]
    fn training_grid_is_seeded_and_deterministic() {
        let arch = ArchSpec::small();
        let runs = training_runs(&arch, 0x5EED);
        assert_eq!(runs.len(), 4 * paper::CONTENTION_THREADS.len());
        assert_eq!(runs, training_runs(&arch, 0x5EED), "same seed, same grid");
        assert_ne!(
            runs,
            training_runs(&arch, 0x5EED ^ 0xBEEF),
            "the jittered variant must follow the seed"
        );
        // Workload-outer, threads-inner: the first ladder is the paper
        // workload, the second its 2x scaling.
        assert_eq!(runs[0].train_images, 60_000);
        assert_eq!(runs[0].threads, paper::CONTENTION_THREADS[0]);
        let n = paper::CONTENTION_THREADS.len();
        assert_eq!(runs[n].train_images, 120_000);
        // The jittered variant stays inside its documented ranges.
        let j = &runs[3 * n];
        assert!((15_000..60_001).contains(&j.train_images), "{j:?}");
        assert!((2_500..10_001).contains(&j.test_images), "{j:?}");
        assert!((5..75).contains(&j.epochs), "{j:?}");
        for run in &runs {
            assert!(run.validate().is_ok(), "{run:?}");
        }
    }

    #[test]
    fn refit_is_bit_identical() {
        let arch = ArchSpec::small();
        let first = fitted(&arch);
        let second = fitted(&arch);
        assert_eq!(first.weights.len(), FEATURE_NAMES.len());
        for (a, b) in first.weights.iter().zip(&second.weights) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(first.train_hash, second.train_hash);
        assert_eq!(first.train_points, 44);
    }

    #[test]
    fn residual_correction_beats_b_in_sample() {
        // The fit's raison d'être: mean |Δ| of (c) over the training
        // grid sits well below (b)'s on the same points.
        let arch = ArchSpec::medium();
        let sim = SimConfig::default();
        let params = Calibration::new(ParamSource::Paper)
            .resolve(&arch, &sim)
            .unwrap();
        let b = StrategyB::from_params(&params).unwrap();
        let model = ResidualModel::fit(&arch, &b, &sim, ParamSource::Paper).unwrap();
        let samples = training_samples(&arch, &b, &sim).unwrap();
        let (mut db, mut dc) = (0.0, 0.0);
        for s in &samples {
            let pb = b.predict(&s.run).unwrap().total_s;
            let pc = pb * model.ratio(&s.run);
            db += (s.measured_s - pb).abs() / pb * 100.0;
            dc += (s.measured_s - pc).abs() / pc * 100.0;
        }
        let (db, dc) = (db / samples.len() as f64, dc / samples.len() as f64);
        assert!(dc < 0.5 * db, "(c) {dc:.3}% vs (b) {db:.3}%");
    }

    #[test]
    fn solve_recovers_exact_linear_data() {
        // z = 2 - 3·x1 + 0.5·x2 on a full-rank design.
        let truth = [2.0, -3.0, 0.5];
        let points: Vec<(Vec<f64>, f64)> = (0..12)
            .map(|i| {
                let x = vec![1.0, i as f64, (i * i) as f64 * 0.1];
                let z = truth[0] * x[0] + truth[1] * x[1] + truth[2] * x[2];
                (x, z)
            })
            .collect();
        let w = solve(&points, 1e-9).unwrap();
        for (got, want) in w.iter().zip(&truth) {
            assert!((got - want).abs() < 1e-5, "{w:?}");
        }
        assert!(solve(&[], LAMBDA).is_err());
    }

    #[test]
    fn store_round_trip_is_bit_identical_and_uncounted() {
        let dir = crate::util::tmp::TempDir::new("residual-store").unwrap();
        let store = Arc::new(Store::open(dir.path()).unwrap());
        let arch = ArchSpec::small();
        let sim = SimConfig::default();
        let params = Calibration::new(ParamSource::Paper)
            .resolve(&arch, &sim)
            .unwrap();
        let b = StrategyB::from_params(&params).unwrap();

        let mut writer = ResidualSource::new(ParamSource::Paper);
        writer.set_store(Arc::clone(&store));
        let fresh = writer.resolve(&arch, &sim, &b).unwrap();
        assert_eq!(writer.fits(), 1);
        assert!(Arc::ptr_eq(&fresh, &writer.resolve(&arch, &sim, &b).unwrap()));
        assert_eq!(writer.fits(), 1, "memo hits are not fits");

        let mut reader = ResidualSource::new(ParamSource::Paper);
        reader.set_store(Arc::clone(&store));
        let served = reader.resolve(&arch, &sim, &b).unwrap();
        assert_eq!(reader.fits(), 0, "store hits are not fits");
        for (a, s) in fresh.weights.iter().zip(&served.weights) {
            assert_eq!(a.to_bits(), s.to_bits());
        }
        assert_eq!(fresh.train_hash, served.train_hash);

        // A different seed invalidates the persisted grid hash — the
        // loader refits rather than serving a stale model.
        let reseeded = SimConfig { seed: 0xFEED, ..SimConfig::default() };
        let mut other = ResidualSource::new(ParamSource::Paper);
        other.set_store(Arc::clone(&store));
        other.resolve(&arch, &reseeded, &b).unwrap();
        assert_eq!(other.fits(), 1, "seed change must refit");
    }
}
