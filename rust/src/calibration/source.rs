//! The three parameter-estimation strategies behind
//! [`Calibrator`](crate::calibration::Calibrator).
//!
//! | Source | Estimation discipline | Owns |
//! |---|---|---|
//! | [`PaperSource`] | published constants (Tables II–IV, VII, VIII) | everything |
//! | [`ProbeSource`] | measured from micsim probes (the model-(b) methodology) | `T_Fprop`/`T_Bprop`/`T_prep`, contention |
//! | [`ComputedSource`] | computed op counts × cycles *fitted* to the probes | the model-(a) parameterization |
//!
//! [`ComputedSource`] is what closes strategy (a)'s loop: the paper
//! calibrated its OperationFactor "to closely match the measured value
//! for 15 threads"; we do the same against the measuring simulator —
//! per-direction cycles-per-op are fitted so the *computed* Table VII/
//! VIII counts reproduce the probed per-image times exactly, instead of
//! reusing micsim's paper-count cycle constants (which left the medium
//! CNN's closed-loop band at ~58 % — the computed-vs-paper op-count
//! gap). The residual Δ is then structural: the single shared
//! OperationFactor distorts the test term (`FProp·OF` vs the measured
//! `T_Fprop`), which is the honest cost of Table V's one-factor form.

use crate::calibration::{
    Calibrator, ContentionSource, ModelParams, StrategyAParams, StrategyBParams,
};
use crate::config::ArchSpec;
use crate::error::Result;
use crate::nn::opcount;
use crate::perfmodel::ParamSource;
use crate::report::paper;
use crate::simulator::{probe, SimConfig};

/// Published-constant calibration: the paper's Tables II–IV, VII and
/// VIII, for exact table reproduction ([`ParamSource::Paper`]).
///
/// Custom architectures have no published rows; like the pre-subsystem
/// constructors, strategy (b) falls back to the simulator probe and
/// strategy (a) resolves to nothing (constructing the model errors).
#[derive(Debug, Clone, Copy, Default)]
pub struct PaperSource;

impl Calibrator for PaperSource {
    fn name(&self) -> &'static str {
        "paper"
    }

    fn resolve(&self, arch: &ArchSpec, sim: &SimConfig) -> Result<ModelParams> {
        let idx = paper::arch_index(&arch.name);
        let a = match (idx, paper::op_counts(&arch.name)) {
            (Some(i), Some(counts)) => Some(StrategyAParams {
                fprop_ops: counts.fprop.total() as f64,
                bprop_ops: counts.bprop.total() as f64,
                prep_ops: paper::MODEL_PREP_OPS[i],
                operation_factor: paper::OPERATION_FACTOR[i],
            }),
            _ => None,
        };
        let b = match idx {
            Some(i) => StrategyBParams {
                t_fprop_s: paper::T_FPROP_S[i],
                t_bprop_s: paper::T_BPROP_S[i],
                t_prep_s: paper::T_PREP_S[i],
            },
            // No paper measurements for custom archs: fall back to the
            // simulator probe (the pre-subsystem StrategyB behaviour).
            None => {
                let m = probe::measure_image_times(arch, sim)?;
                StrategyBParams {
                    t_fprop_s: m.t_fprop_s,
                    t_bprop_s: m.t_bprop_s,
                    t_prep_s: m.t_prep_s,
                }
            }
        };
        Ok(ModelParams {
            arch: arch.name.clone(),
            calibrator: self.name(),
            machine: sim.machine.clone(),
            a,
            b: Some(b),
            contention: ContentionSource::new(arch, ParamSource::Paper)
                .with_sim_config(sim.clone()),
        })
    }
}

/// Measurement-heavy calibration: every *measured* quantity is probed
/// from the simulator ([`probe::measure_image_times`] for the per-image
/// and preparation times, the Table IV contention probe for `T_mem`) —
/// exactly how the authors parameterized model (b) on the real Phi.
///
/// Probes are time measurements; the op-count parameterization of model
/// (a) is not a probe product, so this source resolves no
/// [`StrategyAParams`] — [`ComputedSource`] layers them on top.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProbeSource;

impl Calibrator for ProbeSource {
    fn name(&self) -> &'static str {
        "probe"
    }

    fn resolve(&self, arch: &ArchSpec, sim: &SimConfig) -> Result<ModelParams> {
        let m = probe::measure_image_times(arch, sim)?;
        Ok(ModelParams {
            arch: arch.name.clone(),
            calibrator: self.name(),
            machine: sim.machine.clone(),
            a: None,
            b: Some(StrategyBParams {
                t_fprop_s: m.t_fprop_s,
                t_bprop_s: m.t_bprop_s,
                t_prep_s: m.t_prep_s,
            }),
            contention: ContentionSource::new(arch, ParamSource::Simulator)
                .with_sim_config(sim.clone()),
        })
    }
}

/// Computed-count calibration: first-principles Table VII/VIII op counts
/// ([`opcount::count`], i.e. `OpSource::Computed` end-to-end) with the
/// op-count→cycles mapping *fitted* against the measuring simulator —
/// the closed-loop parameterization of strategy (a).
///
/// Per-direction cycles-per-op are fitted so the computed counts
/// reproduce the probed per-image times bit-for-bit at one thread
/// (`fwd = T_Fprop·s/FProp`, `bwd = T_Bprop·s/BProp`), then folded into
/// the single Table V OperationFactor with the model's
/// `(FProp + BProp + FProp)` term mix, and the Prep estimate is
/// back-derived from the probed preparation time through that factor.
/// Strategy (b)'s parameters and the contention source are the
/// [`ProbeSource`] resolution (the fit anchors).
#[derive(Debug, Clone, Copy, Default)]
pub struct ComputedSource;

impl Calibrator for ComputedSource {
    fn name(&self) -> &'static str {
        "computed"
    }

    fn resolve(&self, arch: &ArchSpec, sim: &SimConfig) -> Result<ModelParams> {
        let probed = ProbeSource.resolve(arch, sim)?;
        let m = probed.b.expect("ProbeSource always resolves strategy-(b) params");
        let counts = opcount::resolve(arch, ParamSource::Simulator.op_source())?;
        let f = counts.fprop.total() as f64;
        let b = counts.bprop.total() as f64;
        let clock = sim.machine.clock_hz;
        // Fit per-direction cycles-per-op over the *computed* counts so
        // they reproduce the probed per-image times exactly.
        let fwd_cycles_fit = m.t_fprop_s * clock / f;
        let bwd_cycles_fit = m.t_bprop_s * clock / b;
        // Fold into the Table V single OperationFactor, weighted by the
        // model's (FProp + BProp + FProp) training/validation term mix.
        let operation_factor =
            (2.0 * f * fwd_cycles_fit + b * bwd_cycles_fit) / (2.0 * f + b);
        // Back-derive the Prep operation estimate from the probed
        // preparation time so the `Prep·OF/s` term lands on it.
        let prep_ops = m.t_prep_s * clock / operation_factor;
        Ok(ModelParams {
            a: Some(StrategyAParams {
                fprop_ops: f,
                bprop_ops: b,
                prep_ops,
                operation_factor,
            }),
            calibrator: self.name(),
            ..probed
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn paper_source_resolves_published_constants_exactly() {
        let sim = SimConfig::default();
        for (i, arch) in ArchSpec::paper_archs().iter().enumerate() {
            let params = PaperSource.resolve(arch, &sim).unwrap();
            let a = params.strategy_a().unwrap();
            assert_eq!(a.operation_factor, paper::OPERATION_FACTOR[i]);
            assert_eq!(a.prep_ops, paper::MODEL_PREP_OPS[i]);
            let counts = paper::op_counts(&arch.name).unwrap();
            assert_eq!(a.fprop_ops, counts.fprop.total() as f64);
            assert_eq!(a.bprop_ops, counts.bprop.total() as f64);
            let b = params.strategy_b().unwrap();
            assert_eq!(b.t_fprop_s, paper::T_FPROP_S[i]);
            assert_eq!(b.t_bprop_s, paper::T_BPROP_S[i]);
            assert_eq!(b.t_prep_s, paper::T_PREP_S[i]);
        }
    }

    #[test]
    fn paper_source_custom_arch_has_probed_b_and_no_a() {
        let mut arch = ArchSpec::small();
        arch.name = "custom".into();
        let sim = SimConfig::default();
        let params = PaperSource.resolve(&arch, &sim).unwrap();
        assert!(params.strategy_a().is_err(), "no paper op counts for customs");
        let b = params.strategy_b().unwrap();
        let m = probe::measure_image_times(&arch, &sim).unwrap();
        assert_eq!(b.t_fprop_s.to_bits(), m.t_fprop_s.to_bits());
    }

    #[test]
    fn probe_source_matches_measure_image_times() {
        let sim = SimConfig::default();
        for arch in ArchSpec::paper_archs() {
            let params = ProbeSource.resolve(&arch, &sim).unwrap();
            assert!(params.strategy_a().is_err(), "probes measure times, not counts");
            let b = params.strategy_b().unwrap();
            let m = probe::measure_image_times(&arch, &sim).unwrap();
            assert_eq!(b.t_fprop_s.to_bits(), m.t_fprop_s.to_bits());
            assert_eq!(b.t_bprop_s.to_bits(), m.t_bprop_s.to_bits());
            assert_eq!(b.t_prep_s.to_bits(), m.t_prep_s.to_bits());
        }
    }

    #[test]
    fn computed_source_fit_reproduces_probed_times() {
        // The fitted calibration round-trips: computed counts × fitted
        // OperationFactor land back on the probed train-image time, so
        // strategy (a)'s training term equals strategy (b)'s.
        let sim = SimConfig::default();
        for arch in ArchSpec::paper_archs() {
            let params = ComputedSource.resolve(&arch, &sim).unwrap();
            let a = params.strategy_a().unwrap();
            let b = params.strategy_b().unwrap();
            let clock = sim.machine.clock_hz;
            let train_cycles =
                (2.0 * a.fprop_ops + a.bprop_ops) * a.operation_factor / clock;
            let probed = 2.0 * b.t_fprop_s + b.t_bprop_s;
            assert!(
                (train_cycles - probed).abs() / probed < 1e-12,
                "{}: {train_cycles} vs {probed}",
                arch.name
            );
            // The prep term lands on the probed preparation time.
            let prep = a.prep_ops * a.operation_factor / clock;
            assert!((prep - b.t_prep_s).abs() / b.t_prep_s < 1e-12, "{}", arch.name);
        }
    }

    #[test]
    fn computed_source_uses_computed_counts() {
        let sim = SimConfig::default();
        let arch = ArchSpec::small();
        let a = ComputedSource.resolve(&arch, &sim).unwrap().strategy_a().unwrap();
        let counts = opcount::count(&arch).unwrap();
        assert_eq!(a.fprop_ops, counts.fprop.total() as f64);
        assert_eq!(a.bprop_ops, counts.bprop.total() as f64);
        // And they differ from the paper tables (the gap the fit absorbs).
        assert_ne!(a.fprop_ops, 58_000.0);
    }

    #[test]
    fn computed_source_is_seed_independent() {
        // The probes are closed-form and deterministic; only genuine
        // simulator-constant changes may move the fit.
        let arch = ArchSpec::medium();
        let base = ComputedSource.resolve(&arch, &SimConfig::default()).unwrap();
        let mut reseeded = SimConfig::default();
        reseeded.seed ^= 0xDEAD_BEEF;
        let again = ComputedSource.resolve(&arch, &reseeded).unwrap();
        let (a1, a2) = (base.strategy_a().unwrap(), again.strategy_a().unwrap());
        assert_eq!(a1.operation_factor.to_bits(), a2.operation_factor.to_bits());
        assert_eq!(a1.prep_ops.to_bits(), a2.prep_ops.to_bits());
        let mut slower = SimConfig::default();
        slower.fwd_cycles_per_op *= 2.0;
        let slow = ComputedSource.resolve(&arch, &slower).unwrap().strategy_a().unwrap();
        assert!(slow.operation_factor > a1.operation_factor);
    }

    #[test]
    fn sources_share_one_contention_resolution_per_params() {
        // The (a, b) pair built from one resolution shares the contention
        // memo: the probe calibration runs once, not once per model.
        let sim = SimConfig::default();
        let params = ComputedSource.resolve(&ArchSpec::small(), &sim).unwrap();
        let run = RunConfig::paper_default("small", 240);
        let c1 = params.contention.clone();
        let c2 = params.contention.clone();
        c1.t_mem_s(run.epochs, run.train_images, run.threads).unwrap();
        c2.t_mem_s(run.epochs, run.train_images, 120).unwrap();
        assert_eq!(params.contention.probe_calibrations(), 1);
    }
}
