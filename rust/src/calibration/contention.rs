//! The MemoryContention(p) parameter and the `T_mem` term.
//!
//! `T_mem(ep, i, p) = MemoryContention(p) · ep · i / p` — the paper's
//! memory/synchronization overhead (Section IV). The contention value per
//! thread count comes either from the paper's Table IV (measured on the
//! real Phi, predicted beyond 240 threads) or from the micsim probe.
//!
//! Under [`ParamSource::Simulator`] the probe needs a calibrated
//! [`CostModel`], which is the expensive part of every prediction — so a
//! source memoizes it (built at most once per source, shared by clones)
//! together with the per-`p` probe values. Accuracy sweeps call
//! `contention_s` once per scenario; without the memo each call re-ran
//! the whole probe calibration (the ROADMAP hot-path item). The memo is
//! invalidated when the simulator configuration changes
//! ([`ContentionSource::with_sim_config`]) and is observable through
//! [`ContentionSource::probe_calibrations`], which the memoization tests
//! pin to exactly one build per source.
//!
//! This module lives in the `calibration` subsystem (it migrated here
//! from `perfmodel::contention`, which still re-exports it): contention
//! is one of the estimated model parameters, and
//! [`crate::calibration::Calibration::resolve`] hands both strategies a
//! *shared* source per (architecture, simulator) so the probe
//! calibration runs once for the (a, b) pair instead of once per model.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::ArchSpec;
use crate::error::{Error, Result};
use crate::perfmodel::ParamSource;
use crate::report::paper;
use crate::simulator::{probe, CostModel, SimConfig};
use crate::util::memo::Memo;

/// Lazily-built probe state shared by clones of one source. Values are
/// deterministic, so memoized results are bit-identical to fresh probes.
#[derive(Debug, Default)]
struct ProbeMemo {
    /// The calibrated cost model, built at most once per source (the
    /// build runs under this lock, so it is already single-flight).
    cost: Mutex<Option<Arc<CostModel>>>,
    /// Probe results per thread count — single-flight, so concurrent
    /// strategy models sharing one source probe each `p` exactly once.
    values: Memo<usize, f64>,
    /// How many times the probe calibration (cost-model build) ran.
    calibrations: AtomicU64,
}

/// Resolves MemoryContention(p) for one architecture.
#[derive(Debug, Clone)]
pub struct ContentionSource {
    arch: ArchSpec,
    source: ParamSource,
    sim_cfg: SimConfig,
    memo: Arc<ProbeMemo>,
}

impl ContentionSource {
    /// A source probing the default simulator configuration.
    pub fn new(arch: &ArchSpec, source: ParamSource) -> Self {
        ContentionSource {
            arch: arch.clone(),
            source,
            sim_cfg: SimConfig::default(),
            memo: Arc::new(ProbeMemo::default()),
        }
    }

    /// Re-target the probe at another simulator configuration. Resets the
    /// memoized probe state — the calibration depends on the machine.
    pub fn with_sim_config(mut self, cfg: SimConfig) -> Self {
        self.sim_cfg = cfg;
        self.memo = Arc::new(ProbeMemo::default());
        self
    }

    /// How many times this source ran the probe calibration (builds of
    /// the micsim cost model). Stays 0 under [`ParamSource::Paper`];
    /// under [`ParamSource::Simulator`] it is at most 1 for any number of
    /// `contention_s`/`t_mem_s` calls.
    pub fn probe_calibrations(&self) -> u64 {
        self.memo.calibrations.load(Ordering::Relaxed)
    }

    /// The memoized calibrated cost model (Simulator source only).
    fn cost_model(&self) -> Result<Arc<CostModel>> {
        let mut slot = self.memo.cost.lock().unwrap();
        if let Some(cost) = slot.as_ref() {
            return Ok(Arc::clone(cost));
        }
        let built = Arc::new(CostModel::new(&self.arch, &self.sim_cfg)?);
        self.memo.calibrations.fetch_add(1, Ordering::Relaxed);
        *slot = Some(Arc::clone(&built));
        Ok(built)
    }

    /// MemoryContention(p) in seconds.
    pub fn contention_s(&self, p: usize) -> Result<f64> {
        match self.source {
            ParamSource::Paper => {
                paper::contention_s(&self.arch.name, p).ok_or_else(|| {
                    Error::Config(format!(
                        "no Table IV column for arch {:?}; use ParamSource::Simulator",
                        self.arch.name
                    ))
                })
            }
            ParamSource::Simulator => self.memo.values.get_or_try_insert_with(p, || {
                let cost = self.cost_model()?;
                Ok(probe::contention_probe_with(&cost, p, &self.sim_cfg))
            }),
        }
    }

    /// The full memory-overhead term `T_mem(ep, i, p)`.
    pub fn t_mem_s(&self, epochs: usize, train_images: usize, p: usize) -> Result<f64> {
        Ok(self.contention_s(p)? * epochs as f64 * train_images as f64 / p as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_tmem_small_240_matches_hand_calc() {
        // 1.40e-2 × 70 × 60000 / 240 = 245 s.
        let c = ContentionSource::new(&ArchSpec::small(), ParamSource::Paper);
        let t = c.t_mem_s(70, 60_000, 240).unwrap();
        assert!((t - 245.0).abs() < 0.5, "{t}");
    }

    #[test]
    fn simulator_source_close_to_paper_at_240() {
        for arch in ArchSpec::paper_archs() {
            let paper_src = ContentionSource::new(&arch, ParamSource::Paper);
            let sim_src = ContentionSource::new(&arch, ParamSource::Simulator);
            let a = paper_src.contention_s(240).unwrap();
            let b = sim_src.contention_s(240).unwrap();
            assert!((a - b).abs() / a < 0.05, "{}: {a} vs {b}", arch.name);
        }
    }

    #[test]
    fn paper_source_rejects_custom_arch() {
        let mut arch = ArchSpec::small();
        arch.name = "custom".into();
        let c = ContentionSource::new(&arch, ParamSource::Paper);
        assert!(c.contention_s(240).is_err());
        let c = ContentionSource::new(&arch, ParamSource::Simulator);
        assert!(c.contention_s(240).is_ok());
    }

    #[test]
    fn tmem_scales_linearly_with_images_and_epochs() {
        let c = ContentionSource::new(&ArchSpec::medium(), ParamSource::Paper);
        let base = c.t_mem_s(70, 60_000, 240).unwrap();
        assert!((c.t_mem_s(140, 60_000, 240).unwrap() / base - 2.0).abs() < 1e-9);
        assert!((c.t_mem_s(70, 120_000, 240).unwrap() / base - 2.0).abs() < 1e-9);
    }

    #[test]
    fn simulator_probe_calibrates_exactly_once() {
        // The ROADMAP hot-path item: repeated contention_s/t_mem_s calls
        // under ParamSource::Simulator must run the probe calibration
        // exactly once, not once per call.
        let c = ContentionSource::new(&ArchSpec::medium(), ParamSource::Simulator);
        assert_eq!(c.probe_calibrations(), 0, "calibration must be lazy");
        for p in [1usize, 15, 30, 60, 120, 180, 240, 240, 15] {
            c.contention_s(p).unwrap();
            c.t_mem_s(70, 60_000, p).unwrap();
        }
        assert_eq!(c.probe_calibrations(), 1);
        // Clones share the memo — still one calibration total.
        let clone = c.clone();
        clone.contention_s(3840).unwrap();
        assert_eq!(c.probe_calibrations(), 1);
    }

    #[test]
    fn paper_source_never_calibrates() {
        let c = ContentionSource::new(&ArchSpec::small(), ParamSource::Paper);
        for p in [1usize, 240, 3840] {
            c.contention_s(p).unwrap();
        }
        assert_eq!(c.probe_calibrations(), 0);
    }

    #[test]
    fn memoized_values_bit_identical_to_fresh_probe() {
        let cfg = SimConfig::default();
        let arch = ArchSpec::large();
        let c = ContentionSource::new(&arch, ParamSource::Simulator);
        for p in [1usize, 61, 240, 960] {
            let fresh = probe::contention_probe(&arch, p, &cfg).unwrap();
            // First call (computes + memoizes) and second call (cache
            // hit) must both equal the unmemoized probe exactly.
            assert_eq!(c.contention_s(p).unwrap().to_bits(), fresh.to_bits(), "p={p}");
            assert_eq!(c.contention_s(p).unwrap().to_bits(), fresh.to_bits(), "p={p}");
        }
    }

    #[test]
    fn with_sim_config_resets_the_memo() {
        let arch = ArchSpec::small();
        let c = ContentionSource::new(&arch, ParamSource::Simulator);
        let at_default = c.contention_s(240).unwrap();
        assert_eq!(c.probe_calibrations(), 1);
        // Contention scales with memory bandwidth (the queue term) — a
        // narrower memory system must re-probe to a different value.
        let mut narrow = SimConfig::default();
        narrow.machine.memory_bw_bytes /= 2.0;
        let c2 = c.with_sim_config(narrow);
        assert_eq!(c2.probe_calibrations(), 0, "retarget must reset the memo");
        let at_half_bw = c2.contention_s(240).unwrap();
        assert_eq!(c2.probe_calibrations(), 1);
        assert_ne!(
            at_default.to_bits(),
            at_half_bw.to_bits(),
            "probe must re-run against the new machine"
        );
    }
}
