//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline build carries no `thiserror`).

use std::fmt;

/// Unified error for all micdl subsystems.
#[derive(Debug)]
pub enum Error {
    /// Configuration rejected (bad layer stack, invalid parameter, ...).
    Config(String),

    /// Dataset file missing or malformed (IDX magic, truncation, ...).
    Dataset(String),

    /// Simulator invariant violated or invalid workload.
    Simulator(String),

    /// PJRT / XLA runtime failure (artifact load, compile, execute).
    Runtime(String),

    /// Artifact registry problem (missing meta.json, shape mismatch, ...).
    Artifact(String),

    Io(std::io::Error),

    Json(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Dataset(m) => write!(f, "dataset error: {m}"),
            Error::Simulator(m) => write!(f, "simulator error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::runtime::xla::Error> for Error {
    fn from(e: crate::runtime::xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
