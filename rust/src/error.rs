//! Crate-wide error type.

use thiserror::Error;

/// Unified error for all micdl subsystems.
#[derive(Error, Debug)]
pub enum Error {
    /// Configuration rejected (bad layer stack, invalid parameter, ...).
    #[error("config error: {0}")]
    Config(String),

    /// Dataset file missing or malformed (IDX magic, truncation, ...).
    #[error("dataset error: {0}")]
    Dataset(String),

    /// Simulator invariant violated or invalid workload.
    #[error("simulator error: {0}")]
    Simulator(String),

    /// PJRT / XLA runtime failure (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact registry problem (missing meta.json, shape mismatch, ...).
    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
