//! `micdl::lab` — the persistent experiment lab (ROADMAP item 1).
//!
//! Everything the sweep subsystem memoizes in-process — resolved model
//! parameters, evaluated cells, simulator measurements — is written
//! through to a content-addressed, JSON-on-disk [`Store`] and served
//! from disk on later invocations. Re-running an identical grid against
//! a warm lab performs zero model / cost-model / measurement
//! recomputation, and an interrupted sweep resumed against the same lab
//! completes bit-identically to a cold full run: cells are keyed by
//! their full axis coordinates (architecture × strategy × workload ×
//! thread count × parameter provenance × `SimConfig::fingerprint()`),
//! so "resume" is nothing more than re-enumerating the grid and letting
//! persisted cells hit.
//!
//! [`Lab`] is the facade the `repro` CLI fronts (`--lab PATH`,
//! `repro lab list|gc|trace-params`, `sweep --resume/--no-store`):
//!
//! ```no_run
//! use micdl::lab::Lab;
//! use micdl::sweep::GridSpec;
//!
//! let lab = Lab::open("./result")?;
//! let results = lab.run(&GridSpec::table9(), 0)?; // cold: computes + persists
//! let again = lab.run(&GridSpec::table9(), 0)?;   // warm: pure store hits
//! assert_eq!(results.results.len(), again.results.len());
//! # Ok::<(), micdl::Error>(())
//! ```
//!
//! Store layout, key grammar, gc semantics and the resume contract are
//! documented in docs/LAB.md.

#![warn(missing_docs)]

pub mod store;

pub use store::{
    cell_key, fnv1a, measured_key, params_key, residual_key, run_id, shard_run_id,
    source_tag, GcReport, Kind, Store, StoreStats, ENTRY_KIND, RUN_KIND, STORE_VERSION,
};

use std::path::Path;
use std::sync::Arc;

use crate::error::Result;
use crate::perfmodel::ParamSource;
use crate::simulator::SimConfig;
use crate::sweep::{GridSpec, SweepResults, SweepRunner};
use crate::util::json::Json;

/// A persistent experiment lab: a [`Store`] plus the run/resume
/// orchestration layered on top of [`SweepRunner`].
#[derive(Debug)]
pub struct Lab {
    store: Arc<Store>,
}

impl Lab {
    /// Open (creating if needed) the lab rooted at `path`.
    pub fn open(path: impl AsRef<Path>) -> Result<Lab> {
        Ok(Lab {
            store: Arc::new(Store::open(path)?),
        })
    }

    /// The underlying store (shared; hand clones to runners or cache
    /// layers).
    pub fn store(&self) -> &Arc<Store> {
        &self.store
    }

    /// A sweep runner wired to this lab's store (`workers` as in
    /// [`SweepRunner::new`]).
    pub fn runner(&self, workers: usize) -> SweepRunner {
        SweepRunner::new(workers).with_store(Arc::clone(&self.store))
    }

    /// The deterministic run id of a grid (FNV-1a of its exact spec
    /// JSON).
    pub fn run_id_for(grid: &GridSpec) -> Result<String> {
        Ok(store::run_id(&grid.to_spec_json()?.emit()))
    }

    /// Run a grid with persistence: writes a `running` manifest, sweeps
    /// (persisted cells hit, missing cells compute and write through),
    /// then marks the manifest `complete`. Calling this again with the
    /// same grid — including after an interruption — serves every
    /// already-persisted cell from disk and recomputes only the rest,
    /// with bit-identical merged results.
    pub fn run(&self, grid: &GridSpec, workers: usize) -> Result<SweepResults> {
        let spec = grid.to_spec_json()?;
        let id = store::run_id(&spec.emit());
        self.store
            .write_run(&id, &Self::manifest(&id, &spec, grid.len(), "running"))?;
        let results = self.runner(workers).run(grid)?;
        self.store
            .write_run(&id, &Self::manifest(&id, &spec, grid.len(), "complete"))?;
        Ok(results)
    }

    /// Run shard `k` of `n` (0-based `k`; [`GridSpec::shard`]) with
    /// persistence. The manifest id derives from the **parent** run id —
    /// `{parent}.{k+1}of{n}` ([`shard_run_id`]) — rather than hashing
    /// the sub-grid, and records its shard membership, so `lab list`
    /// groups shards under the grid they partition and `--resume`
    /// composes with `--shard` by pure id derivation. Cells persist
    /// under the same keys an unsharded run writes; shards sharing a
    /// store therefore compose into a warm full grid.
    pub fn run_shard(
        &self,
        grid: &GridSpec,
        k: usize,
        n: usize,
        workers: usize,
    ) -> Result<SweepResults> {
        let spec = grid.to_spec_json()?;
        let parent = store::run_id(&spec.emit());
        let id = store::shard_run_id(&parent, k, n);
        let scenarios = grid.shard(k, n)?.len();
        self.store.write_run(
            &id,
            &Self::shard_manifest(&id, &spec, scenarios, "running", &parent, k, n),
        )?;
        let results = self.runner(workers).run_shard(grid, k, n)?;
        self.store.write_run(
            &id,
            &Self::shard_manifest(&id, &spec, scenarios, "complete", &parent, k, n),
        )?;
        Ok(results)
    }

    fn manifest(id: &str, spec: &Json, scenarios: usize, status: &str) -> Json {
        Json::obj(vec![
            ("kind", Json::str(RUN_KIND)),
            ("version", Json::num(1)),
            ("id", Json::str(id)),
            ("spec", spec.clone()),
            ("scenarios", Json::num(scenarios as f64)),
            ("status", Json::str(status)),
        ])
    }

    /// A run manifest extended with a `shard` membership object
    /// (`parent` run id, 1-based `index`, `count`).
    fn shard_manifest(
        id: &str,
        spec: &Json,
        scenarios: usize,
        status: &str,
        parent: &str,
        k: usize,
        n: usize,
    ) -> Json {
        Json::obj(vec![
            ("kind", Json::str(RUN_KIND)),
            ("version", Json::num(1)),
            ("id", Json::str(id)),
            ("spec", spec.clone()),
            ("scenarios", Json::num(scenarios as f64)),
            ("status", Json::str(status)),
            (
                "shard",
                Json::obj(vec![
                    ("parent", Json::str(parent)),
                    ("index", Json::num((k + 1) as f64)),
                    ("count", Json::num(n as f64)),
                ]),
            ),
        ])
    }

    /// The manifest of a previous shard run of `grid`, if one exists
    /// (`--resume --shard k/n` consults this, by pure id derivation).
    pub fn find_shard_run(&self, grid: &GridSpec, k: usize, n: usize) -> Result<Option<Json>> {
        let parent = Self::run_id_for(grid)?;
        Ok(self.store.read_run(&store::shard_run_id(&parent, k, n)))
    }

    /// The manifest of a previous run of `grid`, if one exists
    /// (`--resume` consults this to report what it is resuming).
    pub fn find_run(&self, grid: &GridSpec) -> Result<Option<Json>> {
        Ok(self.store.read_run(&Self::run_id_for(grid)?))
    }

    /// All run manifests in the lab, sorted by id.
    pub fn list_runs(&self) -> Result<Vec<Json>> {
        self.store.list_runs()
    }

    /// Garbage-collect damaged store files (see [`Store::gc`]).
    pub fn gc(&self, dry_run: bool) -> Result<GcReport> {
        self.store.gc(dry_run)
    }

    /// The persisted calibration entry for (`arch`, `source`, `sim`):
    /// the canonical key plus the stored payload with its resolution
    /// provenance, or `None` when nothing has been persisted yet. When a
    /// strategy-(c) residual model is persisted for the same coordinates
    /// its provenance (training-grid hash, feature list, seed) rides
    /// along under `"residual"`. Does not perturb store hit/miss
    /// accounting.
    pub fn trace_params(&self, arch: &str, source: ParamSource, sim: &SimConfig) -> Option<Json> {
        let key = store::params_key(arch, source, sim.fingerprint());
        let payload = self.store.peek(Kind::Params, &key)?;
        let mut pairs = vec![
            ("key", Json::str(key)),
            ("entry", payload),
        ];
        let rkey = store::residual_key(arch, source, sim.fingerprint());
        if let Some(residual) = self.store.peek(Kind::Residual, &rkey) {
            pairs.push((
                "residual",
                Json::obj(vec![("key", Json::str(rkey)), ("entry", residual)]),
            ));
        }
        Some(Json::obj(pairs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_lifecycle_and_listing() {
        let dir = crate::util::tmp::TempDir::new("lab").unwrap();
        let lab = Lab::open(dir.path()).unwrap();
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::small()],
            threads: vec![15],
            strategies: vec![crate::sweep::Strategy::A],
            ..GridSpec::default()
        };
        assert!(lab.find_run(&grid).unwrap().is_none());
        let results = lab.run(&grid, 0).unwrap();
        assert_eq!(results.results.len(), 1);
        let manifest = lab.find_run(&grid).unwrap().expect("manifest written");
        assert_eq!(manifest.get("status").unwrap().as_str(), Some("complete"));
        assert_eq!(manifest.get("scenarios").unwrap().as_usize(), Some(1));
        assert_eq!(lab.list_runs().unwrap().len(), 1);
    }

    #[test]
    fn shard_manifests_derive_from_the_parent_run() {
        let dir = crate::util::tmp::TempDir::new("lab").unwrap();
        let lab = Lab::open(dir.path()).unwrap();
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::small()],
            threads: vec![15, 240],
            strategies: vec![crate::sweep::Strategy::A, crate::sweep::Strategy::B],
            ..GridSpec::default()
        };
        let parent = Lab::run_id_for(&grid).unwrap();
        assert!(lab.find_shard_run(&grid, 0, 2).unwrap().is_none());
        let first = lab.run_shard(&grid, 0, 2, 0).unwrap();
        let second = lab.run_shard(&grid, 1, 2, 0).unwrap();
        assert_eq!(first.results.len() + second.results.len(), grid.len());
        let manifest = lab.find_shard_run(&grid, 0, 2).unwrap().expect("written");
        assert_eq!(
            manifest.get("id").unwrap().as_str(),
            Some(format!("{parent}.1of2").as_str())
        );
        assert_eq!(manifest.get("status").unwrap().as_str(), Some("complete"));
        assert_eq!(manifest.get("scenarios").unwrap().as_usize(), Some(2));
        let shard = manifest.get("shard").unwrap();
        assert_eq!(shard.get("parent").unwrap().as_str(), Some(parent.as_str()));
        assert_eq!(shard.get("index").unwrap().as_usize(), Some(1));
        assert_eq!(shard.get("count").unwrap().as_usize(), Some(2));
        // Shards list alongside (and sort under) their parent id.
        let ids: Vec<String> = lab
            .list_runs()
            .unwrap()
            .iter()
            .map(|m| m.get("id").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(ids, [format!("{parent}.1of2"), format!("{parent}.2of2")]);
        // Merged shard results cover the grid: every persisted cell key
        // matches what the unsharded run would write, so a follow-up
        // full run over the same store is pure hits.
        let before = lab.store().stats();
        let full = lab.run(&grid, 0).unwrap();
        let delta = lab.store().stats().since(&before);
        assert_eq!(delta.misses, 0, "warm full run after shards: {delta:?}");
        assert_eq!(full.results.len(), grid.len());
    }

    #[test]
    fn trace_params_after_a_run() {
        let dir = crate::util::tmp::TempDir::new("lab").unwrap();
        let lab = Lab::open(dir.path()).unwrap();
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::medium()],
            threads: vec![240],
            strategies: vec![crate::sweep::Strategy::B],
            ..GridSpec::default()
        };
        let sim = SimConfig::default();
        assert!(lab.trace_params("medium", ParamSource::Paper, &sim).is_none());
        lab.run(&grid, 0).unwrap();
        let trace = lab
            .trace_params("medium", ParamSource::Paper, &sim)
            .expect("params persisted by the run");
        let key = trace.get("key").unwrap().as_str().unwrap();
        assert!(key.starts_with("params:v1:medium:paper:"), "{key}");
        let entry = trace.get("entry").unwrap();
        assert_eq!(entry.get("calibrator").unwrap().as_str(), Some("paper"));
    }
}
