//! `micdl::lab` — the persistent experiment lab (ROADMAP item 1).
//!
//! Everything the sweep subsystem memoizes in-process — resolved model
//! parameters, evaluated cells, simulator measurements — is written
//! through to a content-addressed, JSON-on-disk [`Store`] and served
//! from disk on later invocations. Re-running an identical grid against
//! a warm lab performs zero model / cost-model / measurement
//! recomputation, and an interrupted sweep resumed against the same lab
//! completes bit-identically to a cold full run: cells are keyed by
//! their full axis coordinates (architecture × strategy × workload ×
//! thread count × parameter provenance × `SimConfig::fingerprint()`),
//! so "resume" is nothing more than re-enumerating the grid and letting
//! persisted cells hit.
//!
//! [`Lab`] is the facade the `repro` CLI fronts (`--lab PATH`,
//! `repro lab list|gc|trace-params`, `sweep --resume/--no-store`):
//!
//! ```no_run
//! use micdl::lab::Lab;
//! use micdl::sweep::GridSpec;
//!
//! let lab = Lab::open("./result")?;
//! let results = lab.run(&GridSpec::table9(), 0)?; // cold: computes + persists
//! let again = lab.run(&GridSpec::table9(), 0)?;   // warm: pure store hits
//! assert_eq!(results.results.len(), again.results.len());
//! # Ok::<(), micdl::Error>(())
//! ```
//!
//! Store layout, key grammar, gc semantics and the resume contract are
//! documented in docs/LAB.md.

#![warn(missing_docs)]

pub mod store;

pub use store::{
    cell_key, fnv1a, measured_key, params_key, run_id, source_tag, GcReport, Kind, Store,
    StoreStats, ENTRY_KIND, RUN_KIND, STORE_VERSION,
};

use std::path::Path;
use std::sync::Arc;

use crate::error::Result;
use crate::perfmodel::ParamSource;
use crate::simulator::SimConfig;
use crate::sweep::{GridSpec, SweepResults, SweepRunner};
use crate::util::json::Json;

/// A persistent experiment lab: a [`Store`] plus the run/resume
/// orchestration layered on top of [`SweepRunner`].
#[derive(Debug)]
pub struct Lab {
    store: Arc<Store>,
}

impl Lab {
    /// Open (creating if needed) the lab rooted at `path`.
    pub fn open(path: impl AsRef<Path>) -> Result<Lab> {
        Ok(Lab {
            store: Arc::new(Store::open(path)?),
        })
    }

    /// The underlying store (shared; hand clones to runners or cache
    /// layers).
    pub fn store(&self) -> &Arc<Store> {
        &self.store
    }

    /// A sweep runner wired to this lab's store (`workers` as in
    /// [`SweepRunner::new`]).
    pub fn runner(&self, workers: usize) -> SweepRunner {
        SweepRunner::new(workers).with_store(Arc::clone(&self.store))
    }

    /// The deterministic run id of a grid (FNV-1a of its exact spec
    /// JSON).
    pub fn run_id_for(grid: &GridSpec) -> Result<String> {
        Ok(store::run_id(&grid.to_spec_json()?.emit()))
    }

    /// Run a grid with persistence: writes a `running` manifest, sweeps
    /// (persisted cells hit, missing cells compute and write through),
    /// then marks the manifest `complete`. Calling this again with the
    /// same grid — including after an interruption — serves every
    /// already-persisted cell from disk and recomputes only the rest,
    /// with bit-identical merged results.
    pub fn run(&self, grid: &GridSpec, workers: usize) -> Result<SweepResults> {
        let spec = grid.to_spec_json()?;
        let id = store::run_id(&spec.emit());
        self.store
            .write_run(&id, &Self::manifest(&id, &spec, grid.len(), "running"))?;
        let results = self.runner(workers).run(grid)?;
        self.store
            .write_run(&id, &Self::manifest(&id, &spec, grid.len(), "complete"))?;
        Ok(results)
    }

    fn manifest(id: &str, spec: &Json, scenarios: usize, status: &str) -> Json {
        Json::obj(vec![
            ("kind", Json::str(RUN_KIND)),
            ("version", Json::num(1)),
            ("id", Json::str(id)),
            ("spec", spec.clone()),
            ("scenarios", Json::num(scenarios as f64)),
            ("status", Json::str(status)),
        ])
    }

    /// The manifest of a previous run of `grid`, if one exists
    /// (`--resume` consults this to report what it is resuming).
    pub fn find_run(&self, grid: &GridSpec) -> Result<Option<Json>> {
        Ok(self.store.read_run(&Self::run_id_for(grid)?))
    }

    /// All run manifests in the lab, sorted by id.
    pub fn list_runs(&self) -> Result<Vec<Json>> {
        self.store.list_runs()
    }

    /// Garbage-collect damaged store files (see [`Store::gc`]).
    pub fn gc(&self, dry_run: bool) -> Result<GcReport> {
        self.store.gc(dry_run)
    }

    /// The persisted calibration entry for (`arch`, `source`, `sim`):
    /// the canonical key plus the stored payload with its resolution
    /// provenance, or `None` when nothing has been persisted yet. Does
    /// not perturb store hit/miss accounting.
    pub fn trace_params(&self, arch: &str, source: ParamSource, sim: &SimConfig) -> Option<Json> {
        let key = store::params_key(arch, source, sim.fingerprint());
        let payload = self.store.peek(Kind::Params, &key)?;
        Some(Json::obj(vec![
            ("key", Json::str(key)),
            ("entry", payload),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_lifecycle_and_listing() {
        let dir = crate::util::tmp::TempDir::new("lab").unwrap();
        let lab = Lab::open(dir.path()).unwrap();
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::small()],
            threads: vec![15],
            strategies: vec![crate::sweep::Strategy::A],
            ..GridSpec::default()
        };
        assert!(lab.find_run(&grid).unwrap().is_none());
        let results = lab.run(&grid, 0).unwrap();
        assert_eq!(results.results.len(), 1);
        let manifest = lab.find_run(&grid).unwrap().expect("manifest written");
        assert_eq!(manifest.get("status").unwrap().as_str(), Some("complete"));
        assert_eq!(manifest.get("scenarios").unwrap().as_usize(), Some(1));
        assert_eq!(lab.list_runs().unwrap().len(), 1);
    }

    #[test]
    fn trace_params_after_a_run() {
        let dir = crate::util::tmp::TempDir::new("lab").unwrap();
        let lab = Lab::open(dir.path()).unwrap();
        let grid = GridSpec {
            archs: vec![crate::config::ArchSpec::medium()],
            threads: vec![240],
            strategies: vec![crate::sweep::Strategy::B],
            ..GridSpec::default()
        };
        let sim = SimConfig::default();
        assert!(lab.trace_params("medium", ParamSource::Paper, &sim).is_none());
        lab.run(&grid, 0).unwrap();
        let trace = lab
            .trace_params("medium", ParamSource::Paper, &sim)
            .expect("params persisted by the run");
        let key = trace.get("key").unwrap().as_str().unwrap();
        assert!(key.starts_with("params:v1:medium:paper:"), "{key}");
        let entry = trace.get("entry").unwrap();
        assert_eq!(entry.get("calibrator").unwrap().as_str(), Some("paper"));
    }
}
