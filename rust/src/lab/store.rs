//! The content-addressed JSON-on-disk entry store behind [`crate::lab::Lab`].
//!
//! Layout under the store root (see docs/LAB.md for the full contract):
//!
//! ```text
//! <root>/
//!   params/    resolved ModelParams per (arch, source, sim fingerprint)
//!   cells/     full sweep-cell predictions per scenario axis key
//!   measured/  simulator measurements per (arch, workload, fingerprint)
//!   runs/      sweep run manifests (not content-addressed entries)
//! ```
//!
//! Every entry file is named by the FNV-1a 64-bit hash of its canonical
//! key string (`{hash:016x}.json`) and wraps its payload in a versioned
//! envelope that repeats the full key:
//!
//! ```text
//! {"kind": "micdl-lab-entry", "version": 1, "key": "<canonical key>", "payload": {…}}
//! ```
//!
//! [`Store::get`] re-verifies the envelope kind, version and the *full*
//! stored key string, so a (vanishingly unlikely) hash collision, a
//! corrupt file or a foreign file in the directory reads as a miss — the
//! entry is then recomputed and overwritten — never as wrong data.
//! Writes go through a temp file + atomic rename; payload values are
//! deterministic for their key, so concurrent same-key writers race
//! harmlessly.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::Result;
use crate::perfmodel::ParamSource;
use crate::util::json::Json;

/// Store schema version. Bump on any incompatible change to the entry
/// envelope, the canonical key grammar, or a payload layout; entries
/// written by another version read as misses and are garbage-collected
/// by [`Store::gc`].
pub const STORE_VERSION: u64 = 1;

/// Envelope `kind` tag on every content-addressed entry file.
pub const ENTRY_KIND: &str = "micdl-lab-entry";

/// Envelope `kind` tag on run manifests under `runs/`.
pub const RUN_KIND: &str = "micdl-lab-run";

/// FNV-1a 64-bit hash — the store's content address. Stable across
/// platforms and releases (it is a file-name contract, not an in-process
/// detail), which is why this is hand-rolled rather than `DefaultHasher`.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The short provenance tag a [`ParamSource`] contributes to canonical
/// keys ("paper" table constants vs "sim"-calibrated parameters).
pub fn source_tag(source: ParamSource) -> &'static str {
    match source {
        ParamSource::Paper => "paper",
        ParamSource::Simulator => "sim",
    }
}

/// Canonical key for a resolved parameter set (calibration output).
pub fn params_key(arch: &str, source: ParamSource, fingerprint: u64) -> String {
    format!("params:v1:{arch}:{}:{fingerprint:016x}", source_tag(source))
}

/// Canonical key for a fitted strategy-(c) residual model
/// ([`crate::calibration::ResidualModel`]) — same addressing scheme as
/// [`params_key`], in its own namespace.
pub fn residual_key(arch: &str, source: ParamSource, fingerprint: u64) -> String {
    format!("residual:v1:{arch}:{}:{fingerprint:016x}", source_tag(source))
}

/// Canonical key for a fully evaluated sweep cell (prediction plus
/// optional measurement) — the scenario axes crossed with parameter
/// provenance and the simulator fingerprint.
#[allow(clippy::too_many_arguments)]
pub fn cell_key(
    arch: &str,
    strategy: &str,
    threads: usize,
    train_images: usize,
    test_images: usize,
    epochs: usize,
    source: ParamSource,
    fingerprint: u64,
) -> String {
    format!(
        "cell:v1:{arch}:{strategy}:{threads}:{train_images}:{test_images}:{epochs}:{}:{fingerprint:016x}",
        source_tag(source)
    )
}

/// Canonical key for a simulator measurement (strategy-independent).
pub fn measured_key(
    arch: &str,
    threads: usize,
    train_images: usize,
    test_images: usize,
    epochs: usize,
    fingerprint: u64,
) -> String {
    format!("measured:v1:{arch}:{threads}:{train_images}:{test_images}:{epochs}:{fingerprint:016x}")
}

/// The run id for a grid: FNV-1a of the grid's exact spec JSON. The
/// same grid always maps to the same manifest, which is what makes
/// `--resume` a pure lookup.
pub fn run_id(spec_json: &str) -> String {
    format!("{:016x}", fnv1a(spec_json.as_bytes()))
}

/// Manifest id for shard `k` (0-based) of `n` of a parent run:
/// `{parent}.{k+1}of{n}`. Hashing the shard's sub-grid would mint an id
/// with no visible relation to the grid it came from; deriving from the
/// parent id keeps shards grouped under their grid in `lab list` and
/// lets `--resume --shard` find the manifest by pure derivation. The
/// `.` separator sorts before every hex digit, so shard manifests list
/// immediately after their parent.
pub fn shard_run_id(parent: &str, k: usize, n: usize) -> String {
    format!("{parent}.{}of{n}", k + 1)
}

/// The content-addressed entry namespaces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Resolved `ModelParams` (calibration results, with provenance).
    Params,
    /// Evaluated sweep cells (prediction + optional measurement).
    Cells,
    /// Simulator measurements keyed independently of strategy.
    Measured,
    /// Fitted strategy-(c) residual models (weights + provenance).
    Residual,
}

impl Kind {
    /// All entry namespaces, in directory order.
    pub const ALL: [Kind; 4] = [Kind::Params, Kind::Cells, Kind::Measured, Kind::Residual];

    /// Directory name under the store root.
    pub fn dir(self) -> &'static str {
        match self {
            Kind::Params => "params",
            Kind::Cells => "cells",
            Kind::Measured => "measured",
            Kind::Residual => "residual",
        }
    }
}

/// Disk-store hit/miss counters, reported separately from the
/// in-process [`crate::sweep::CacheStats`] — a warm lab shows up here
/// even when the in-process memo starts cold.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Lookups served from disk.
    pub hits: u64,
    /// Lookups that missed (entry absent, corrupt, or version-skewed).
    pub misses: u64,
}

impl StoreStats {
    /// hits / (hits + misses); 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter delta since an earlier snapshot of the same store.
    pub fn since(&self, earlier: &StoreStats) -> StoreStats {
        StoreStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
        }
    }

    /// Sum with another snapshot or delta — how
    /// [`crate::sweep::merge_shards`] folds per-shard store traffic into
    /// the merged run's accounting.
    pub fn merged(&self, other: &StoreStats) -> StoreStats {
        StoreStats {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
        }
    }
}

/// What [`Store::gc`] did (or, with `dry_run`, would do).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcReport {
    /// Files examined across all store directories.
    pub scanned: usize,
    /// Files removed (corrupt, version-skewed, or leftover temp files).
    pub removed: usize,
    /// Healthy files kept.
    pub kept: usize,
    /// True when nothing was actually deleted.
    pub dry_run: bool,
}

/// A content-addressed, disk-backed entry store. Cheap to open; safe to
/// share across threads behind an `Arc` (all counters are atomic, all
/// writes atomic-rename).
#[derive(Debug)]
pub struct Store {
    root: PathBuf,
    /// Hit and miss counters packed into one word — hits in the high 32
    /// bits, misses in the low 32 — so a [`Store::stats`] snapshot is a
    /// single atomic load. Two independent counters would let a reader
    /// tear (load hits, lose the race, load newer misses), which made
    /// [`StoreStats::since`] deltas mix traffic from concurrent runs
    /// sharing one `Arc<Store>` — exactly what sharded sweep drivers do.
    /// 2³² lookups per side outlasts any realistic store lifetime.
    traffic: AtomicU64,
}

/// One packed-counter increment for a hit (high half of `traffic`).
const HIT_UNIT: u64 = 1 << 32;
/// One packed-counter increment for a miss (low half of `traffic`).
const MISS_UNIT: u64 = 1;

impl Store {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<Store> {
        let root = root.as_ref().to_path_buf();
        for kind in Kind::ALL {
            fs::create_dir_all(root.join(kind.dir()))?;
        }
        fs::create_dir_all(root.join("runs"))?;
        Ok(Store {
            root,
            traffic: AtomicU64::new(0),
        })
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Current hit/miss counters (monotonic over the store's lifetime;
    /// callers wanting per-run numbers snapshot before and
    /// [`StoreStats::since`] after). The snapshot is coherent: both
    /// counters come from one atomic load of the packed word, so the
    /// pair was simultaneously true at some instant even while other
    /// runs sharing the store keep recording.
    pub fn stats(&self) -> StoreStats {
        let packed = self.traffic.load(Ordering::Relaxed);
        StoreStats {
            hits: packed >> 32,
            misses: packed & (HIT_UNIT - 1),
        }
    }

    fn entry_path(&self, kind: Kind, key: &str) -> PathBuf {
        self.root
            .join(kind.dir())
            .join(format!("{:016x}.json", fnv1a(key.as_bytes())))
    }

    fn read_entry(path: &Path, key: &str) -> Option<Json> {
        let text = fs::read_to_string(path).ok()?;
        let doc = Json::parse(&text).ok()?;
        if doc.get("kind")?.as_str()? != ENTRY_KIND {
            return None;
        }
        if doc.get("version")?.as_usize()? as u64 != STORE_VERSION {
            return None;
        }
        // Full-key equality: a hash collision or foreign file is a miss,
        // never silently wrong data.
        if doc.get("key")?.as_str()? != key {
            return None;
        }
        doc.get("payload").cloned()
    }

    /// Look up an entry's payload, counting a hit or miss. Corrupt,
    /// version-skewed or key-mismatched files read as misses.
    pub fn get(&self, kind: Kind, key: &str) -> Option<Json> {
        let payload = self.peek(kind, key);
        self.record(payload.is_some());
        payload
    }

    /// Count one hit (`true`) or miss (`false`). For layers that
    /// [`Store::peek`] and then apply extra validity conditions (e.g. a
    /// measuring sweep rejecting a measurement-less cell) before
    /// deciding what the lookup really was.
    pub fn record(&self, hit: bool) {
        let unit = if hit { HIT_UNIT } else { MISS_UNIT };
        self.traffic.fetch_add(unit, Ordering::Relaxed);
    }

    /// Like [`Store::get`] but without touching the hit/miss counters —
    /// for introspection paths (`trace-params`) that must not perturb
    /// per-run store accounting.
    pub fn peek(&self, kind: Kind, key: &str) -> Option<Json> {
        Self::read_entry(&self.entry_path(kind, key), key)
    }

    /// Write an entry (versioned envelope + payload) via temp file and
    /// atomic rename. Same-key writers race harmlessly: payloads are
    /// deterministic functions of their key.
    pub fn put(&self, kind: Kind, key: &str, payload: Json) -> Result<()> {
        let doc = Json::obj(vec![
            ("kind", Json::str(ENTRY_KIND)),
            ("version", Json::num(STORE_VERSION as f64)),
            ("key", Json::str(key)),
            ("payload", payload),
        ]);
        self.write_atomic(&self.entry_path(kind, key), &doc)
    }

    fn write_atomic(&self, path: &Path, doc: &Json) -> Result<()> {
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        fs::write(&tmp, doc.emit())?;
        fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Path of the manifest for run `id` (exists or not).
    pub fn run_path(&self, id: &str) -> PathBuf {
        self.root.join("runs").join(format!("{id}.json"))
    }

    /// Read a run manifest. Manifest reads bypass the hit/miss counters
    /// — they are bookkeeping, not memoized computation.
    pub fn read_run(&self, id: &str) -> Option<Json> {
        let doc = Json::parse(&fs::read_to_string(self.run_path(id)).ok()?).ok()?;
        if doc.get("kind")?.as_str()? != RUN_KIND {
            return None;
        }
        Some(doc)
    }

    /// Write (or overwrite) a run manifest atomically.
    pub fn write_run(&self, id: &str, manifest: &Json) -> Result<()> {
        self.write_atomic(&self.run_path(id), manifest)
    }

    /// All parseable run manifests, sorted by id.
    pub fn list_runs(&self) -> Result<Vec<Json>> {
        let mut runs = Vec::new();
        for entry in fs::read_dir(self.root.join("runs"))? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            if let Some(id) = path.file_stem().and_then(|s| s.to_str()) {
                if let Some(doc) = self.read_run(id) {
                    runs.push(doc);
                }
            }
        }
        runs.sort_by(|a, b| {
            let id = |d: &Json| d.get("id").and_then(|i| i.as_str().map(String::from));
            id(a).cmp(&id(b))
        });
        Ok(runs)
    }

    /// Remove damaged files: unparseable entries, entries from another
    /// [`STORE_VERSION`], and leftover temp files. Healthy entries are
    /// never removed — they are content-addressed and shared across
    /// runs, so "unreferenced" is not a meaningful state — and run
    /// manifests that parse are always kept (a `running` manifest is
    /// what `--resume` looks for).
    pub fn gc(&self, dry_run: bool) -> Result<GcReport> {
        let mut report = GcReport {
            dry_run,
            ..GcReport::default()
        };
        let mut dirs: Vec<PathBuf> =
            Kind::ALL.iter().map(|k| self.root.join(k.dir())).collect();
        dirs.push(self.root.join("runs"));
        for dir in dirs {
            let in_runs = dir.ends_with("runs");
            for entry in fs::read_dir(&dir)? {
                let path = entry?.path();
                if !path.is_file() {
                    continue;
                }
                report.scanned += 1;
                let healthy = if path.extension().and_then(|e| e.to_str()) != Some("json") {
                    false // leftover temp file
                } else if in_runs {
                    path.file_stem()
                        .and_then(|s| s.to_str())
                        .and_then(|id| self.read_run(id))
                        .is_some()
                } else {
                    fs::read_to_string(&path)
                        .ok()
                        .and_then(|text| Json::parse(&text).ok())
                        .map(|doc| {
                            doc.get("kind").and_then(Json::as_str) == Some(ENTRY_KIND)
                                && doc.get("version").and_then(Json::as_usize)
                                    == Some(STORE_VERSION as usize)
                                && doc.get("key").and_then(Json::as_str).is_some()
                        })
                        .unwrap_or(false)
                };
                if healthy {
                    report.kept += 1;
                } else {
                    report.removed += 1;
                    if !dry_run {
                        fs::remove_file(&path)?;
                    }
                }
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn fnv1a_known_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn roundtrip_and_counters() {
        let dir = TempDir::new("store").unwrap();
        let store = Store::open(dir.path()).unwrap();
        let key = params_key("small", ParamSource::Paper, 7);
        assert!(store.get(Kind::Params, &key).is_none());
        let payload = Json::obj(vec![("x", Json::num(1.5))]);
        store.put(Kind::Params, &key, payload.clone()).unwrap();
        assert_eq!(store.get(Kind::Params, &key), Some(payload));
        assert_eq!(store.stats(), StoreStats { hits: 1, misses: 1 });
    }

    #[test]
    fn stats_snapshots_are_coherent_under_concurrent_recording() {
        let dir = TempDir::new("store").unwrap();
        let store = Store::open(dir.path()).unwrap();
        const PAIRS: u64 = 20_000;
        const THREADS: u64 = 4;
        std::thread::scope(|scope| {
            for _ in 0..THREADS {
                scope.spawn(|| {
                    for _ in 0..PAIRS {
                        store.record(true);
                        store.record(false);
                    }
                });
            }
            // Every recorder counts a hit strictly before its matching
            // miss, so any coherent snapshot satisfies
            // `misses <= hits <= misses + THREADS` (at most one
            // unmatched hit in flight per thread). The old two-load
            // snapshot tears past the upper and lower bound alike.
            for _ in 0..20_000 {
                let s = store.stats();
                assert!(
                    s.misses <= s.hits && s.hits <= s.misses + THREADS,
                    "torn snapshot: {s:?}"
                );
            }
        });
        let total = THREADS * PAIRS;
        assert_eq!(
            store.stats(),
            StoreStats {
                hits: total,
                misses: total
            }
        );
    }

    #[test]
    fn shard_run_ids_derive_from_and_sort_under_the_parent() {
        assert_eq!(shard_run_id("00000000000000ab", 0, 3), "00000000000000ab.1of3");
        assert_eq!(shard_run_id("00000000000000ab", 2, 3), "00000000000000ab.3of3");
        // `.` < any hex digit, so shards group right after the parent id
        // in the lexicographic `list_runs` order.
        let mut ids = vec![
            "00000000000000ac".to_string(),
            shard_run_id("00000000000000ab", 1, 3),
            "00000000000000ab".to_string(),
        ];
        ids.sort();
        assert_eq!(
            ids,
            [
                "00000000000000ab",
                "00000000000000ab.2of3",
                "00000000000000ac"
            ]
        );
    }

    #[test]
    fn key_mismatch_reads_as_miss() {
        let dir = TempDir::new("store").unwrap();
        let store = Store::open(dir.path()).unwrap();
        let key = "cell:v1:x";
        store.put(Kind::Cells, key, Json::num(1)).unwrap();
        // Overwrite the file with an envelope carrying a different key
        // (what a hash collision would look like).
        let path = store.entry_path(Kind::Cells, key);
        let forged = Json::obj(vec![
            ("kind", Json::str(ENTRY_KIND)),
            ("version", Json::num(STORE_VERSION as f64)),
            ("key", Json::str("cell:v1:other")),
            ("payload", Json::num(2)),
        ]);
        std::fs::write(&path, forged.emit()).unwrap();
        assert!(store.get(Kind::Cells, key).is_none());
    }

    #[test]
    fn corrupt_and_version_skew_read_as_miss_and_gc_removes_them() {
        let dir = TempDir::new("store").unwrap();
        let store = Store::open(dir.path()).unwrap();
        store.put(Kind::Params, "params:v1:ok", Json::num(1)).unwrap();
        let corrupt = store.entry_path(Kind::Params, "params:v1:bad");
        std::fs::write(&corrupt, "{ not json").unwrap();
        let skewed = store.entry_path(Kind::Cells, "cell:v1:old");
        let old = Json::obj(vec![
            ("kind", Json::str(ENTRY_KIND)),
            ("version", Json::num(99)),
            ("key", Json::str("cell:v1:old")),
            ("payload", Json::num(2)),
        ]);
        std::fs::write(&skewed, old.emit()).unwrap();
        let tmp = dir.path().join("measured").join("feed.tmp.123");
        std::fs::write(&tmp, "partial").unwrap();
        assert!(store.get(Kind::Params, "params:v1:bad").is_none());
        assert!(store.get(Kind::Cells, "cell:v1:old").is_none());

        let dry = store.gc(true).unwrap();
        assert_eq!(dry, GcReport { scanned: 4, removed: 3, kept: 1, dry_run: true });
        assert!(corrupt.exists() && skewed.exists() && tmp.exists());
        let real = store.gc(false).unwrap();
        assert_eq!(real, GcReport { scanned: 4, removed: 3, kept: 1, dry_run: false });
        assert!(!corrupt.exists() && !skewed.exists() && !tmp.exists());
        assert!(store.peek(Kind::Params, "params:v1:ok").is_some());
    }

    #[test]
    fn gc_keeps_parseable_run_manifests() {
        let dir = TempDir::new("store").unwrap();
        let store = Store::open(dir.path()).unwrap();
        let manifest = Json::obj(vec![
            ("kind", Json::str(RUN_KIND)),
            ("version", Json::num(1)),
            ("id", Json::str("abc")),
            ("status", Json::str("running")),
        ]);
        store.write_run("abc", &manifest).unwrap();
        std::fs::write(store.run_path("junk"), "garbage").unwrap();
        let report = store.gc(false).unwrap();
        assert_eq!(report.removed, 1);
        assert!(store.read_run("abc").is_some());
        assert!(store.read_run("junk").is_none());
    }

    #[test]
    fn run_manifest_listing_sorted() {
        let dir = TempDir::new("store").unwrap();
        let store = Store::open(dir.path()).unwrap();
        for id in ["bb", "aa"] {
            let m = Json::obj(vec![
                ("kind", Json::str(RUN_KIND)),
                ("version", Json::num(1)),
                ("id", Json::str(id)),
                ("status", Json::str("complete")),
            ]);
            store.write_run(id, &m).unwrap();
        }
        let runs = store.list_runs().unwrap();
        let ids: Vec<&str> = runs
            .iter()
            .map(|r| r.get("id").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(ids, ["aa", "bb"]);
    }

    #[test]
    fn keys_embed_all_axes() {
        let k = cell_key("small", "a", 240, 60_000, 10_000, 70, ParamSource::Simulator, 0xAB);
        assert_eq!(k, "cell:v1:small:a:240:60000:10000:70:sim:00000000000000ab");
        let m = measured_key("large", 15, 600, 100, 2, 1);
        assert_eq!(m, "measured:v1:large:15:600:100:2:0000000000000001");
        let p = params_key("medium", ParamSource::Paper, u64::MAX);
        assert_eq!(p, "params:v1:medium:paper:ffffffffffffffff");
    }
}
