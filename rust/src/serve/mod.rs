//! Prediction-as-a-service: the batched what-if query engine behind
//! `repro predict --batch` and the embedded `repro serve` HTTP server.
//!
//! The sweep engine answers "evaluate this grid"; this module answers
//! "evaluate these *questions*" — a [`QueryBatch`] of heterogeneous
//! (architecture, strategy, thread-ladder, workload, sim-variant)
//! queries — without giving up any of the sweep's guarantees:
//!
//! * **Bit-identity** — every query expands to a [`Query::to_grid`]
//!   sweep grid and every cell runs through the sweep runner's single
//!   evaluation path and the sweep dump's single row serializer, so a
//!   predict row is byte-for-byte the row `repro sweep run` would emit
//!   for the same cell.
//! * **Bounded resolution** — a batch resolves parameter tables at
//!   most once per distinct (architecture, sim fingerprint) pair, no
//!   matter how many queries or cells reference the pair
//!   ([`PredictEngine`] resolves serially up front, then fans out).
//! * **Warm starts** — pointing the engine at a lab store
//!   ([`PredictEngine::with_store`], `--lab`) turns previously swept
//!   cells into store hits; a fully warm batch performs zero
//!   calibration resolutions.
//!
//! [`Server`] wraps the engine in a zero-dependency HTTP/1.1 front end
//! (`POST /predict`, `GET /healthz`, `GET /stats`, `POST /shutdown`).
//! See `docs/SERVE.md` for the batch schema, endpoint reference, and
//! throughput methodology.

#![warn(missing_docs)]

pub mod batch;
pub mod engine;
pub mod http;

pub use batch::{Query, QueryBatch};
pub use engine::{predict_doc, PredictEngine, QueryResult, ServeStats};
pub use http::Server;
