//! The embedded prediction server: a zero-dependency HTTP/1.1 front end
//! over [`PredictEngine`], built on `std::net::TcpListener` and the
//! scoped-thread pool pattern the sweep runner uses.
//!
//! Endpoints:
//!
//! * `GET /healthz` — liveness: `{"ok": true}`;
//! * `GET /stats` — cumulative [`crate::serve::ServeStats`] JSON;
//! * `POST /predict` — evaluate a query batch (the body is the
//!   [`crate::serve::QueryBatch`] JSON); 200 with the predict document
//!   on success, 400 with `{"error": "..."}` on a malformed batch;
//! * `POST /shutdown` — acknowledge, then stop accepting and drain the
//!   worker pool (used by tests and the CI smoke for a clean exit).
//!
//! Protocol-level problems get explicit `{"error": ...}` responses
//! rather than a dropped connection: 411 for a POST without a
//! `Content-Length`, 400 for an unparseable one, 413 for a body over
//! the cap, 431 for an oversized request head.
//!
//! Every response closes its connection (`Connection: close`) — the
//! protocol surface is deliberately minimal; batching amortizes the
//! per-connection cost, not keep-alive.

use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::serve::batch::QueryBatch;
use crate::serve::engine::{predict_doc, PredictEngine};
use crate::util::json::Json;

/// Per-connection I/O deadline: a stalled client must not pin a worker.
const IO_TIMEOUT: Duration = Duration::from_secs(30);
/// Request head (request line + headers) size cap.
const MAX_HEAD: usize = 64 * 1024;
/// Request body size cap (a million-query ladder batch is ~100 MB).
const MAX_BODY: usize = 256 * 1024 * 1024;

/// A parsed (enough) HTTP/1.1 request.
struct Request {
    method: String,
    path: String,
    body: String,
}

/// The prediction server. Bind, then [`Server::run`] — which blocks
/// until a `POST /shutdown` arrives.
pub struct Server {
    listener: TcpListener,
    engine: Arc<PredictEngine>,
    workers: usize,
    stop: AtomicBool,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:8787`; port 0 picks a free port —
    /// read it back with [`Server::local_addr`]). `workers` accept
    /// loops share the listener (0 = one per available CPU).
    pub fn bind(engine: Arc<PredictEngine>, addr: &str, workers: usize) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Config(format!("cannot bind {addr}: {e}")))?;
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            workers
        };
        Ok(Server { listener, engine, workers, stop: AtomicBool::new(false) })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(Error::Io)
    }

    /// Accept and serve until shut down. Blocks the calling thread;
    /// the worker pool lives in a [`std::thread::scope`], so a clean
    /// return means every worker has drained.
    pub fn run(&self) -> Result<()> {
        let mut listeners = Vec::with_capacity(self.workers);
        for _ in 0..self.workers {
            listeners.push(self.listener.try_clone().map_err(Error::Io)?);
        }
        std::thread::scope(|scope| {
            for listener in listeners {
                scope.spawn(move || self.worker(listener));
            }
        });
        Ok(())
    }

    /// One accept loop. Wake connections sent by [`Server::shutdown`]
    /// are never parsed: the stop flag is checked right after accept.
    fn worker(&self, listener: TcpListener) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let _ = self.handle(stream);
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                }
                Err(_) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
    }

    /// Flip the stop flag and wake every worker blocked in `accept` by
    /// self-connecting once per worker (the `/shutdown` handler's other
    /// half; also usable directly by an embedding test).
    fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let Ok(mut addr) = self.listener.local_addr() else { return };
        if addr.ip().is_unspecified() {
            addr.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
        }
        for _ in 0..self.workers {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
        }
    }

    /// Serve one connection.
    fn handle(&self, mut stream: TcpStream) -> std::io::Result<()> {
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        stream.set_write_timeout(Some(IO_TIMEOUT))?;
        let req = match read_request(&mut stream)? {
            Parsed::Request(req) => req,
            Parsed::Closed => return Ok(()), // nothing arrived — nothing to answer
            Parsed::Reject(status, reason, msg) => {
                let doc = Json::obj(vec![("error", Json::str(msg))]);
                return respond(&mut stream, status, reason, &doc.emit());
            }
        };
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => respond(&mut stream, 200, "OK", "{\"ok\": true}"),
            ("GET", "/stats") => {
                respond(&mut stream, 200, "OK", &self.engine.stats().to_json().emit())
            }
            ("POST", "/predict") => {
                let reply = QueryBatch::from_json(&req.body)
                    .and_then(|batch| self.engine.eval_batch(&batch));
                match reply {
                    Ok(results) => {
                        let doc = predict_doc(&results, &self.engine.stats());
                        respond(&mut stream, 200, "OK", &doc.emit())
                    }
                    Err(e) => {
                        let doc = Json::obj(vec![("error", Json::str(e.to_string()))]);
                        respond(&mut stream, 400, "Bad Request", &doc.emit())
                    }
                }
            }
            ("POST", "/shutdown") => {
                let out = respond(&mut stream, 200, "OK", "{\"ok\": true}");
                self.shutdown();
                out
            }
            _ => respond(&mut stream, 404, "Not Found", "{\"error\": \"not found\"}"),
        }
    }
}

/// First index of `needle` in `haystack`.
fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// What one connection's request parse produced.
enum Parsed {
    /// A complete request, ready to route.
    Request(Request),
    /// Connection closed before a full head arrived — nothing to
    /// answer (shutdown wake connections land here).
    Closed,
    /// A protocol-level reject: answer `(status, reason)` with an
    /// `{"error": message}` body, then close.
    Reject(u16, &'static str, String),
}

/// Read one request: head up to the blank line, then exactly
/// `Content-Length` body bytes. Cap breaches and missing/unparseable
/// lengths come back as [`Parsed::Reject`] so the client gets an
/// explicit 4xx instead of a silently dropped connection.
fn read_request(stream: &mut TcpStream) -> std::io::Result<Parsed> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 16 * 1024];
    let head_end = loop {
        if let Some(pos) = find(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return Ok(Parsed::Reject(
                431,
                "Request Header Fields Too Large",
                format!("request head exceeds {MAX_HEAD} bytes"),
            ));
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Ok(Parsed::Closed);
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.split("\r\n");
    let mut request_line = lines.next().unwrap_or("").split_whitespace();
    let method = request_line.next().unwrap_or("").to_string();
    let path = request_line.next().unwrap_or("").to_string();
    let mut content_length: Option<usize> = None;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                match value.trim().parse() {
                    Ok(len) => content_length = Some(len),
                    Err(_) => {
                        return Ok(Parsed::Reject(
                            400,
                            "Bad Request",
                            format!("unparseable Content-Length {:?}", value.trim()),
                        ))
                    }
                }
            }
        }
    }
    let content_length = match content_length {
        Some(len) if len > MAX_BODY => {
            return Ok(Parsed::Reject(
                413,
                "Payload Too Large",
                format!("body of {len} bytes exceeds the {MAX_BODY}-byte cap"),
            ))
        }
        Some(len) => len,
        // A POST carries its batch in the body; without a length the
        // server would parse an empty batch and emit a confusing 400.
        None if method == "POST" => {
            return Ok(Parsed::Reject(
                411,
                "Length Required",
                "POST requests need a Content-Length header".to_string(),
            ))
        }
        None => 0,
    };
    let mut body: Vec<u8> = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(Parsed::Request(Request {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    }))
}

/// Write one `Connection: close` JSON response.
fn respond(stream: &mut TcpStream, status: u16, reason: &str, body: &str) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_locates_the_head_terminator() {
        assert_eq!(find(b"GET / HTTP/1.1\r\n\r\nbody", b"\r\n\r\n"), Some(14));
        assert_eq!(find(b"partial\r\n", b"\r\n\r\n"), None);
    }
}
