//! The query-batch schema: what `repro predict --batch` and
//! `POST /predict` accept.
//!
//! A batch is a JSON array of query objects (or an object with a single
//! `queries` array), each query naming one architecture, the strategies
//! to evaluate, and a thread ladder — either an explicit `threads` list
//! or a `threads_range` object in exactly the sweep-spec grammar
//! ([`crate::sweep::threads_range_from_json`]):
//!
//! ```json
//! [
//!   {"arch": "small", "strategy": "both", "threads": [1, 15, 61, 240]},
//!   {"arch": "large", "strategy": "b",
//!    "threads_range": {"from": 1, "to": 244},
//!    "train_images": 120000, "test_images": 20000, "epochs": 30,
//!    "sim": {"name": "overclocked", "clock_ghz": 1.5}}
//! ]
//! ```
//!
//! Every query expands to a small [`GridSpec`] ([`Query::to_grid`]) and
//! is evaluated through exactly the sweep engine's cell path, so predict
//! results are bit-identical to the corresponding sweep cells.

use crate::config::ArchSpec;
use crate::error::{Error, Result};
use crate::perfmodel::ParamSource;
use crate::sweep::grid::{threads_range_from_json, GridSpec, SimVariant, Strategy};
use crate::util::json::Json;

/// One what-if query: an architecture × strategy set × thread ladder
/// over a single workload (and optionally a simulator variant).
#[derive(Debug, Clone)]
pub struct Query {
    /// Architecture name (`small` / `medium` / `large`).
    pub arch: String,
    /// Model strategies to evaluate (`"a"` / `"b"` / `"both"`;
    /// default both).
    pub strategies: Vec<Strategy>,
    /// The thread ladder the query fans out over.
    pub threads: Vec<usize>,
    /// Training (and validation) image count (default 60,000 — the
    /// paper workload).
    pub train_images: usize,
    /// Test image count (default 10,000).
    pub test_images: usize,
    /// Training epochs (`None` = the paper default for the
    /// architecture, exactly like an empty sweep epoch axis).
    pub epochs: Option<usize>,
    /// Optional simulator-variant override set (the sweep sim axis,
    /// one variant per query).
    pub sim: Option<SimVariant>,
}

impl Query {
    /// The JSON keys a query object may carry (unknown keys are
    /// rejected — a typo must not silently predict the wrong scenario).
    const KNOWN_KEYS: [&'static str; 8] = [
        "arch",
        "strategy",
        "threads",
        "threads_range",
        "train_images",
        "test_images",
        "epochs",
        "sim",
    ];

    /// Parse one query object.
    pub fn from_json(node: &Json) -> Result<Query> {
        let Some(pairs) = node.as_obj() else {
            return Err(Error::Config("batch queries must be JSON objects".into()));
        };
        for (key, _) in pairs {
            if !Self::KNOWN_KEYS.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "unknown query key {key:?} (known keys: {:?})",
                    Self::KNOWN_KEYS
                )));
            }
        }
        let arch = node
            .get("arch")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Config("query needs an \"arch\" string".into()))?
            .to_string();
        let strategies = match node.get("strategy") {
            None => vec![Strategy::A, Strategy::B],
            Some(s) => {
                let text = s.as_str().ok_or_else(|| {
                    Error::Config("query strategy must be a string (a|b|c|both)".into())
                })?;
                Strategy::parse_list(text)?
            }
        };
        if node.get("threads").is_some() && node.get("threads_range").is_some() {
            return Err(Error::Config(
                "query gives both \"threads\" and \"threads_range\" — pick one".into(),
            ));
        }
        let threads = match (node.get("threads"), node.get("threads_range")) {
            (Some(t), None) => match (t.as_arr(), t.as_usize()) {
                (Some(arr), _) => arr
                    .iter()
                    .map(|v| {
                        v.as_usize().ok_or_else(|| {
                            Error::Config("query threads entries must be integers".into())
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                (None, Some(p)) => vec![p],
                (None, None) => {
                    return Err(Error::Config(
                        "query threads must be an integer or an integer array".into(),
                    ))
                }
            },
            (None, Some(range)) => threads_range_from_json(range, "threads_range")?,
            (None, None) => {
                return Err(Error::Config(
                    "query needs \"threads\" or \"threads_range\"".into(),
                ))
            }
            (Some(_), Some(_)) => unreachable!("rejected above"),
        };
        let int = |key: &str, default: usize| -> Result<usize> {
            match node.get(key) {
                None => Ok(default),
                Some(v) => v.as_usize().ok_or_else(|| {
                    Error::Config(format!("query {key} must be an integer"))
                }),
            }
        };
        let epochs = match node.get("epochs") {
            None => None,
            Some(v) => Some(v.as_usize().ok_or_else(|| {
                Error::Config("query epochs must be an integer".into())
            })?),
        };
        let sim = match node.get("sim") {
            None => None,
            Some(v) => Some(SimVariant::from_json(v)?),
        };
        Ok(Query {
            arch,
            strategies,
            threads,
            train_images: int("train_images", 60_000)?,
            test_images: int("test_images", 10_000)?,
            epochs,
            sim,
        })
    }

    /// Expand the query into the equivalent sweep grid (validated): one
    /// architecture × the query's strategies × its thread ladder on the
    /// default 7120P machine. Evaluating this grid cell-by-cell is what
    /// makes predict output bit-identical to `repro sweep run`.
    pub fn to_grid(&self, params: ParamSource) -> Result<GridSpec> {
        let grid = GridSpec {
            archs: vec![ArchSpec::by_name(&self.arch)?],
            images: vec![(self.train_images, self.test_images)],
            epochs: self.epochs.map(|e| vec![e]).unwrap_or_default(),
            threads: self.threads.clone(),
            strategies: self.strategies.clone(),
            sims: self.sim.clone().map(|v| vec![v]).unwrap_or_default(),
            params,
            measure: false,
            ..GridSpec::default()
        };
        grid.validate()?;
        Ok(grid)
    }
}

/// A parsed prediction batch: the unit `POST /predict` and
/// `repro predict --batch` evaluate.
#[derive(Debug, Clone)]
pub struct QueryBatch {
    /// The queries, in input order (results keep this order).
    pub queries: Vec<Query>,
}

impl QueryBatch {
    /// Parse a batch document: a JSON array of query objects, or an
    /// object `{"queries": [...]}`. Empty batches are rejected.
    pub fn from_json(text: &str) -> Result<QueryBatch> {
        let doc = Json::parse(text)?;
        let arr = match (doc.as_arr(), doc.as_obj()) {
            (Some(arr), _) => arr,
            (None, Some(pairs)) => {
                for (key, _) in pairs {
                    if key != "queries" {
                        return Err(Error::Config(format!(
                            "unknown batch key {key:?} (a batch is an array of \
                             queries or {{\"queries\": [...]}})"
                        )));
                    }
                }
                doc.get("queries").and_then(Json::as_arr).ok_or_else(|| {
                    Error::Config("batch \"queries\" must be an array".into())
                })?
            }
            (None, None) => {
                return Err(Error::Config(
                    "a batch is a JSON array of queries or {\"queries\": [...]}".into(),
                ))
            }
        };
        let queries = arr.iter().map(Query::from_json).collect::<Result<Vec<_>>>()?;
        if queries.is_empty() {
            return Err(Error::Config("batch has no queries".into()));
        }
        Ok(QueryBatch { queries })
    }

    /// Total cells the batch expands to (sum of ladder × strategy sizes).
    pub fn cells(&self) -> usize {
        self.queries
            .iter()
            .map(|q| q.threads.len() * q.strategies.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_array_and_object_forms_with_defaults() {
        let batch = QueryBatch::from_json(
            r#"[{"arch": "small", "threads": [1, 15, 240]}]"#,
        )
        .unwrap();
        assert_eq!(batch.queries.len(), 1);
        let q = &batch.queries[0];
        assert_eq!(q.arch, "small");
        assert_eq!(q.strategies, vec![Strategy::A, Strategy::B]);
        assert_eq!(q.threads, vec![1, 15, 240]);
        assert_eq!((q.train_images, q.test_images), (60_000, 10_000));
        assert_eq!(q.epochs, None);
        assert!(q.sim.is_none());
        assert_eq!(batch.cells(), 6);

        let wrapped = QueryBatch::from_json(
            r#"{"queries": [{"arch": "large", "strategy": "b", "threads": 240,
                             "epochs": 5, "sim": {"clock_ghz": 1.5}}]}"#,
        )
        .unwrap();
        let q = &wrapped.queries[0];
        assert_eq!(q.strategies, vec![Strategy::B]);
        assert_eq!(q.threads, vec![240]);
        assert_eq!(q.epochs, Some(5));
        assert_eq!(q.sim.as_ref().unwrap().clock_ghz, Some(1.5));
        assert_eq!(wrapped.cells(), 1);
    }

    #[test]
    fn threads_range_shares_the_sweep_grammar_and_rejects_reversal() {
        let batch = QueryBatch::from_json(
            r#"[{"arch": "small", "threads_range": {"from": 10, "to": 30, "step": 10}}]"#,
        )
        .unwrap();
        assert_eq!(batch.queries[0].threads, vec![10, 20, 30]);
        // The silent-empty-axis bugfix applies to serve queries too.
        let err = QueryBatch::from_json(
            r#"[{"arch": "small", "threads_range": {"from": 30, "to": 10}}]"#,
        )
        .expect_err("reversed range must be rejected");
        assert!(err.to_string().contains("below range start"), "{err}");
    }

    #[test]
    fn rejects_malformed_batches() {
        assert!(QueryBatch::from_json("[]").is_err());
        assert!(QueryBatch::from_json("{}").is_err());
        assert!(QueryBatch::from_json(r#"[{"threads": [1]}]"#).is_err());
        assert!(QueryBatch::from_json(r#"[{"arch": "small"}]"#).is_err());
        assert!(QueryBatch::from_json(r#"[{"arch": "small", "thread": [1]}]"#).is_err());
        assert!(QueryBatch::from_json(
            r#"[{"arch": "small", "threads": [1], "threads_range": {"from": 1}}]"#
        )
        .is_err());
        assert!(QueryBatch::from_json(r#"[{"arch": "small", "threads": [0]}]"#)
            .unwrap()
            .queries[0]
            .to_grid(ParamSource::Paper)
            .is_err());
        assert!(QueryBatch::from_json(r#"{"batch": []}"#).is_err());
    }

    #[test]
    fn to_grid_expands_to_the_equivalent_sweep_grid() {
        let batch = QueryBatch::from_json(
            r#"[{"arch": "medium", "strategy": "a", "threads": [15, 240],
                 "train_images": 1000, "test_images": 100, "epochs": 2}]"#,
        )
        .unwrap();
        let grid = batch.queries[0].to_grid(ParamSource::Simulator).unwrap();
        assert_eq!(grid.archs[0].name, "medium");
        assert_eq!(grid.strategies, vec![Strategy::A]);
        assert_eq!(grid.threads, vec![15, 240]);
        assert_eq!(grid.images, vec![(1000, 100)]);
        assert_eq!(grid.epochs, vec![2]);
        assert_eq!(grid.params, ParamSource::Simulator);
        assert!(!grid.measure);
        assert_eq!(grid.len(), 2);
        // Omitted epochs leave the axis empty → paper default per arch.
        let defaulted = QueryBatch::from_json(r#"[{"arch": "small", "threads": [1]}]"#)
            .unwrap()
            .queries[0]
            .to_grid(ParamSource::Paper)
            .unwrap();
        assert!(defaulted.epochs.is_empty());
        assert_eq!(defaulted.enumerate()[0].epochs, 70);
    }
}
