//! The batched prediction engine: evaluate [`QueryBatch`]es against
//! precomputed parameter tables, bit-identically to the sweep engine.
//!
//! A [`PredictEngine`] owns one long-lived [`SweepCache`]. Each batch is
//! evaluated in two phases:
//!
//! 1. **Resolve** — build the model for every distinct (architecture,
//!    strategy, sim fingerprint) combination the batch touches. Model
//!    construction is what triggers
//!    [`crate::calibration::Calibration::resolve`], and both the model
//!    memo and the calibration memo are single-flight
//!    ([`crate::util::memo::Memo`]) keyed by exactly those axes, so
//!    across the whole engine **each distinct (arch, sim fingerprint)
//!    pair resolves at most once, ever** — concurrent batches racing on
//!    the same pair coalesce onto one in-flight resolution instead of
//!    duplicating it. The resolutions ≤ pairs invariant therefore holds
//!    structurally, with no cross-batch serialization: batches resolve
//!    in parallel (the PR-8-era engine-level resolve mutex is gone).
//! 2. **Evaluate** — fan the queries out over a scoped-thread pool
//!    (the [`crate::sweep::runner`] claim-by-cursor pattern) and run
//!    every scenario through [`crate::sweep::runner::evaluate`] — the
//!    single cell path shared with `repro sweep run`, which is what
//!    makes predict rows bit-identical to the corresponding sweep
//!    cells. Workers only ever hit the memos built in phase 1.
//!
//! With a lab store attached ([`PredictEngine::with_store`]), cells that
//! a previous sweep or batch persisted short-circuit to store hits and a
//! fully warm batch performs zero calibration resolutions.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::lab::{Store, StoreStats};
use crate::perfmodel::ParamSource;
use crate::serve::batch::QueryBatch;
use crate::sweep::grid::{GridSpec, Scenario};
use crate::sweep::summary::result_row_json;
use crate::sweep::{runner, ScenarioResult, SweepCache};
use crate::util::json::Json;

/// One evaluated query: its expanded grid and the per-cell results in
/// grid-enumeration order (the same order `repro sweep run` reports).
#[derive(Debug)]
pub struct QueryResult {
    /// The grid the query expanded to ([`crate::serve::Query::to_grid`]).
    pub grid: GridSpec,
    /// One result per scenario, in enumeration order.
    pub results: Vec<ScenarioResult>,
}

impl QueryResult {
    /// The query's result rows in the sweep dump's `results[]` shape —
    /// produced by the same [`result_row_json`] the sweep JSON dump
    /// uses, so the bytes match cell for cell.
    pub fn rows(&self) -> Vec<Json> {
        self.results.iter().map(|r| result_row_json(&self.grid, r)).collect()
    }
}

/// Cumulative engine telemetry, exported by `GET /stats` and the
/// predict CLI footer.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeStats {
    /// Queries evaluated across all successful batches.
    pub queries: u64,
    /// Successful batches evaluated.
    pub batches: u64,
    /// Scenario cells evaluated across all successful batches.
    pub cells: u64,
    /// Parameter-table resolutions performed by the engine's cache
    /// since construction ([`SweepCache::calibration_resolutions`]).
    pub calibration_resolutions: u64,
    /// Lab-store hit/miss counters, when a store is attached.
    pub store: Option<StoreStats>,
}

impl ServeStats {
    /// The machine-readable form (the `GET /stats` body and the
    /// `"stats"` object of a predict document).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("queries", Json::num(self.queries as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("cells", Json::num(self.cells as f64)),
            (
                "calibration_resolutions",
                Json::num(self.calibration_resolutions as f64),
            ),
        ];
        if let Some(s) = &self.store {
            pairs.push((
                "store",
                Json::obj(vec![
                    ("hits", Json::num(s.hits as f64)),
                    ("misses", Json::num(s.misses as f64)),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

/// The batched what-if query engine behind `repro predict --batch` and
/// `repro serve`. Cheap to share (`&self` methods, internally
/// synchronized); one engine instance serves any number of batches and
/// keeps its calibration/model memos warm across them.
pub struct PredictEngine {
    cache: SweepCache,
    params: ParamSource,
    workers: usize,
    queries: AtomicU64,
    batches: AtomicU64,
    cells: AtomicU64,
}

impl PredictEngine {
    /// A fresh engine. `workers` bounds the per-batch evaluation pool
    /// (0 = one per available CPU, like [`crate::sweep::SweepRunner`]).
    pub fn new(params: ParamSource, workers: usize) -> PredictEngine {
        PredictEngine {
            cache: SweepCache::new(),
            params,
            workers,
            queries: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            cells: AtomicU64::new(0),
        }
    }

    /// Attach a lab store: previously persisted cells short-circuit to
    /// store hits (a fully warm batch resolves zero parameter tables),
    /// and nothing is written back — predict queries never measure.
    pub fn with_store(mut self, store: Arc<Store>) -> PredictEngine {
        self.cache.set_store(store);
        self
    }

    /// The engine's parameter provenance.
    pub fn params(&self) -> ParamSource {
        self.params
    }

    /// Evaluate a batch, keeping every cell's result. Queries come back
    /// in input order; within a query, cells in grid-enumeration order.
    pub fn eval_batch(&self, batch: &QueryBatch) -> Result<Vec<QueryResult>> {
        Ok(self.run(batch, true)?.0)
    }

    /// Evaluate a batch for effect only (throughput benches): every
    /// cell is computed and counted, no result rows are kept. Returns
    /// *this* batch's cell count — not a delta of the cumulative
    /// counter, which concurrent batches advance too.
    pub fn drain_batch(&self, batch: &QueryBatch) -> Result<u64> {
        Ok(self.run(batch, false)?.1)
    }

    /// Cumulative telemetry snapshot.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            queries: self.queries.load(Ordering::SeqCst),
            batches: self.batches.load(Ordering::SeqCst),
            cells: self.cells.load(Ordering::SeqCst),
            calibration_resolutions: self.cache.calibration_resolutions(),
            store: self.cache.store().map(|s| s.stats()),
        }
    }

    /// Resolved worker count for a batch of `n` queries.
    fn workers_for(&self, n: usize) -> usize {
        let requested = if self.workers == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.workers
        };
        requested.min(n).max(1)
    }

    /// Phase 1: resolve every distinct (arch, strategy, sim
    /// fingerprint) model the batch touches. The model memo underneath
    /// is single-flight, so concurrent batches resolving the same pair
    /// coalesce onto one computation. Returns the number of distinct
    /// (arch, fingerprint) pairs in this batch.
    fn resolve_tables(&self, grids: &[GridSpec]) -> Result<usize> {
        let mut pairs: Vec<(String, u64)> = Vec::new();
        let mut models: Vec<(String, u8, u64)> = Vec::new();
        for grid in grids {
            // Queries expand to single-arch/machine/image grids, so one
            // probe scenario per strategy covers the whole grid: the
            // model memo ignores the workload axes.
            let probe = Scenario {
                id: 0,
                sim: 0,
                arch: 0,
                machine: 0,
                train_images: grid.images[0].0,
                test_images: grid.images[0].1,
                epochs: grid.epochs.first().copied().unwrap_or(1),
                threads: grid.threads[0],
                strategy: grid.strategies[0],
            };
            let fp = grid.resolved_sim(self.cache.sim(), &probe).fingerprint();
            let pair = (grid.archs[0].name.clone(), fp);
            if !pairs.contains(&pair) {
                pairs.push(pair);
            }
            for &strategy in &grid.strategies {
                let key = (grid.archs[0].name.clone(), strategy as u8, fp);
                if models.contains(&key) {
                    continue;
                }
                models.push(key);
                self.cache.model(grid, &Scenario { strategy, ..probe.clone() })?;
            }
        }
        Ok(pairs.len())
    }

    /// Shared batch path: expand + validate every query, resolve the
    /// parameter tables (single-flight across batches), then evaluate
    /// the cells (parallel over queries). Counters only advance for
    /// batches that succeed. Returns the results plus this batch's cell
    /// count.
    fn run(&self, batch: &QueryBatch, keep: bool) -> Result<(Vec<QueryResult>, u64)> {
        let grids: Vec<GridSpec> = batch
            .queries
            .iter()
            .map(|q| q.to_grid(self.params))
            .collect::<Result<Vec<_>>>()?;
        self.resolve_tables(&grids)?;

        let cells = AtomicU64::new(0);
        let workers = self.workers_for(grids.len());
        let out: Vec<QueryResult> = if workers <= 1 {
            let mut out = Vec::with_capacity(grids.len());
            for grid in &grids {
                out.push(self.eval_query(grid, keep, &cells)?);
            }
            out
        } else {
            let cursor = AtomicUsize::new(0);
            let stop = AtomicBool::new(false);
            let failure: Mutex<Option<(usize, Error)>> = Mutex::new(None);
            let slots: Vec<Mutex<Option<QueryResult>>> =
                grids.iter().map(|_| Mutex::new(None)).collect();
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let i = cursor.fetch_add(1, Ordering::SeqCst);
                        if i >= grids.len() {
                            break;
                        }
                        match self.eval_query(&grids[i], keep, &cells) {
                            Ok(res) => *slots[i].lock().unwrap() = Some(res),
                            Err(e) => {
                                let mut slot = failure.lock().unwrap();
                                if slot.as_ref().map(|(j, _)| i < *j).unwrap_or(true) {
                                    *slot = Some((i, e));
                                }
                                stop.store(true, Ordering::SeqCst);
                            }
                        }
                    });
                }
            });
            if let Some((_, e)) = failure.into_inner().unwrap() {
                return Err(e);
            }
            slots
                .into_iter()
                .map(|s| s.into_inner().unwrap().expect("every slot filled"))
                .collect()
        };

        let batch_cells = cells.load(Ordering::SeqCst);
        self.queries.fetch_add(grids.len() as u64, Ordering::SeqCst);
        self.batches.fetch_add(1, Ordering::SeqCst);
        self.cells.fetch_add(batch_cells, Ordering::SeqCst);
        Ok((out, batch_cells))
    }

    /// Evaluate one query's scenarios through the sweep cell path.
    fn eval_query(&self, grid: &GridSpec, keep: bool, cells: &AtomicU64) -> Result<QueryResult> {
        let scenarios = grid.enumerate();
        let mut results = Vec::with_capacity(if keep { scenarios.len() } else { 0 });
        for scn in &scenarios {
            let r = runner::evaluate(grid, &self.cache, scn)?;
            cells.fetch_add(1, Ordering::Relaxed);
            if keep {
                results.push(r);
            }
        }
        Ok(QueryResult { grid: grid.clone(), results })
    }
}

/// The predict response document — shared by `repro predict --batch`
/// and `POST /predict` so both paths emit identical bytes for identical
/// batches (modulo the stats object, which is cumulative). `results[]`
/// concatenates every query's rows in batch order, each row in the
/// sweep dump's exact shape.
pub fn predict_doc(results: &[QueryResult], stats: &ServeStats) -> Json {
    let rows: Vec<Json> = results.iter().flat_map(QueryResult::rows).collect();
    Json::obj(vec![
        ("queries", Json::num(results.len() as f64)),
        ("cells", Json::num(rows.len() as f64)),
        ("stats", stats.to_json()),
        ("results", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::SweepRunner;

    fn batch(text: &str) -> QueryBatch {
        QueryBatch::from_json(text).unwrap()
    }

    #[test]
    fn batch_rows_are_bit_identical_to_sweep_cells() {
        let engine = PredictEngine::new(ParamSource::Paper, 2);
        let b = batch(
            r#"[{"arch": "small", "threads": [1, 15, 240]},
                {"arch": "large", "strategy": "b", "threads_range": {"from": 60, "to": 240, "step": 60}},
                {"arch": "small", "threads": [15], "sim": {"clock_ghz": 1.5}}]"#,
        );
        let results = engine.eval_batch(&b).unwrap();
        assert_eq!(results.len(), 3);
        for (q, res) in b.queries.iter().zip(&results) {
            let grid = q.to_grid(ParamSource::Paper).unwrap();
            let sweep = SweepRunner::serial().run(&grid).unwrap();
            let sweep_rows: Vec<String> =
                sweep.results.iter().map(|r| result_row_json(&grid, r).emit()).collect();
            let serve_rows: Vec<String> = res.rows().iter().map(Json::emit).collect();
            assert_eq!(serve_rows, sweep_rows, "arch {}", q.arch);
        }
    }

    #[test]
    fn one_resolution_per_distinct_arch_sim_pair() {
        let engine = PredictEngine::new(ParamSource::Paper, 1);
        // 4 queries, but only 3 distinct (arch, sim fingerprint) pairs:
        // small/default appears twice.
        let b = batch(
            r#"[{"arch": "small", "threads": [1, 15]},
                {"arch": "small", "threads": [240], "epochs": 3},
                {"arch": "medium", "threads": [15]},
                {"arch": "small", "threads": [15], "sim": {"clock_ghz": 1.5}}]"#,
        );
        engine.eval_batch(&b).unwrap();
        let stats = engine.stats();
        assert_eq!(stats.queries, 4);
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.cells, b.cells() as u64);
        assert_eq!(stats.calibration_resolutions, 3, "{stats:?}");
        // A second identical batch hits the memos: zero new resolutions.
        engine.eval_batch(&b).unwrap();
        assert_eq!(engine.stats().calibration_resolutions, 3);
        assert_eq!(engine.stats().batches, 2);
    }

    #[test]
    fn drain_counts_cells_without_keeping_rows() {
        let engine = PredictEngine::new(ParamSource::Paper, 0);
        let b = batch(r#"[{"arch": "small", "threads_range": {"from": 1, "to": 61, "step": 10}}]"#);
        let cells = engine.drain_batch(&b).unwrap();
        assert_eq!(cells, b.cells() as u64);
        assert_eq!(engine.stats().cells, cells);
    }

    #[test]
    fn concurrent_batches_share_the_engine_safely() {
        // Batches resolve in parallel — no engine-level resolve mutex.
        // The model and calibration memos underneath are single-flight,
        // so even batches racing on the same (arch, fingerprint) pair
        // resolve it exactly once; the resolutions == pairs pin below
        // holds structurally, not because batches are serialized.
        // (Also a regression guard: the per-batch cell counts must come
        // from per-batch counters, not deltas of the shared counter.)
        let engine = PredictEngine::new(ParamSource::Paper, 2);
        let a = batch(r#"[{"arch": "small", "threads": [1, 15, 61, 240]}]"#);
        let b = batch(r#"[{"arch": "medium", "strategy": "b", "threads": [15, 240]}]"#);
        for _ in 0..4 {
            std::thread::scope(|scope| {
                let ha = scope.spawn(|| engine.drain_batch(&a).unwrap());
                let hb = scope.spawn(|| engine.drain_batch(&b).unwrap());
                // Per-batch cell counts, not deltas of the shared counter.
                assert_eq!(ha.join().unwrap(), a.cells() as u64);
                assert_eq!(hb.join().unwrap(), b.cells() as u64);
            });
        }
        let stats = engine.stats();
        assert_eq!(stats.batches, 8);
        assert_eq!(stats.cells, 4 * (a.cells() + b.cells()) as u64);
        // One resolution per distinct (arch, sim fingerprint), ever.
        assert_eq!(stats.calibration_resolutions, 2, "{stats:?}");
    }

    #[test]
    fn failed_batches_do_not_advance_counters() {
        let engine = PredictEngine::new(ParamSource::Paper, 1);
        let b = batch(r#"[{"arch": "nope", "threads": [1]}]"#);
        assert!(engine.eval_batch(&b).is_err());
        let stats = engine.stats();
        assert_eq!((stats.queries, stats.batches, stats.cells), (0, 0, 0));
    }
}
