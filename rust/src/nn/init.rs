//! Deterministic weight initialization (no external RNG dependency).
//!
//! Mirrors the scheme of `python/compile/model.py::init_params`:
//! weights ~ Uniform(−r, r) with r = 1/√fan_in, biases zero. The streams
//! need not match the JAX init bit-for-bit — only the distribution matters —
//! but they must be reproducible from a seed, which this xorshift64* stream
//! guarantees across platforms.

/// Minimal xorshift64* PRNG — deterministic, seedable, dependency-free.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // splitmix64 finalizer decorrelates nearby seeds (1 vs 2 must not
        // collide) and avoids the all-zero fixed point.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        XorShift64 { state: z | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [-r, r).
    #[inline]
    pub fn uniform_sym(&mut self, r: f32) -> f32 {
        (self.next_f32() * 2.0 - 1.0) * r
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

/// Fill a weight buffer with Uniform(−1/√fan_in, 1/√fan_in).
pub fn init_weights(rng: &mut XorShift64, buf: &mut [f32], fan_in: usize) {
    let r = 1.0 / (fan_in.max(1) as f32).sqrt();
    for w in buf.iter_mut() {
        *w = rng.uniform_sym(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = XorShift64::new(7);
        for _ in 0..10_000 {
            let v = rng.next_f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn uniform_sym_bounded_and_centered() {
        let mut rng = XorShift64::new(9);
        let mut sum = 0.0f64;
        const N: usize = 50_000;
        for _ in 0..N {
            let v = rng.uniform_sym(0.5);
            assert!(v.abs() <= 0.5);
            sum += v as f64;
        }
        assert!((sum / N as f64).abs() < 0.01, "mean {}", sum / N as f64);
    }

    #[test]
    fn init_scale_respects_fan_in() {
        let mut rng = XorShift64::new(3);
        let mut buf = vec![0.0f32; 1000];
        init_weights(&mut rng, &mut buf, 100);
        let r = 0.1f32;
        assert!(buf.iter().all(|w| w.abs() <= r));
        assert!(buf.iter().any(|w| w.abs() > r * 0.5));
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = XorShift64::new(5);
        for _ in 0..1000 {
            assert!(rng.next_below(10) < 10);
        }
    }
}
