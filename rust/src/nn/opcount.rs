//! Per-image operation counting — the reproduction of Tables VII and VIII.
//!
//! The paper derives `FProp` / `BProp` (operations to forward/backward one
//! image) from a theoretical analysis of Cireşan's code, and admits the
//! constants "are approximations, they are relative to each other, and yet
//! far from precise". We therefore support **two parameter sources**:
//!
//! * [`OpSource::Computed`] — a first-principles count from the layer
//!   geometry, documented per layer type below;
//! * [`OpSource::Paper`] — the exact Table VII/VIII values embedded as
//!   constants (see [`crate::report::paper`]).
//!
//! `repro exp table7|table8` prints both side by side with ratios, making
//! the approximation gap visible instead of hiding it.
//!
//! ## Counting scheme (Computed)
//!
//! Counted per image, one "operation" = one scalar arithmetic op:
//!
//! * **conv fwd**: each output neuron does `fan_in` multiply-adds
//!   (`2·fan_in` ops) plus activation (4 ops: the tanh is table-driven in
//!   the original code).
//! * **pool fwd**: each output neuron scans its `w²` window (`w²` compares)
//!   and records the argmax (1 op).
//! * **dense fwd**: `2·fan_in + 4` per unit, as conv.
//! * **conv bwd**: per output neuron, the delta costs `2·fan_in` (pushing
//!   its error to every input it reads) + 3 for the activation derivative;
//!   per weight, gradient accumulate + decay + update = 3 ops amortized
//!   over the neurons sharing it (`3·weights` total).
//! * **pool bwd**: route the delta through the argmax (2 ops per output
//!   neuron).
//! * **dense bwd**: symmetric to conv bwd with `fan_in` per unit.

use crate::config::arch::{ArchSpec, ResolvedLayer};
use crate::error::Result;

/// Layer classes the paper aggregates over in Tables VII/VIII.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerClass {
    MaxPool,
    FullyConnected,
    Convolution,
}

/// Operation counts for one direction, broken down by layer class.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounts {
    pub max_pool: u64,
    pub fully_connected: u64,
    pub convolution: u64,
}

impl OpCounts {
    pub fn total(&self) -> u64 {
        self.max_pool + self.fully_connected + self.convolution
    }

    pub fn get(&self, class: LayerClass) -> u64 {
        match class {
            LayerClass::MaxPool => self.max_pool,
            LayerClass::FullyConnected => self.fully_connected,
            LayerClass::Convolution => self.convolution,
        }
    }

    fn add(&mut self, class: LayerClass, ops: u64) {
        match class {
            LayerClass::MaxPool => self.max_pool += ops,
            LayerClass::FullyConnected => self.fully_connected += ops,
            LayerClass::Convolution => self.convolution += ops,
        }
    }
}

/// Forward + backward counts for one architecture.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArchOpCounts {
    pub fprop: OpCounts,
    pub bprop: OpCounts,
}

impl ArchOpCounts {
    /// Ops per training image (one forward + one backward).
    pub fn train_image(&self) -> u64 {
        self.fprop.total() + self.bprop.total()
    }
}

/// Which parameter source feeds the models/simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OpSource {
    /// First-principles counts from the layer geometry (this module).
    Computed,
    /// The paper's Table VII/VIII constants (exact reproduction inputs).
    #[default]
    Paper,
}

/// Per-layer operation record (used by the simulator's per-layer costs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerOps {
    pub class: LayerClass,
    pub fwd: u64,
    pub bwd: u64,
    /// Map row width for spatial layers (vectorization modelling), or
    /// `fan_in` for dense layers.
    pub vector_width: usize,
    /// Trainable weights (memory-traffic modelling).
    pub weights: u64,
    /// Output neurons.
    pub neurons: u64,
}

/// Cost of the activation function in the forward direction.
const ACT_FWD_OPS: u64 = 4;
/// Cost of the activation derivative in the backward direction.
const ACT_BWD_OPS: u64 = 3;
/// Gradient accumulate + decay + update per weight.
const WEIGHT_UPDATE_OPS: u64 = 3;

/// Count every trainable/pooling layer of `arch`.
pub fn layer_ops(arch: &ArchSpec) -> Result<Vec<LayerOps>> {
    let shapes = arch.shapes()?;
    let mut out = Vec::new();
    for shape in &shapes {
        match shape.spec {
            ResolvedLayer::Input { .. } => {}
            ResolvedLayer::Conv { maps, kernel, in_maps, out_hw, .. } => {
                let neurons = (maps * out_hw * out_hw) as u64;
                let fan_in = (in_maps * kernel * kernel) as u64;
                let weights = shape.weights as u64;
                let fwd = neurons * (2 * fan_in + ACT_FWD_OPS);
                let bwd = neurons * (2 * fan_in + ACT_BWD_OPS)
                    + weights * WEIGHT_UPDATE_OPS;
                out.push(LayerOps {
                    class: LayerClass::Convolution,
                    fwd,
                    bwd,
                    vector_width: out_hw,
                    weights,
                    neurons,
                });
            }
            ResolvedLayer::Pool { window, maps, out_hw, .. } => {
                let neurons = (maps * out_hw * out_hw) as u64;
                let win = (window * window) as u64;
                let fwd = neurons * (win + 1);
                let bwd = neurons * 2;
                out.push(LayerOps {
                    class: LayerClass::MaxPool,
                    fwd,
                    bwd,
                    vector_width: out_hw,
                    weights: 0,
                    neurons,
                });
            }
            ResolvedLayer::Dense { units, fan_in, .. } => {
                let neurons = units as u64;
                let fi = fan_in as u64;
                let weights = shape.weights as u64;
                let fwd = neurons * (2 * fi + ACT_FWD_OPS);
                let bwd = neurons * (2 * fi + ACT_BWD_OPS)
                    + weights * WEIGHT_UPDATE_OPS;
                out.push(LayerOps {
                    class: LayerClass::FullyConnected,
                    fwd,
                    bwd,
                    vector_width: fan_in,
                    weights,
                    neurons,
                });
            }
        }
    }
    Ok(out)
}

/// Aggregate per-class counts (the Tables VII/VIII layout).
pub fn count(arch: &ArchSpec) -> Result<ArchOpCounts> {
    let mut fprop = OpCounts::default();
    let mut bprop = OpCounts::default();
    for layer in layer_ops(arch)? {
        fprop.add(layer.class, layer.fwd);
        bprop.add(layer.class, layer.bwd);
    }
    Ok(ArchOpCounts { fprop, bprop })
}

/// Resolve counts from the chosen source for a *paper* architecture.
/// `Computed` works for any [`ArchSpec`]; `Paper` requires small/medium/large.
pub fn resolve(arch: &ArchSpec, source: OpSource) -> Result<ArchOpCounts> {
    match source {
        OpSource::Computed => count(arch),
        OpSource::Paper => crate::report::paper::op_counts(&arch.name)
            .ok_or_else(|| {
                crate::error::Error::Config(format!(
                    "no paper op counts for custom arch {:?}; use --ops computed",
                    arch.name
                ))
            }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchSpec;

    fn counts(name: &str) -> ArchOpCounts {
        count(&ArchSpec::by_name(name).unwrap()).unwrap()
    }

    #[test]
    fn convolution_dominates_fprop() {
        // Table VII: convolution is ~79-96% of forward ops in every arch.
        for name in ["small", "medium", "large"] {
            let c = counts(name);
            let frac = c.fprop.convolution as f64 / c.fprop.total() as f64;
            assert!(frac > 0.70, "{name}: conv frac {frac}");
        }
    }

    #[test]
    fn bprop_exceeds_fprop() {
        // Table VII vs VIII: backward is several times forward.
        for name in ["small", "medium", "large"] {
            let c = counts(name);
            assert!(c.bprop.total() > c.fprop.total(), "{name}");
        }
    }

    #[test]
    fn fprop_ratios_match_paper_shape() {
        // Table VII reports medium/small = 9.64 and large/medium = 9.57.
        // Our principled counts reproduce the order of magnitude (the paper
        // itself calls its constants imprecise); assert the ratio is
        // within a factor ~2 of the paper's.
        let s = counts("small").fprop.total() as f64;
        let m = counts("medium").fprop.total() as f64;
        let l = counts("large").fprop.total() as f64;
        // Computed ratios run larger than the paper's: the paper's deeper
        // convolutional layers were counted with (undocumented) sparse map
        // connectivity, while we count dense connectivity. Order and
        // magnitude are preserved; the exact Table VII inputs come from
        // OpSource::Paper.
        let r1 = m / s;
        let r2 = l / m;
        assert!(r1 > 4.0 && r1 < 40.0, "medium/small fprop ratio {r1}");
        assert!(r2 > 2.0 && r2 < 40.0, "large/medium fprop ratio {r2}");
    }

    #[test]
    fn totals_same_decade_as_paper() {
        // Computed totals should be the same order of magnitude as
        // Table VII/VIII (paper: small 58k/524k, medium 559k/6119k,
        // large 5349k/73178k).
        let paper = [(58_000u64, 524_000u64), (559_000, 6_119_000), (5_349_000, 73_178_000)];
        for (name, (pf, _pb)) in ["small", "medium", "large"].iter().zip(paper) {
            let c = counts(name);
            let ratio = c.fprop.total() as f64 / pf as f64;
            // Within one decade (dense vs the paper's sparse connectivity).
            assert!(ratio > 0.3 && ratio < 10.0,
                    "{name}: fprop {} vs paper {pf}", c.fprop.total());
        }
    }

    #[test]
    fn small_exact_values_pinned() {
        // Regression pin for the documented counting scheme (small arch):
        //  conv: 3380 neurons × (2·16 + 4) = 121,680 fwd
        //  pool: 845 × (4+1) = 4,225 fwd
        //  dense: 10 × (2·845 + 4) = 16,940 fwd
        let c = counts("small");
        assert_eq!(c.fprop.convolution, 121_680);
        assert_eq!(c.fprop.max_pool, 4_225);
        assert_eq!(c.fprop.fully_connected, 16_940);
        // bwd conv: 3380 × (32+3) + 85×3 = 118,555
        assert_eq!(c.bprop.convolution, 118_555);
    }

    #[test]
    fn layer_ops_sum_equals_aggregate() {
        for name in ["small", "medium", "large"] {
            let arch = ArchSpec::by_name(name).unwrap();
            let per_layer = layer_ops(&arch).unwrap();
            let agg = count(&arch).unwrap();
            let fwd: u64 = per_layer.iter().map(|l| l.fwd).sum();
            let bwd: u64 = per_layer.iter().map(|l| l.bwd).sum();
            assert_eq!(fwd, agg.fprop.total());
            assert_eq!(bwd, agg.bprop.total());
        }
    }

    #[test]
    fn resolve_paper_matches_tables() {
        let arch = ArchSpec::small();
        let c = resolve(&arch, OpSource::Paper).unwrap();
        assert_eq!(c.fprop.total(), 58_000);
        assert_eq!(c.bprop.total(), 524_000);
    }

    #[test]
    fn resolve_paper_rejects_custom_arch() {
        let mut arch = ArchSpec::small();
        arch.name = "custom".into();
        assert!(resolve(&arch, OpSource::Paper).is_err());
        assert!(resolve(&arch, OpSource::Computed).is_ok());
    }
}
