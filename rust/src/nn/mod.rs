//! Neural-network structure: layer graph, parameters, op counting.
//!
//! This module owns the *static* view of a CNN — geometry, parameter
//! layout, weight initialization, and the per-image operation counts that
//! drive both the performance models ([`crate::perfmodel`]) and the
//! simulator's workload costs ([`crate::simulator`]). The *dynamic* compute
//! (actual forward/backward arithmetic) lives in [`crate::engine`] (pure
//! Rust) and in the AOT JAX/Pallas artifacts run by [`crate::runtime`].

pub mod init;
pub mod network;
pub mod opcount;
pub mod roofline;

pub use network::Network;
pub use opcount::{ArchOpCounts, LayerClass, OpCounts, OpSource};
