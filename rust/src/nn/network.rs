//! A network instance: resolved architecture + owned parameter buffers.
//!
//! In the paper's parallel scheme (Fig. 4) *each thread owns one network
//! instance* and trains it on its image chunk. `Network` is that instance:
//! cheap to clone (for spawning per-worker copies), deterministic to
//! initialize, and serializable for checkpointing.

use crate::config::arch::{ArchSpec, LayerShape, ResolvedLayer};
use crate::error::{Error, Result};
use crate::nn::init::{init_weights, XorShift64};
use crate::util::json::Json;

/// Parameters of one trainable layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerParams {
    /// Flattened weights; layout documented per layer type in [`Network`].
    pub w: Vec<f32>,
    /// Biases, one per map/unit.
    pub b: Vec<f32>,
}

/// A CNN instance with owned weights.
///
/// Weight layouts (row-major):
/// * conv: `w[map][in_map][ky][kx]`, `b[map]`
/// * dense: `w[fan_in][unit]` (input-major, matching the JAX artifact), `b[unit]`
#[derive(Debug, Clone)]
pub struct Network {
    pub arch: ArchSpec,
    pub params: Vec<LayerParams>,
    shapes: Vec<LayerShape>,
}

impl Network {
    /// Build with deterministic initialization from `seed`.
    pub fn new(arch: ArchSpec, seed: u64) -> Result<Self> {
        let shapes = arch.shapes()?;
        let mut rng = XorShift64::new(seed);
        let mut params = Vec::new();
        for shape in &shapes {
            match shape.spec {
                ResolvedLayer::Conv { maps, kernel, in_maps, .. } => {
                    let fan_in = in_maps * kernel * kernel;
                    let mut w = vec![0.0; maps * fan_in];
                    init_weights(&mut rng, &mut w, fan_in);
                    params.push(LayerParams { w, b: vec![0.0; maps] });
                }
                ResolvedLayer::Dense { units, fan_in, .. } => {
                    let mut w = vec![0.0; fan_in * units];
                    init_weights(&mut rng, &mut w, fan_in);
                    params.push(LayerParams { w, b: vec![0.0; units] });
                }
                _ => {}
            }
        }
        Ok(Network { arch, params, shapes })
    }

    /// Resolved layer shapes (cached at construction).
    pub fn shapes(&self) -> &[LayerShape] {
        &self.shapes
    }

    /// Serialize to JSON (checkpointing).
    pub fn to_json(&self) -> String {
        let params: Vec<Json> = self
            .params
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("w", Json::Arr(p.w.iter().map(|&x| Json::Num(x as f64)).collect())),
                    ("b", Json::Arr(p.b.iter().map(|&x| Json::Num(x as f64)).collect())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("arch", Json::parse(&self.arch.to_json()).expect("own json")),
            ("params", Json::Arr(params)),
        ])
        .emit()
    }

    /// Deserialize a checkpoint written by [`Network::to_json`]. Validates
    /// that every parameter buffer matches the architecture's shape walk.
    pub fn from_json(text: &str) -> Result<Network> {
        let v = Json::parse(text)?;
        let arch = ArchSpec::from_json(&v.expect("arch")?.emit())?;
        let shapes = arch.shapes()?;
        let mut net = Network::new(arch, 0)?;
        let params = v
            .expect("params")?
            .as_arr()
            .ok_or_else(|| Error::Json("params must be an array".into()))?;
        if params.len() != net.params.len() {
            return Err(Error::Json(format!(
                "checkpoint has {} param layers, arch wants {}",
                params.len(),
                net.params.len()
            )));
        }
        for (i, p) in params.iter().enumerate() {
            let read = |key: &str| -> Result<Vec<f32>> {
                p.expect(key)?
                    .as_arr()
                    .ok_or_else(|| Error::Json(format!("params[{i}].{key} not array")))?
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .map(|f| f as f32)
                            .ok_or_else(|| Error::Json(format!("params[{i}].{key}: non-number")))
                    })
                    .collect()
            };
            let w = read("w")?;
            let b = read("b")?;
            if w.len() != net.params[i].w.len() || b.len() != net.params[i].b.len() {
                return Err(Error::Json(format!(
                    "params[{i}]: shape mismatch ({}/{} weights, {}/{} biases)",
                    w.len(),
                    net.params[i].w.len(),
                    b.len(),
                    net.params[i].b.len()
                )));
            }
            net.params[i] = LayerParams { w, b };
        }
        let _ = shapes;
        Ok(net)
    }

    /// Total parameter count (weights + biases).
    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.w.len() + p.b.len()).sum()
    }

    /// Parameter memory footprint in bytes (f32).
    pub fn param_bytes(&self) -> usize {
        self.num_params() * 4
    }

    /// Average the parameters of several instances into a fresh network
    /// (the coordinator's model-combine step after data-parallel training).
    pub fn average(instances: &[Network]) -> Result<Network> {
        assert!(!instances.is_empty());
        let mut out = instances[0].clone();
        let n = instances.len() as f32;
        for layer in 0..out.params.len() {
            for other in &instances[1..] {
                for (acc, v) in out.params[layer]
                    .w
                    .iter_mut()
                    .zip(other.params[layer].w.iter())
                {
                    *acc += v;
                }
                for (acc, v) in out.params[layer]
                    .b
                    .iter_mut()
                    .zip(other.params[layer].b.iter())
                {
                    *acc += v;
                }
            }
            for v in out.params[layer].w.iter_mut() {
                *v /= n;
            }
            for v in out.params[layer].b.iter_mut() {
                *v /= n;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_shape_walk() {
        for arch in ArchSpec::paper_archs() {
            let expected: usize = arch.shapes().unwrap().iter().map(|l| l.weights).sum();
            let net = Network::new(arch.clone(), 1).unwrap();
            assert_eq!(net.num_params(), expected, "{}", arch.name);
        }
    }

    #[test]
    fn small_has_8545_params() {
        // 85 (conv incl bias) + 845*10 + 10 = 8,545.
        let net = Network::new(ArchSpec::small(), 0).unwrap();
        assert_eq!(net.num_params(), 8_545);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Network::new(ArchSpec::small(), 42).unwrap();
        let b = Network::new(ArchSpec::small(), 42).unwrap();
        assert_eq!(a.params, b.params);
        let c = Network::new(ArchSpec::small(), 43).unwrap();
        assert_ne!(a.params, c.params);
    }

    #[test]
    fn biases_start_zero() {
        let net = Network::new(ArchSpec::medium(), 7).unwrap();
        for p in &net.params {
            assert!(p.b.iter().all(|&b| b == 0.0));
        }
    }

    #[test]
    fn average_of_identical_is_identity() {
        let a = Network::new(ArchSpec::small(), 5).unwrap();
        let avg = Network::average(&[a.clone(), a.clone()]).unwrap();
        for (pa, pv) in a.params.iter().zip(avg.params.iter()) {
            for (x, y) in pa.w.iter().zip(pv.w.iter()) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn average_is_elementwise_mean() {
        let mut a = Network::new(ArchSpec::small(), 5).unwrap();
        let mut b = Network::new(ArchSpec::small(), 5).unwrap();
        a.params[0].w[0] = 1.0;
        b.params[0].w[0] = 3.0;
        let avg = Network::average(&[a, b]).unwrap();
        assert!((avg.params[0].w[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn json_checkpoint_roundtrip() {
        let net = Network::new(ArchSpec::small(), 9).unwrap();
        let json = net.to_json();
        let back = Network::from_json(&json).unwrap();
        assert_eq!(back.params.len(), net.params.len());
        for (a, b) in net.params.iter().zip(back.params.iter()) {
            for (x, y) in a.w.iter().zip(b.w.iter()) {
                assert!((x - y).abs() < 1e-6);
            }
        }
        assert_eq!(back.shapes().len(), net.shapes().len());
    }

    #[test]
    fn from_json_rejects_shape_mismatch() {
        let net = Network::new(ArchSpec::small(), 9).unwrap();
        let json = net.to_json();
        // Corrupt: drop one weight from the first layer.
        let v = crate::util::json::Json::parse(&json).unwrap();
        let mut txt = v.emit();
        let at = txt.find("\"w\":[").unwrap() + 5;
        let comma = txt[at..].find(',').unwrap();
        txt.replace_range(at..at + comma + 1, "");
        assert!(Network::from_json(&txt).is_err());
    }
}
