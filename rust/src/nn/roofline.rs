//! Roofline analysis: arithmetic intensity and attainable performance.
//!
//! Two targets:
//!
//! * **KNC** (the paper's machine): peak = 61 cores × 16 lanes × 2 flops ×
//!   1.238 GHz ≈ 2.4 TFLOP/s f32, machine balance ≈ 6.9 flop/byte against
//!   the 352 GB/s GDDR system. Used to situate the calibrated simulator
//!   cost (≈31 cycles/op forward) against the theoretical ceiling — the
//!   achieved-vs-roofline *efficiency ratio* that EXPERIMENTS.md §Perf
//!   reports.
//! * **TPU (MXU)** — the Hardware-Adaptation view (DESIGN.md): each conv
//!   layer as the im2col matmul the Pallas kernel runs, with MXU tile
//!   occupancy (M, N vs the 128×128 systolic array) and VMEM residency of
//!   one grid step. interpret=True wallclock is meaningless, so kernel
//!   quality is assessed from these static estimates.

use crate::config::arch::{ArchSpec, ResolvedLayer};
use crate::config::MachineConfig;
use crate::error::Result;

/// Roofline record for one layer on the KNC target.
#[derive(Debug, Clone)]
pub struct LayerRoofline {
    pub name: String,
    /// FLOPs per image (2 × MACs).
    pub flops: f64,
    /// Bytes moved per image (weights once + input/output activations).
    pub bytes: f64,
    /// Arithmetic intensity, flop/byte.
    pub intensity: f64,
    /// Attainable GFLOP/s on the machine (min(peak, intensity × bw)).
    pub attainable_gflops: f64,
    /// Time at the roofline, seconds/image.
    pub roofline_s: f64,
}

/// MXU mapping record for one conv/dense layer (the Pallas kernel view).
#[derive(Debug, Clone)]
pub struct MxuMapping {
    pub name: String,
    /// Matmul dims after im2col, with batch folded into M (B = 64).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Fraction of the 128×128 MXU tile grid actually used.
    pub mxu_occupancy: f64,
    /// VMEM bytes for one grid step (A tile + B tile + out tile + bias).
    pub vmem_bytes: usize,
}

/// Per-layer KNC roofline for an architecture.
pub fn knc_roofline(arch: &ArchSpec, machine: &MachineConfig) -> Result<Vec<LayerRoofline>> {
    let peak_flops = machine.peak_flops_thread() * machine.cores as f64;
    let bw = machine.memory_bw_bytes;
    let mut out = Vec::new();
    for shape in arch.shapes()? {
        let (name, macs, w_bytes, in_neurons, out_neurons) = match shape.spec {
            ResolvedLayer::Conv { maps, kernel, in_maps, in_hw, out_hw } => (
                format!("conv{kernel}x{kernel}x{maps}"),
                (maps * out_hw * out_hw * in_maps * kernel * kernel) as f64,
                shape.weights as f64 * 4.0,
                (in_maps * in_hw * in_hw) as f64,
                shape.neurons as f64,
            ),
            ResolvedLayer::Dense { units, fan_in, .. } => (
                format!("dense{units}"),
                (units * fan_in) as f64,
                shape.weights as f64 * 4.0,
                fan_in as f64,
                units as f64,
            ),
            ResolvedLayer::Pool { window, maps, in_hw, out_hw } => (
                format!("pool{window}x{window}"),
                (maps * out_hw * out_hw * window * window) as f64 / 2.0,
                0.0,
                (maps * in_hw * in_hw) as f64,
                shape.neurons as f64,
            ),
            ResolvedLayer::Input { .. } => continue,
        };
        let flops = 2.0 * macs;
        let bytes = w_bytes + 4.0 * (in_neurons + out_neurons);
        let intensity = flops / bytes.max(1.0);
        let attainable = (intensity * bw).min(peak_flops);
        out.push(LayerRoofline {
            name,
            flops,
            bytes,
            intensity,
            attainable_gflops: attainable / 1e9,
            roofline_s: flops / attainable,
        });
    }
    Ok(out)
}

/// Whole-net roofline time per image (sum of layer roofline times).
pub fn knc_roofline_time_s(arch: &ArchSpec, machine: &MachineConfig) -> Result<f64> {
    Ok(knc_roofline(arch, machine)?.iter().map(|l| l.roofline_s).sum())
}

/// Achieved-vs-roofline efficiency of the (simulated) machine: roofline
/// time / measured per-image time. The paper's code measured ~1.45 ms for
/// the small forward pass; the roofline is far lower — the ratio is the
/// "how far from peak" number the §Perf analysis tracks.
pub fn knc_efficiency(arch: &ArchSpec, machine: &MachineConfig, measured_s: f64) -> Result<f64> {
    Ok(knc_roofline_time_s(arch, machine)? / measured_s)
}

/// MXU tile mapping of every matmul the Pallas kernel runs for `arch`
/// (batch folded into M, as in `python/compile/model.py`).
pub fn mxu_mapping(arch: &ArchSpec, batch: usize) -> Result<Vec<MxuMapping>> {
    const TILE: usize = 128;
    const BLOCK_M: usize = 128;
    const BLOCK_N: usize = 128;
    let mut out = Vec::new();
    for shape in arch.shapes()? {
        let (name, m, k, n) = match shape.spec {
            ResolvedLayer::Conv { maps, kernel, in_maps, out_hw, .. } => (
                format!("conv{kernel}x{kernel}x{maps}"),
                batch * out_hw * out_hw,
                in_maps * kernel * kernel,
                maps,
            ),
            ResolvedLayer::Dense { units, fan_in, .. } => {
                (format!("dense{units}"), batch, fan_in, units)
            }
            _ => continue,
        };
        // Occupancy: used / allocated cells in the padded tile grid.
        let pad = |x: usize| x.div_ceil(TILE) * TILE;
        let mxu_occupancy = (m * n) as f64 / (pad(m) * pad(n)) as f64;
        let bm = BLOCK_M.min(m.max(8));
        let bn = BLOCK_N.min(n.max(8));
        let vmem_bytes = 4 * (bm * k + k * bn + bm * bn + bn);
        out.push(MxuMapping { name, m, k, n, mxu_occupancy, vmem_bytes });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phi() -> MachineConfig {
        MachineConfig::xeon_phi_7120p()
    }

    #[test]
    fn conv_layers_have_higher_intensity_than_dense() {
        // Weight sharing gives convolutions far better flop/byte than
        // dense layers (each dense weight is used once per image).
        let rl = knc_roofline(&ArchSpec::medium(), &phi()).unwrap();
        let conv = rl.iter().find(|l| l.name.starts_with("conv")).unwrap();
        let dense = rl.iter().find(|l| l.name.starts_with("dense")).unwrap();
        assert!(conv.intensity > dense.intensity * 2.0,
                "{} vs {}", conv.intensity, dense.intensity);
    }

    #[test]
    fn attainable_never_exceeds_peak() {
        let m = phi();
        let peak = m.peak_flops_thread() * m.cores as f64 / 1e9;
        for arch in ArchSpec::paper_archs() {
            for l in knc_roofline(&arch, &m).unwrap() {
                assert!(l.attainable_gflops <= peak + 1e-6, "{}", l.name);
                assert!(l.roofline_s > 0.0);
            }
        }
    }

    #[test]
    fn roofline_time_far_below_measured() {
        // Table III small forward = 1.45 ms measured; the roofline is
        // orders of magnitude lower (the paper's code was nowhere near
        // peak — ~31 cycles/op). Efficiency ratio must be << 1.
        let arch = ArchSpec::small();
        let eff = knc_efficiency(&arch, &phi(), 1.45e-3).unwrap();
        assert!(eff > 0.0 && eff < 0.2, "{eff}");
    }

    #[test]
    fn larger_archs_have_larger_roofline_times() {
        let m = phi();
        let t: Vec<f64> = ArchSpec::paper_archs()
            .iter()
            .map(|a| knc_roofline_time_s(a, &m).unwrap())
            .collect();
        assert!(t[0] < t[1] && t[1] < t[2], "{t:?}");
    }

    #[test]
    fn mxu_mapping_matches_python_shapes() {
        // Must agree with python/tests/test_kernels.py ARCH_MATMUL_SHAPES.
        let maps = mxu_mapping(&ArchSpec::large(), 64).unwrap();
        let c3 = maps.iter().find(|m| m.name == "conv6x6x100").unwrap();
        assert_eq!((c3.m, c3.k, c3.n), (64 * 36, 2160, 100));
        let f = maps.iter().find(|m| m.name == "dense150").unwrap();
        assert_eq!((f.m, f.k, f.n), (64, 900, 150));
    }

    #[test]
    fn vmem_fits_budget_for_all_arch_layers() {
        // One grid step must fit comfortably in 16 MiB VMEM (same bound
        // as the python-side test).
        for arch in ArchSpec::paper_archs() {
            for m in mxu_mapping(&arch, 64).unwrap() {
                assert!(m.vmem_bytes < 4 * 1024 * 1024, "{}: {}", m.name, m.vmem_bytes);
            }
        }
    }

    #[test]
    fn mxu_occupancy_within_unit_interval() {
        for m in mxu_mapping(&ArchSpec::small(), 64).unwrap() {
            assert!(m.mxu_occupancy > 0.0 && m.mxu_occupancy <= 1.0);
        }
    }
}
