//! Strategy (a) — the minimal-measurement model (Table V).
//!
//! ```text
//! T(i, it, ep, p, s) = T_comp + T_mem
//!   T_comp = (Prep·OF + 4i + 2it + 10ep)/s
//!          + [ (FProp+BProp)·⌈i/p⌉·ep          (training)
//!            +  FProp      ·⌈i/p⌉·ep           (validation)
//!            +  FProp      ·⌈it/p⌉·ep ]        (test)
//!            · OF · CPI(p) / s
//!   T_mem  = MemoryContention(p) · ep · i / p
//! ```
//!
//! `Prep` is the operation estimate of Table II (10⁹/10¹⁰/10¹¹); `FProp`
//! and `BProp` are the Table VII/VIII counts (or our computed ones);
//! `OF` is the OperationFactor (15, "adjusted to closely match the
//! measured value for 15 threads … at the same time account for
//! vectorization"); `CPI(p)` is the thread-ladder factor; `s` = 1.238 GHz.
//!
//! The OperationFactor applies to `Prep` as well as to the propagation
//! terms: with it, the model reproduces the paper's own Table X
//! predictions for the small and large CNNs to three significant figures
//! and medium within 5% (`tests::table10_matches_paper`), while without
//! it the large-CNN column is ~20% off — so this is the reading of
//! Table V most consistent with the paper's published numbers.
//!
//! Every operand comes from the [`crate::calibration`] subsystem:
//! [`ParamSource::Paper`] resolves the published constants,
//! [`ParamSource::Simulator`] the closed-loop fit
//! ([`crate::calibration::ComputedSource`] — computed op counts with
//! cycles fitted against the measuring simulator).

use crate::calibration::{Calibration, ModelParams};
use crate::config::{ArchSpec, MachineConfig, RunConfig};
use crate::error::Result;
use crate::perfmodel::{model_cpi, ContentionSource, ParamSource, PerfModel, Prediction};

/// Strategy (a) with resolved parameters.
#[derive(Debug, Clone)]
pub struct StrategyA {
    /// Machine the CPI/clock terms evaluate against.
    pub machine: MachineConfig,
    /// FProp operations per image — the Table V training/validation/
    /// test propagation terms (Table VII totals).
    pub fprop_ops: f64,
    /// BProp operations per image — the Table V training term
    /// (Table VIII totals).
    pub bprop_ops: f64,
    /// Prep operation estimate — the Table V `Prep·OF/s` term
    /// (Table II: 10⁹/10¹⁰/10¹¹).
    pub prep_ops: f64,
    /// OperationFactor `OF` scaling every compute term (Table III).
    pub operation_factor: f64,
    contention: ContentionSource,
}

impl StrategyA {
    /// Build the model against the default simulator configuration
    /// ([`StrategyA::with_sim`] with
    /// [`crate::simulator::SimConfig::default`]).
    #[deprecated(note = "use Calibration::strategy(arch, Strategy::A, sim) \
                         (or StrategyA::from_params on a resolved set)")]
    pub fn new(arch: &ArchSpec, source: ParamSource) -> Result<StrategyA> {
        StrategyA::with_sim(arch, source, &crate::simulator::SimConfig::default())
    }

    /// Build the model with every derived/measured parameter resolved by
    /// the [`Calibration`] for `source` against `sim` — the closed-loop
    /// constructor the sweep cache uses for the grid's sim axis. Under
    /// [`ParamSource::Simulator`] the OperationFactor fit, the Prep
    /// estimate, and the contention probe all run against exactly this
    /// configuration (the same simulator that produces the sweep's
    /// measurements); under [`ParamSource::Paper`] the published
    /// Tables II–IV values are used and only the CPI/clock terms and the
    /// machine follow `sim`.
    #[deprecated(note = "use Calibration::strategy(arch, Strategy::A, sim) \
                         (or StrategyA::from_params on a resolved set)")]
    pub fn with_sim(
        arch: &ArchSpec,
        source: ParamSource,
        sim: &crate::simulator::SimConfig,
    ) -> Result<StrategyA> {
        StrategyA::from_params(&Calibration::new(source).resolve(arch, sim)?)
    }

    /// Build the model from an already-resolved parameter set (what the
    /// sweep cache does, so the (a, b) pair of a cell shares one
    /// calibration). Errors when the calibrator resolved no
    /// strategy-(a) operands (paper source on a custom architecture).
    pub fn from_params(params: &ModelParams) -> Result<StrategyA> {
        let a = params.strategy_a()?;
        Ok(StrategyA {
            machine: params.machine.clone(),
            fprop_ops: a.fprop_ops,
            bprop_ops: a.bprop_ops,
            prep_ops: a.prep_ops,
            operation_factor: a.operation_factor,
            contention: params.contention.clone(),
        })
    }

    /// Re-target the model at another machine configuration (the sweep
    /// machine axis). CPI/clock terms and — under
    /// [`ParamSource::Simulator`] — the contention probe follow the new
    /// machine; Paper-source contention stays the published Table IV
    /// values (measured on the 1.238 GHz testbed, the only machine the
    /// paper measured).
    pub fn with_machine(mut self, machine: MachineConfig) -> Self {
        let sim = crate::simulator::SimConfig {
            machine: machine.clone(),
            ..crate::simulator::SimConfig::default()
        };
        self.contention = self.contention.with_sim_config(sim);
        self.machine = machine;
        self
    }
}

impl PerfModel for StrategyA {
    fn predict(&self, run: &RunConfig) -> Result<Prediction> {
        run.validate()?;
        let s = self.machine.clock_hz;
        let of = self.operation_factor;
        let cpi = model_cpi(&self.machine, run.threads);
        let (i, it, ep, p) = (
            run.train_images as f64,
            run.test_images as f64,
            run.epochs as f64,
            run.threads as f64,
        );
        // The paper's published predictions use the *fractional* per-thread
        // share i/p (Table X reproduces only under real division; physical
        // ceiling-division imbalance is one of the effects the simulator
        // models and the analytic models miss).
        let chunk_i = i / run.threads as f64;
        let chunk_it = it / run.threads as f64;

        let prep_s = (self.prep_ops * of + 4.0 * i + 2.0 * it + 10.0 * ep) / s;
        let train_s =
            (self.fprop_ops + self.bprop_ops + self.fprop_ops) * chunk_i * ep * of * cpi / s;
        let test_s = self.fprop_ops * chunk_it * ep * of * cpi / s;
        let mem_s = self.contention.t_mem_s(run.epochs, run.train_images, run.threads)?;
        let _ = p;

        Ok(Prediction {
            prep_s,
            train_s,
            test_s,
            mem_s,
            total_s: prep_s + train_s + test_s + mem_s,
        })
    }

    fn name(&self) -> &'static str {
        "a"
    }
}

#[cfg(test)]
#[allow(deprecated)] // the equivalence pins exercise the deprecated constructors
mod tests {
    use super::*;
    use crate::report::paper;

    fn predict_minutes(arch: &str, p: usize) -> f64 {
        let arch = ArchSpec::by_name(arch).unwrap();
        let model = StrategyA::new(&arch, ParamSource::Paper).unwrap();
        let run = RunConfig::paper_default(&arch.name, p);
        model.predict(&run).unwrap().total_s / 60.0
    }

    #[test]
    fn table10_matches_paper() {
        // Table X, strategy (a) columns: predicted minutes at 480–3840
        // threads. Small and large reproduce to ~1%; medium to ~6%
        // (see module docs on the OperationFactor reading).
        let tolerances = [("small", 0.02), ("medium", 0.02), ("large", 0.02)];
        for (row, &threads) in paper::TABLE10_THREADS.iter().enumerate() {
            for (col, (arch, tol)) in tolerances.iter().enumerate() {
                let want = paper::TABLE10_MINUTES[row][col * 2];
                let got = predict_minutes(arch, threads);
                let rel = (got - want).abs() / want;
                assert!(rel < *tol, "{arch}@{threads}: {got:.1} vs {want} ({rel:.3})");
            }
        }
    }

    #[test]
    fn table11_small_240_480_matches_paper() {
        // Table XI: scaling images and epochs, small CNN, strategy (a).
        let arch = ArchSpec::small();
        let model = StrategyA::new(&arch, ParamSource::Paper).unwrap();
        for (row, &(i, it)) in paper::TABLE11_IMAGES.iter().enumerate() {
            for (ecol, &ep) in paper::TABLE11_EPOCHS.iter().enumerate() {
                for (tcol, &p) in paper::TABLE11_THREADS.iter().enumerate() {
                    let run = RunConfig {
                        train_images: i,
                        test_images: it,
                        epochs: ep,
                        threads: p,
                    };
                    let got = model.predict(&run).unwrap().total_s / 60.0;
                    let want = paper::TABLE11_MINUTES[row][tcol * 3 + ecol];
                    let rel = (got - want).abs() / want;
                    assert!(
                        rel < 0.03,
                        "i={i} ep={ep} p={p}: {got:.1} vs {want} ({rel:.3})"
                    );
                }
            }
        }
    }

    #[test]
    fn prediction_terms_positive_and_sum() {
        let arch = ArchSpec::medium();
        let model = StrategyA::new(&arch, ParamSource::Paper).unwrap();
        let pr = model.predict(&RunConfig::paper_default("medium", 240)).unwrap();
        assert!(pr.prep_s > 0.0 && pr.train_s > 0.0 && pr.test_s > 0.0 && pr.mem_s > 0.0);
        let sum = pr.prep_s + pr.train_s + pr.test_s + pr.mem_s;
        assert!((pr.total_s - sum).abs() < 1e-9);
    }

    #[test]
    fn cpi_step_visible_between_120_and_122() {
        // 122 threads = 2/core (CPI 1) → 183 = 3/core (CPI 1.5): the
        // compute term must jump by the ladder.
        let arch = ArchSpec::small();
        let model = StrategyA::new(&arch, ParamSource::Paper).unwrap();
        let t122 = model
            .predict(&RunConfig::paper_default("small", 122))
            .unwrap();
        let t183 = model
            .predict(&RunConfig::paper_default("small", 183))
            .unwrap();
        let per_image_122 = t122.train_s * 122.0;
        let per_image_183 = t183.train_s * 183.0;
        assert!(per_image_183 / per_image_122 > 1.4, "ladder jump missing");
    }

    #[test]
    fn custom_arch_with_simulator_params() {
        let arch = ArchSpec::from_json(
            r#"{"name":"tiny","layers":[
                {"type":"conv","maps":4,"kernel":4},
                {"type":"pool","window":2},
                {"type":"dense","units":10}]}"#,
        )
        .unwrap();
        let model = StrategyA::new(&arch, ParamSource::Simulator).unwrap();
        let pr = model
            .predict(&RunConfig { train_images: 1000, test_images: 100, epochs: 2, threads: 16 })
            .unwrap();
        assert!(pr.total_s.is_finite() && pr.total_s > 0.0);
    }

    #[test]
    fn custom_arch_under_paper_source_errors() {
        // No published Table VII/VIII rows for customs: the paper
        // calibrator resolves no strategy-(a) operands.
        let mut arch = ArchSpec::small();
        arch.name = "custom".into();
        assert!(StrategyA::new(&arch, ParamSource::Paper).is_err());
    }

    #[test]
    fn closed_loop_fit_matches_strategy_b_train_term() {
        // Under ParamSource::Simulator the fitted OperationFactor makes
        // the (2·FProp + BProp)·OF/s training cycles land exactly on the
        // probed 2·T_Fprop + T_Bprop — strategy (a)'s training term
        // coincides with (b)'s, leaving only the Table V single-factor
        // structure (the test term) as residual.
        let arch = ArchSpec::medium();
        let a = StrategyA::new(&arch, ParamSource::Simulator).unwrap();
        let b = crate::perfmodel::StrategyB::new(&arch, ParamSource::Simulator).unwrap();
        let a_train =
            (2.0 * a.fprop_ops + a.bprop_ops) * a.operation_factor / a.machine.clock_hz;
        let b_train = 2.0 * b.t_fprop_s + b.t_bprop_s;
        assert!((a_train - b_train).abs() / b_train < 1e-12, "{a_train} vs {b_train}");
    }
}
