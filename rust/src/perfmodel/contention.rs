//! The MemoryContention(p) parameter and the `T_mem` term.
//!
//! `T_mem(ep, i, p) = MemoryContention(p) · ep · i / p` — the paper's
//! memory/synchronization overhead (Section IV). The contention value per
//! thread count comes either from the paper's Table IV (measured on the
//! real Phi, predicted beyond 240 threads) or from the micsim probe.

use crate::config::ArchSpec;
use crate::error::{Error, Result};
use crate::perfmodel::ParamSource;
use crate::report::paper;
use crate::simulator::{probe, SimConfig};

/// Resolves MemoryContention(p) for one architecture.
#[derive(Debug, Clone)]
pub struct ContentionSource {
    arch: ArchSpec,
    source: ParamSource,
    sim_cfg: SimConfig,
}

impl ContentionSource {
    pub fn new(arch: &ArchSpec, source: ParamSource) -> Self {
        ContentionSource {
            arch: arch.clone(),
            source,
            sim_cfg: SimConfig::default(),
        }
    }

    pub fn with_sim_config(mut self, cfg: SimConfig) -> Self {
        self.sim_cfg = cfg;
        self
    }

    /// MemoryContention(p) in seconds.
    pub fn contention_s(&self, p: usize) -> Result<f64> {
        match self.source {
            ParamSource::Paper => {
                paper::contention_s(&self.arch.name, p).ok_or_else(|| {
                    Error::Config(format!(
                        "no Table IV column for arch {:?}; use ParamSource::Simulator",
                        self.arch.name
                    ))
                })
            }
            ParamSource::Simulator => probe::contention_probe(&self.arch, p, &self.sim_cfg),
        }
    }

    /// The full memory-overhead term `T_mem(ep, i, p)`.
    pub fn t_mem_s(&self, epochs: usize, train_images: usize, p: usize) -> Result<f64> {
        Ok(self.contention_s(p)? * epochs as f64 * train_images as f64 / p as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_tmem_small_240_matches_hand_calc() {
        // 1.40e-2 × 70 × 60000 / 240 = 245 s.
        let c = ContentionSource::new(&ArchSpec::small(), ParamSource::Paper);
        let t = c.t_mem_s(70, 60_000, 240).unwrap();
        assert!((t - 245.0).abs() < 0.5, "{t}");
    }

    #[test]
    fn simulator_source_close_to_paper_at_240() {
        for arch in ArchSpec::paper_archs() {
            let paper_src = ContentionSource::new(&arch, ParamSource::Paper);
            let sim_src = ContentionSource::new(&arch, ParamSource::Simulator);
            let a = paper_src.contention_s(240).unwrap();
            let b = sim_src.contention_s(240).unwrap();
            assert!((a - b).abs() / a < 0.05, "{}: {a} vs {b}", arch.name);
        }
    }

    #[test]
    fn paper_source_rejects_custom_arch() {
        let mut arch = ArchSpec::small();
        arch.name = "custom".into();
        let c = ContentionSource::new(&arch, ParamSource::Paper);
        assert!(c.contention_s(240).is_err());
        let c = ContentionSource::new(&arch, ParamSource::Simulator);
        assert!(c.contention_s(240).is_ok());
    }

    #[test]
    fn tmem_scales_linearly_with_images_and_epochs() {
        let c = ContentionSource::new(&ArchSpec::medium(), ParamSource::Paper);
        let base = c.t_mem_s(70, 60_000, 240).unwrap();
        assert!((c.t_mem_s(140, 60_000, 240).unwrap() / base - 2.0).abs() < 1e-9);
        assert!((c.t_mem_s(70, 120_000, 240).unwrap() / base - 2.0).abs() < 1e-9);
    }
}
