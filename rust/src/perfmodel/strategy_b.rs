//! Strategy (b) — the measurement-based model (Table VI).
//!
//! ```text
//! T(i, it, ep, p) = T_prep
//!   + [ (T_Fprop + T_Bprop)·⌈i/p⌉          (training)
//!     +  T_Fprop           ·⌈i/p⌉          (validation)
//!     +  T_Fprop           ·⌈it/p⌉ ]       (test)
//!     · ep · CPI(p)
//!   + MemoryContention(p) · ep · i / p
//! ```
//!
//! `T_Fprop`/`T_Bprop` are the *measured* per-image forward/backward
//! times at one hardware thread (Table III: measured on the authors'
//! 7120P; re-measured from micsim under [`ParamSource::Simulator`]), and
//! `T_prep` the measured preparation time. The CPI ladder rescales the
//! single-thread measurements to SMT occupancy ("when one hardware
//! thread is available per core, one instruction per cycle can be
//! assumed; for four threads per core only 0.5 instructions per cycle
//! per thread").
//!
//! With the paper's Table III/IV parameters this reproduces all twelve
//! strategy-(b) cells of Table X to three significant figures
//! (`tests::table10_matches_paper_exactly`).

use crate::calibration::{Calibration, ModelParams};
use crate::config::{ArchSpec, MachineConfig, RunConfig};
use crate::error::Result;
use crate::perfmodel::{model_cpi, ContentionSource, ParamSource, PerfModel, Prediction};
use crate::simulator::SimConfig;

/// Strategy (b) with resolved measured parameters.
#[derive(Debug, Clone)]
pub struct StrategyB {
    /// Machine the CPI terms evaluate against.
    pub machine: MachineConfig,
    /// Measured forward time per image, seconds — `T_Fprop` in the
    /// Table VI training/validation/test terms (Table III row 1).
    pub t_fprop_s: f64,
    /// Measured backward time per image, seconds — `T_Bprop` in the
    /// Table VI training term (Table III row 2).
    pub t_bprop_s: f64,
    /// Measured preparation time, seconds — the Table VI `T_prep`
    /// constant term (Table III row 3).
    pub t_prep_s: f64,
    contention: ContentionSource,
}

impl StrategyB {
    /// Build the model against the default simulator configuration
    /// ([`StrategyB::with_sim`] with [`SimConfig::default`]).
    #[deprecated(note = "use Calibration::strategy(arch, Strategy::B, sim) \
                         (or StrategyB::from_params on a resolved set)")]
    pub fn new(arch: &ArchSpec, source: ParamSource) -> Result<StrategyB> {
        StrategyB::with_sim(arch, source, &SimConfig::default())
    }

    /// Build the model with its measured parameters resolved by the
    /// [`Calibration`] for `source` against `sim` — the closed-loop
    /// constructor the sweep cache uses for the grid's sim axis. Under
    /// [`ParamSource::Simulator`] (and for custom architectures the
    /// paper never measured) `T_Fprop`/`T_Bprop`/`T_prep` are probed
    /// from exactly this configuration — the same simulator that
    /// produces the sweep's measurements; under [`ParamSource::Paper`]
    /// the Table III values are used and only the CPI terms and the
    /// machine follow `sim`.
    #[deprecated(note = "use Calibration::strategy(arch, Strategy::B, sim) \
                         (or StrategyB::from_params on a resolved set)")]
    pub fn with_sim(
        arch: &ArchSpec,
        source: ParamSource,
        sim: &SimConfig,
    ) -> Result<StrategyB> {
        StrategyB::from_params(&Calibration::new(source).resolve(arch, sim)?)
    }

    /// Build the model from an already-resolved parameter set (what the
    /// sweep cache does, so the (a, b) pair of a cell shares one
    /// calibration).
    pub fn from_params(params: &ModelParams) -> Result<StrategyB> {
        let b = params.strategy_b()?;
        Ok(StrategyB {
            machine: params.machine.clone(),
            t_fprop_s: b.t_fprop_s,
            t_bprop_s: b.t_bprop_s,
            t_prep_s: b.t_prep_s,
            contention: params.contention.clone(),
        })
    }

    /// Re-target the model at another machine configuration (the sweep
    /// machine axis) — see [`crate::perfmodel::StrategyA::with_machine`].
    pub fn with_machine(mut self, machine: MachineConfig) -> Self {
        let sim = SimConfig { machine: machine.clone(), ..SimConfig::default() };
        self.contention = self.contention.with_sim_config(sim);
        self.machine = machine;
        self
    }
}

impl PerfModel for StrategyB {
    fn predict(&self, run: &RunConfig) -> Result<Prediction> {
        run.validate()?;
        let cpi = model_cpi(&self.machine, run.threads);
        let ep = run.epochs as f64;
        // Fractional shares — see strategy_a.rs on why not ceiling.
        let chunk_i = run.train_images as f64 / run.threads as f64;
        let chunk_it = run.test_images as f64 / run.threads as f64;

        let prep_s = self.t_prep_s;
        let train_s =
            (self.t_fprop_s + self.t_bprop_s + self.t_fprop_s) * chunk_i * ep * cpi;
        let test_s = self.t_fprop_s * chunk_it * ep * cpi;
        let mem_s = self.contention.t_mem_s(run.epochs, run.train_images, run.threads)?;

        Ok(Prediction {
            prep_s,
            train_s,
            test_s,
            mem_s,
            total_s: prep_s + train_s + test_s + mem_s,
        })
    }

    fn name(&self) -> &'static str {
        "b"
    }
}

#[cfg(test)]
#[allow(deprecated)] // the equivalence pins exercise the deprecated constructors
mod tests {
    use super::*;

    fn predict_minutes(arch: &str, p: usize) -> f64 {
        let arch = ArchSpec::by_name(arch).unwrap();
        let model = StrategyB::new(&arch, ParamSource::Paper).unwrap();
        let run = RunConfig::paper_default(&arch.name, p);
        model.predict(&run).unwrap().total_s / 60.0
    }

    #[test]
    fn table10_matches_paper_exactly() {
        // Table X strategy-(b) columns: all twelve cells within 1.5%.
        for (row, &threads) in paper::TABLE10_THREADS.iter().enumerate() {
            for (col, arch) in ["small", "medium", "large"].iter().enumerate() {
                let want = paper::TABLE10_MINUTES[row][col * 2 + 1];
                let got = predict_minutes(arch, threads);
                let rel = (got - want).abs() / want;
                assert!(rel < 0.015, "{arch}@{threads}: {got:.2} vs {want} ({rel:.4})");
            }
        }
    }

    #[test]
    fn paper_params_match_table3() {
        let m = StrategyB::new(&ArchSpec::large(), ParamSource::Paper).unwrap();
        assert_eq!(m.t_fprop_s, 148.88e-3);
        assert_eq!(m.t_bprop_s, 859.19e-3);
        assert_eq!(m.t_prep_s, 13.5);
    }

    #[test]
    fn simulator_params_close_to_paper_params() {
        for arch in ArchSpec::paper_archs() {
            let a = StrategyB::new(&arch, ParamSource::Paper).unwrap();
            let b = StrategyB::new(&arch, ParamSource::Simulator).unwrap();
            assert!(
                (a.t_fprop_s - b.t_fprop_s).abs() / a.t_fprop_s < 0.15,
                "{}: fprop {} vs {}",
                arch.name,
                a.t_fprop_s,
                b.t_bprop_s
            );
        }
    }

    #[test]
    fn more_threads_never_slower_up_to_two_per_core(){
        // Within CPI 1 territory (p ≤ 122) prediction decreases in p.
        let arch = ArchSpec::medium();
        let model = StrategyB::new(&arch, ParamSource::Paper).unwrap();
        let mut prev = f64::INFINITY;
        for p in [1, 15, 30, 60, 120] {
            let t = model.predict(&RunConfig::paper_default("medium", p)).unwrap().total_s;
            assert!(t < prev, "p={p}");
            prev = t;
        }
    }

    #[test]
    fn with_sim_probes_the_given_simulator() {
        // The closed-loop constructor: measured parameters follow the
        // passed simulator configuration under ParamSource::Simulator.
        let arch = ArchSpec::small();
        let base =
            StrategyB::with_sim(&arch, ParamSource::Simulator, &SimConfig::default())
                .unwrap();
        let mut slower = SimConfig::default();
        slower.fwd_cycles_per_op *= 2.0;
        let slow = StrategyB::with_sim(&arch, ParamSource::Simulator, &slower).unwrap();
        assert!(slow.t_fprop_s > base.t_fprop_s);
        // Paper source keeps the Table III values regardless of sim.
        let paper = StrategyB::with_sim(&arch, ParamSource::Paper, &slower).unwrap();
        assert_eq!(paper.t_fprop_s, 1.45e-3);
        // And new() is exactly with_sim(default).
        let plain = StrategyB::new(&arch, ParamSource::Simulator).unwrap();
        assert_eq!(plain.t_fprop_s.to_bits(), base.t_fprop_s.to_bits());
    }

    #[test]
    fn custom_arch_uses_probe_measurements() {
        let arch = ArchSpec::from_json(
            r#"{"name":"tiny2","layers":[
                {"type":"conv","maps":2,"kernel":4},
                {"type":"pool","window":2},
                {"type":"dense","units":10}]}"#,
        )
        .unwrap();
        let model = StrategyB::new(&arch, ParamSource::Paper).unwrap();
        assert!(model.t_fprop_s > 0.0 && model.t_fprop_s < 1e-2);
    }
}
