//! The paper's contribution: two parameterized performance models for the
//! execution time of CNN training on the Intel Xeon Phi.
//!
//! `T(i, it, ep, p, s)` predicts total execution time from the number of
//! training/validation images `i`, test images `it`, epochs `ep`,
//! processing units `p`, and clock speed `s`:
//!
//! * **Strategy (a)** ([`strategy_a`], Table V) — minimal measurement:
//!   only memory contention is measured; compute terms come from
//!   operation counts (Table VII/VIII), the OperationFactor, and the CPI
//!   ladder.
//! * **Strategy (b)** ([`strategy_b`], Table VI) — measured sequential
//!   work: per-image forward/backward times and the preparation time are
//!   measured (on the real Phi in the paper; from [`crate::simulator`]
//!   here), then scaled by the CPI ladder.
//!
//! Both share the memory-overhead term
//! `T_mem(ep, i, p) = MemoryContention(p) · ep · i / p` ([`contention`])
//! and the prediction-accuracy metric Δ ([`accuracy`]).
//!
//! Parameter provenance is explicit and lives in one subsystem
//! ([`crate::calibration`]): [`ParamSource::Paper`] reproduces the
//! paper's tables exactly (Tables II–IV, VII, VIII embedded in
//! [`crate::report::paper`], resolved by
//! [`crate::calibration::PaperSource`]); [`ParamSource::Simulator`]
//! re-estimates every parameter from micsim
//! ([`crate::calibration::ComputedSource`]: probed times + computed op
//! counts with fitted cycles), closing the loop the way the authors did
//! on real hardware.

#![warn(missing_docs)]

pub mod accuracy;
pub mod cluster;
pub mod strategy_a;
pub mod strategy_b;
pub mod strategy_c;

// Migrated to the calibration subsystem; re-exported so existing
// `perfmodel::contention` / `perfmodel::ContentionSource` paths hold.
pub use crate::calibration::contention;
pub use crate::calibration::ContentionSource;

pub use accuracy::{average_delta, delta_pct, Band, DeltaAccumulator};
pub use strategy_a::StrategyA;
pub use strategy_b::StrategyB;
pub use strategy_c::StrategyC;

use crate::config::{ArchSpec, MachineConfig, RunConfig};
use crate::error::Result;
use crate::nn::OpSource;

/// Where the models' measured/derived parameters come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParamSource {
    /// The paper's published values (exact table reproduction).
    #[default]
    Paper,
    /// Re-estimated against the micsim probes (self-consistent
    /// reproduction — the closed loop).
    Simulator,
}

impl ParamSource {
    /// The op-count source this parameter source implies — the single
    /// place the `ParamSource → OpSource` mapping lives (it used to be
    /// hard-wired in the strategy constructors, where the two enums
    /// could drift; the calibrators route through here).
    pub fn op_source(self) -> OpSource {
        match self {
            ParamSource::Paper => OpSource::Paper,
            ParamSource::Simulator => OpSource::Computed,
        }
    }
}

/// A prediction with its term-level breakdown (the Table V/VI structure).
#[derive(Debug, Clone, Copy)]
pub struct Prediction {
    /// Sequential preparation term, seconds.
    pub prep_s: f64,
    /// Training + validation compute term.
    pub train_s: f64,
    /// Test compute term.
    pub test_s: f64,
    /// Memory overhead term `T_mem`.
    pub mem_s: f64,
    /// Total predicted execution time.
    pub total_s: f64,
}

/// Common interface over the strategies.
pub trait PerfModel {
    /// Predict execution time for a workload.
    fn predict(&self, run: &RunConfig) -> Result<Prediction>;
    /// Model name for reports ("a" / "b" / "c").
    fn name(&self) -> &'static str;
}

// Boxed models (what `Calibration::strategy` hands out) are models too,
// so call sites generic over `M: PerfModel` take either form.
impl<T: PerfModel + ?Sized> PerfModel for Box<T> {
    fn predict(&self, run: &RunConfig) -> Result<Prediction> {
        (**self).predict(run)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// The CPI factor the models apply for `p` threads on `machine`
/// (Table III: derived from threads-per-core occupancy, saturating at the
/// ladder's last entry beyond the hardware thread count).
pub fn model_cpi(machine: &MachineConfig, p: usize) -> f64 {
    machine.cpi(machine.occupancy(p))
}

/// Convenience: build both models for an architecture. One calibration
/// resolution is shared by the pair (the [`crate::calibration`] facade
/// policy), which keeps it bit-identical to the deprecated per-model
/// constructors.
pub fn both_models(
    arch: &ArchSpec,
    source: ParamSource,
) -> Result<(StrategyA, StrategyB)> {
    let params = crate::calibration::Calibration::new(source)
        .resolve(arch, &crate::simulator::SimConfig::default())?;
    Ok((StrategyA::from_params(&params)?, StrategyB::from_params(&params)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_cpi_ladder() {
        let m = MachineConfig::xeon_phi_7120p();
        assert_eq!(model_cpi(&m, 1), 1.0);
        assert_eq!(model_cpi(&m, 120), 1.0);
        assert_eq!(model_cpi(&m, 122), 1.0); // exactly 2/core
        assert_eq!(model_cpi(&m, 180), 1.5);
        assert_eq!(model_cpi(&m, 240), 2.0);
        assert_eq!(model_cpi(&m, 3840), 2.0);
    }

    #[test]
    fn both_models_construct_for_all_archs() {
        for arch in ArchSpec::paper_archs() {
            assert!(both_models(&arch, ParamSource::Paper).is_ok());
            assert!(both_models(&arch, ParamSource::Simulator).is_ok());
        }
    }

    #[test]
    fn param_source_op_source_mapping_is_total() {
        assert_eq!(ParamSource::Paper.op_source(), OpSource::Paper);
        assert_eq!(ParamSource::Simulator.op_source(), OpSource::Computed);
    }
}
