//! Strategy (c) — strategy (b) corrected by the sweep-trained residual
//! regressor ([`crate::calibration::ResidualModel`]).
//!
//! ```text
//! T_c(i, it, ep, p) = T_b(i, it, ep, p) · exp(w · x(i, it, ep, p))
//! ```
//!
//! where `w` is the ridge fit of `ln(measured / T_b)` over the seeded
//! training grid and `x` the scenario feature vector
//! ([`crate::calibration::residual::FEATURE_NAMES`]). The correction is
//! a single multiplicative ratio, applied to every term of the
//! breakdown, so the Table V/VI structure of the prediction survives
//! and `total_s` remains exactly the (b) total times the ratio.
//!
//! Build through the facade — `Calibration::strategy(arch, Strategy::C,
//! sim)` — which resolves the (b) parameters and the fitted residual
//! model from one shared, store-backed calibration.

use std::sync::Arc;

use crate::calibration::ResidualModel;
use crate::config::RunConfig;
use crate::error::Result;
use crate::perfmodel::{PerfModel, Prediction, StrategyB};

/// Strategy (c): a [`StrategyB`] inner model plus a fitted residual
/// correction.
#[derive(Debug, Clone)]
pub struct StrategyC {
    inner: StrategyB,
    residual: Arc<ResidualModel>,
}

impl StrategyC {
    /// Wrap a resolved (b) model with its fitted residual.
    pub fn new(inner: StrategyB, residual: Arc<ResidualModel>) -> StrategyC {
        StrategyC { inner, residual }
    }

    /// The fitted residual model (provenance, weights, ratio).
    pub fn residual(&self) -> &ResidualModel {
        &self.residual
    }

    /// The uncorrected inner (b) model.
    pub fn inner(&self) -> &StrategyB {
        &self.inner
    }
}

impl PerfModel for StrategyC {
    fn predict(&self, run: &RunConfig) -> Result<Prediction> {
        let base = self.inner.predict(run)?;
        let ratio = self.residual.ratio(run);
        Ok(Prediction {
            prep_s: base.prep_s * ratio,
            train_s: base.train_s * ratio,
            test_s: base.test_s * ratio,
            mem_s: base.mem_s * ratio,
            total_s: base.total_s * ratio,
        })
    }

    fn name(&self) -> &'static str {
        "c"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration::Calibration;
    use crate::config::ArchSpec;
    use crate::perfmodel::ParamSource;
    use crate::simulator::SimConfig;
    use crate::sweep::Strategy;

    #[test]
    fn facade_builds_c_and_scales_every_term() {
        let cal = Calibration::new(ParamSource::Paper);
        let arch = ArchSpec::small();
        let sim = SimConfig::default();
        let c = cal.strategy(&arch, Strategy::C, &sim).unwrap();
        let b = cal.strategy(&arch, Strategy::B, &sim).unwrap();
        assert_eq!(c.name(), "c");
        assert_eq!(cal.resolutions(), 1, "(b) and (c) share one resolution");
        assert_eq!(cal.residual_fits(), 1, "one fit for the pair");
        let run = RunConfig::paper_default("small", 240);
        let pb = b.predict(&run).unwrap();
        let pc = c.predict(&run).unwrap();
        let ratio = pc.total_s / pb.total_s;
        assert!(ratio.is_finite() && ratio > 0.0);
        for (term_c, term_b) in [
            (pc.prep_s, pb.prep_s),
            (pc.train_s, pb.train_s),
            (pc.test_s, pb.test_s),
            (pc.mem_s, pb.mem_s),
        ] {
            assert_eq!((term_b * ratio).to_bits(), term_c.to_bits());
        }
    }

    #[test]
    fn c_beats_b_on_the_paper_workload() {
        // The measured-accuracy ordering the conformance baseline pins,
        // spot-checked at model level on the Table IX thread set.
        let cal = Calibration::new(ParamSource::Paper);
        let sim = SimConfig::default();
        for arch in ArchSpec::paper_archs() {
            let b = cal.strategy(&arch, Strategy::B, &sim).unwrap();
            let c = cal.strategy(&arch, Strategy::C, &sim).unwrap();
            let (mut db, mut dc) = (0.0, 0.0);
            for &p in RunConfig::MEASURED_THREADS.iter() {
                let run = RunConfig::paper_default(&arch.name, p);
                let measured =
                    crate::simulator::simulate_training(&arch, &run, &sim)
                        .unwrap()
                        .execution_s;
                let pb = b.predict(&run).unwrap().total_s;
                let pc = c.predict(&run).unwrap().total_s;
                db += (measured - pb).abs() / pb * 100.0;
                dc += (measured - pc).abs() / pc * 100.0;
            }
            assert!(
                dc < db,
                "{}: (c) {:.3}% must beat (b) {:.3}%",
                arch.name,
                dc / 7.0,
                db / 7.0
            );
        }
    }
}
