//! Multi-node extension — the paper's stated future work.
//!
//! "Future work will develop performance models of deep learning on
//! large-scale parallel computing systems that comprise multiple nodes
//! with many-core processors." (Section VII.)
//!
//! This module builds that model: `N` nodes, each an Intel Xeon Phi
//! running the paper's data-parallel scheme on `i/N` images, with a
//! weight-synchronization step per epoch over the interconnect:
//!
//! ```text
//! T_cluster(i, it, ep, p, N) =
//!     T_node(i/N, it/N, ep, p)            per-node single-Phi model
//!   + ep · T_allreduce(W, N)              weight combine per epoch
//!
//! T_allreduce(W, N) = 2·(N−1)/N · W·4 / link_bw + 2·(N−1) · latency
//!                     (ring all-reduce on W f32 weights)
//! ```
//!
//! The single-node term reuses either strategy (a) or (b); the
//! communication term is the standard ring-allreduce cost model. The
//! cluster experiment (`repro exp cluster`) reports predicted time and
//! parallel efficiency up to 16 nodes.

use crate::config::{ArchSpec, RunConfig};
use crate::error::{Error, Result};
use crate::perfmodel::{ParamSource, PerfModel, Prediction};

/// Interconnect description.
#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// Per-link bandwidth, bytes/s.
    pub link_bw_bytes: f64,
    /// Per-message latency, seconds.
    pub latency_s: f64,
}

impl Interconnect {
    /// FDR InfiniBand-class interconnect (the era's HPC standard:
    /// ~6.8 GB/s effective, ~1.5 µs latency).
    pub fn infiniband_fdr() -> Self {
        Interconnect { link_bw_bytes: 6.8e9, latency_s: 1.5e-6 }
    }

    /// 10 GbE (the pessimistic option).
    pub fn ten_gbe() -> Self {
        Interconnect { link_bw_bytes: 1.25e9, latency_s: 50.0e-6 }
    }

    /// Ring all-reduce seconds for `weights` f32 parameters over `n` nodes.
    pub fn allreduce_s(&self, weights: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let bytes = weights as f64 * 4.0;
        2.0 * (n as f64 - 1.0) / n as f64 * bytes / self.link_bw_bytes
            + 2.0 * (n as f64 - 1.0) * self.latency_s
    }
}

/// Cluster-level prediction.
#[derive(Debug, Clone, Copy)]
pub struct ClusterPrediction {
    /// The per-node (sharded-workload) prediction.
    pub node: Prediction,
    /// Communication seconds over the whole run.
    pub comm_s: f64,
    /// Total predicted cluster execution time, seconds.
    pub total_s: f64,
    /// Speedup over the single-node prediction.
    pub speedup: f64,
    /// Parallel efficiency: speedup / N.
    pub efficiency: f64,
}

/// The multi-node model wrapping a single-Phi strategy.
pub struct ClusterModel<M: PerfModel> {
    /// The single-node strategy being scaled out.
    pub node_model: M,
    /// Trainable weights synchronized per step (allreduce payload).
    pub weights: usize,
    /// Interconnect description for the communication term.
    pub interconnect: Interconnect,
}

impl<M: PerfModel> ClusterModel<M> {
    /// Wrap `node_model` for `arch` behind `interconnect`.
    pub fn new(arch: &ArchSpec, node_model: M, interconnect: Interconnect) -> Result<Self> {
        Ok(ClusterModel {
            node_model,
            weights: arch.total_weights()?,
            interconnect,
        })
    }

    /// Predict a cluster run: `run` describes the *global* workload;
    /// images shard evenly across `nodes`.
    pub fn predict(&self, run: &RunConfig, nodes: usize) -> Result<ClusterPrediction> {
        if nodes == 0 {
            return Err(Error::Config("need at least one node".into()));
        }
        let single = self.node_model.predict(run)?;
        let node_run = RunConfig {
            train_images: run.train_images.div_ceil(nodes),
            test_images: run.test_images.div_ceil(nodes),
            ..*run
        };
        let node = self.node_model.predict(&node_run)?;
        let comm_s =
            run.epochs as f64 * self.interconnect.allreduce_s(self.weights, nodes);
        let total_s = node.total_s + comm_s;
        let speedup = single.total_s / total_s;
        Ok(ClusterPrediction {
            node,
            comm_s,
            total_s,
            speedup,
            efficiency: speedup / nodes as f64,
        })
    }
}

/// Convenience: strategy-(b) cluster model over InfiniBand.
pub fn default_cluster(arch: &ArchSpec) -> Result<ClusterModel<crate::perfmodel::StrategyB>> {
    let params = crate::calibration::Calibration::new(ParamSource::Paper)
        .resolve(arch, &crate::simulator::SimConfig::default())?;
    let node = crate::perfmodel::StrategyB::from_params(&params)?;
    ClusterModel::new(arch, node, Interconnect::infiniband_fdr())
}

#[cfg(test)]
#[allow(deprecated)] // exercises the deprecated direct constructors
mod tests {
    use super::*;

    #[test]
    fn allreduce_zero_for_single_node() {
        let ic = Interconnect::infiniband_fdr();
        assert_eq!(ic.allreduce_s(1_000_000, 1), 0.0);
        assert!(ic.allreduce_s(1_000_000, 2) > 0.0);
    }

    #[test]
    fn allreduce_volume_term_saturates_with_nodes() {
        // 2(N-1)/N bytes/bw grows but approaches 2× the single transfer.
        let ic = Interconnect::infiniband_fdr();
        let t2 = ic.allreduce_s(10_000_000, 2);
        let t64 = ic.allreduce_s(10_000_000, 64);
        assert!(t64 < t2 * 2.5);
    }

    #[test]
    fn cluster_speeds_up_but_sublinearly() {
        let arch = ArchSpec::medium();
        let model = default_cluster(&arch).unwrap();
        let run = RunConfig::paper_default("medium", 240);
        let p1 = model.predict(&run, 1).unwrap();
        let p4 = model.predict(&run, 4).unwrap();
        let p16 = model.predict(&run, 16).unwrap();
        assert!(p4.total_s < p1.total_s);
        assert!(p16.total_s < p4.total_s);
        assert!(p4.efficiency <= 1.0 + 1e-9);
        assert!(p16.efficiency < p4.efficiency, "efficiency should decay");
    }

    #[test]
    fn single_node_matches_underlying_model() {
        let arch = ArchSpec::small();
        let model = default_cluster(&arch).unwrap();
        let run = RunConfig::paper_default("small", 240);
        let c = model.predict(&run, 1).unwrap();
        let direct = model.node_model.predict(&run).unwrap();
        assert!((c.total_s - direct.total_s).abs() < 1e-9);
        assert!((c.efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn slow_interconnect_hurts_large_models_more() {
        let small = ArchSpec::small();
        let large = ArchSpec::large();
        let run_s = RunConfig::paper_default("small", 240);
        let run_l = RunConfig::paper_default("large", 240);
        let mk = |arch: &ArchSpec, ic: Interconnect| {
            let node = crate::perfmodel::StrategyB::new(arch, ParamSource::Paper).unwrap();
            ClusterModel::new(arch, node, ic).unwrap()
        };
        let eff = |arch: &ArchSpec, run: &RunConfig, ic: Interconnect| {
            mk(arch, ic).predict(run, 8).unwrap().efficiency
        };
        let degr_small = eff(&small, &run_s, Interconnect::infiniband_fdr())
            - eff(&small, &run_s, Interconnect::ten_gbe());
        let degr_large = eff(&large, &run_l, Interconnect::infiniband_fdr())
            - eff(&large, &run_l, Interconnect::ten_gbe());
        // Large has 43× the weights of small -> more comm-sensitive
        // relative to... actually more total weights but also much more
        // compute; assert only that both degrade and stay in [0, 1].
        assert!(degr_small >= 0.0 && degr_large >= 0.0);
    }

    #[test]
    fn rejects_zero_nodes() {
        let arch = ArchSpec::small();
        let model = default_cluster(&arch).unwrap();
        assert!(model.predict(&RunConfig::paper_default("small", 240), 0).is_err());
    }
}
