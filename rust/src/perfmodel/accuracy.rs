//! Prediction accuracy Δ (Section V).
//!
//! `Δ = |T_measured − T_predicted| / T_predicted × 100%`, averaged over
//! the measured thread counts {1, 15, 30, 60, 120, 180, 240} — the
//! Table IX metric.

use crate::config::{ArchSpec, RunConfig};
use crate::error::Result;
use crate::perfmodel::PerfModel;
use crate::simulator::{probe, SimConfig};

/// Single-point accuracy, percent.
pub fn delta_pct(measured_s: f64, predicted_s: f64) -> f64 {
    (measured_s - predicted_s).abs() / predicted_s * 100.0
}

/// Average Δ of `model` against micsim "measurements" over `threads`.
pub fn average_delta(
    arch: &ArchSpec,
    model: &dyn PerfModel,
    threads: &[usize],
    sim_cfg: &SimConfig,
) -> Result<f64> {
    let mut sum = 0.0;
    for &p in threads {
        let run = RunConfig::paper_default(&arch.name, p);
        let predicted = model.predict(&run)?.total_s;
        let measured = probe::measured_execution_s(arch, p, sim_cfg)?;
        sum += delta_pct(measured, predicted);
    }
    Ok(sum / threads.len() as f64)
}

/// Streaming mean/max accumulator for Δ values.
///
/// The sweep engine's grid-level accuracy aggregation
/// ([`crate::sweep::SweepResults::accuracy`]) and the Table IX experiment
/// both fold per-scenario Δ through this. Pushing values in enumeration
/// order keeps the mean **bit-identical** to [`average_delta`] (same
/// addition order, same final division) — asserted by
/// `experiments::table9::tests::sweep_path_matches_pointwise_average_delta`.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeltaAccumulator {
    sum: f64,
    n: usize,
    max: f64,
    max_at_threads: usize,
}

impl DeltaAccumulator {
    /// Fold in one scenario's Δ, remembering the thread count of the
    /// worst point.
    pub fn push(&mut self, delta_pct: f64, threads: usize) {
        if self.n == 0 || delta_pct > self.max {
            self.max = delta_pct;
            self.max_at_threads = threads;
        }
        self.sum += delta_pct;
        self.n += 1;
    }

    /// Number of points folded in so far.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Mean Δ, percent (`None` before the first push).
    pub fn mean_pct(&self) -> Option<f64> {
        (self.n > 0).then(|| self.sum / self.n as f64)
    }

    /// Worst-point Δ and the thread count it occurred at.
    pub fn max_pct(&self) -> Option<(f64, usize)> {
        (self.n > 0).then_some((self.max, self.max_at_threads))
    }
}

/// A named ceiling for a Δ statistic — the executable form of a paper
/// accuracy claim ("mean prediction error ≈ 15 % for model (a)"): the
/// published value alongside the hard ceiling the reproduction's
/// observed statistic must stay under. The conformance harness
/// ([`crate::sweep::conformance`]) stores one per strategy and fails the
/// build when a fresh measured sweep exceeds it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Band {
    /// The paper's published value, percent.
    pub paper_pct: f64,
    /// Ceiling the observed statistic must not exceed, percent.
    pub ceiling_pct: f64,
}

impl Band {
    /// Whether an observed Δ statistic conforms. Non-finite observations
    /// never conform — a NaN mean is a broken pipeline, not a pass.
    pub fn admits(&self, observed_pct: f64) -> bool {
        observed_pct.is_finite() && observed_pct <= self.ceiling_pct
    }
}

/// Per-point Δ series (for figure annotations / debugging).
pub fn delta_series(
    arch: &ArchSpec,
    model: &dyn PerfModel,
    threads: &[usize],
    sim_cfg: &SimConfig,
) -> Result<Vec<(usize, f64)>> {
    threads
        .iter()
        .map(|&p| {
            let run = RunConfig::paper_default(&arch.name, p);
            let predicted = model.predict(&run)?.total_s;
            let measured = probe::measured_execution_s(arch, p, sim_cfg)?;
            Ok((p, delta_pct(measured, predicted)))
        })
        .collect()
}

#[cfg(test)]
#[allow(deprecated)] // exercises the deprecated direct constructors
mod tests {
    use super::*;
    use crate::perfmodel::{ParamSource, StrategyA, StrategyB};

    #[test]
    fn delta_pct_basic() {
        assert!((delta_pct(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert!((delta_pct(90.0, 100.0) - 10.0).abs() < 1e-12);
        assert_eq!(delta_pct(100.0, 100.0), 0.0);
    }

    #[test]
    fn average_delta_in_papers_ballpark() {
        // Paper Table IX: Δ between ~7% and ~17%. Our simulator stands in
        // for the testbed, so we assert the same ballpark: both models
        // within 30%, i.e. the models actually predict the simulator.
        let cfg = SimConfig::default();
        let threads = RunConfig::MEASURED_THREADS;
        for arch in ArchSpec::paper_archs() {
            let a = StrategyA::new(&arch, ParamSource::Paper).unwrap();
            let b = StrategyB::new(&arch, ParamSource::Paper).unwrap();
            let da = average_delta(&arch, &a, &threads, &cfg).unwrap();
            let db = average_delta(&arch, &b, &threads, &cfg).unwrap();
            assert!(da < 30.0, "{}: Δa = {da:.1}%", arch.name);
            assert!(db < 30.0, "{}: Δb = {db:.1}%", arch.name);
        }
    }

    #[test]
    fn accumulator_matches_average_delta_bit_for_bit() {
        let cfg = SimConfig::default();
        let arch = ArchSpec::medium();
        let model = StrategyA::new(&arch, ParamSource::Paper).unwrap();
        let threads = [1usize, 15, 240];
        let mut acc = DeltaAccumulator::default();
        for (p, d) in delta_series(&arch, &model, &threads, &cfg).unwrap() {
            acc.push(d, p);
        }
        let avg = average_delta(&arch, &model, &threads, &cfg).unwrap();
        assert_eq!(acc.count(), 3);
        assert_eq!(acc.mean_pct().unwrap().to_bits(), avg.to_bits());
    }

    #[test]
    fn accumulator_tracks_max_and_its_thread_count() {
        let mut acc = DeltaAccumulator::default();
        assert!(acc.mean_pct().is_none() && acc.max_pct().is_none());
        acc.push(5.0, 1);
        acc.push(12.0, 240);
        acc.push(7.0, 30);
        assert_eq!(acc.count(), 3);
        assert_eq!(acc.max_pct(), Some((12.0, 240)));
        assert!((acc.mean_pct().unwrap() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn band_admits_at_and_below_ceiling_only() {
        let band = Band { paper_pct: 15.0, ceiling_pct: 18.0 };
        assert!(band.admits(10.0));
        assert!(band.admits(18.0));
        assert!(!band.admits(18.001));
        assert!(!band.admits(f64::NAN));
        assert!(!band.admits(f64::INFINITY));
    }

    #[test]
    fn delta_series_covers_all_points() {
        let cfg = SimConfig::default();
        let arch = ArchSpec::small();
        let model = StrategyB::new(&arch, ParamSource::Paper).unwrap();
        let series = delta_series(&arch, &model, &[1, 15, 240], &cfg).unwrap();
        assert_eq!(series.len(), 3);
        assert!(series.iter().all(|&(_, d)| d.is_finite() && d >= 0.0));
    }
}
