//! Prediction accuracy Δ (Section V).
//!
//! `Δ = |T_measured − T_predicted| / T_predicted × 100%`, averaged over
//! the measured thread counts {1, 15, 30, 60, 120, 180, 240} — the
//! Table IX metric.

use crate::config::{ArchSpec, RunConfig};
use crate::error::Result;
use crate::perfmodel::PerfModel;
use crate::simulator::{probe, SimConfig};

/// Single-point accuracy, percent.
pub fn delta_pct(measured_s: f64, predicted_s: f64) -> f64 {
    (measured_s - predicted_s).abs() / predicted_s * 100.0
}

/// Average Δ of `model` against micsim "measurements" over `threads`.
pub fn average_delta(
    arch: &ArchSpec,
    model: &dyn PerfModel,
    threads: &[usize],
    sim_cfg: &SimConfig,
) -> Result<f64> {
    let mut sum = 0.0;
    for &p in threads {
        let run = RunConfig::paper_default(&arch.name, p);
        let predicted = model.predict(&run)?.total_s;
        let measured = probe::measured_execution_s(arch, p, sim_cfg)?;
        sum += delta_pct(measured, predicted);
    }
    Ok(sum / threads.len() as f64)
}

/// Per-point Δ series (for figure annotations / debugging).
pub fn delta_series(
    arch: &ArchSpec,
    model: &dyn PerfModel,
    threads: &[usize],
    sim_cfg: &SimConfig,
) -> Result<Vec<(usize, f64)>> {
    threads
        .iter()
        .map(|&p| {
            let run = RunConfig::paper_default(&arch.name, p);
            let predicted = model.predict(&run)?.total_s;
            let measured = probe::measured_execution_s(arch, p, sim_cfg)?;
            Ok((p, delta_pct(measured, predicted)))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{ParamSource, StrategyA, StrategyB};

    #[test]
    fn delta_pct_basic() {
        assert!((delta_pct(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert!((delta_pct(90.0, 100.0) - 10.0).abs() < 1e-12);
        assert_eq!(delta_pct(100.0, 100.0), 0.0);
    }

    #[test]
    fn average_delta_in_papers_ballpark() {
        // Paper Table IX: Δ between ~7% and ~17%. Our simulator stands in
        // for the testbed, so we assert the same ballpark: both models
        // within 30%, i.e. the models actually predict the simulator.
        let cfg = SimConfig::default();
        let threads = RunConfig::MEASURED_THREADS;
        for arch in ArchSpec::paper_archs() {
            let a = StrategyA::new(&arch, ParamSource::Paper).unwrap();
            let b = StrategyB::new(&arch, ParamSource::Paper).unwrap();
            let da = average_delta(&arch, &a, &threads, &cfg).unwrap();
            let db = average_delta(&arch, &b, &threads, &cfg).unwrap();
            assert!(da < 30.0, "{}: Δa = {da:.1}%", arch.name);
            assert!(db < 30.0, "{}: Δb = {db:.1}%", arch.name);
        }
    }

    #[test]
    fn delta_series_covers_all_points() {
        let cfg = SimConfig::default();
        let arch = ArchSpec::small();
        let model = StrategyB::new(&arch, ParamSource::Paper).unwrap();
        let series = delta_series(&arch, &model, &[1, 15, 240], &cfg).unwrap();
        assert_eq!(series.len(), 3);
        assert!(series.iter().all(|&(_, d)| d.is_finite() && d >= 0.0));
    }
}
