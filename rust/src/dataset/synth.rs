//! Deterministic synthetic digit corpus — the MNIST substitute.
//!
//! Renders each digit 0–9 from a 5×7 seven-segment-style glyph, scaled into
//! the 29×29 canvas with per-sample jitter (translation, scale, intensity,
//! noise) driven by a seeded xorshift stream. The result is a linearly
//! non-trivial 10-class problem with MNIST's shapes and label balance:
//! a small CNN reaches >90% accuracy in a few hundred SGD steps, so the
//! end-to-end example produces a meaningful falling loss curve
//! (EXPERIMENTS.md §e2e).

use crate::dataset::{IMAGE_HW, IMAGE_PIXELS, NUM_CLASSES};
use crate::nn::init::XorShift64;

/// 5×7 bitmap glyphs for digits 0–9 (row-major, 1 = ink).
const GLYPHS: [[u8; 35]; 10] = [
    // 0
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,1,1, 1,0,1,0,1, 1,1,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 1
    [0,0,1,0,0, 0,1,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,1,1,1,0],
    // 2
    [0,1,1,1,0, 1,0,0,0,1, 0,0,0,0,1, 0,0,0,1,0, 0,0,1,0,0, 0,1,0,0,0, 1,1,1,1,1],
    // 3
    [1,1,1,1,1, 0,0,0,1,0, 0,0,1,0,0, 0,0,0,1,0, 0,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 4
    [0,0,0,1,0, 0,0,1,1,0, 0,1,0,1,0, 1,0,0,1,0, 1,1,1,1,1, 0,0,0,1,0, 0,0,0,1,0],
    // 5
    [1,1,1,1,1, 1,0,0,0,0, 1,1,1,1,0, 0,0,0,0,1, 0,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 6
    [0,0,1,1,0, 0,1,0,0,0, 1,0,0,0,0, 1,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 7
    [1,1,1,1,1, 0,0,0,0,1, 0,0,0,1,0, 0,0,1,0,0, 0,1,0,0,0, 0,1,0,0,0, 0,1,0,0,0],
    // 8
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 9
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,1, 0,0,0,0,1, 0,0,0,1,0, 0,1,1,0,0],
];

/// Render one sample of digit `label` with jitter from `rng` into a
/// 29×29 f32 image in [0, 1].
pub fn render_digit(label: usize, rng: &mut XorShift64) -> Vec<f32> {
    assert!(label < NUM_CLASSES);
    let glyph = &GLYPHS[label];
    let mut img = vec![0.0f32; IMAGE_PIXELS];

    // Jitter: scale 2.5–3.5× per axis, translation within the canvas,
    // ink intensity 0.6–1.0.
    let sx = 2.5 + rng.next_f32();
    let sy = 2.5 + rng.next_f32();
    let gw = (5.0 * sx) as usize;
    let gh = (7.0 * sy) as usize;
    let max_tx = IMAGE_HW.saturating_sub(gw + 2).max(1);
    let max_ty = IMAGE_HW.saturating_sub(gh + 2).max(1);
    let tx = 1 + rng.next_below(max_tx);
    let ty = 1 + rng.next_below(max_ty);
    let intensity = 0.6 + 0.4 * rng.next_f32();

    for y in 0..gh.min(IMAGE_HW - ty) {
        let gy = ((y as f32 / sy) as usize).min(6);
        for x in 0..gw.min(IMAGE_HW - tx) {
            let gx = ((x as f32 / sx) as usize).min(4);
            if glyph[gy * 5 + gx] == 1 {
                img[(ty + y) * IMAGE_HW + (tx + x)] = intensity;
            }
        }
    }

    // Pixel noise (±0.08) and salt speckles.
    for v in img.iter_mut() {
        *v = (*v + (rng.next_f32() - 0.5) * 0.16).clamp(0.0, 1.0);
    }
    for _ in 0..6 {
        let at = rng.next_below(IMAGE_PIXELS);
        img[at] = (img[at] + 0.5 * rng.next_f32()).clamp(0.0, 1.0);
    }
    img
}

/// Generate `n` samples with balanced, shuffled labels.
pub fn generate(n: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<usize>) {
    let mut rng = XorShift64::new(seed);
    let mut labels: Vec<usize> = (0..n).map(|i| i % NUM_CLASSES).collect();
    // Fisher-Yates shuffle.
    for i in (1..n).rev() {
        let j = rng.next_below(i + 1);
        labels.swap(i, j);
    }
    let images = labels.iter().map(|&l| render_digit(l, &mut rng)).collect();
    (images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_correct_size_and_range() {
        let mut rng = XorShift64::new(1);
        for label in 0..10 {
            let img = render_digit(label, &mut rng);
            assert_eq!(img.len(), IMAGE_PIXELS);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn digits_have_ink() {
        let mut rng = XorShift64::new(2);
        for label in 0..10 {
            let img = render_digit(label, &mut rng);
            let ink: f32 = img.iter().sum();
            assert!(ink > 5.0, "digit {label} has ink {ink}");
        }
    }

    #[test]
    fn different_digits_differ_more_than_same_digit() {
        // Average intra-class distance should be below inter-class distance
        // (i.e. the classes are actually separable).
        let mut rng = XorShift64::new(3);
        let a1 = render_digit(1, &mut rng);
        let a2 = render_digit(1, &mut rng);
        let b = render_digit(8, &mut rng);
        let dist = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(a, b)| (a - b).powi(2)).sum()
        };
        // Not guaranteed sample-by-sample, but 1-vs-1 should usually be
        // closer than 1-vs-8 under the same jitter stream; use a margin.
        assert!(dist(&a1, &a2) < dist(&a1, &b) * 1.5);
    }

    #[test]
    fn generate_is_balanced_and_deterministic() {
        let (im1, la1) = generate(100, 9);
        let (im2, la2) = generate(100, 9);
        assert_eq!(la1, la2);
        assert_eq!(im1, im2);
        for c in 0..10 {
            assert_eq!(la1.iter().filter(|&&l| l == c).count(), 10);
        }
        let (_, la3) = generate(100, 10);
        assert_ne!(la1, la3);
    }

    #[test]
    fn labels_are_shuffled() {
        let (_, labels) = generate(50, 4);
        // Not the trivial 0,1,2,... pattern.
        let trivial: Vec<usize> = (0..50).map(|i| i % 10).collect();
        assert_ne!(labels, trivial);
    }
}
