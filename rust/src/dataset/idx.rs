//! IDX file format (the MNIST container): reader and writer.
//!
//! Format: big-endian magic `[0, 0, dtype, ndim]`, then `ndim` u32 dims,
//! then the payload. MNIST uses dtype 0x08 (unsigned byte): images are
//! `[n, 28, 28]`, labels `[n]`.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};

/// dtype byte for u8 payloads (the only one MNIST uses).
pub const DTYPE_U8: u8 = 0x08;

/// A parsed IDX tensor of unsigned bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IdxU8 {
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl IdxU8 {
    pub fn len(&self) -> usize {
        self.dims.first().copied().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Elements per record (product of trailing dims).
    pub fn record_size(&self) -> usize {
        self.dims.iter().skip(1).product::<usize>().max(1)
    }

    /// Borrow record `idx`.
    pub fn record(&self, idx: usize) -> &[u8] {
        let sz = self.record_size();
        &self.data[idx * sz..(idx + 1) * sz]
    }
}

/// Read an IDX u8 tensor from any reader.
pub fn read_idx_u8<R: Read>(mut r: R) -> Result<IdxU8> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)
        .map_err(|e| Error::Dataset(format!("idx header: {e}")))?;
    if magic[0] != 0 || magic[1] != 0 {
        return Err(Error::Dataset(format!(
            "bad idx magic {magic:?} (first two bytes must be zero)"
        )));
    }
    if magic[2] != DTYPE_U8 {
        return Err(Error::Dataset(format!(
            "unsupported idx dtype 0x{:02x} (only u8/0x08 supported)",
            magic[2]
        )));
    }
    let ndim = magic[3] as usize;
    if ndim == 0 || ndim > 4 {
        return Err(Error::Dataset(format!("unreasonable idx ndim {ndim}")));
    }
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)
            .map_err(|e| Error::Dataset(format!("idx dims: {e}")))?;
        dims.push(u32::from_be_bytes(b) as usize);
    }
    let total: usize = dims.iter().product();
    if total > 1 << 31 {
        return Err(Error::Dataset(format!("idx payload too large: {dims:?}")));
    }
    let mut data = vec![0u8; total];
    r.read_exact(&mut data)
        .map_err(|e| Error::Dataset(format!("idx payload truncated: {e}")))?;
    Ok(IdxU8 { dims, data })
}

/// Write an IDX u8 tensor.
pub fn write_idx_u8<W: Write>(mut w: W, t: &IdxU8) -> Result<()> {
    let total: usize = t.dims.iter().product();
    if total != t.data.len() {
        return Err(Error::Dataset(format!(
            "dims {:?} disagree with payload {}",
            t.dims,
            t.data.len()
        )));
    }
    w.write_all(&[0, 0, DTYPE_U8, t.dims.len() as u8])?;
    for &d in &t.dims {
        w.write_all(&(d as u32).to_be_bytes())?;
    }
    w.write_all(&t.data)?;
    Ok(())
}

/// Load an IDX file from disk (gzip not supported — ungzip first).
pub fn load_idx_file(path: &Path) -> Result<IdxU8> {
    let file = std::fs::File::open(path)
        .map_err(|e| Error::Dataset(format!("{}: {e}", path.display())))?;
    read_idx_u8(std::io::BufReader::new(file))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> IdxU8 {
        IdxU8 { dims: vec![3, 2, 2], data: (0..12).collect() }
    }

    #[test]
    fn roundtrip() {
        let t = sample();
        let mut buf = Vec::new();
        write_idx_u8(&mut buf, &t).unwrap();
        let back = read_idx_u8(&buf[..]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn record_access() {
        let t = sample();
        assert_eq!(t.len(), 3);
        assert_eq!(t.record_size(), 4);
        assert_eq!(t.record(1), &[4, 5, 6, 7]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = Vec::new();
        write_idx_u8(&mut buf, &sample()).unwrap();
        buf[0] = 1;
        assert!(read_idx_u8(&buf[..]).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        let mut buf = Vec::new();
        write_idx_u8(&mut buf, &sample()).unwrap();
        buf[2] = 0x0D; // float
        assert!(read_idx_u8(&buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let mut buf = Vec::new();
        write_idx_u8(&mut buf, &sample()).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_idx_u8(&buf[..]).is_err());
    }

    #[test]
    fn rejects_dim_payload_mismatch_on_write() {
        let t = IdxU8 { dims: vec![5], data: vec![1, 2] };
        let mut buf = Vec::new();
        assert!(write_idx_u8(&mut buf, &t).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::tmp::TempDir::new("idx").unwrap();
        let path = dir.path().join("t.idx");
        let t = sample();
        let mut f = std::fs::File::create(&path).unwrap();
        write_idx_u8(&mut f, &t).unwrap();
        drop(f);
        assert_eq!(load_idx_file(&path).unwrap(), t);
    }
}
