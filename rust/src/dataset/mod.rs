//! Dataset handling: MNIST IDX files + the synthetic substitute corpus.
//!
//! The paper trains on MNIST (60k train / 10k test, 28×28, padded to 29×29
//! by Cireşan's code). Real IDX files are loaded when present
//! ([`idx`] / [`mnist`]); when they are not (this reproduction environment
//! has no network access), [`synth`] procedurally renders a deterministic
//! digit corpus with the same shapes and label distribution, exercising
//! identical code paths (documented substitution, DESIGN.md §1).

pub mod idx;
pub mod mnist;
pub mod synth;

pub use mnist::{Dataset, load_or_synth};

/// Image side after padding (Cireşan pads 28×28 MNIST to 29×29).
pub const IMAGE_HW: usize = 29;
/// Pixels per image.
pub const IMAGE_PIXELS: usize = IMAGE_HW * IMAGE_HW;
/// Number of classes.
pub const NUM_CLASSES: usize = 10;
