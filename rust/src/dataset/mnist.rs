//! Dataset assembly: real MNIST IDX files if present, synthetic otherwise.

use std::path::Path;

use crate::dataset::idx::load_idx_file;
use crate::dataset::synth;
use crate::dataset::{IMAGE_HW, IMAGE_PIXELS};
use crate::error::{Error, Result};

/// An in-memory labelled image set (29×29 f32 images in [0,1]).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
    /// "mnist" or "synthetic" — recorded in experiment output.
    pub source: &'static str,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Borrow sample `idx`.
    pub fn sample(&self, idx: usize) -> (&[f32], usize) {
        (&self.images[idx], self.labels[idx])
    }

    /// Truncate to the first `n` samples (cheap workload scaling).
    pub fn truncated(mut self, n: usize) -> Dataset {
        self.images.truncate(n);
        self.labels.truncate(n);
        self
    }
}

/// Pad a 28×28 u8 MNIST image into the 29×29 f32 canvas (Cireşan pads with
/// a zero column/row; values scaled to [0,1]).
pub fn pad_mnist_image(raw: &[u8]) -> Vec<f32> {
    debug_assert_eq!(raw.len(), 28 * 28);
    let mut img = vec![0.0f32; IMAGE_PIXELS];
    for y in 0..28 {
        for x in 0..28 {
            img[y * IMAGE_HW + x] = raw[y * 28 + x] as f32 / 255.0;
        }
    }
    img
}

/// Load MNIST from a directory holding the standard (un-gzipped) files:
/// `train-images-idx3-ubyte`, `train-labels-idx1-ubyte`,
/// `t10k-images-idx3-ubyte`, `t10k-labels-idx1-ubyte`.
pub fn load_mnist_dir(dir: &Path) -> Result<(Dataset, Dataset)> {
    let load_pair = |img_name: &str, lab_name: &str| -> Result<Dataset> {
        let images_idx = load_idx_file(&dir.join(img_name))?;
        let labels_idx = load_idx_file(&dir.join(lab_name))?;
        if images_idx.dims.len() != 3
            || images_idx.dims[1] != 28
            || images_idx.dims[2] != 28
        {
            return Err(Error::Dataset(format!(
                "{img_name}: expected [n,28,28], got {:?}",
                images_idx.dims
            )));
        }
        if labels_idx.len() != images_idx.len() {
            return Err(Error::Dataset(format!(
                "{img_name}/{lab_name}: {} images vs {} labels",
                images_idx.len(),
                labels_idx.len()
            )));
        }
        let images = (0..images_idx.len())
            .map(|i| pad_mnist_image(images_idx.record(i)))
            .collect();
        let labels = labels_idx.data.iter().map(|&l| l as usize).collect();
        Ok(Dataset { images, labels, source: "mnist" })
    };
    Ok((
        load_pair("train-images-idx3-ubyte", "train-labels-idx1-ubyte")?,
        load_pair("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")?,
    ))
}

/// Load real MNIST from `dir` when available, else synthesize `(n_train,
/// n_test)` samples (documented substitution — DESIGN.md §1).
pub fn load_or_synth(
    dir: Option<&Path>,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    if let Some(dir) = dir {
        if let Ok((train, test)) = load_mnist_dir(dir) {
            return (train.truncated(n_train), test.truncated(n_test));
        }
    }
    let (train_images, train_labels) = synth::generate(n_train, seed);
    let (test_images, test_labels) = synth::generate(n_test, seed ^ 0xDEAD_BEEF);
    (
        Dataset { images: train_images, labels: train_labels, source: "synthetic" },
        Dataset { images: test_images, labels: test_labels, source: "synthetic" },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::idx::{write_idx_u8, IdxU8};

    #[test]
    fn synth_fallback_shapes() {
        let (train, test) = load_or_synth(None, 50, 20, 7);
        assert_eq!(train.len(), 50);
        assert_eq!(test.len(), 20);
        assert_eq!(train.images[0].len(), IMAGE_PIXELS);
        assert_eq!(train.source, "synthetic");
    }

    #[test]
    fn pad_mnist_image_keeps_values() {
        let mut raw = vec![0u8; 28 * 28];
        raw[0] = 255;
        raw[27] = 128;
        let img = pad_mnist_image(&raw);
        assert_eq!(img.len(), IMAGE_PIXELS);
        assert!((img[0] - 1.0).abs() < 1e-6);
        assert!((img[27] - 128.0 / 255.0).abs() < 1e-6);
        // Padded column/row zero.
        assert_eq!(img[28], 0.0);
        assert_eq!(img[28 * IMAGE_HW], 0.0);
    }

    #[test]
    fn loads_idx_mnist_dir() {
        let dir = crate::util::tmp::TempDir::new("idx").unwrap();
        let write = |name: &str, t: &IdxU8| {
            let mut f = std::fs::File::create(dir.path().join(name)).unwrap();
            write_idx_u8(&mut f, t).unwrap();
        };
        let images = IdxU8 { dims: vec![3, 28, 28], data: vec![100; 3 * 784] };
        let labels = IdxU8 { dims: vec![3], data: vec![1, 2, 3] };
        write("train-images-idx3-ubyte", &images);
        write("train-labels-idx1-ubyte", &labels);
        write("t10k-images-idx3-ubyte", &images);
        write("t10k-labels-idx1-ubyte", &labels);

        let (train, test) = load_mnist_dir(dir.path()).unwrap();
        assert_eq!(train.len(), 3);
        assert_eq!(test.labels, vec![1, 2, 3]);
        assert_eq!(train.source, "mnist");
    }

    #[test]
    fn rejects_mismatched_label_count() {
        let dir = crate::util::tmp::TempDir::new("idx").unwrap();
        let write = |name: &str, t: &IdxU8| {
            let mut f = std::fs::File::create(dir.path().join(name)).unwrap();
            write_idx_u8(&mut f, t).unwrap();
        };
        write("train-images-idx3-ubyte",
              &IdxU8 { dims: vec![3, 28, 28], data: vec![0; 3 * 784] });
        write("train-labels-idx1-ubyte", &IdxU8 { dims: vec![2], data: vec![0, 1] });
        write("t10k-images-idx3-ubyte",
              &IdxU8 { dims: vec![1, 28, 28], data: vec![0; 784] });
        write("t10k-labels-idx1-ubyte", &IdxU8 { dims: vec![1], data: vec![0] });
        assert!(load_mnist_dir(dir.path()).is_err());
    }

    #[test]
    fn missing_dir_falls_back_to_synth() {
        let (train, _) =
            load_or_synth(Some(Path::new("/definitely/not/here")), 10, 5, 1);
        assert_eq!(train.source, "synthetic");
    }

    #[test]
    fn truncated_limits_len() {
        let (train, _) = load_or_synth(None, 30, 5, 1);
        assert_eq!(train.truncated(10).len(), 10);
    }
}
