//! End-to-end benchmark: regenerate every paper table and figure.
//!
//! One case per experiment id — `cargo bench --bench bench_tables` is the
//! "rebuild the whole evaluation section" harness (deliverable (d)). The
//! rendered outputs themselves are printed once at the end so the bench
//! doubles as the artifact generator.

use micdl::experiments::{self, ExpOptions};
use micdl::util::bench::Bench;

fn main() {
    let mut b = Bench::default();
    let opts = ExpOptions::default();

    for id in experiments::ALL_WITH_SCALING {
        b.case(&format!("exp/{id}"), || experiments::run(id, &opts).unwrap().len());
    }
    b.print_report("paper tables & figures");

    println!("\n================ rendered reproduction ================\n");
    print!("{}", experiments::run("all", &opts).unwrap());
}
