//! Benchmarks for the performance models (deliverable (d), model side).
//!
//! One case per model × architecture, plus the full Fig. 5–7 sweeps and
//! the Table X extrapolation — i.e. the code that regenerates the paper's
//! prediction columns, timed.

use micdl::config::{ArchSpec, RunConfig};
use micdl::perfmodel::{both_models, ParamSource, PerfModel};
use micdl::util::bench::Bench;

fn main() {
    let mut b = Bench::default();

    for arch in ArchSpec::paper_archs() {
        let (model_a, model_b) = both_models(&arch, ParamSource::Paper).unwrap();
        let run = RunConfig::paper_default(&arch.name, 240);
        b.case(&format!("strategy_a/{}/predict@240", arch.name), || {
            model_a.predict(&run).unwrap().total_s
        });
        b.case(&format!("strategy_b/{}/predict@240", arch.name), || {
            model_b.predict(&run).unwrap().total_s
        });
    }

    // Full figure sweep (7 thread counts × 2 models), per architecture.
    for arch in ArchSpec::paper_archs() {
        let (model_a, model_b) = both_models(&arch, ParamSource::Paper).unwrap();
        b.case(&format!("fig_sweep/{}", arch.name), || {
            let mut acc = 0.0;
            for &p in RunConfig::MEASURED_THREADS.iter() {
                let run = RunConfig::paper_default(&arch.name, p);
                acc += model_a.predict(&run).unwrap().total_s;
                acc += model_b.predict(&run).unwrap().total_s;
            }
            acc
        });
    }

    // Table X extrapolation (4 thread counts × 3 archs × 2 models).
    b.case("table10_sweep", || {
        let mut acc = 0.0;
        for arch in ArchSpec::paper_archs() {
            let (a, bm) = both_models(&arch, ParamSource::Paper).unwrap();
            for &p in RunConfig::PREDICTED_THREADS.iter() {
                let run = RunConfig::paper_default(&arch.name, p);
                acc += a.predict(&run).unwrap().total_s;
                acc += bm.predict(&run).unwrap().total_s;
            }
        }
        acc
    });

    b.print_report("perfmodel");
}
