//! Sweep-engine benchmark: the hot path future PRs must not regress.
//!
//! Cases cover grid enumeration, serial vs parallel evaluation of a
//! mid-size grid, and a paper-scale 1,464-scenario run. Besides the
//! stdout report, the run writes `BENCH_sweep.json` (median/mean/min per
//! case, plus mandatory `generated_by`/`host` provenance — anonymous
//! runs are refused) so the perf trajectory is diffable across CI runs:
//! `MICDL_BENCH_GENERATED_BY=$(whoami) cargo bench --bench bench_sweep`.

use micdl::calibration::Calibration;
use micdl::config::ArchSpec;
use micdl::perfmodel::ParamSource;
use micdl::simulator::SimConfig;
use micdl::sweep::{merge_shards, GridSpec, SweepRunner};
use micdl::util::bench::Bench;
use micdl::util::json::Json;

fn mid_grid() -> GridSpec {
    // 3 archs × 61 thread counts × 2 strategies = 366 scenarios.
    GridSpec {
        threads: (1..=244).step_by(4).collect(),
        ..GridSpec::default()
    }
}

fn full_grid() -> GridSpec {
    // 3 archs × 244 thread counts × 2 strategies = 1,464 scenarios.
    GridSpec {
        threads: (1..=244).collect(),
        ..GridSpec::default()
    }
}

fn main() {
    let mut b = Bench::default();

    let grid = mid_grid();
    b.case("sweep/enumerate/366", || grid.enumerate().len());
    b.case("sweep/serial/366", || {
        SweepRunner::serial().run(&grid).unwrap().len()
    });
    b.case("sweep/parallel/366", || {
        SweepRunner::new(0).run(&grid).unwrap().len()
    });

    // Sharded throughput: the mid grid split into 3 in-process shards
    // plus the merge_shards reassembly — what one `--shards 3` driver
    // wave costs beyond the whole-grid parallel case above.
    b.case("sweep/shard3+merge/366", || {
        let runner = SweepRunner::new(0);
        let shards: Vec<_> = (0..3).map(|k| runner.run_shard(&grid, k, 3).unwrap()).collect();
        merge_shards(&grid, shards).unwrap().len()
    });

    let measured = GridSpec { measure: true, ..mid_grid() };
    b.case("sweep/parallel+measure/366", || {
        SweepRunner::new(0).run(&measured).unwrap().len()
    });

    // Contended cold sweep: 16 workers race a fresh cache whose
    // distinct-key census is tiny relative to the scenario count (1
    // arch × 244 ladder points × 2 strategies over 2 model keys, 1
    // cost table, 244 measurements). Every first touch contends on the
    // single-flight memos; the miss pin asserts the duplicate-work
    // contract inside the timed loop.
    let contended = GridSpec {
        archs: vec![ArchSpec::small()],
        threads: (1..=244).collect(),
        measure: true,
        ..GridSpec::default()
    };
    b.case("sweep/contended-cold+measure/488", || {
        let res = SweepRunner::new(16).run(&contended).unwrap();
        assert_eq!(res.cache.misses, 2 + 1 + 244, "{:?}", res.cache);
        res.len()
    });

    let big = full_grid();
    b.case("sweep/parallel/1464", || {
        SweepRunner::new(0).run(&big).unwrap().len()
    });

    // Sim-ablation axis: 4 seed variants × the measured mid grid. Seeds
    // share no cache entries across variants (distinct fingerprints), so
    // this times the worst-case ablation path.
    let ablation = GridSpec {
        sims: (0..4)
            .map(|i| micdl::sweep::SimVariant {
                name: format!("seed{i}"),
                seed: Some(0x5EED + i as u64),
                ..Default::default()
            })
            .collect(),
        measure: true,
        ..mid_grid()
    };
    b.case("sweep/parallel+measure+ablation4/1464", || {
        SweepRunner::new(0).run(&ablation).unwrap().len()
    });

    // Calibration resolution — the probe-memoization hot path every
    // ParamSource::Simulator sweep cell rides. Cold: a fresh Calibration
    // per iteration (full probe + fit per architecture). Hot: one shared
    // Calibration, so iterations time the memo hit.
    let archs = ArchSpec::paper_archs();
    let sim = SimConfig::default();
    b.case("calibration/resolve-cold/3archs", || {
        let cal = Calibration::new(ParamSource::Simulator);
        for arch in &archs {
            cal.resolve(arch, &sim).unwrap();
        }
        cal.resolutions()
    });
    let shared = Calibration::new(ParamSource::Simulator);
    b.case("calibration/resolve-hot/3archs", || {
        for arch in &archs {
            shared.resolve(arch, &sim).unwrap();
        }
        shared.resolutions()
    });

    b.print_report("scenario sweep engine");

    b.write_snapshot(
        "BENCH_sweep.json",
        "sweep",
        vec![
            ("grid_mid", Json::num(mid_grid().len() as f64)),
            ("grid_full", Json::num(full_grid().len() as f64)),
        ],
    );
}
