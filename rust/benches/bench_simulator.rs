//! Benchmarks for micsim (deliverable (d) measurement side + §Perf).
//!
//! The chunked-vs-per-image comparison is the §Perf headline: identical
//! semantics, orders-of-magnitude wall-clock difference (EXPERIMENTS.md
//! §Perf). Also times the contention probe (Table IV) and the measured
//! thread sweep behind Figs. 5–7.

use micdl::config::{ArchSpec, RunConfig};
use micdl::simulator::{probe, simulate_training, Fidelity, SimConfig};
use micdl::util::bench::Bench;

fn main() {
    let mut b = Bench::default();

    // Chunked full-size paper workloads (what the fig5-7 sweeps run).
    for arch in ArchSpec::paper_archs() {
        let cfg = SimConfig::default();
        let run = RunConfig::paper_default(&arch.name, 240);
        b.case(&format!("chunked/{}/p240_full", arch.name), || {
            simulate_training(&arch, &run, &cfg).unwrap().total_s
        });
    }

    // Fidelity comparison on a downscaled workload (per-image is O(i·ep)).
    let arch = ArchSpec::small();
    let small_run =
        RunConfig { train_images: 6_000, test_images: 1_000, epochs: 1, threads: 240 };
    let cfg_chunk = SimConfig { fidelity: Fidelity::Chunked, ..Default::default() };
    let cfg_image = SimConfig { fidelity: Fidelity::PerImage, ..Default::default() };
    b.case("fidelity/chunked/6k_images", || {
        simulate_training(&arch, &small_run, &cfg_chunk).unwrap().total_s
    });
    b.case("fidelity/per_image/6k_images", || {
        simulate_training(&arch, &small_run, &cfg_image).unwrap().total_s
    });

    // Contention probe sweep (Table IV's 11 thread counts × 3 archs).
    b.case("contention_probe/table4_sweep", || {
        let cfg = SimConfig::default();
        let mut acc = 0.0;
        for arch in ArchSpec::paper_archs() {
            for &p in [1usize, 15, 30, 60, 120, 180, 240, 480, 960, 1920, 3840].iter() {
                acc += probe::contention_probe(&arch, p, &cfg).unwrap();
            }
        }
        acc
    });

    // Full measured sweep backing one figure.
    b.case("measured_sweep/fig5", || {
        let cfg = SimConfig::default();
        let arch = ArchSpec::small();
        let mut acc = 0.0;
        for &p in RunConfig::MEASURED_THREADS.iter() {
            acc += probe::measured_execution_s(&arch, p, &cfg).unwrap();
        }
        acc
    });

    // Oversubscribed run (3,840 software threads).
    let big_run = RunConfig::paper_default("small", 3840);
    let cfg = SimConfig::default();
    b.case("chunked/small/p3840_oversub", || {
        simulate_training(&ArchSpec::small(), &big_run, &cfg).unwrap().total_s
    });

    b.print_report("simulator");
}
