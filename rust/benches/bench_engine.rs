//! Benchmarks for the pure-Rust CNN engine (the Cireşan-code substitute).
//!
//! Per-image forward and train (fwd+bwd) across the three paper
//! architectures — the Rust analogue of Table III's measured per-image
//! times (which the paper obtained from its C++ code on the Phi). Used by
//! the §Perf pass to track engine hot-path changes.

use micdl::config::ArchSpec;
use micdl::engine;
use micdl::nn::Network;
use micdl::util::bench::Bench;

fn image(seed: u32) -> Vec<f32> {
    (0..841)
        .map(|i| {
            (((i as u32).wrapping_mul(2654435761).wrapping_add(seed) >> 16) & 0xff) as f32 / 255.0
        })
        .collect()
}

fn main() {
    let mut b = Bench::default();
    let img = image(7);

    for arch in ArchSpec::paper_archs() {
        let net = Network::new(arch.clone(), 42).unwrap();
        b.case(&format!("engine/{}/forward", arch.name), || {
            engine::forward(&net, &img).unwrap().logits()[0]
        });

        let mut train_net = Network::new(arch.clone(), 42).unwrap();
        b.case(&format!("engine/{}/train_image", arch.name), || {
            engine::train_image(&mut train_net, &img, 3, 0.01).unwrap()
        });
    }

    // Classification (forward + softmax + argmax) on the small net —
    // the validation/test phase unit of work.
    let net = Network::new(ArchSpec::small(), 1).unwrap();
    b.case("engine/small/classify", || engine::classify(&net, &img, 3).unwrap().0);

    b.print_report("engine");

    // Report the per-image times next to the paper's Table III for
    // orientation (the engine runs on this host, not on a Phi — the
    // comparison is structural, not absolute).
    println!("\nTable III reference (measured on Xeon Phi 7120P, 1 thread):");
    println!("  small fprop 1.45 ms / bprop 5.30 ms");
    println!("  medium fprop 12.55 ms / bprop 69.73 ms");
    println!("  large fprop 148.88 ms / bprop 859.19 ms");
}
