//! Serve-engine benchmark: batched prediction throughput, cold vs hot.
//!
//! The ladder batch is ≈10⁶ cells (4,098 queries × the full 1..=244
//! thread ladder, cycling the three paper architectures and both
//! strategies); dividing a case's median by `batch_cells` gives the
//! per-cell cost. Cold builds a fresh engine per iteration (parameter
//! tables resolve from scratch — exactly once per distinct (arch, sim
//! fingerprint) pair, asserted); hot times the steady-state memo-served
//! path `repro serve` rides. Besides the stdout report, the run writes
//! `BENCH_serve.json` with mandatory `generated_by`/`host` provenance,
//! like bench_sweep — anonymous runs are refused:
//! `MICDL_BENCH_GENERATED_BY=$(whoami) cargo bench --bench bench_serve`.

use micdl::calibration::Calibration;
use micdl::config::ArchSpec;
use micdl::perfmodel::ParamSource;
use micdl::serve::{PredictEngine, Query, QueryBatch};
use micdl::simulator::SimConfig;
use micdl::sweep::Strategy;
use micdl::util::bench::Bench;
use micdl::util::json::Json;

/// `queries` ladder queries cycling the paper architectures and
/// strategies: 4,098 × 244 = 999,912 cells ≈ 1e6.
fn ladder_batch(queries: usize) -> QueryBatch {
    let archs = ["small", "medium", "large"];
    QueryBatch {
        queries: (0..queries)
            .map(|i| Query {
                arch: archs[i % archs.len()].to_string(),
                strategies: vec![if i % 2 == 0 { Strategy::A } else { Strategy::B }],
                threads: (1..=244).collect(),
                train_images: 60_000,
                test_images: 10_000,
                epochs: None,
                sim: None,
            })
            .collect(),
    }
}

fn main() {
    let mut b = Bench::quick();

    let big = ladder_batch(4_098);
    let cells = big.cells() as u64;

    b.case("serve/cold-batch/1e6", || {
        let engine = PredictEngine::new(ParamSource::Simulator, 0);
        let n = engine.drain_batch(&big).unwrap();
        assert_eq!(n, cells);
        assert_eq!(
            engine.stats().calibration_resolutions,
            3,
            "one resolve per distinct (arch, sim fingerprint) pair"
        );
        n
    });

    // Hot: one shared engine across iterations — the memos stay warm,
    // so this is the steady-state batched throughput.
    let shared = PredictEngine::new(ParamSource::Simulator, 0);
    shared.drain_batch(&big).unwrap();
    b.case("serve/hot-batch/1e6", || shared.drain_batch(&big).unwrap());
    assert_eq!(shared.stats().calibration_resolutions, 3);

    // One 244-cell ladder query, hot: the smallest useful batch.
    let single = ladder_batch(1);
    b.case("serve/hot-batch/244", || shared.drain_batch(&single).unwrap());

    // Parallel resolve under contention: 8 threads fire the same
    // 6-query ladder batch at a *fresh* engine, all racing on the same
    // three (arch, sim fingerprint) pairs. With single-flight memos the
    // racers coalesce — the engine still performs exactly 3 calibration
    // resolutions (asserted), and the case times how fast concurrent
    // batches get through a cold engine with no resolve serialization.
    let small = ladder_batch(6);
    b.case("serve/parallel-resolve-cold/8x6", || {
        let engine = PredictEngine::new(ParamSource::Simulator, 2);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| engine.drain_batch(&small).unwrap());
            }
        });
        let stats = engine.stats();
        assert_eq!(
            stats.calibration_resolutions, 3,
            "concurrent batches must coalesce onto one resolve per pair"
        );
        stats.batches
    });

    // Reference: the raw hot-resolve cost the engine's per-batch
    // resolve phase rides (compare the per-cell hot-batch cost to it).
    let archs = ArchSpec::paper_archs();
    let sim = SimConfig::default();
    let cal = Calibration::new(ParamSource::Simulator);
    b.case("calibration/resolve-hot/3archs", || {
        for arch in &archs {
            cal.resolve(arch, &sim).unwrap();
        }
        cal.resolutions()
    });

    b.print_report("serve engine (batched prediction)");

    b.write_snapshot(
        "BENCH_serve.json",
        "serve",
        vec![
            ("batch_queries", Json::num(big.queries.len() as f64)),
            ("batch_cells", Json::num(cells as f64)),
        ],
    );
}
